//! Quickstart: load the AOT artifacts, run one inference per zoo model on
//! the PJRT CPU backend, and print a latency table.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! With `--calibrate`, sweeps every compiled batch size per model and
//! prints the (model, batch) → latency table used to sanity-check the
//! platform simulator's calibration (EXPERIMENTS.md §Calibration).

use bcedge::runtime::PjrtRuntime;
use bcedge::util::bench;
use bcedge::util::cli::Args;
use bcedge::workload::models::{ModelId, ModelSpec};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["calibrate"]).map_err(anyhow::Error::msg)?;
    let dir = args.get_or("artifacts", "artifacts");
    let rt = PjrtRuntime::load(dir)?;
    println!(
        "bcedge quickstart — PJRT platform: {} | {} artifacts in {dir}/",
        rt.platform_name(),
        rt.index().len()
    );

    bench::banner("single-batch inference across the zoo");
    println!("{:<6} {:>10} {:>12} {:>12} {:>10}",
             "model", "batch", "compile(ms)", "latency(ms)", "SLO(ms)");
    for model in ModelId::all() {
        let spec = ModelSpec::get(model);
        let compile_ms = rt.warm(model, 1)?;
        let input = vec![0.5f32; spec.input_elems];
        // Warm run (first execution pays allocation), then measured run.
        rt.execute(model, 1, &input)?;
        let out = rt.execute(model, 1, &input)?;
        println!("{:<6} {:>10} {:>12.1} {:>12.3} {:>10.0}",
                 spec.name, 1, compile_ms, out.latency_ms, spec.slo_ms);
        assert!(out.data.iter().all(|x| x.is_finite()),
                "non-finite output from {model:?}");
    }

    if args.flag("calibrate") {
        bench::banner("batch sweep (calibration table)");
        let batches = rt.index().batch_sizes.clone();
        let mut csv = bench::Csv::create(
            "results/calibration.csv",
            "model,batch,latency_ms,per_sample_ms,throughput_rps",
        )?;
        println!("{:<6} {:>6} {:>12} {:>14} {:>14}",
                 "model", "batch", "latency(ms)", "per-sample(ms)", "rps");
        for model in ModelId::all() {
            let spec = ModelSpec::get(model);
            for &b in &batches {
                if rt.index().get(model, b).is_none() {
                    continue;
                }
                rt.warm(model, b)?;
                let input = vec![0.5f32; spec.input_elems * b];
                rt.execute(model, b, &input)?; // warm
                let mut best = f64::INFINITY;
                for _ in 0..3 {
                    best = best.min(rt.execute(model, b, &input)?.latency_ms);
                }
                let rps = b as f64 / best * 1e3;
                println!("{:<6} {:>6} {:>12.3} {:>14.3} {:>14.1}",
                         spec.name, b, best, best / b as f64, rps);
                csv.rowf(&[model as usize as f64, b as f64, best,
                           best / b as f64, rps])?;
            }
        }
        println!("\nwrote results/calibration.csv");
    }
    println!("\nquickstart OK");
    Ok(())
}
