//! Motivational study (paper Fig. 1): sweep batch size × concurrent
//! instances for YOLO-v5 on the simulated Xavier NX and print the
//! throughput/latency surfaces, demonstrating the paper's core
//! observation — "higher-throughput and lower-latency appear in moderate
//! batch size and number of concurrent models", with collapse and OOM at
//! the extremes.
//!
//!     cargo run --release --example interference_study

use bcedge::platform::PlatformSim;
use bcedge::runtime::executor::{BatchJob, Dispatcher, SimDispatcher};
use bcedge::util::bench;
use bcedge::util::time::VirtualClock;
use bcedge::workload::models::ModelId;

fn main() -> anyhow::Result<()> {
    let batches = [1usize, 2, 4, 8, 16, 32, 64, 128];
    let concs = [1usize, 2, 3, 4, 5, 6, 7, 8];
    let model = ModelId::Yolo;

    bench::banner("Fig. 1(a): throughput (requests/s), yolo on sim Xavier NX");
    print_header(&concs);
    let mut csv = bench::Csv::create(
        "results/interference_study.csv",
        "batch,concurrency,throughput_rps,latency_ms,oom",
    )?;
    for &b in &batches {
        print!("b={b:<4}");
        for &c in &concs {
            match run_cell(model, b, c) {
                Some((rps, _)) => print!(" {rps:>8.1}"),
                None => print!(" {:>8}", "OOM"),
            }
        }
        println!();
    }

    bench::banner("Fig. 1(b): end-to-end batch latency (ms)");
    print_header(&concs);
    for &b in &batches {
        print!("b={b:<4}");
        for &c in &concs {
            match run_cell(model, b, c) {
                Some((rps, lat)) => {
                    print!(" {lat:>8.1}");
                    csv.rowf(&[b as f64, c as f64, rps, lat, 0.0])?;
                }
                None => {
                    print!(" {:>8}", "OOM");
                    csv.rowf(&[b as f64, c as f64, 0.0, 0.0, 1.0])?;
                }
            }
        }
        println!();
    }

    // The paper's claim, checked mechanically: the best throughput cell is
    // interior (neither b=1/c=1 nor the maximal corner).
    let mut best = (0usize, 0usize, 0.0f64);
    for &b in &batches {
        for &c in &concs {
            if let Some((rps, _)) = run_cell(model, b, c) {
                if rps > best.2 {
                    best = (b, c, rps);
                }
            }
        }
    }
    println!("\npeak throughput {:.1} rps at batch={} concurrency={}",
             best.2, best.0, best.1);
    assert!(best.0 > 1 && best.0 < 128, "peak not interior in batch");
    assert!(run_cell(model, 128, 8).is_none(),
            "extreme corner should OOM (Fig. 1)");
    println!("wrote results/interference_study.csv\ninterference_study OK");
    Ok(())
}

fn print_header(concs: &[usize]) {
    print!("     ");
    for c in concs {
        print!(" {:>8}", format!("m_c={c}"));
    }
    println!();
}

/// Run one (batch, concurrency) cell: c concurrent instance-batches,
/// returning (aggregate throughput, per-batch latency), or None on OOM.
fn run_cell(model: ModelId, b: usize, c: usize) -> Option<(f64, f64)> {
    let clock = VirtualClock::new();
    let mut d = SimDispatcher::new(PlatformSim::xavier_nx(), clock);
    let jobs: Vec<BatchJob> =
        (0..c).map(|_| BatchJob { model, batch: b, n_real: b }).collect();
    let results = d.run_group(&jobs);
    if results.iter().any(|r| r.is_err()) {
        return None;
    }
    let lats: Vec<f64> = results.into_iter().map(|r| r.unwrap()).collect();
    let span = lats.iter().cloned().fold(0.0, f64::max);
    let served = (b * c) as f64;
    Some((served / (span / 1e3), span))
}
