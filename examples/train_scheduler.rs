//! Offline SAC training (paper §V-A Training Details): train the BCEdge
//! scheduler against the platform simulator, report convergence, and save
//! a deployable policy checkpoint.
//!
//!     cargo run --release --example train_scheduler -- --episodes 200 \
//!         --out results/sac_policy.json
//!
//! Deploy the checkpoint with
//!     cargo run --release --example serve_zoo -- --policy results/sac_policy.json

use bcedge::coordinator::sac_sched::SchedEnv;
use bcedge::coordinator::STATE_DIM;
use bcedge::platform::PlatformSpec;
use bcedge::rl::env::{train_episodes, Env};
use bcedge::rl::sac::{DiscreteSac, SacConfig};
use bcedge::rl::ActionSpace;
use bcedge::util::cli::Args;
use bcedge::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]).map_err(anyhow::Error::msg)?;
    let episodes: usize =
        args.get_parse("episodes", 200).map_err(anyhow::Error::msg)?;
    let rps: f64 = args.get_parse("rps", 30.0).map_err(anyhow::Error::msg)?;
    let out = args.get_or("out", "results/sac_policy.json");
    let platform = match args.get_or("platform", "nx") {
        "nano" => PlatformSpec::jetson_nano(),
        "tx2" => PlatformSpec::jetson_tx2(),
        _ => PlatformSpec::xavier_nx(),
    };

    println!("== offline SAC training ==");
    println!("platform {} | {rps} rps | {episodes} episodes", platform.name);

    let space = ActionSpace::standard();
    let mut env = SchedEnv::new(space.clone(), rps, platform);
    env.episode_len = 96;
    let mut rng = Pcg32::seeded(0x7EA1);
    // Offline settings: the paper trains with minibatch 512 on a GPU rig;
    // 128 keeps CPU wall time sane at equal sample efficiency here.
    let cfg = SacConfig { batch_size: 128, warmup: 256, ..Default::default() };
    let mut agent = DiscreteSac::new(STATE_DIM, env.n_actions(), cfg, &mut rng);

    let mut best_window = f32::NEG_INFINITY;
    let chunk = 10usize.min(episodes.max(1));
    let mut done = 0;
    while done < episodes {
        let n = chunk.min(episodes - done);
        let hist = train_episodes(&mut env, &mut agent, n, 96, &mut rng);
        done += n;
        let ret: f32 = hist.iter().map(|h| h.0).sum::<f32>() / n as f32;
        let loss: f32 = hist.iter().map(|h| h.1).sum::<f32>() / n as f32;
        best_window = best_window.max(ret);
        println!(
            "episode {done:>4}: mean return {ret:>9.2} | mean loss {loss:>9.4} | alpha {:.4}",
            agent.alpha()
        );
    }

    if let Some(parent) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(out, agent.policy_json().to_string())?;
    println!("\nsaved policy checkpoint to {out}");
    println!("best 10-episode mean return: {best_window:.2}");
    println!("train_scheduler OK");
    Ok(())
}
