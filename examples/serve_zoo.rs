//! End-to-end serving driver (the repo's E2E validation): load the six
//! real AOT models, serve Poisson traffic through the full coordinator —
//! SLO-priority queues → SAC scheduler → dynamic batcher → concurrent
//! instances → PJRT execution — and report per-model throughput, latency,
//! and SLO violations. Results recorded in EXPERIMENTS.md §E2E.
//!
//!     cargo run --release --example serve_zoo -- --rps 30 --seconds 30
//!
//! Options: --rps N (default 30, the paper's rate), --seconds N (default
//! 30), --scheduler sac|tac|deeprt|fixed (default sac), --threads N,
//! --policy FILE (deploy a checkpoint from train_scheduler).

use bcedge::coordinator::baselines::{tac, DeepRtScheduler, FixedScheduler};
use bcedge::coordinator::sac_sched;
use bcedge::coordinator::{Engine, EngineConfig, Scheduler};
use bcedge::rl::ActionSpace;
use bcedge::runtime::{PjrtRuntime, RealDispatcher};
use bcedge::util::cli::Args;
use bcedge::util::rng::Pcg32;
use bcedge::workload::models::{ModelId, ModelSpec};
use bcedge::workload::PoissonGenerator;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]).map_err(anyhow::Error::msg)?;
    let rps: f64 = args.get_parse("rps", 30.0).map_err(anyhow::Error::msg)?;
    let seconds: f64 =
        args.get_parse("seconds", 30.0).map_err(anyhow::Error::msg)?;
    let threads: usize =
        args.get_parse("threads", 4).map_err(anyhow::Error::msg)?;
    let sched_name = args.get_or("scheduler", "sac").to_string();
    let dir = args.get_or("artifacts", "artifacts");

    println!("== BCEdge end-to-end serving ==");
    println!("backend: PJRT CPU | rps {rps} | horizon {seconds}s | scheduler {sched_name}");

    let runtime = Arc::new(PjrtRuntime::load(dir)?);
    let mut dispatcher = RealDispatcher::new(runtime.clone(), threads);
    print!("warming executables (compile-once, TensorRT-style)... ");
    let compile_ms = dispatcher.warm_all(&runtime.index().batch_sizes.clone())?;
    println!("{:.1} ms total, {} engines", compile_ms,
             runtime.cached_executables());
    dispatcher.reset_origin(); // horizon excludes one-time compilation

    let space = ActionSpace::standard();
    let mut engine = Engine::new(
        dispatcher,
        EngineConfig {
            action_space: space.clone(),
            use_predictor: true,
            pad_to_artifacts: true,
            max_total_instances: 4,
            learn: true, // online adaptation, as deployed BCEdge does
            ..Default::default()
        },
    );

    let mut rng = Pcg32::seeded(2024);
    let mut scheduler: Box<dyn Scheduler> = match sched_name.as_str() {
        "sac" => {
            let mut s = sac_sched::sac(space.clone(), &mut rng);
            if let Some(path) = args.get("policy") {
                let text = std::fs::read_to_string(path)?;
                let v = bcedge::util::json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                s.agent.load_policy(&v).map_err(anyhow::Error::msg)?;
                s.set_greedy(true);
                println!("deployed trained policy from {path} (greedy mode)");
            }
            Box::new(s)
        }
        "tac" => Box::new(tac(space.clone(), &mut rng)),
        "deeprt" => Box::new(DeepRtScheduler::default()),
        "fixed" => Box::new(FixedScheduler { batch: 4, m_c: 2 }),
        other => anyhow::bail!("unknown scheduler {other}"),
    };

    let horizon_ms = seconds * 1e3;
    let mut gen = PoissonGenerator::new(rps, 7);
    engine.submit(gen.generate_horizon(horizon_ms));

    let t0 = std::time::Instant::now();
    let slots = engine.run(scheduler.as_mut(), horizon_ms);
    let wall_s = t0.elapsed().as_secs_f64();

    println!("\n== results ({slots} scheduling slots, {wall_s:.1}s wall) ==");
    println!("{:<6} {:>10} {:>12} {:>12} {:>12} {:>10}",
             "model", "completed", "mean(ms)", "p99(ms)", "SLO(ms)", "viol%");
    let m = &engine.metrics;
    for model in ModelId::all() {
        let spec = ModelSpec::get(model);
        let completed = m
            .outcomes()
            .iter()
            .filter(|o| o.model == model && !o.dropped)
            .count();
        if completed == 0 {
            continue;
        }
        println!("{:<6} {:>10} {:>12.2} {:>12.2} {:>12.0} {:>9.1}%",
                 spec.name,
                 completed,
                 m.mean_latency_ms(Some(model)),
                 latency_p99(m, model),
                 spec.slo_ms,
                 100.0 * m.violation_rate_for(model));
    }
    println!("\naggregate: {:.1} rps served | mean latency {:.2} ms | p99 {:.2} ms | violation rate {:.2}% | mean utility {:.3}",
             m.throughput_rps(horizon_ms),
             m.mean_latency_ms(None),
             m.latency_percentile(0.99),
             100.0 * m.violation_rate(),
             m.mean_utility(None));
    anyhow::ensure!(m.completed() > 0, "no requests served");
    println!("serve_zoo OK");
    Ok(())
}

fn latency_p99(m: &bcedge::metrics::Metrics, model: ModelId) -> f64 {
    let xs: Vec<f64> = m
        .outcomes()
        .iter()
        .filter(|o| o.model == model && !o.dropped)
        .map(|o| o.e2e_ms)
        .collect();
    bcedge::util::stats::percentile(&xs, 0.99)
}
