"""L2: the BCEdge model zoo — six JAX models calling the L1 Pallas kernels.

Stand-ins for the paper's Table IV zoo (see DESIGN.md §4 Substitutions):
each keeps the *architectural motif* of the original at edge-friendly
scale (3×32×32 images / 14-token sequences), because the scheduler only
observes models through their latency/memory/SLO profiles — what matters
for reproduction is a *heterogeneous* zoo, not ImageNet accuracy.

| zoo name | paper model     | motif kept                                 |
|----------|-----------------|--------------------------------------------|
| yolo     | YOLO-v5         | conv backbone + per-cell detection head     |
| mob      | MobileNet-v3    | depthwise-separable blocks, hard-swish      |
| res      | ResNet-18       | residual blocks with projection shortcut    |
| eff      | EfficientNet-B0 | MBConv: expand → depthwise → SE → project   |
| inc      | Inception-v3    | parallel 1×1 / 3×3 / double-3×3 / pool-proj |
| bert     | TinyBERT        | transformer encoder over a 14-token input   |

Weights are fixed-seed random constants *closed over* by the apply
function, so AOT lowering bakes them into the HLO and the Rust request
path feeds inputs only. All models take f32 inputs (bert takes f32 token
ids and casts in-graph) so the runtime marshals a single dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention as att
from .kernels import conv as cv
from .kernels import fused, matmul

IMG_SHAPE = (3, 32, 32)   # paper: 3×224×224, downscaled for CPU interpret mode
SEQ_LEN = 14              # paper: TinyBERT input 1×14 (Speech Commands)
VOCAB = 64
N_CLASSES = 10
BERT_CLASSES = 12         # Speech Commands v2 core word count


@dataclasses.dataclass(frozen=True)
class ModelMeta:
    """Static description the AOT manifest exports for the Rust runtime."""
    name: str
    paper_name: str
    input_shape: tuple[int, ...]   # per-sample, excludes batch dim
    output_shape: tuple[int, ...]  # per-sample
    param_count: int
    slo_ms: float                  # paper Table IV


class _Params:
    """Deterministic parameter factory; counts every weight it hands out."""

    def __init__(self, name: str):
        seed = int(np.frombuffer(name.encode().ljust(8, b"\0")[:8],
                                 dtype=np.uint32)[0])
        self._rng = np.random.default_rng(seed)
        self.count = 0

    def w(self, *shape: int, scale: float | None = None) -> jax.Array:
        fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
        s = scale if scale is not None else (2.0 / max(fan_in, 1)) ** 0.5
        arr = self._rng.normal(size=shape).astype(np.float32) * s
        self.count += arr.size
        return jnp.asarray(arr)

    def b(self, n: int) -> jax.Array:
        self.count += n
        return jnp.zeros((n,), jnp.float32)

    def ones(self, n: int) -> jax.Array:
        self.count += n
        return jnp.ones((n,), jnp.float32)


def _gap(x: jax.Array) -> jax.Array:
    """Global average pool (N, C, H, W) → (N, C)."""
    return jnp.mean(x, axis=(2, 3))


def _head(x2d: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return fused.bias_act(matmul.matmul(x2d, w), b, "identity")


# --------------------------------------------------------------------------
# yolo — conv backbone + detection head (B, cells, 3 anchors × (5 + classes))
# --------------------------------------------------------------------------

def _build_yolo() -> tuple[Callable, ModelMeta]:
    p = _Params("yolo")
    w1, b1 = p.w(16, 3, 3, 3), p.b(16)
    w2, b2 = p.w(32, 16, 3, 3), p.b(32)
    w3, b3 = p.w(32, 32, 3, 3), p.b(32)
    n_anchor_out = 3 * (5 + N_CLASSES)   # 3 anchors × (box4 + obj + classes)
    wh, bh = p.w(n_anchor_out, 32, 1, 1), p.b(n_anchor_out)

    def apply(x: jax.Array) -> jax.Array:
        n = x.shape[0]
        h = cv.conv2d(x, w1, b1, stride=2, act="relu")    # (N,16,16,16)
        h = cv.conv2d(h, w2, b2, stride=2, act="relu")    # (N,32, 8, 8)
        h = cv.conv2d(h, w3, b3, stride=1, act="relu")    # (N,32, 8, 8)
        d = cv.conv2d(h, wh, bh, stride=1, act="identity")  # (N,45,8,8)
        return d.transpose(0, 2, 3, 1).reshape(n, 8 * 8 * 3, 5 + N_CLASSES)

    meta = ModelMeta("yolo", "YOLO-v5", IMG_SHAPE,
                     (8 * 8 * 3, 5 + N_CLASSES), p.count, 138.0)
    return apply, meta


# --------------------------------------------------------------------------
# mob — depthwise-separable blocks with hard-swish (MobileNet-v3 motif)
# --------------------------------------------------------------------------

def _build_mob() -> tuple[Callable, ModelMeta]:
    p = _Params("mob")
    w0, b0 = p.w(16, 3, 3, 3), p.b(16)
    dw1, db1 = p.w(16, 1, 3, 3), p.b(16)
    pw1, pb1 = p.w(24, 16, 1, 1), p.b(24)
    dw2, db2 = p.w(24, 1, 3, 3), p.b(24)
    pw2, pb2 = p.w(32, 24, 1, 1), p.b(32)
    wf, bf = p.w(32, N_CLASSES), p.b(N_CLASSES)

    def apply(x: jax.Array) -> jax.Array:
        h = cv.conv2d(x, w0, b0, stride=2, act="hardswish")        # (N,16,16,16)
        h = cv.depthwise_conv2d(h, dw1, db1, stride=1, act="hardswish")
        h = cv.conv2d(h, pw1, pb1, stride=1, act="identity")       # (N,24,16,16)
        h = cv.depthwise_conv2d(h, dw2, db2, stride=2, act="hardswish")
        h = cv.conv2d(h, pw2, pb2, stride=1, act="identity")       # (N,32, 8, 8)
        return _head(_gap(h), wf, bf)

    meta = ModelMeta("mob", "MobileNet-v3", IMG_SHAPE, (N_CLASSES,),
                     p.count, 86.0)
    return apply, meta


# --------------------------------------------------------------------------
# res — two residual blocks (ResNet-18 motif)
# --------------------------------------------------------------------------

def _build_res() -> tuple[Callable, ModelMeta]:
    p = _Params("res")
    w0, b0 = p.w(16, 3, 3, 3), p.b(16)
    # block 1: 16 → 16, identity shortcut
    w11, b11 = p.w(16, 16, 3, 3), p.b(16)
    w12, b12 = p.w(16, 16, 3, 3), p.b(16)
    # block 2: 16 → 32 stride 2, 1×1 projection shortcut
    w21, b21 = p.w(32, 16, 3, 3), p.b(32)
    w22, b22 = p.w(32, 32, 3, 3), p.b(32)
    wp, bp = p.w(32, 16, 1, 1), p.b(32)
    wf, bf = p.w(32, N_CLASSES), p.b(N_CLASSES)

    def apply(x: jax.Array) -> jax.Array:
        h = cv.conv2d(x, w0, b0, stride=1, act="relu")             # (N,16,32,32)
        r = cv.conv2d(h, w11, b11, stride=1, act="relu")
        r = cv.conv2d(r, w12, b12, stride=1, act="identity")
        h = jax.nn.relu(h + r)
        r = cv.conv2d(h, w21, b21, stride=2, act="relu")
        r = cv.conv2d(r, w22, b22, stride=1, act="identity")
        sc = cv.conv2d(h, wp, bp, stride=2, act="identity")
        h = jax.nn.relu(sc + r)                                    # (N,32,16,16)
        return _head(_gap(h), wf, bf)

    meta = ModelMeta("res", "ResNet-18", IMG_SHAPE, (N_CLASSES,),
                     p.count, 58.0)
    return apply, meta


# --------------------------------------------------------------------------
# eff — MBConv with squeeze-and-excite (EfficientNet-B0 motif)
# --------------------------------------------------------------------------

def _build_eff() -> tuple[Callable, ModelMeta]:
    p = _Params("eff")
    w0, b0 = p.w(16, 3, 3, 3), p.b(16)
    # MBConv: expand 16→48, depthwise s2, SE, project 48→24
    we, be = p.w(48, 16, 1, 1), p.b(48)
    dw, db = p.w(48, 1, 3, 3), p.b(48)
    ws1, bs1 = p.w(48, 12), p.b(12)     # SE squeeze
    ws2, bs2 = p.w(12, 48), p.b(48)     # SE excite
    wpr, bpr = p.w(24, 48, 1, 1), p.b(24)
    wf, bf = p.w(24, N_CLASSES), p.b(N_CLASSES)

    def apply(x: jax.Array) -> jax.Array:
        h = cv.conv2d(x, w0, b0, stride=2, act="hardswish")        # (N,16,16,16)
        e = cv.conv2d(h, we, be, stride=1, act="hardswish")        # (N,48,16,16)
        e = cv.depthwise_conv2d(e, dw, db, stride=2, act="hardswish")  # (N,48,8,8)
        # squeeze-and-excite on channel stats
        s = _gap(e)                                                # (N,48)
        s = fused.bias_act(matmul.matmul(s, ws1), bs1, "relu")
        s = fused.bias_act(matmul.matmul(s, ws2), bs2, "sigmoid")  # (N,48)
        e = e * s[:, :, None, None]
        h = cv.conv2d(e, wpr, bpr, stride=1, act="identity")       # (N,24,8,8)
        return _head(_gap(h), wf, bf)

    meta = ModelMeta("eff", "EfficientNet-B0", IMG_SHAPE, (N_CLASSES,),
                     p.count, 93.0)
    return apply, meta


# --------------------------------------------------------------------------
# inc — one inception block: 1×1 / 3×3 / double-3×3 / pool-proj branches
# --------------------------------------------------------------------------

def _build_inc() -> tuple[Callable, ModelMeta]:
    p = _Params("inc")
    w0, b0 = p.w(16, 3, 3, 3), p.b(16)
    wa, ba = p.w(8, 16, 1, 1), p.b(8)            # branch a: 1×1
    wb1, bb1 = p.w(8, 16, 1, 1), p.b(8)          # branch b: 1×1 → 3×3
    wb2, bb2 = p.w(16, 8, 3, 3), p.b(16)
    wc1, bc1 = p.w(8, 16, 1, 1), p.b(8)          # branch c: 1×1 → 3×3 → 3×3
    wc2, bc2 = p.w(8, 8, 3, 3), p.b(8)
    wc3, bc3 = p.w(8, 8, 3, 3), p.b(8)
    wd, bd = p.w(8, 16, 1, 1), p.b(8)            # branch d: avgpool → 1×1
    wf, bf = p.w(40, N_CLASSES), p.b(N_CLASSES)  # 8+16+8+8 = 40 channels

    def apply(x: jax.Array) -> jax.Array:
        h = cv.conv2d(x, w0, b0, stride=2, act="relu")             # (N,16,16,16)
        a = cv.conv2d(h, wa, ba, stride=1, act="relu")
        b = cv.conv2d(h, wb1, bb1, stride=1, act="relu")
        b = cv.conv2d(b, wb2, bb2, stride=1, act="relu")
        c = cv.conv2d(h, wc1, bc1, stride=1, act="relu")
        c = cv.conv2d(c, wc2, bc2, stride=1, act="relu")
        c = cv.conv2d(c, wc3, bc3, stride=1, act="relu")
        # 3×3 average pool, stride 1, SAME — cheap data movement in jnp.
        d = jax.lax.reduce_window(
            h, 0.0, jax.lax.add, (1, 1, 3, 3), (1, 1, 1, 1), "SAME") / 9.0
        d = cv.conv2d(d, wd, bd, stride=1, act="relu")
        h = jnp.concatenate([a, b, c, d], axis=1)                  # (N,40,16,16)
        return _head(_gap(h), wf, bf)

    meta = ModelMeta("inc", "Inception-v3", IMG_SHAPE, (N_CLASSES,),
                     p.count, 66.0)
    return apply, meta


# --------------------------------------------------------------------------
# bert — 2-layer transformer encoder over 14 tokens (TinyBERT motif)
# --------------------------------------------------------------------------

def _build_bert() -> tuple[Callable, ModelMeta]:
    p = _Params("bert")
    d, heads, ffn = 64, 2, 128
    emb = p.w(VOCAB, d, scale=0.1)
    pos = p.w(SEQ_LEN, d, scale=0.1)
    layers = []
    for _ in range(2):
        layers.append(dict(
            wq=p.w(d, d), wk=p.w(d, d), wv=p.w(d, d), wo=p.w(d, d),
            w1=p.w(d, ffn), b1=p.b(ffn), w2=p.w(ffn, d), b2=p.b(d),
            g1=p.ones(d), g2=p.ones(d),
        ))
    wf, bf = p.w(d, BERT_CLASSES), p.b(BERT_CLASSES)

    def _ln(x: jax.Array, g: jax.Array) -> jax.Array:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        return (x - mu) / jnp.sqrt(var + 1e-5) * g

    def apply(x: jax.Array) -> jax.Array:
        """x: (N, S) f32 token ids → (N, classes) logits.

        Fully batch-vectorized: token-wise ops (projections, LN, FFN) fold
        the batch into the matmul M dimension; attention runs through the
        batched Pallas kernel. HLO size is therefore flat in batch size.
        """
        n = x.shape[0]
        ids = jnp.clip(x.astype(jnp.int32), 0, VOCAB - 1)
        h = emb[ids] + pos[None, :, :]                            # (N, S, d)
        for ly in layers:
            a = att.batched_multi_head_attention(
                _ln(h, ly["g1"][None, None, :]), ly["wq"], ly["wk"],
                ly["wv"], ly["wo"], heads)
            h = h + a
            flat = _ln(h, ly["g2"][None, None, :]).reshape(n * SEQ_LEN, d)
            f = fused.bias_act(matmul.matmul(flat, ly["w1"]), ly["b1"], "gelu")
            f = fused.bias_act(matmul.matmul(f, ly["w2"]), ly["b2"], "identity")
            h = h + f.reshape(n, SEQ_LEN, d)
        pooled = jnp.mean(h, axis=1)                              # (N, d)
        return _head(pooled, wf, bf)

    meta = ModelMeta("bert", "TinyBERT", (SEQ_LEN,), (BERT_CLASSES,),
                     p.count, 114.0)
    return apply, meta


_BUILDERS = {
    "yolo": _build_yolo,
    "mob": _build_mob,
    "res": _build_res,
    "eff": _build_eff,
    "inc": _build_inc,
    "bert": _build_bert,
}

MODEL_NAMES = tuple(_BUILDERS)


def build(name: str) -> tuple[Callable, ModelMeta]:
    """Return (apply_fn, meta) for a zoo model. apply_fn: (N, *in) → (N, *out)."""
    if name not in _BUILDERS:
        raise KeyError(f"unknown model {name!r}; zoo = {MODEL_NAMES}")
    return _BUILDERS[name]()


def example_input(name: str, batch: int) -> jax.ShapeDtypeStruct:
    """AOT lowering spec for a given batch size (f32 for every model)."""
    _, meta = build(name)
    return jax.ShapeDtypeStruct((batch, *meta.input_shape), jnp.float32)
