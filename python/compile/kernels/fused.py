"""L1 Pallas kernel: fused bias + activation epilogue.

Convolution / dense epilogues (bias add, ReLU / hard-swish / sigmoid /
GELU) are memory-bound; fusing them into one elementwise kernel keeps the
activation tile resident in fast memory instead of a round trip to HBM.
Rows are tiled; the bias vector (one entry per output channel) rides along
whole in every grid step — it is tiny relative to the activation tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 128

ACTIVATIONS = ("identity", "relu", "hardswish", "sigmoid", "gelu")


def _apply_act(x: jax.Array, act: str) -> jax.Array:
    if act == "identity":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "hardswish":
        return x * jnp.clip(x + 3.0, 0.0, 6.0) / 6.0
    if act == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-x))
    if act == "gelu":
        # tanh approximation — what the MXU-era TPU libraries ship.
        c = 0.7978845608028654  # sqrt(2/pi)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    raise ValueError(f"unknown activation {act!r}")


def _bias_act_kernel(x_ref, b_ref, o_ref, *, act: str):
    o_ref[...] = _apply_act(x_ref[...] + b_ref[...][None, :], act)


def bias_act(x: jax.Array, b: jax.Array, act: str = "relu") -> jax.Array:
    """y = act(x + b[None, :]) for x: (R, C), b: (C,)."""
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")
    if x.ndim != 2 or b.ndim != 1 or x.shape[1] != b.shape[0]:
        raise ValueError(f"bias_act shape mismatch: x={x.shape} b={b.shape}")
    r, c = x.shape
    br = min(ROW_BLOCK, r)
    rem = r % br
    if rem:
        x = jnp.pad(x, ((0, br - rem), (0, 0)))
    rp = x.shape[0]
    out = pl.pallas_call(
        functools.partial(_bias_act_kernel, act=act),
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c), jnp.float32),
        interpret=True,
    )(x, b)
    return out[:r, :]
