"""L1 kernel composition: 2-D convolution as im2col + Pallas matmul.

The paper's edge workloads are conv-dominated CNNs accelerated by
TensorRT. On a TPU-style target the idiomatic mapping is NOT a direct
threadblock port of a CUDA conv kernel but a reshape of the convolution
into the MXU's native primitive: im2col gathers each receptive field into
a row, then a single tiled Pallas matmul (kernels/matmul.py) performs the
contraction, and the fused bias+activation epilogue (kernels/fused.py)
finishes in fast memory. See DESIGN.md §Hardware-Adaptation.

Patch extraction is pure data movement (strided slices), so it stays in
jnp and lets XLA fuse it with the surrounding layout ops; all FLOPs run
in the Pallas kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import fused, matmul


def _im2col(x: jax.Array, kh: int, kw: int, stride: int,
            padding: str) -> tuple[jax.Array, int, int]:
    """x: (N, C, H, W) → patches (N*Ho*Wo, C*kh*kw), plus (Ho, Wo)."""
    n, c, h, w = x.shape
    if padding == "SAME":
        ho = -(-h // stride)
        wo = -(-w // stride)
        pad_h = max((ho - 1) * stride + kh - h, 0)
        pad_w = max((wo - 1) * stride + kw - w, 0)
        x = jnp.pad(x, ((0, 0), (0, 0),
                        (pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2)))
    elif padding == "VALID":
        ho = (h - kh) // stride + 1
        wo = (w - kw) // stride + 1
    else:
        raise ValueError(padding)
    # Gather kh*kw strided views; each is (N, C, Ho, Wo).
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(jax.lax.slice(
                x,
                (0, 0, i, j),
                (n, c, i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1),
                (1, 1, stride, stride),
            ))
    # (kh*kw, N, C, Ho, Wo) → (N, Ho, Wo, C, kh*kw) → rows.
    patches = jnp.stack(cols, axis=-1)          # (N, C, Ho, Wo, kh*kw)
    patches = patches.transpose(0, 2, 3, 1, 4)  # (N, Ho, Wo, C, kh*kw)
    return patches.reshape(n * ho * wo, c * kh * kw), ho, wo


def conv2d(x: jax.Array, w: jax.Array, b: jax.Array | None = None, *,
           stride: int = 1, padding: str = "SAME",
           act: str = "identity") -> jax.Array:
    """Conv2d with optional fused bias+activation.

    x: (N, C, H, W), w: (O, C, kh, kw), b: (O,) → (N, O, H', W').
    """
    n = x.shape[0]
    o, c, kh, kw = w.shape
    assert x.shape[1] == c, f"channel mismatch {x.shape} vs {w.shape}"
    rows, ho, wo = _im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(o, c * kh * kw).T           # (C*kh*kw, O)
    y = matmul.matmul(rows, wmat)                # (N*Ho*Wo, O)
    if b is not None or act != "identity":
        y = fused.bias_act(y, b if b is not None else jnp.zeros((o,)), act)
    return y.reshape(n, ho, wo, o).transpose(0, 3, 1, 2)


def depthwise_conv2d(x: jax.Array, w: jax.Array, b: jax.Array | None = None,
                     *, stride: int = 1, padding: str = "SAME",
                     act: str = "identity") -> jax.Array:
    """Depthwise conv (one filter per channel) via grouped im2col matmul.

    x: (N, C, H, W), w: (C, 1, kh, kw) → (N, C, H', W').

    Depthwise convs are contraction-poor (K = kh*kw), so rather than C
    separate skinny matmuls we build a block-diagonal weight matrix and
    run ONE Pallas matmul — trading a few zero-multiplies for a single
    MXU-shaped contraction. For the zoo's C ≤ 64 this keeps the kernel
    count (and dispatch overhead) flat.
    """
    n, c, _, _ = x.shape
    assert w.shape[0] == c and w.shape[1] == 1
    kh, kw = w.shape[2], w.shape[3]
    rows, ho, wo = _im2col(x, kh, kw, stride, padding)   # (R, C*kh*kw)
    # Block-diagonal (C*kh*kw, C): column ch takes channel ch's kh*kw taps.
    wflat = w.reshape(c, kh * kw)                         # (C, kh*kw)
    eye = jnp.eye(c, dtype=x.dtype)                       # (C, C)
    # (C, kh*kw, C) with taps on the diagonal, then fold to (C*kh*kw, C).
    wblock = (eye[:, None, :] * wflat[:, :, None])
    # rows columns are ordered (channel, tap) — match that ordering.
    wblock = wblock.reshape(c * kh * kw, c)
    y = matmul.matmul(rows, wblock)                       # (R, C)
    if b is not None or act != "identity":
        y = fused.bias_act(y, b if b is not None else jnp.zeros((c,)), act)
    return y.reshape(n, ho, wo, c).transpose(0, 3, 1, 2)
