"""L1 Pallas kernel: fused scaled-dot-product attention.

TinyBERT's hot spot. Sequence length in the zoo is tiny (14 tokens), so a
single-block FlashAttention-style kernel holds Q, K, V and the score
matrix entirely in fast memory: one grid step computes
softmax(QKᵀ/√d)·V with no HBM round trip for the S×S scores. On a real
TPU this is the regime where VMEM residency beats any tiling cleverness —
the adaptation of the paper's GPU framing per DESIGN.md
§Hardware-Adaptation.

Larger sequences fall back to row-tiling over Q (still exact: softmax is
per-row, so K/V ride along whole while Q is tiled).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Q_BLOCK = 128


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # Numerically stable softmax, fully in-register/VMEM.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Single-head attention. q: (S, D), k: (S, D), v: (S, D) → (S, D)."""
    if q.ndim != 2 or q.shape != k.shape or k.shape != v.shape:
        raise ValueError(f"attention expects matching (S, D): {q.shape} {k.shape} {v.shape}")
    s, d = q.shape
    scale = 1.0 / float(d) ** 0.5
    bq = min(Q_BLOCK, s)
    rem = s % bq
    qp = jnp.pad(q, ((0, bq - rem if rem else 0), (0, 0)))
    sp = qp.shape[0]
    out = pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale),
        grid=(sp // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, d), jnp.float32),
        interpret=True,
    )(qp, k, v)
    return out[:s, :]


def _batched_attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def batched_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Batch of independent single-head attentions.

    q, k, v: (B, S, D) → (B, S, D). The grid iterates over B so the
    kernel body is identical to the single-sequence case: with S=14,
    D≤64 the whole per-sample problem is VMEM-resident. One pallas_call
    regardless of batch keeps AOT HLO size flat across compiled batch
    sizes (vs unrolling B kernel calls).
    """
    if q.ndim != 3 or q.shape != k.shape or k.shape != v.shape:
        raise ValueError(f"batched_attention expects matching (B, S, D): "
                         f"{q.shape} {k.shape} {v.shape}")
    bsz, s, d = q.shape
    scale = 1.0 / float(d) ** 0.5
    return pl.pallas_call(
        functools.partial(_batched_attention_kernel, scale=scale),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, d), jnp.float32),
        interpret=True,
    )(q, k, v)


def multi_head_attention(x: jax.Array, wq: jax.Array, wk: jax.Array,
                         wv: jax.Array, wo: jax.Array,
                         n_heads: int) -> jax.Array:
    """MHA over x: (S, D). Projections via the Pallas matmul kernel."""
    from . import matmul as mm
    s, d = x.shape
    assert d % n_heads == 0
    hd = d // n_heads
    q = mm.matmul(x, wq)
    k = mm.matmul(x, wk)
    v = mm.matmul(x, wv)
    heads = []
    for h in range(n_heads):
        sl = slice(h * hd, (h + 1) * hd)
        heads.append(attention(q[:, sl], k[:, sl], v[:, sl]))
    cat = jnp.concatenate(heads, axis=-1)
    return mm.matmul(cat, wo)


def batched_multi_head_attention(x: jax.Array, wq: jax.Array, wk: jax.Array,
                                 wv: jax.Array, wo: jax.Array,
                                 n_heads: int) -> jax.Array:
    """MHA over a batch of sequences x: (B, S, D).

    Projections treat tokens position-wise, so the batch folds into the
    matmul M dimension ((B*S, D) GEMMs — exactly the MXU-friendly shape);
    only the attention itself needs per-sample isolation, handled by the
    batched kernel's grid. Head count × 1 pallas_call per layer, flat in B.
    """
    from . import matmul as mm
    bsz, s, d = x.shape
    assert d % n_heads == 0
    hd = d // n_heads
    flat = x.reshape(bsz * s, d)
    q = mm.matmul(flat, wq).reshape(bsz, s, d)
    k = mm.matmul(flat, wk).reshape(bsz, s, d)
    v = mm.matmul(flat, wv).reshape(bsz, s, d)
    heads = []
    for h in range(n_heads):
        sl = slice(h * hd, (h + 1) * hd)
        heads.append(batched_attention(q[..., sl], k[..., sl], v[..., sl]))
    cat = jnp.concatenate(heads, axis=-1).reshape(bsz * s, d)
    return mm.matmul(cat, wo).reshape(bsz, s, d)
