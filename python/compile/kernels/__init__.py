"""BCEdge L1 Pallas kernels (build-time only)."""
from . import matmul, fused, conv, attention, ref  # noqa: F401
