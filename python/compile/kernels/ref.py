"""Pure-jnp correctness oracles for every L1 Pallas kernel.

These are the *specification*: pytest (python/tests/) asserts
``assert_allclose(kernel(x), ref(x))`` across hypothesis-generated shape
sweeps. Keep each oracle a direct transcription of the math with no
tiling, padding, or fusion tricks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(a, b)


def bias_act_ref(x: jax.Array, b: jax.Array, act: str = "relu") -> jax.Array:
    y = x + b[None, :]
    if act == "identity":
        return y
    if act == "relu":
        return jax.nn.relu(y)
    if act == "hardswish":
        return jax.nn.hard_swish(y)
    if act == "sigmoid":
        return jax.nn.sigmoid(y)
    if act == "gelu":
        return jax.nn.gelu(y)  # default tanh approximation matches fused.py
    raise ValueError(act)


def conv2d_ref(x: jax.Array, w: jax.Array, b: jax.Array | None = None, *,
               stride: int = 1, padding: str = "SAME",
               act: str = "identity") -> jax.Array:
    """x: (N, C, H, W), w: (O, C, kh, kw) → (N, O, H', W')."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if b is not None:
        y = y + b[None, :, None, None]
    n, o, ho, wo = y.shape
    flat = y.transpose(0, 2, 3, 1).reshape(-1, o)
    flat = bias_act_ref(flat, jnp.zeros((o,)), act)
    return flat.reshape(n, ho, wo, o).transpose(0, 3, 1, 2)


def depthwise_conv2d_ref(x: jax.Array, w: jax.Array,
                         b: jax.Array | None = None, *, stride: int = 1,
                         padding: str = "SAME",
                         act: str = "identity") -> jax.Array:
    """x: (N, C, H, W), w: (C, 1, kh, kw) → (N, C, H', W')."""
    c = x.shape[1]
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"), feature_group_count=c,
    )
    if b is not None:
        y = y + b[None, :, None, None]
    n, o, ho, wo = y.shape
    flat = y.transpose(0, 2, 3, 1).reshape(-1, o)
    flat = bias_act_ref(flat, jnp.zeros((o,)), act)
    return flat.reshape(n, ho, wo, o).transpose(0, 3, 1, 2)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Single-head scaled dot-product attention. q,k,v: (S, D)."""
    d = q.shape[-1]
    scores = jnp.matmul(q, k.T) / jnp.sqrt(jnp.float32(d))
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.matmul(probs, v)


def multi_head_attention_ref(x: jax.Array, wq: jax.Array, wk: jax.Array,
                             wv: jax.Array, wo: jax.Array,
                             n_heads: int) -> jax.Array:
    s, d = x.shape
    hd = d // n_heads
    q, k, v = x @ wq, x @ wk, x @ wv
    heads = []
    for h in range(n_heads):
        sl = slice(h * hd, (h + 1) * hd)
        heads.append(attention_ref(q[:, sl], k[:, sl], v[:, sl]))
    return jnp.concatenate(heads, axis=-1) @ wo
