"""L1 Pallas kernel: tiled matrix multiply.

This is the single hot primitive of the BCEdge model zoo: dense layers,
im2col convolutions, and attention score/value products all lower to this
kernel. The tiling is written for a TPU-style memory hierarchy — each grid
step streams one (bm, bk) tile of A and one (bk, bn) tile of B into fast
memory (VMEM on TPU) and accumulates into a resident (bm, bn) output tile,
which is the systolic-array (MXU) friendly schedule. Under
``interpret=True`` (required for CPU PJRT execution — real TPU lowering
emits a Mosaic custom-call the CPU plugin cannot run) the same BlockSpec
structure lowers to plain HLO.

VMEM budget check (see DESIGN.md §9): with the default 64×64×64 f32 tiles
a grid step touches 3 × 16 KiB = 48 KiB, double-buffered 96 KiB — far
below the ~16 MiB VMEM of a TPU core, leaving headroom to fuse the
bias/activation epilogue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. Small models in the zoo frequently have dims below
# these, so `matmul` pads to tile multiples first (zero padding is exact
# for matmul).
BM = 64
BN = 64
BK = 64


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """Grid point (i, j, k): accumulate A[i,k] @ B[k,j] into O[i,j].

    The K axis is the innermost grid dimension, so the (i, j) output tile
    stays resident while the kernel sweeps K — the classic output-
    stationary MXU schedule. The output block doubles as the accumulator,
    avoiding a scratch buffer (exact in f32).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulate; on a real MXU this is the bf16×bf16→f32 contraction.
    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


def matmul(a: jax.Array, b: jax.Array, *, bm: int = BM, bn: int = BN,
           bk: int = BK) -> jax.Array:
    """C = A @ B via the tiled Pallas kernel. A: (M, K), B: (K, N) → (M, N)."""
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul expects rank-2 operands, got {a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    # Clamp tiles to the (8-aligned) problem so tiny layers don't pay for a
    # mostly-zero 64^3 tile.
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    a = _pad_to(_pad_to(a, bm, 0), bk, 1)
    b = _pad_to(_pad_to(b, bk, 0), bn, 1)
    mp, kp = a.shape
    _, np_ = b.shape
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a, b)
    return out[:m, :n]


def linear(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """Dense layer y = x @ w (+ b) on rank-2 x, built on the Pallas matmul."""
    y = matmul(x, w)
    if b is not None:
        y = y + b[None, :]
    return y
