"""AOT pipeline: lower every (model, batch-size) pair to HLO text.

Build-time only — `make artifacts` runs this once; the Rust request path
never touches Python. For each zoo model and each compiled batch size we
emit ``artifacts/<model>_b<batch>.hlo.txt`` plus a single
``artifacts/manifest.json`` describing shapes / params / FLOPs for the
Rust runtime and platform model.

Interchange is HLO **text**, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
Lowering converts stablehlo → XlaComputation with ``return_tuple=True``,
so the Rust side unwraps a 1-tuple (`to_tuple1`).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as zoo

# Batch sizes with a compiled executable. The dynamic batcher pads to the
# nearest size upward (TensorRT-engine-per-batch analogue, DESIGN.md §2).
BATCH_SIZES = (1, 2, 4, 8, 16, 32)


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flops_estimate(lowered) -> float:
    """Per-inference FLOP estimate from XLA's cost analysis (if available)."""
    try:
        cost = lowered.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        return float(cost.get("flops", 0.0))
    except Exception:
        return 0.0


def lower_one(name: str, batch: int, out_dir: str) -> dict:
    apply_fn, meta = zoo.build(name)
    spec = zoo.example_input(name, batch)
    t0 = time.time()
    lowered = jax.jit(lambda x: (apply_fn(x),)).lower(spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}_b{batch}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    dt = time.time() - t0
    entry = {
        "model": name,
        "paper_name": meta.paper_name,
        "batch": batch,
        "path": os.path.basename(path),
        "input_shape": [batch, *meta.input_shape],
        "output_shape": [batch, *meta.output_shape],
        "param_count": meta.param_count,
        "slo_ms": meta.slo_ms,
        "flops": flops_estimate(lowered),
        "hlo_bytes": len(text),
    }
    print(f"  {name} b={batch}: {len(text)/1e6:.2f} MB HLO in {dt:.1f}s",
          flush=True)
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--models", default=",".join(zoo.MODEL_NAMES),
                    help="comma-separated subset of the zoo")
    ap.add_argument("--batches", default=",".join(map(str, BATCH_SIZES)),
                    help="comma-separated batch sizes")
    args = ap.parse_args()

    names = [n.strip() for n in args.models.split(",") if n.strip()]
    batches = [int(b) for b in args.batches.split(",") if b.strip()]
    os.makedirs(args.out, exist_ok=True)

    entries = []
    for name in names:
        print(f"[aot] lowering {name} ...", flush=True)
        for b in batches:
            entries.append(lower_one(name, b, args.out))

    manifest = {
        "format": "bcedge-aot-v1",
        "interchange": "hlo-text",
        "return_tuple": True,
        "batch_sizes": batches,
        "models": sorted({e["model"] for e in entries}),
        "entries": entries,
    }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    total = sum(e["hlo_bytes"] for e in entries)
    print(f"[aot] wrote {len(entries)} artifacts ({total/1e6:.1f} MB) "
          f"+ {mpath}")


if __name__ == "__main__":
    sys.exit(main())
