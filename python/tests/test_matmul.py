"""L1 Pallas matmul kernel vs pure-jnp oracle.

The CORE correctness signal for the compute hot path: hypothesis sweeps
the shape space (including degenerate, tile-aligned, and tile-straddling
sizes) and asserts allclose against ref.matmul_ref.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref

SETTINGS = dict(deadline=None, max_examples=25)


def _mat(rng, r, c, scale=1.0):
    return jnp.asarray(rng.normal(size=(r, c)).astype(np.float32) * scale)


@settings(**SETTINGS)
@given(m=st.integers(1, 100), k=st.integers(1, 100), n=st.integers(1, 100),
       seed=st.integers(0, 2**32 - 1))
def test_matmul_matches_ref_random_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = _mat(rng, m, k)
    b = _mat(rng, k, n)
    np.testing.assert_allclose(matmul.matmul(a, b), ref.matmul_ref(a, b),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [
    (64, 64, 64),     # exactly one tile
    (128, 128, 128),  # 2x2x2 tiles
    (65, 64, 64),     # one row over a tile boundary
    (64, 65, 64),     # contraction over a boundary
    (1, 1, 1),        # degenerate
    (1, 200, 1),      # long contraction, multiple K tiles
    (200, 1, 200),    # rank-1 outer-product-ish
])
def test_matmul_tile_boundaries(rng, m, k, n):
    a = _mat(rng, m, k)
    b = _mat(rng, k, n)
    np.testing.assert_allclose(matmul.matmul(a, b), ref.matmul_ref(a, b),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (64, 64, 64)])
def test_matmul_custom_tiles(rng, bm, bn, bk):
    a = _mat(rng, 40, 56)
    b = _mat(rng, 56, 24)
    got = matmul.matmul(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_zero_inputs():
    a = jnp.zeros((17, 23), jnp.float32)
    b = jnp.zeros((23, 9), jnp.float32)
    assert not np.asarray(matmul.matmul(a, b)).any()


def test_matmul_identity(rng):
    a = _mat(rng, 33, 33)
    eye = jnp.eye(33, dtype=jnp.float32)
    np.testing.assert_allclose(matmul.matmul(a, eye), a, rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_shapes(rng):
    with pytest.raises(ValueError):
        matmul.matmul(_mat(rng, 3, 4), _mat(rng, 5, 6))
    with pytest.raises(ValueError):
        matmul.matmul(jnp.zeros((3,)), jnp.zeros((3, 3)))


def test_linear_bias(rng):
    x = _mat(rng, 7, 11)
    w = _mat(rng, 11, 5)
    b = jnp.asarray(rng.normal(size=(5,)).astype(np.float32))
    np.testing.assert_allclose(matmul.linear(x, w, b), x @ w + b[None, :],
                               rtol=1e-4, atol=1e-4)
