"""AOT pipeline: HLO text emission + manifest integrity."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered_res(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entry = aot.lower_one("res", 2, str(out))
    return out, entry


def test_hlo_text_structure(lowered_res):
    out, entry = lowered_res
    text = (out / entry["path"]).read_text()
    # HLO text module with an ENTRY computation and a tuple root —
    # exactly what HloModuleProto::from_text_file + to_tuple1 expect.
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert entry["hlo_bytes"] == len(text)


def test_entry_fields(lowered_res):
    _, entry = lowered_res
    assert entry["model"] == "res"
    assert entry["batch"] == 2
    assert entry["input_shape"] == [2, 3, 32, 32]
    assert entry["output_shape"] == [2, 10]
    assert entry["slo_ms"] == 58.0
    assert entry["param_count"] > 0


def test_flops_reported_nonnegative(tmp_path):
    # XLA's pre-optimization cost analysis under-counts FLOPs hidden in the
    # pallas interpret-mode while-loops, so scaling with batch is NOT
    # asserted (the rust platform model calibrates from measured latency,
    # not this field); the manifest just needs a well-formed number.
    e1 = aot.lower_one("mob", 1, str(tmp_path))
    assert e1["flops"] >= 0.0


def test_manifest_round_trip(tmp_path, monkeypatch):
    monkeypatch.setattr(aot, "BATCH_SIZES", (1,))
    import sys
    monkeypatch.setattr(sys, "argv",
                        ["aot", "--out", str(tmp_path), "--models", "mob",
                         "--batches", "1"])
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "bcedge-aot-v1"
    assert manifest["return_tuple"] is True
    assert manifest["models"] == ["mob"]
    (e,) = manifest["entries"]
    assert os.path.exists(tmp_path / e["path"])


def test_repo_manifest_complete_if_built():
    """If `make artifacts` has run, the manifest must cover the full zoo
    at every advertised batch size, with every file present."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(root, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    manifest = json.load(open(mpath))
    assert set(manifest["models"]) == set(model.MODEL_NAMES)
    expect = {(m, b) for m in manifest["models"]
              for b in manifest["batch_sizes"]}
    got = {(e["model"], e["batch"]) for e in manifest["entries"]}
    assert got == expect
    for e in manifest["entries"]:
        assert os.path.exists(os.path.join(root, e["path"])), e["path"]
