"""im2col + Pallas-matmul convolutions vs jax.lax.conv oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, ref


def _x(rng, n, c, h, w):
    return jnp.asarray(rng.normal(size=(n, c, h, w)).astype(np.float32))


@settings(deadline=None, max_examples=15)
@given(n=st.integers(1, 4), cin=st.integers(1, 8), cout=st.integers(1, 8),
       hw=st.integers(4, 20), k=st.sampled_from([1, 3, 5]),
       stride=st.sampled_from([1, 2]),
       padding=st.sampled_from(["SAME", "VALID"]),
       seed=st.integers(0, 2**31))
def test_conv2d_matches_ref(n, cin, cout, hw, k, stride, padding, seed):
    if padding == "VALID" and hw < k:
        return
    rng = np.random.default_rng(seed)
    x = _x(rng, n, cin, hw, hw)
    w = jnp.asarray(rng.normal(size=(cout, cin, k, k)).astype(np.float32) * 0.2)
    b = jnp.asarray(rng.normal(size=(cout,)).astype(np.float32))
    got = conv.conv2d(x, w, b, stride=stride, padding=padding, act="relu")
    want = ref.conv2d_ref(x, w, b, stride=stride, padding=padding, act="relu")
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(n=st.integers(1, 3), c=st.integers(1, 8), hw=st.integers(4, 16),
       stride=st.sampled_from([1, 2]), seed=st.integers(0, 2**31))
def test_depthwise_matches_ref(n, c, hw, stride, seed):
    rng = np.random.default_rng(seed)
    x = _x(rng, n, c, hw, hw)
    w = jnp.asarray(rng.normal(size=(c, 1, 3, 3)).astype(np.float32) * 0.2)
    b = jnp.asarray(rng.normal(size=(c,)).astype(np.float32))
    got = conv.depthwise_conv2d(x, w, b, stride=stride, act="hardswish")
    want = ref.depthwise_conv2d_ref(x, w, b, stride=stride, act="hardswish")
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_conv_1x1_is_channel_mix(rng):
    """1×1 conv must equal a per-pixel dense layer."""
    x = _x(rng, 2, 4, 6, 6)
    w = jnp.asarray(rng.normal(size=(3, 4, 1, 1)).astype(np.float32))
    got = conv.conv2d(x, w, stride=1)
    want = jnp.einsum("nchw,oc->nohw", x, w[:, :, 0, 0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv_stride2_halves_spatial(rng):
    x = _x(rng, 1, 3, 16, 16)
    w = jnp.asarray(rng.normal(size=(5, 3, 3, 3)).astype(np.float32))
    assert conv.conv2d(x, w, stride=2).shape == (1, 5, 8, 8)


def test_conv_odd_input_same_padding(rng):
    x = _x(rng, 1, 2, 7, 9)
    w = jnp.asarray(rng.normal(size=(2, 2, 3, 3)).astype(np.float32))
    got = conv.conv2d(x, w, stride=2)
    want = ref.conv2d_ref(x, w, stride=2)
    assert got.shape == want.shape == (1, 2, 4, 5)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_conv_channel_mismatch_asserts(rng):
    x = _x(rng, 1, 3, 8, 8)
    w = jnp.asarray(rng.normal(size=(4, 2, 3, 3)).astype(np.float32))
    with pytest.raises(AssertionError):
        conv.conv2d(x, w)
