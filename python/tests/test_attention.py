"""Pallas attention kernels (single, batched, MHA) vs oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref


def _qkv(rng, *shape):
    return tuple(jnp.asarray(rng.normal(size=shape).astype(np.float32))
                 for _ in range(3))


@settings(deadline=None, max_examples=20)
@given(s=st.integers(1, 64), d=st.integers(1, 64), seed=st.integers(0, 2**31))
def test_attention_matches_ref(s, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _qkv(rng, s, d)
    np.testing.assert_allclose(attention.attention(q, k, v),
                               ref.attention_ref(q, k, v),
                               rtol=1e-4, atol=1e-5)


@settings(deadline=None, max_examples=10)
@given(b=st.integers(1, 8), s=st.integers(1, 20), d=st.integers(1, 32),
       seed=st.integers(0, 2**31))
def test_batched_attention_matches_per_sample(b, s, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _qkv(rng, b, s, d)
    got = attention.batched_attention(q, k, v)
    for i in range(b):
        np.testing.assert_allclose(got[i], ref.attention_ref(q[i], k[i], v[i]),
                                   rtol=1e-4, atol=1e-5)


def test_attention_rows_sum_property(rng):
    """Attention output is a convex combination of V rows: with V = const
    vector c, output must be exactly c."""
    s, d = 14, 32
    q = jnp.asarray(rng.normal(size=(s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(s, d)).astype(np.float32))
    v = jnp.ones((s, d), jnp.float32) * 7.0
    np.testing.assert_allclose(attention.attention(q, k, v), v,
                               rtol=1e-5, atol=1e-5)


def test_q_block_boundary(rng):
    s = attention.Q_BLOCK + 3   # forces padding + 2 grid steps
    d = 16
    q, k, v = _qkv(rng, s, d)
    np.testing.assert_allclose(attention.attention(q, k, v),
                               ref.attention_ref(q, k, v),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("heads", [1, 2, 4])
def test_mha_matches_ref(rng, heads):
    s, d = 14, 32
    x = jnp.asarray(rng.normal(size=(s, d)).astype(np.float32))
    ws = [jnp.asarray(rng.normal(size=(d, d)).astype(np.float32) * 0.2)
          for _ in range(4)]
    got = attention.multi_head_attention(x, *ws, heads)
    want = ref.multi_head_attention_ref(x, *ws, heads)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("heads", [1, 2])
def test_batched_mha_matches_unbatched(rng, heads):
    b, s, d = 3, 14, 32
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    ws = [jnp.asarray(rng.normal(size=(d, d)).astype(np.float32) * 0.2)
          for _ in range(4)]
    got = attention.batched_multi_head_attention(x, *ws, heads)
    for i in range(b):
        want = attention.multi_head_attention(x[i], *ws, heads)
        np.testing.assert_allclose(got[i], want, rtol=1e-3, atol=1e-4)


def test_attention_shape_validation():
    with pytest.raises(ValueError):
        attention.attention(jnp.zeros((3, 4)), jnp.zeros((5, 4)),
                            jnp.zeros((3, 4)))
    with pytest.raises(ValueError):
        attention.batched_attention(jnp.zeros((3, 4)), jnp.zeros((3, 4)),
                                    jnp.zeros((3, 4)))
