"""Fused bias+activation epilogue kernel vs oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused, ref


@settings(deadline=None, max_examples=20)
@given(r=st.integers(1, 300), c=st.integers(1, 64),
       act=st.sampled_from(fused.ACTIVATIONS), seed=st.integers(0, 2**31))
def test_bias_act_matches_ref(r, c, act, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(r, c)).astype(np.float32) * 3)
    b = jnp.asarray(rng.normal(size=(c,)).astype(np.float32))
    np.testing.assert_allclose(fused.bias_act(x, b, act),
                               ref.bias_act_ref(x, b, act),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("act", fused.ACTIVATIONS)
def test_bias_act_row_block_boundary(rng, act):
    # Exactly the ROW_BLOCK and one over it.
    for r in (fused.ROW_BLOCK, fused.ROW_BLOCK + 1):
        x = jnp.asarray(rng.normal(size=(r, 10)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(10,)).astype(np.float32))
        np.testing.assert_allclose(fused.bias_act(x, b, act),
                                   ref.bias_act_ref(x, b, act),
                                   rtol=1e-4, atol=1e-5)


def test_relu_clamps_negative(rng):
    x = -jnp.abs(jnp.asarray(rng.normal(size=(5, 5)).astype(np.float32))) - 1.0
    out = fused.bias_act(x, jnp.zeros((5,)), "relu")
    assert (np.asarray(out) == 0).all()


def test_sigmoid_range(rng):
    # f32 sigmoid saturates to exactly 0/1 for |x| ≳ 17, so bounds are
    # inclusive.
    x = jnp.asarray(rng.normal(size=(20, 8)).astype(np.float32) * 10)
    out = np.asarray(fused.bias_act(x, jnp.zeros((8,)), "sigmoid"))
    assert (out >= 0).all() and (out <= 1).all()
    mid = np.asarray(fused.bias_act(x / 20.0, jnp.zeros((8,)), "sigmoid"))
    assert (mid > 0).all() and (mid < 1).all()


def test_unknown_activation_raises(rng):
    with pytest.raises(ValueError):
        fused.bias_act(jnp.zeros((2, 2)), jnp.zeros((2,)), "swish9000")


def test_shape_mismatch_raises():
    with pytest.raises(ValueError):
        fused.bias_act(jnp.zeros((2, 3)), jnp.zeros((4,)), "relu")
