"""L2 model zoo: shapes, determinism, finiteness, batch consistency."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model


def _input(rng, name, batch):
    _, meta = model.build(name)
    x = rng.normal(size=(batch, *meta.input_shape)).astype(np.float32)
    if name == "bert":
        x = np.abs(x) * 10.0   # token-id-ish values
    return jnp.asarray(x)


@pytest.mark.parametrize("name", model.MODEL_NAMES)
def test_output_shape_and_finite(rng, name):
    apply_fn, meta = model.build(name)
    x = _input(rng, name, 2)
    y = np.asarray(apply_fn(x))
    assert y.shape == (2, *meta.output_shape)
    assert np.isfinite(y).all()


@pytest.mark.parametrize("name", model.MODEL_NAMES)
def test_build_is_deterministic(rng, name):
    """Two independent builds bake identical weights (fixed seeds), so the
    AOT artifact is reproducible."""
    a1, _ = model.build(name)
    a2, _ = model.build(name)
    x = _input(rng, name, 1)
    np.testing.assert_array_equal(np.asarray(a1(x)), np.asarray(a2(x)))


@pytest.mark.parametrize("name", model.MODEL_NAMES)
def test_batch_consistency(rng, name):
    """Row i of a batched run must equal the single-sample run — the
    dynamic batcher depends on batching being semantically transparent."""
    apply_fn, _ = model.build(name)
    x = _input(rng, name, 3)
    batched = np.asarray(apply_fn(x))
    for i in range(3):
        single = np.asarray(apply_fn(x[i:i + 1]))[0]
        np.testing.assert_allclose(batched[i], single, rtol=1e-3, atol=1e-4)


def test_zoo_covers_paper_table_iv():
    names = set(model.MODEL_NAMES)
    assert names == {"yolo", "mob", "res", "eff", "inc", "bert"}
    slos = {m: model.build(m)[1].slo_ms for m in names}
    assert slos == {"yolo": 138.0, "mob": 86.0, "res": 58.0,
                    "eff": 93.0, "inc": 66.0, "bert": 114.0}


def test_heterogeneous_params():
    counts = {m: model.build(m)[1].param_count for m in model.MODEL_NAMES}
    assert len(set(counts.values())) == len(counts), counts


def test_unknown_model_raises():
    with pytest.raises(KeyError):
        model.build("vgg")


def test_bert_clips_out_of_vocab(rng):
    apply_fn, meta = model.build("bert")
    x = jnp.full((1, *meta.input_shape), 1e6, jnp.float32)
    assert np.isfinite(np.asarray(apply_fn(x))).all()
