//! Offline stub of the `xla` (xla_extension) PJRT bindings.
//!
//! The build environment for this repository does not ship the real
//! `xla_extension` shared library, so this crate provides just the API
//! surface `bcedge::runtime::pjrt` compiles against. Constructors that
//! would touch PJRT return [`Error`], which `PjrtRuntime::load` already
//! treats as "real backend unavailable" — the simulation backend and the
//! entire coordinator test surface are independent of it. Replacing this
//! path dependency with the real bindings requires no source changes in
//! `bcedge`.
//!
//! Type fidelity notes: the real crate wraps PJRT handles in `Rc`, which
//! makes its types `!Send`/`!Sync`; the `_not_send` markers reproduce
//! that so the `unsafe impl Send` reasoning in `runtime/pjrt.rs` stays
//! honest against this stub too.

use std::rc::Rc;

/// Stub error: every PJRT entry point fails with this.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} is unavailable (offline stub build without xla_extension)"
    )))
}

/// PJRT client handle (stub).
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    /// The real binding creates a CPU PJRT client; the stub reports the
    /// backend as unavailable so callers fall back to simulation.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _not_send: Rc<()>,
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _not_send: Rc<()>,
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _not_send: Rc::new(()) }
    }
}

/// Host literal (stub): carries no data, only enough shape to type-check.
pub struct Literal {
    _not_send: Rc<()>,
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _not_send: Rc::new(()) }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _not_send: Rc::new(()) })
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Device buffer returned by an execution (stub).
pub struct PjRtBuffer {
    _not_send: Rc<()>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {
    _not_send: Rc<()>,
}

impl PjRtLoadedExecutable {
    /// Mirrors the real signature shape: generic over the argument
    /// literal type, returns per-device/per-output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_shape_round_trip_is_inert() {
        let l = Literal::vec1(&[1.0, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
