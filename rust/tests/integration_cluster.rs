//! Integration tests for the heterogeneous edge-cluster tier: SLO-aware
//! routing against the heterogeneity-blind baseline, with a mid-run node
//! drain/rejoin and cluster-wide request conservation.

use bcedge::cluster::{CacheConfig, ClusterConfig, ClusterReport,
                      DrainScenario, FrontEndConfig, NodeSpec, RoutePolicy,
                      run_cluster};
use bcedge::metrics::ShedReason;
use bcedge::platform::PlatformSpec;
use bcedge::predictor::AdmissionMode;
use bcedge::serve::{AdmissionConfig, ClockKind, LoadGenConfig,
                    SchedulerSpec, ServeConfig};
use std::collections::HashSet;

/// Tentpole acceptance: on a heterogeneous 3-node cluster (Xavier NX +
/// TX2 + Nano, increasingly distant links) at the cluster's feasibility
/// limit, SLO-aware routing yields a strictly lower accepted-violation
/// rate than round-robin — while cluster-wide conservation (outcomes +
/// sheds + leftover == attempts, outcome ids unique across nodes) holds
/// through a mid-run drain/rejoin of the primary node.
///
/// Why the separation is structural, not tuned: the Table-V platform
/// scales make the Nano ~12.5× and the TX2 ~4.4× slower per batch than
/// the NX. Even at 3× the paper SLOs (`slo_scale`), no model's batch
/// fits any deadline on the Nano, and only the lightest models fit on
/// the TX2 — so round-robin sends a third of the traffic somewhere it
/// can only complete late (every Nano outcome violates by construction),
/// while the SLO-aware policy prices RTT + queue backlog + batch latency
/// per node, routes around the infeasible hardware, spills light models
/// to the TX2 when the NX queue builds, and sheds the hopeless remainder
/// at the edge with the typed `no-feasible-node` reason instead of
/// letting it violate. Node admission is OFF in both runs so routing is
/// the only protection being measured.
#[test]
fn slo_routing_beats_round_robin_on_heterogeneous_cluster() {
    let run = |policy: RoutePolicy| -> ClusterReport {
        let cfg = ClusterConfig {
            nodes: vec![
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
                NodeSpec::new(PlatformSpec::jetson_tx2(), 2, 6.0),
                NodeSpec::new(PlatformSpec::jetson_nano(), 1, 12.0),
            ],
            policy,
            serve: ServeConfig {
                clock: ClockKind::Wall,
                scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 2 },
                admission: None,
                queue_capacity: 1024,
                ..Default::default()
            },
            // Mid-run lifecycle: the PRIMARY node leaves at 0.6 s (its
            // backlog flushes through the drain protocol; the router
            // stops dispatching immediately) and rejoins at 1.2 s with a
            // fresh request-id window. Same scenario in both runs.
            drain: Some(DrainScenario {
                node: 0,
                at_ms: 600.0,
                rejoin_at_ms: 1_200.0,
            }),
            frontend: Default::default(),
        };
        let load = LoadGenConfig {
            rps: 180.0,
            seconds: 2.0,
            seed: 20_24,
            slo_scale: 3.0,
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();

        // Cluster-wide conservation through the drain/rejoin: every
        // attempt is accounted exactly once...
        assert_eq!(report.metrics.outcomes().len() as u64
                       + report.metrics.shed_total()
                       + report.leftover as u64,
                   report.attempts,
                   "requests lost or double-counted ({})", policy.name());
        // ...attempts split exactly into edge sheds + node dispatches...
        let dispatched: u64 =
            report.per_node.iter().map(|n| n.dispatched).sum();
        assert_eq!(dispatched + report.router_sheds(), report.attempts);
        // ...and no request was served twice, across nodes OR across the
        // drained node's two incarnations (disjoint id windows).
        let mut seen = HashSet::new();
        for o in report.metrics.outcomes() {
            assert!(seen.insert(o.id),
                    "request {} served twice ({})", o.id, policy.name());
        }
        // The lifecycle really ran: one drain, one rejoin, and the
        // primary node served two segments.
        assert_eq!(report.drains, 1, "{}: node never drained", policy.name());
        assert_eq!(report.rejoins, 1, "{}: node never rejoined",
                   policy.name());
        assert_eq!(report.per_node[0].segments, 2,
                   "{}: rejoined node did not serve a second segment",
                   policy.name());
        assert!(report.metrics.completed() > 0);
        report
    };

    let rr = run(RoutePolicy::RoundRobin);
    let slo = run(RoutePolicy::SloAware);

    // Round-robin genuinely drowns the slow nodes: a third of the load
    // lands on hardware that can only complete late (loose bound so CI
    // scheduler jitter cannot flake it; arrival pacing targets absolute
    // timestamps, so a slow submitter only makes the load burstier —
    // never lighter).
    assert!(rr.per_node[2].dispatched > 0,
            "round-robin never used the Nano — scenario is broken");
    assert!(rr.metrics.violation_rate() > 0.15,
            "round-robin not suffering on heterogeneous hardware: {:.3}",
            rr.metrics.violation_rate());
    // The SLO-aware router knows the Nano can never make a deadline:
    // nothing is dispatched there, and the hopeless remainder is shed at
    // the edge with the typed reason instead of violating.
    assert_eq!(slo.per_node[2].dispatched, 0,
               "slo-aware routed to a structurally infeasible node");
    assert!(slo.router_sheds() > 0,
            "slo-aware never shed at the edge under overload");
    // `no-feasible-node` is recorded ONLY at the router: its count is
    // exactly the attempts that never reached a node's ingress.
    let slo_dispatched: u64 =
        slo.per_node.iter().map(|n| n.dispatched).sum();
    assert_eq!(slo.metrics.shed_by_reason(ShedReason::NoFeasibleNode),
               slo.attempts - slo_dispatched);
    // The headline: strictly lower accepted-violation rate.
    assert!(slo.metrics.violation_rate() < rr.metrics.violation_rate(),
            "slo-aware routing did not help: {:.3} vs round-robin {:.3}",
            slo.metrics.violation_rate(),
            rr.metrics.violation_rate());
}

/// The Table-V trio behind increasingly distant links.
fn trio() -> Vec<NodeSpec> {
    vec![
        NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
        NodeSpec::new(PlatformSpec::jetson_tx2(), 2, 6.0),
        NodeSpec::new(PlatformSpec::jetson_nano(), 1, 12.0),
    ]
}

fn assert_cluster_conserved(report: &ClusterReport, label: &str) {
    assert_eq!(report.metrics.outcomes().len() as u64
                   + report.metrics.shed_total()
                   + report.cache_served()
                   + report.leftover as u64,
               report.attempts,
               "{label}: requests lost or double-counted");
    let dispatched: u64 = report.per_node.iter().map(|n| n.dispatched).sum();
    assert_eq!(dispatched + report.router_sheds() + report.cache_served(),
               report.attempts, "{label}: dispatch split broken");
    let mut seen = HashSet::new();
    for o in report.metrics.outcomes() {
        assert!(seen.insert(o.id), "{label}: request {} served twice", o.id);
    }
}

/// Fabric acceptance (differential): the SAME scenario — nodes, policy,
/// scheduler, seed — run once on each clock arm. Both arms conserve
/// every request, and the virtual fabric's violation rate lands within
/// tolerance of the live wall run's: the event-heap simulation is a
/// faithful stand-in for the threaded stack, not a different system that
/// happens to share flags. (Tolerance is loose because the wall arm
/// genuinely schedules threads — CI jitter shifts batch boundaries — but
/// both arms simulate the same Table-V latencies, so the rates cannot
/// drift structurally.)
#[test]
fn virtual_fabric_tracks_wall_arm_within_tolerance() {
    let run = |clock: ClockKind| -> ClusterReport {
        let serve = ServeConfig::builder()
            .clock(clock)
            .scheduler(SchedulerSpec::Fixed { batch: 4, m_c: 2 })
            .admission(None)
            .queue_capacity(4096)
            .build()
            .unwrap();
        let cfg = ClusterConfig::builder()
            .nodes(trio())
            .policy(RoutePolicy::SloAware)
            .serve(serve)
            .build()
            .unwrap();
        let load = LoadGenConfig::builder()
            .rps(150.0)
            .seconds(2.0)
            .seed(1234)
            .slo_scale(3.0)
            .build()
            .unwrap();
        run_cluster(&cfg, &load).unwrap()
    };
    let wall = run(ClockKind::Wall);
    let virt = run(ClockKind::Virtual);
    assert_cluster_conserved(&wall, "wall");
    assert_cluster_conserved(&virt, "virtual");
    assert!(wall.metrics.completed() > 0 && virt.metrics.completed() > 0);
    // Same offered load reaches both arms.
    assert_eq!(wall.attempts, virt.attempts,
               "arms disagreed on the arrival trace");
    let (vw, vv) =
        (wall.metrics.violation_rate(), virt.metrics.violation_rate());
    assert!((vw - vv).abs() < 0.2,
            "violation rates diverged across clock arms: wall {vw:.3} \
             vs virtual {vv:.3}");
}

/// Fabric acceptance (tentpole): the FULL dynamic stack — migration +
/// replication epochs, a mid-run drain/rejoin, sharded routing from the
/// gossiped view, and the result cache — runs bit-identically across two
/// virtual runs for every (seed, shard count) tried. Before the fabric,
/// the virtual arm silently pinned shards static and skipped the
/// rebalancer; this pins that the carve-out is gone.
#[test]
fn full_dynamic_stack_is_bit_identical_per_seed_and_shards() {
    for (seed, shards) in [(7u64, 1usize), (7, 3), (41, 2)] {
        let mk_cfg = |admission: AdmissionConfig| {
            ClusterConfig::builder()
                .nodes(trio())
                .policy(RoutePolicy::PowerOfTwoChoices)
                .serve(
                    ServeConfig::builder()
                        .clock(ClockKind::Virtual)
                        .scheduler(SchedulerSpec::Fixed { batch: 4, m_c: 2 })
                        .queue_capacity(1024)
                        .admission(Some(admission))
                        .build()
                        .unwrap(),
                )
                .drain(Some(DrainScenario {
                    node: 0,
                    at_ms: 3_000.0,
                    rejoin_at_ms: 6_000.0,
                }))
                .frontend(FrontEndConfig {
                    router_shards: shards,
                    gossip_ms: 5.0,
                    cache: Some(CacheConfig { ttl_ms: 500.0, capacity: 4096 }),
                })
                .build()
                .unwrap()
        };
        let cfg = mk_cfg(AdmissionConfig::default());
        let load = LoadGenConfig::builder()
            .rps(200.0)
            .seconds(10.0)
            .seed(seed)
            .slo_scale(3.0)
            .repeat_fraction(0.5)
            .build()
            .unwrap();
        let tag = format!("seed {seed} / {shards} shard(s)");
        let a = run_cluster(&cfg, &load).unwrap();
        let b = run_cluster(&cfg, &load).unwrap();
        assert_cluster_conserved(&a, &tag);

        // Every dynamic subsystem genuinely ran.
        assert_eq!(a.drains, 1, "{tag}: node never drained");
        assert_eq!(a.rejoins, 1, "{tag}: node never rejoined");
        assert!(a.metrics.rebalance_epochs() > 0,
                "{tag}: rebalance controller never ticked");
        assert!(a.cache_served() > 0, "{tag}: cache never served");
        assert_eq!(a.frontend.shards, shards);
        assert!(a.frontend.decisions > 0);

        // Bit-identical across runs: outcome stream, scheduling slots,
        // routing, control-plane actions, and cache dispositions.
        assert_eq!(a.metrics.outcomes(), b.metrics.outcomes(),
                   "{tag}: outcome streams diverged");
        assert_eq!(a.slots, b.slots, "{tag}: slot counts diverged");
        assert_eq!(a.attempts, b.attempts);
        assert_eq!(a.leftover, b.leftover, "{tag}: leftover diverged");
        let dispatched = |r: &ClusterReport| -> Vec<u64> {
            r.per_node.iter().map(|n| n.dispatched).collect()
        };
        assert_eq!(dispatched(&a), dispatched(&b),
                   "{tag}: per-node dispatch diverged");
        assert_eq!(a.frontend.decisions, b.frontend.decisions,
                   "{tag}: routing decisions diverged");
        assert_eq!(a.frontend.misroutes, b.frontend.misroutes,
                   "{tag}: misroutes diverged");
        assert_eq!(a.frontend.cache, b.frontend.cache,
                   "{tag}: cache stats diverged");
        assert_eq!(a.metrics.migrations(), b.metrics.migrations(),
                   "{tag}: migrations diverged");
        assert_eq!((a.metrics.scale_ups(), a.metrics.scale_downs()),
                   (b.metrics.scale_ups(), b.metrics.scale_downs()),
                   "{tag}: replication actions diverged");

        // Differential arm: `--admission predictive` with the predictor
        // pinned COLD (warmup = usize::MAX, so no station ever probes
        // it) must fall back to the snapshot formula on every decision
        // — same outcome stream, slots, dispatch, routing, and
        // control-plane actions as the snapshot arm, bit for bit.
        let cold = run_cluster(
            &mk_cfg(AdmissionConfig {
                mode: AdmissionMode::Predictive,
                predictor_warmup: usize::MAX,
                ..Default::default()
            }),
            &load,
        )
        .unwrap();
        assert_cluster_conserved(&cold, &format!("{tag} cold-predictive"));
        assert_eq!(a.metrics.outcomes(), cold.metrics.outcomes(),
                   "{tag}: cold predictive arm diverged from snapshot");
        assert_eq!(a.slots, cold.slots, "{tag}: cold arm slots diverged");
        assert_eq!(a.attempts, cold.attempts);
        assert_eq!(a.leftover, cold.leftover,
                   "{tag}: cold arm leftover diverged");
        assert_eq!(dispatched(&a), dispatched(&cold),
                   "{tag}: cold arm per-node dispatch diverged");
        assert_eq!(a.frontend.decisions, cold.frontend.decisions,
                   "{tag}: cold arm routing decisions diverged");
        assert_eq!(a.frontend.misroutes, cold.frontend.misroutes,
                   "{tag}: cold arm misroutes diverged");
        assert_eq!(a.frontend.cache, cold.frontend.cache,
                   "{tag}: cold arm cache stats diverged");
        assert_eq!(a.metrics.migrations(), cold.metrics.migrations(),
                   "{tag}: cold arm migrations diverged");
        assert_eq!((a.metrics.scale_ups(), a.metrics.scale_downs()),
                   (cold.metrics.scale_ups(), cold.metrics.scale_downs()),
                   "{tag}: cold arm replication actions diverged");
        // The two arms differ ONLY in the counters: the snapshot arm
        // never priced headroom; the cold arm priced every engine-gate
        // decision and fell back on every single one.
        assert_eq!(a.metrics.headroom_decisions(), 0,
                   "{tag}: snapshot arm counted headroom decisions");
        assert!(cold.metrics.headroom_decisions() > 0,
                "{tag}: cold predictive arm never hit the gate");
        assert_eq!(cold.metrics.headroom_fallbacks(),
                   cold.metrics.headroom_decisions(),
                   "{tag}: a pinned-cold predictor must always fall back");
    }
}

/// Predictive SLO-aware routing (the warm arm): predictions flow
/// engine → gauge lanes → gossip → router, and the whole run stays
/// bit-deterministic per seed — the headroom counters included. Routing
/// headroom decisions are counted once per routed arrival, exactly the
/// front end's decision count.
#[test]
fn warm_predictive_slo_routing_is_deterministic_and_counted() {
    let cfg = ClusterConfig::builder()
        .nodes(trio())
        .policy(RoutePolicy::SloAware)
        .serve(
            ServeConfig::builder()
                .clock(ClockKind::Virtual)
                .scheduler(SchedulerSpec::Fixed { batch: 4, m_c: 2 })
                .queue_capacity(1024)
                .admission(Some(AdmissionConfig {
                    mode: AdmissionMode::Predictive,
                    ..Default::default()
                }))
                .build()
                .unwrap(),
        )
        .frontend(FrontEndConfig {
            router_shards: 2,
            gossip_ms: 5.0,
            cache: None,
        })
        .build()
        .unwrap();
    let load = LoadGenConfig::builder()
        .rps(150.0)
        .seconds(6.0)
        .seed(4243)
        .slo_scale(3.0)
        .build()
        .unwrap();
    let a = run_cluster(&cfg, &load).unwrap();
    let b = run_cluster(&cfg, &load).unwrap();
    assert_cluster_conserved(&a, "warm predictive");
    assert!(a.metrics.completed() > 0);

    // Every routed arrival was priced as one headroom decision.
    assert_eq!(a.frontend.headroom_decisions, a.frontend.decisions,
               "routing headroom decisions != front-end decisions");
    assert!(a.frontend.headroom_fallbacks <= a.frontend.headroom_decisions);
    // The gate priced its own decisions on top of the router's.
    assert!(a.metrics.headroom_decisions() >= a.frontend.headroom_decisions);
    assert!(a.metrics.headroom_fallbacks() <= a.metrics.headroom_decisions());

    // Bit-determinism of the warm predictive arm, counters included.
    assert_eq!(a.metrics.outcomes(), b.metrics.outcomes(),
               "warm predictive outcome streams diverged");
    assert_eq!(a.slots, b.slots);
    assert_eq!(a.attempts, b.attempts);
    assert_eq!(a.leftover, b.leftover);
    assert_eq!(a.frontend.decisions, b.frontend.decisions);
    assert_eq!((a.frontend.headroom_decisions, a.frontend.headroom_fallbacks),
               (b.frontend.headroom_decisions, b.frontend.headroom_fallbacks),
               "headroom counters diverged across identical runs");
    assert_eq!((a.metrics.headroom_decisions(), a.metrics.headroom_fallbacks()),
               (b.metrics.headroom_decisions(), b.metrics.headroom_fallbacks()));
}
