//! Integration tests for the heterogeneous edge-cluster tier: SLO-aware
//! routing against the heterogeneity-blind baseline, with a mid-run node
//! drain/rejoin and cluster-wide request conservation.

use bcedge::cluster::{ClusterConfig, ClusterReport, DrainScenario, NodeSpec,
                      RoutePolicy, run_cluster};
use bcedge::metrics::ShedReason;
use bcedge::platform::PlatformSpec;
use bcedge::serve::{ClockKind, LoadGenConfig, SchedulerSpec, ServeConfig};
use std::collections::HashSet;

/// Tentpole acceptance: on a heterogeneous 3-node cluster (Xavier NX +
/// TX2 + Nano, increasingly distant links) at the cluster's feasibility
/// limit, SLO-aware routing yields a strictly lower accepted-violation
/// rate than round-robin — while cluster-wide conservation (outcomes +
/// sheds + leftover == attempts, outcome ids unique across nodes) holds
/// through a mid-run drain/rejoin of the primary node.
///
/// Why the separation is structural, not tuned: the Table-V platform
/// scales make the Nano ~12.5× and the TX2 ~4.4× slower per batch than
/// the NX. Even at 3× the paper SLOs (`slo_scale`), no model's batch
/// fits any deadline on the Nano, and only the lightest models fit on
/// the TX2 — so round-robin sends a third of the traffic somewhere it
/// can only complete late (every Nano outcome violates by construction),
/// while the SLO-aware policy prices RTT + queue backlog + batch latency
/// per node, routes around the infeasible hardware, spills light models
/// to the TX2 when the NX queue builds, and sheds the hopeless remainder
/// at the edge with the typed `no-feasible-node` reason instead of
/// letting it violate. Node admission is OFF in both runs so routing is
/// the only protection being measured.
#[test]
fn slo_routing_beats_round_robin_on_heterogeneous_cluster() {
    let run = |policy: RoutePolicy| -> ClusterReport {
        let cfg = ClusterConfig {
            nodes: vec![
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
                NodeSpec::new(PlatformSpec::jetson_tx2(), 2, 6.0),
                NodeSpec::new(PlatformSpec::jetson_nano(), 1, 12.0),
            ],
            policy,
            serve: ServeConfig {
                clock: ClockKind::Wall,
                scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 2 },
                admission: None,
                queue_capacity: 1024,
                ..Default::default()
            },
            // Mid-run lifecycle: the PRIMARY node leaves at 0.6 s (its
            // backlog flushes through the drain protocol; the router
            // stops dispatching immediately) and rejoins at 1.2 s with a
            // fresh request-id window. Same scenario in both runs.
            drain: Some(DrainScenario {
                node: 0,
                at_ms: 600.0,
                rejoin_at_ms: 1_200.0,
            }),
            frontend: Default::default(),
        };
        let load = LoadGenConfig {
            rps: 180.0,
            seconds: 2.0,
            seed: 20_24,
            slo_scale: 3.0,
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();

        // Cluster-wide conservation through the drain/rejoin: every
        // attempt is accounted exactly once...
        assert_eq!(report.metrics.outcomes().len() as u64
                       + report.metrics.shed_total()
                       + report.leftover as u64,
                   report.attempts,
                   "requests lost or double-counted ({})", policy.name());
        // ...attempts split exactly into edge sheds + node dispatches...
        let dispatched: u64 =
            report.per_node.iter().map(|n| n.dispatched).sum();
        assert_eq!(dispatched + report.router_sheds(), report.attempts);
        // ...and no request was served twice, across nodes OR across the
        // drained node's two incarnations (disjoint id windows).
        let mut seen = HashSet::new();
        for o in report.metrics.outcomes() {
            assert!(seen.insert(o.id),
                    "request {} served twice ({})", o.id, policy.name());
        }
        // The lifecycle really ran: one drain, one rejoin, and the
        // primary node served two segments.
        assert_eq!(report.drains, 1, "{}: node never drained", policy.name());
        assert_eq!(report.rejoins, 1, "{}: node never rejoined",
                   policy.name());
        assert_eq!(report.per_node[0].segments, 2,
                   "{}: rejoined node did not serve a second segment",
                   policy.name());
        assert!(report.metrics.completed() > 0);
        report
    };

    let rr = run(RoutePolicy::RoundRobin);
    let slo = run(RoutePolicy::SloAware);

    // Round-robin genuinely drowns the slow nodes: a third of the load
    // lands on hardware that can only complete late (loose bound so CI
    // scheduler jitter cannot flake it; arrival pacing targets absolute
    // timestamps, so a slow submitter only makes the load burstier —
    // never lighter).
    assert!(rr.per_node[2].dispatched > 0,
            "round-robin never used the Nano — scenario is broken");
    assert!(rr.metrics.violation_rate() > 0.15,
            "round-robin not suffering on heterogeneous hardware: {:.3}",
            rr.metrics.violation_rate());
    // The SLO-aware router knows the Nano can never make a deadline:
    // nothing is dispatched there, and the hopeless remainder is shed at
    // the edge with the typed reason instead of violating.
    assert_eq!(slo.per_node[2].dispatched, 0,
               "slo-aware routed to a structurally infeasible node");
    assert!(slo.router_sheds() > 0,
            "slo-aware never shed at the edge under overload");
    // `no-feasible-node` is recorded ONLY at the router: its count is
    // exactly the attempts that never reached a node's ingress.
    let slo_dispatched: u64 =
        slo.per_node.iter().map(|n| n.dispatched).sum();
    assert_eq!(slo.metrics.shed_by_reason(ShedReason::NoFeasibleNode),
               slo.attempts - slo_dispatched);
    // The headline: strictly lower accepted-violation rate.
    assert!(slo.metrics.violation_rate() < rr.metrics.violation_rate(),
            "slo-aware routing did not help: {:.3} vs round-robin {:.3}",
            slo.metrics.violation_rate(),
            rr.metrics.violation_rate());
}
