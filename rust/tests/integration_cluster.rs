//! Integration tests for the heterogeneous edge-cluster tier: SLO-aware
//! routing against the heterogeneity-blind baseline, with a mid-run node
//! drain/rejoin and cluster-wide request conservation.

use bcedge::cluster::{CacheConfig, ClusterConfig, ClusterReport,
                      DrainScenario, FrontEndConfig, NodeSpec, RoutePolicy,
                      run_cluster};
use bcedge::metrics::ShedReason;
use bcedge::platform::PlatformSpec;
use bcedge::predictor::AdmissionMode;
use bcedge::serve::{AdmissionConfig, ClockKind, LoadGenConfig,
                    SchedulerSpec, ServeConfig};
use bcedge::workload::SessionSpec;
use std::collections::HashSet;

/// Tentpole acceptance: on a heterogeneous 3-node cluster (Xavier NX +
/// TX2 + Nano, increasingly distant links) at the cluster's feasibility
/// limit, SLO-aware routing yields a strictly lower accepted-violation
/// rate than round-robin — while cluster-wide conservation (outcomes +
/// sheds + leftover == attempts, outcome ids unique across nodes) holds
/// through a mid-run drain/rejoin of the primary node.
///
/// Why the separation is structural, not tuned: the Table-V platform
/// scales make the Nano ~12.5× and the TX2 ~4.4× slower per batch than
/// the NX. Even at 3× the paper SLOs (`slo_scale`), no model's batch
/// fits any deadline on the Nano, and only the lightest models fit on
/// the TX2 — so round-robin sends a third of the traffic somewhere it
/// can only complete late (every Nano outcome violates by construction),
/// while the SLO-aware policy prices RTT + queue backlog + batch latency
/// per node, routes around the infeasible hardware, spills light models
/// to the TX2 when the NX queue builds, and sheds the hopeless remainder
/// at the edge with the typed `no-feasible-node` reason instead of
/// letting it violate. Node admission is OFF in both runs so routing is
/// the only protection being measured.
#[test]
fn slo_routing_beats_round_robin_on_heterogeneous_cluster() {
    let run = |policy: RoutePolicy| -> ClusterReport {
        let cfg = ClusterConfig {
            nodes: vec![
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
                NodeSpec::new(PlatformSpec::jetson_tx2(), 2, 6.0),
                NodeSpec::new(PlatformSpec::jetson_nano(), 1, 12.0),
            ],
            policy,
            serve: ServeConfig {
                clock: ClockKind::Wall,
                scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 2 },
                admission: None,
                queue_capacity: 1024,
                ..Default::default()
            },
            // Mid-run lifecycle: the PRIMARY node leaves at 0.6 s (its
            // backlog flushes through the drain protocol; the router
            // stops dispatching immediately) and rejoins at 1.2 s with a
            // fresh request-id window. Same scenario in both runs.
            drain: Some(DrainScenario {
                node: 0,
                at_ms: 600.0,
                rejoin_at_ms: 1_200.0,
            }),
            frontend: Default::default(),
        };
        let load = LoadGenConfig {
            rps: 180.0,
            seconds: 2.0,
            seed: 20_24,
            slo_scale: 3.0,
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();

        // Cluster-wide conservation through the drain/rejoin: every
        // attempt is accounted exactly once...
        assert_eq!(report.metrics.outcomes().len() as u64
                       + report.metrics.shed_total()
                       + report.leftover as u64,
                   report.attempts,
                   "requests lost or double-counted ({})", policy.name());
        // ...attempts split exactly into edge sheds + node dispatches...
        let dispatched: u64 =
            report.per_node.iter().map(|n| n.dispatched).sum();
        assert_eq!(dispatched + report.router_sheds(), report.attempts);
        // ...and no request was served twice, across nodes OR across the
        // drained node's two incarnations (disjoint id windows).
        let mut seen = HashSet::new();
        for o in report.metrics.outcomes() {
            assert!(seen.insert(o.id),
                    "request {} served twice ({})", o.id, policy.name());
        }
        // The lifecycle really ran: one drain, one rejoin, and the
        // primary node served two segments.
        assert_eq!(report.drains, 1, "{}: node never drained", policy.name());
        assert_eq!(report.rejoins, 1, "{}: node never rejoined",
                   policy.name());
        assert_eq!(report.per_node[0].segments, 2,
                   "{}: rejoined node did not serve a second segment",
                   policy.name());
        assert!(report.metrics.completed() > 0);
        report
    };

    let rr = run(RoutePolicy::RoundRobin);
    let slo = run(RoutePolicy::SloAware);

    // Round-robin genuinely drowns the slow nodes: a third of the load
    // lands on hardware that can only complete late (loose bound so CI
    // scheduler jitter cannot flake it; arrival pacing targets absolute
    // timestamps, so a slow submitter only makes the load burstier —
    // never lighter).
    assert!(rr.per_node[2].dispatched > 0,
            "round-robin never used the Nano — scenario is broken");
    assert!(rr.metrics.violation_rate() > 0.15,
            "round-robin not suffering on heterogeneous hardware: {:.3}",
            rr.metrics.violation_rate());
    // The SLO-aware router knows the Nano can never make a deadline:
    // nothing is dispatched there, and the hopeless remainder is shed at
    // the edge with the typed reason instead of violating.
    assert_eq!(slo.per_node[2].dispatched, 0,
               "slo-aware routed to a structurally infeasible node");
    assert!(slo.router_sheds() > 0,
            "slo-aware never shed at the edge under overload");
    // `no-feasible-node` is recorded ONLY at the router: its count is
    // exactly the attempts that never reached a node's ingress.
    let slo_dispatched: u64 =
        slo.per_node.iter().map(|n| n.dispatched).sum();
    assert_eq!(slo.metrics.shed_by_reason(ShedReason::NoFeasibleNode),
               slo.attempts - slo_dispatched);
    // The headline: strictly lower accepted-violation rate.
    assert!(slo.metrics.violation_rate() < rr.metrics.violation_rate(),
            "slo-aware routing did not help: {:.3} vs round-robin {:.3}",
            slo.metrics.violation_rate(),
            rr.metrics.violation_rate());
}

/// The Table-V trio behind increasingly distant links.
fn trio() -> Vec<NodeSpec> {
    vec![
        NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
        NodeSpec::new(PlatformSpec::jetson_tx2(), 2, 6.0),
        NodeSpec::new(PlatformSpec::jetson_nano(), 1, 12.0),
    ]
}

fn assert_cluster_conserved(report: &ClusterReport, label: &str) {
    assert_eq!(report.metrics.outcomes().len() as u64
                   + report.metrics.shed_total()
                   + report.cache_served()
                   + report.leftover as u64,
               report.attempts,
               "{label}: requests lost or double-counted");
    let dispatched: u64 = report.per_node.iter().map(|n| n.dispatched).sum();
    assert_eq!(dispatched + report.router_sheds() + report.cache_served(),
               report.attempts, "{label}: dispatch split broken");
    let mut seen = HashSet::new();
    for o in report.metrics.outcomes() {
        assert!(seen.insert(o.id), "{label}: request {} served twice", o.id);
    }
}

/// Session-tier conservation: attempts grow with spawned decode steps,
/// and the dispatch split gains the session-abort disposition (heads
/// aborted at admission, steps orphaned by a drain — neither reaches a
/// node's ingress).
fn assert_llm_conserved(report: &ClusterReport, label: &str) {
    assert_eq!(report.metrics.outcomes().len() as u64
                   + report.metrics.shed_total()
                   + report.leftover as u64,
               report.attempts,
               "{label}: session rounds lost or double-counted");
    let dispatched: u64 = report.per_node.iter().map(|n| n.dispatched).sum();
    assert_eq!(dispatched + report.router_sheds()
                   + report.metrics.shed_by_reason(ShedReason::SessionAbort),
               report.attempts, "{label}: session dispatch split broken");
    let mut seen = HashSet::new();
    for o in report.metrics.outcomes() {
        assert!(seen.insert(o.id), "{label}: round {} served twice", o.id);
    }
    // Dual-SLO misses are bounded by the rounds that could miss them.
    assert!(report.metrics.ttft_misses() <= report.metrics.sessions_started(),
            "{label}: more TTFT misses than sessions");
    assert!(report.metrics.tpot_misses() <= report.frontend.session_steps,
            "{label}: more TPOT misses than decode steps");
}

/// Fabric acceptance (differential): the SAME scenario — nodes, policy,
/// scheduler, seed — run once on each clock arm. Both arms conserve
/// every request, and the virtual fabric's violation rate lands within
/// tolerance of the live wall run's: the event-heap simulation is a
/// faithful stand-in for the threaded stack, not a different system that
/// happens to share flags. (Tolerance is loose because the wall arm
/// genuinely schedules threads — CI jitter shifts batch boundaries — but
/// both arms simulate the same Table-V latencies, so the rates cannot
/// drift structurally.)
#[test]
fn virtual_fabric_tracks_wall_arm_within_tolerance() {
    let run = |clock: ClockKind| -> ClusterReport {
        let serve = ServeConfig::builder()
            .clock(clock)
            .scheduler(SchedulerSpec::Fixed { batch: 4, m_c: 2 })
            .admission(None)
            .queue_capacity(4096)
            .build()
            .unwrap();
        let cfg = ClusterConfig::builder()
            .nodes(trio())
            .policy(RoutePolicy::SloAware)
            .serve(serve)
            .build()
            .unwrap();
        let load = LoadGenConfig::builder()
            .rps(150.0)
            .seconds(2.0)
            .seed(1234)
            .slo_scale(3.0)
            .build()
            .unwrap();
        run_cluster(&cfg, &load).unwrap()
    };
    let wall = run(ClockKind::Wall);
    let virt = run(ClockKind::Virtual);
    assert_cluster_conserved(&wall, "wall");
    assert_cluster_conserved(&virt, "virtual");
    assert!(wall.metrics.completed() > 0 && virt.metrics.completed() > 0);
    // Same offered load reaches both arms.
    assert_eq!(wall.attempts, virt.attempts,
               "arms disagreed on the arrival trace");
    let (vw, vv) =
        (wall.metrics.violation_rate(), virt.metrics.violation_rate());
    assert!((vw - vv).abs() < 0.2,
            "violation rates diverged across clock arms: wall {vw:.3} \
             vs virtual {vv:.3}");
}

/// Fabric acceptance (tentpole): the FULL dynamic stack — migration +
/// replication epochs, a mid-run drain/rejoin, sharded routing from the
/// gossiped view, and the result cache — runs bit-identically across two
/// virtual runs for every (seed, shard count) tried. Before the fabric,
/// the virtual arm silently pinned shards static and skipped the
/// rebalancer; this pins that the carve-out is gone.
#[test]
fn full_dynamic_stack_is_bit_identical_per_seed_and_shards() {
    for (seed, shards) in [(7u64, 1usize), (7, 3), (41, 2)] {
        let mk_cfg = |admission: AdmissionConfig| {
            ClusterConfig::builder()
                .nodes(trio())
                .policy(RoutePolicy::PowerOfTwoChoices)
                .serve(
                    ServeConfig::builder()
                        .clock(ClockKind::Virtual)
                        .scheduler(SchedulerSpec::Fixed { batch: 4, m_c: 2 })
                        .queue_capacity(1024)
                        .admission(Some(admission))
                        .build()
                        .unwrap(),
                )
                .drain(Some(DrainScenario {
                    node: 0,
                    at_ms: 3_000.0,
                    rejoin_at_ms: 6_000.0,
                }))
                .frontend(FrontEndConfig {
                    router_shards: shards,
                    gossip_ms: 5.0,
                    cache: Some(CacheConfig { ttl_ms: 500.0, capacity: 4096 }),
                    ..Default::default()
                })
                .build()
                .unwrap()
        };
        let cfg = mk_cfg(AdmissionConfig::default());
        let load = LoadGenConfig::builder()
            .rps(200.0)
            .seconds(10.0)
            .seed(seed)
            .slo_scale(3.0)
            .repeat_fraction(0.5)
            .build()
            .unwrap();
        let tag = format!("seed {seed} / {shards} shard(s)");
        let a = run_cluster(&cfg, &load).unwrap();
        let b = run_cluster(&cfg, &load).unwrap();
        assert_cluster_conserved(&a, &tag);

        // Every dynamic subsystem genuinely ran.
        assert_eq!(a.drains, 1, "{tag}: node never drained");
        assert_eq!(a.rejoins, 1, "{tag}: node never rejoined");
        assert!(a.metrics.rebalance_epochs() > 0,
                "{tag}: rebalance controller never ticked");
        assert!(a.cache_served() > 0, "{tag}: cache never served");
        assert_eq!(a.frontend.shards, shards);
        assert!(a.frontend.decisions > 0);

        // Bit-identical across runs: outcome stream, scheduling slots,
        // routing, control-plane actions, and cache dispositions.
        assert_eq!(a.metrics.outcomes(), b.metrics.outcomes(),
                   "{tag}: outcome streams diverged");
        assert_eq!(a.slots, b.slots, "{tag}: slot counts diverged");
        assert_eq!(a.attempts, b.attempts);
        assert_eq!(a.leftover, b.leftover, "{tag}: leftover diverged");
        let dispatched = |r: &ClusterReport| -> Vec<u64> {
            r.per_node.iter().map(|n| n.dispatched).collect()
        };
        assert_eq!(dispatched(&a), dispatched(&b),
                   "{tag}: per-node dispatch diverged");
        assert_eq!(a.frontend.decisions, b.frontend.decisions,
                   "{tag}: routing decisions diverged");
        assert_eq!(a.frontend.misroutes, b.frontend.misroutes,
                   "{tag}: misroutes diverged");
        assert_eq!(a.frontend.cache, b.frontend.cache,
                   "{tag}: cache stats diverged");
        assert_eq!(a.metrics.migrations(), b.metrics.migrations(),
                   "{tag}: migrations diverged");
        assert_eq!((a.metrics.scale_ups(), a.metrics.scale_downs()),
                   (b.metrics.scale_ups(), b.metrics.scale_downs()),
                   "{tag}: replication actions diverged");

        // Differential arm: `--admission predictive` with the predictor
        // pinned COLD (warmup = usize::MAX, so no station ever probes
        // it) must fall back to the snapshot formula on every decision
        // — same outcome stream, slots, dispatch, routing, and
        // control-plane actions as the snapshot arm, bit for bit.
        let cold = run_cluster(
            &mk_cfg(AdmissionConfig {
                mode: AdmissionMode::Predictive,
                predictor_warmup: usize::MAX,
                ..Default::default()
            }),
            &load,
        )
        .unwrap();
        assert_cluster_conserved(&cold, &format!("{tag} cold-predictive"));
        assert_eq!(a.metrics.outcomes(), cold.metrics.outcomes(),
                   "{tag}: cold predictive arm diverged from snapshot");
        assert_eq!(a.slots, cold.slots, "{tag}: cold arm slots diverged");
        assert_eq!(a.attempts, cold.attempts);
        assert_eq!(a.leftover, cold.leftover,
                   "{tag}: cold arm leftover diverged");
        assert_eq!(dispatched(&a), dispatched(&cold),
                   "{tag}: cold arm per-node dispatch diverged");
        assert_eq!(a.frontend.decisions, cold.frontend.decisions,
                   "{tag}: cold arm routing decisions diverged");
        assert_eq!(a.frontend.misroutes, cold.frontend.misroutes,
                   "{tag}: cold arm misroutes diverged");
        assert_eq!(a.frontend.cache, cold.frontend.cache,
                   "{tag}: cold arm cache stats diverged");
        assert_eq!(a.metrics.migrations(), cold.metrics.migrations(),
                   "{tag}: cold arm migrations diverged");
        assert_eq!((a.metrics.scale_ups(), a.metrics.scale_downs()),
                   (cold.metrics.scale_ups(), cold.metrics.scale_downs()),
                   "{tag}: cold arm replication actions diverged");
        // The two arms differ ONLY in the counters: the snapshot arm
        // never priced headroom; the cold arm priced every engine-gate
        // decision and fell back on every single one.
        assert_eq!(a.metrics.headroom_decisions(), 0,
                   "{tag}: snapshot arm counted headroom decisions");
        assert!(cold.metrics.headroom_decisions() > 0,
                "{tag}: cold predictive arm never hit the gate");
        assert_eq!(cold.metrics.headroom_fallbacks(),
                   cold.metrics.headroom_decisions(),
                   "{tag}: a pinned-cold predictor must always fall back");

        // Fourth arm: the LLM session workload on the same (seed,
        // shards) grid — cache off (session rounds are stateful and
        // never dedupe), finite links so the contention trackers are
        // genuinely inside the replay loop. The whole session tier
        // (head admission gate, step spawning, link charging, dual-SLO
        // counters) must replay bit-identically.
        let mut llm_cfg = mk_cfg(AdmissionConfig::default());
        llm_cfg.frontend.cache = None;
        for node in &mut llm_cfg.nodes {
            node.net = node.net.with_bandwidth(8.0);
        }
        let llm_load = LoadGenConfig {
            repeat_fraction: 0.0,
            session: Some(SessionSpec {
                decode_steps: 3,
                ttft_slo_scale: 2.0,
                tpot_ms: 120.0,
            }),
            ..load
        };
        let la = run_cluster(&llm_cfg, &llm_load).unwrap();
        let lb = run_cluster(&llm_cfg, &llm_load).unwrap();
        assert_llm_conserved(&la, &format!("{tag} llm"));
        assert!(la.frontend.session_steps > 0,
                "{tag}: llm arm never spawned a decode step");
        assert!(la.attempts > la.metrics.sessions_started(),
                "{tag}: attempts did not grow with spawned steps");
        assert_eq!(la.metrics.outcomes(), lb.metrics.outcomes(),
                   "{tag}: llm outcome streams diverged");
        assert_eq!(la.slots, lb.slots, "{tag}: llm slots diverged");
        assert_eq!(la.attempts, lb.attempts,
                   "{tag}: llm attempts diverged");
        assert_eq!(dispatched(&la), dispatched(&lb),
                   "{tag}: llm per-node dispatch diverged");
        assert_eq!(
            (la.metrics.sessions_started(), la.frontend.session_steps,
             la.frontend.session_aborts),
            (lb.metrics.sessions_started(), lb.frontend.session_steps,
             lb.frontend.session_aborts),
            "{tag}: session counters diverged");
        assert_eq!(
            (la.metrics.ttft_misses(), la.metrics.tpot_misses()),
            (lb.metrics.ttft_misses(), lb.metrics.tpot_misses()),
            "{tag}: dual-SLO counters diverged");
    }
}

/// Predictive SLO-aware routing (the warm arm): predictions flow
/// engine → gauge lanes → gossip → router, and the whole run stays
/// bit-deterministic per seed — the headroom counters included. Routing
/// headroom decisions are counted once per routed arrival, exactly the
/// front end's decision count.
#[test]
fn warm_predictive_slo_routing_is_deterministic_and_counted() {
    let cfg = ClusterConfig::builder()
        .nodes(trio())
        .policy(RoutePolicy::SloAware)
        .serve(
            ServeConfig::builder()
                .clock(ClockKind::Virtual)
                .scheduler(SchedulerSpec::Fixed { batch: 4, m_c: 2 })
                .queue_capacity(1024)
                .admission(Some(AdmissionConfig {
                    mode: AdmissionMode::Predictive,
                    ..Default::default()
                }))
                .build()
                .unwrap(),
        )
        .frontend(FrontEndConfig {
            router_shards: 2,
            gossip_ms: 5.0,
            cache: None,
            ..Default::default()
        })
        .build()
        .unwrap();
    let load = LoadGenConfig::builder()
        .rps(150.0)
        .seconds(6.0)
        .seed(4243)
        .slo_scale(3.0)
        .build()
        .unwrap();
    let a = run_cluster(&cfg, &load).unwrap();
    let b = run_cluster(&cfg, &load).unwrap();
    assert_cluster_conserved(&a, "warm predictive");
    assert!(a.metrics.completed() > 0);

    // Every routed arrival was priced as one headroom decision.
    assert_eq!(a.frontend.headroom_decisions, a.frontend.decisions,
               "routing headroom decisions != front-end decisions");
    assert!(a.frontend.headroom_fallbacks <= a.frontend.headroom_decisions);
    // The gate priced its own decisions on top of the router's.
    assert!(a.metrics.headroom_decisions() >= a.frontend.headroom_decisions);
    assert!(a.metrics.headroom_fallbacks() <= a.metrics.headroom_decisions());

    // Bit-determinism of the warm predictive arm, counters included.
    assert_eq!(a.metrics.outcomes(), b.metrics.outcomes(),
               "warm predictive outcome streams diverged");
    assert_eq!(a.slots, b.slots);
    assert_eq!(a.attempts, b.attempts);
    assert_eq!(a.leftover, b.leftover);
    assert_eq!(a.frontend.decisions, b.frontend.decisions);
    assert_eq!((a.frontend.headroom_decisions, a.frontend.headroom_fallbacks),
               (b.frontend.headroom_decisions, b.frontend.headroom_fallbacks),
               "headroom counters diverged across identical runs");
    assert_eq!((a.metrics.headroom_decisions(), a.metrics.headroom_fallbacks()),
               (b.metrics.headroom_decisions(), b.metrics.headroom_fallbacks()));
}

/// Sessions survive (and are correctly accounted through) a mid-run
/// drain/rejoin: decode steps spawned while their node is out of the
/// cluster have nowhere to go — decode state is node-local — so those
/// sessions end as typed `session-abort` sheds, extended conservation
/// holds round-for-round, and the node serves a second segment after
/// rejoining.
#[test]
fn virtual_drain_rejoin_with_live_sessions_conserves() {
    let cfg = ClusterConfig::builder()
        .nodes(vec![
            NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
            NodeSpec::new(PlatformSpec::xavier_nx(), 2, 4.0),
        ])
        .policy(RoutePolicy::JoinShortestBacklog)
        .serve(
            ServeConfig::builder()
                .clock(ClockKind::Virtual)
                .scheduler(SchedulerSpec::Fixed { batch: 4, m_c: 2 })
                .admission(None)
                .queue_capacity(4096)
                .build()
                .unwrap(),
        )
        .drain(Some(DrainScenario {
            node: 0,
            at_ms: 3_000.0,
            rejoin_at_ms: 6_000.0,
        }))
        .build()
        .unwrap();
    let load = LoadGenConfig::builder()
        .rps(120.0)
        .seconds(10.0)
        .seed(31)
        .slo_scale(3.0)
        .session(Some(SessionSpec {
            decode_steps: 4,
            ttft_slo_scale: 2.0,
            tpot_ms: 150.0,
        }))
        .build()
        .unwrap();
    let report = run_cluster(&cfg, &load).unwrap();
    assert_llm_conserved(&report, "drain/rejoin llm");
    assert_eq!(report.drains, 1, "node never drained");
    assert_eq!(report.rejoins, 1, "node never rejoined");
    assert!(report.metrics.completed() > 0);
    assert!(report.metrics.sessions_started() > 0,
            "no sessions admitted");
    assert!(report.frontend.session_steps > 0,
            "no decode steps spawned");
    // Sessions in flight when the drain hit lost their node mid-decode:
    // at 120 rps with half the load on node 0, some step MUST have
    // spawned inside the 3s window.
    assert!(report.frontend.session_aborts > 0,
            "a 3s drain orphaned no in-flight session");
}

/// Acceptance experiment (ISSUE 10 tentpole): under heavy-payload
/// overload of the links — every node behind a 2 Mbit/s fair-share pipe
/// that the offered vision payloads oversubscribe ~2.5× — SLO-aware
/// routing that PRICES link contention (`--net-pricing contention`)
/// yields a strictly lower dual-SLO (TTFT + TPOT) miss rate than the
/// same router blinded to it (`--net-pricing static-rtt`). Both arms
/// charge the wire identically; only what routing SEES differs.
///
/// Why the separation is structural, not tuned: compute is deliberately
/// overprovisioned (two Xavier NX pools for a load one could serve), so
/// the compute-side gauges the static arm prices — backlog, service
/// estimates — look healthy all run. The link queue is the ONLY signal
/// of distress, and the static arm cannot see it: it keeps dispatching,
/// every transfer queues behind an unboundedly growing backlog of
/// in-flight payloads, and end-to-end latency blows past the TTFT
/// deadline for nearly every session admitted late in the run. The
/// contention arm prices `transfer × (in-flight + 1)` into the same
/// feasibility check, so once a link saturates it sheds heads at the
/// edge (`no-feasible-node`) instead of dispatching them to violate —
/// bounding the link queue near the deadline budget and keeping the
/// rounds it DOES serve inside their SLOs.
#[test]
fn contention_pricing_beats_static_rtt_on_dual_slo_misses() {
    let run = |contention_pricing: bool| -> ClusterReport {
        let mut nodes = vec![
            NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
            NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
        ];
        for node in &mut nodes {
            node.net = node.net.with_bandwidth(2.0);
        }
        let cfg = ClusterConfig::builder()
            .nodes(nodes)
            .policy(RoutePolicy::SloAware)
            .serve(
                ServeConfig::builder()
                    .clock(ClockKind::Virtual)
                    .scheduler(SchedulerSpec::Fixed { batch: 4, m_c: 2 })
                    .admission(None)
                    .queue_capacity(4096)
                    .build()
                    .unwrap(),
            )
            .frontend(FrontEndConfig {
                contention_pricing,
                ..Default::default()
            })
            .build()
            .unwrap();
        let load = LoadGenConfig::builder()
            .rps(120.0)
            .seconds(8.0)
            .seed(77)
            .slo_scale(3.0)
            .session(Some(SessionSpec {
                decode_steps: 2,
                ttft_slo_scale: 2.0,
                tpot_ms: 400.0,
            }))
            .build()
            .unwrap();
        let report = run_cluster(&cfg, &load).unwrap();
        assert_llm_conserved(
            &report,
            if contention_pricing { "contention" } else { "static-rtt" },
        );
        assert!(report.metrics.completed() > 0);
        report
    };
    let miss_rate = |r: &ClusterReport| -> f64 {
        (r.metrics.ttft_misses() + r.metrics.tpot_misses()) as f64
            / r.metrics.recorded_outcomes().max(1) as f64
    };

    let blind = run(false);
    let priced = run(true);

    // The scenario genuinely hurts the blind arm: the invisible link
    // queue pushes a large share of its rounds past their deadlines.
    assert!(miss_rate(&blind) > 0.3,
            "static-rtt arm not suffering — links not oversubscribed? \
             miss rate {:.3}", miss_rate(&blind));
    // The contention arm's defense is the edge: saturated links price
    // the head out, and the router sheds it with the typed reason
    // instead of dispatching it to violate.
    assert!(priced.router_sheds() > 0,
            "contention pricing never shed at the edge under overload");
    // The headline: strictly lower dual-SLO miss rate.
    assert!(miss_rate(&priced) < miss_rate(&blind),
            "contention pricing did not help: {:.3} vs static-rtt {:.3}",
            miss_rate(&priced), miss_rate(&blind));
}
