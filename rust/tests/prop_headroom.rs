//! Property layer pinning the headroom algebra behind predictive
//! admission & routing (`predictor::headroom`): monotonicity in queue
//! depth and RTT, antitonicity in slack, quantile ordering
//! (mean-infeasible ⇒ p95-infeasible), and the fallback contract —
//! the snapshot formula engages iff the predictor is cold/NaN,
//! including the all-NaN lane aggregation an ex-drainer pool publishes.
//!
//! Runs on the `util::prop` mini-framework; replay any failure with
//! `BCEDGE_PROP_SEED=<seed>`.

use bcedge::predictor::{batches_ahead, headroom_ms, predicted_batch_cost_ms,
                        AdmissionMode, AdmissionQuantile};
use bcedge::serve::ingress::MAX_POOL;
use bcedge::serve::{AdmissionConfig, SharedGauges};
use bcedge::util::prop::{check, check_with, Config};
use bcedge::util::rng::Pcg32;
use bcedge::workload::models::ModelId;

/// A plausible decision point: queue depth, batching quantum, per-batch
/// cost, network RTT, and remaining slack.
fn decision_point(rng: &mut Pcg32) -> (usize, usize, f64, f64, f64) {
    (
        rng.range(0, 129),            // queue_len
        rng.range(1, 17),             // ref_batch
        1.0 + rng.f64() * 99.0,       // batch_cost_ms
        rng.f64() * 40.0,             // rtt_ms
        rng.f64() * 500.0 - 50.0,     // slack_ms (sometimes DOA)
    )
}

/// An inflation estimate as a station might see it: mostly warm (finite
/// positive), sometimes the cold/failed shapes (NaN, zero, negative,
/// infinite) the fallback contract must catch.
fn inflation_like(rng: &mut Pcg32) -> f64 {
    match rng.below(8) {
        0 => f64::NAN,
        1 => 0.0,
        2 => -(0.1 + rng.f64()),
        3 => f64::INFINITY,
        _ => 0.1 + rng.f64() * 7.9,
    }
}

/// A dispersion factor: finite (possibly sub-1) or unknown (NaN).
fn p95_factor_like(rng: &mut Pcg32) -> f64 {
    if rng.below(4) == 0 { f64::NAN } else { 0.5 + rng.f64() * 2.5 }
}

#[test]
fn headroom_monotone_in_queue_and_rtt_antitone_in_slack() {
    check(&decision_point, |&(q, rb, cost, rtt, slack)| {
        let h = headroom_ms(q, rb, cost, rtt, slack);
        if !h.is_finite() {
            return Err(format!("headroom not finite: {h}"));
        }
        // More queue ahead never shrinks headroom (nondecreasing in
        // ref_batch quanta)...
        for dq in [1usize, rb, 3 * rb + 1] {
            let h2 = headroom_ms(q + dq, rb, cost, rtt, slack);
            if h2 < h {
                return Err(format!("queue {q}+{dq} shrank headroom: \
                                    {h2} < {h}"));
            }
        }
        // ...a full extra batch quantum strictly grows it...
        let h_batch = headroom_ms(q + rb, rb, cost, rtt, slack);
        if h_batch <= h {
            return Err(format!("+1 batch quantum did not grow headroom: \
                                {h_batch} <= {h}"));
        }
        // ...farther nodes are strictly worse...
        let h_rtt = headroom_ms(q, rb, cost, rtt + 5.0, slack);
        if h_rtt <= h {
            return Err(format!("+5 ms rtt did not grow headroom: \
                                {h_rtt} <= {h}"));
        }
        // ...and more slack strictly helps.
        let h_slack = headroom_ms(q, rb, cost, rtt, slack + 5.0);
        if h_slack >= h {
            return Err(format!("+5 ms slack did not shrink headroom: \
                                {h_slack} >= {h}"));
        }
        Ok(())
    });
}

#[test]
fn batches_ahead_matches_snapshot_quantization() {
    check(
        &|rng: &mut Pcg32| (rng.range(0, 4096), rng.range(0, 64)),
        |&(q, rb)| {
            let b = batches_ahead(q, rb);
            // Counting its own batch, never zero, and exactly the
            // snapshot formula's integer division (ref_batch 0 clamps).
            let want = q / rb.max(1) + 1;
            if b != want {
                return Err(format!("batches_ahead({q}, {rb}) = {b}, \
                                    want {want}"));
            }
            Ok(())
        },
    );
}

/// A configuration the mean quantile refuses is refused at p95 too: the
/// dispersion factor is clamped to ≥ 1 (NaN degrades to exactly 1), so
/// p95 pricing can only be stricter.
#[test]
fn mean_infeasible_implies_p95_infeasible() {
    check_with(
        Config { cases: 512, ..Default::default() },
        &|rng: &mut Pcg32| {
            let (q, rb, _, _, slack) = decision_point(rng);
            (q, rb, 1.0 + rng.f64() * 99.0, inflation_like(rng),
             p95_factor_like(rng), slack)
        },
        |&(q, rb, isolated, inflation, factor, slack)| {
            let cfg_mean = AdmissionConfig {
                mode: AdmissionMode::Predictive,
                ref_batch: rb,
                ..Default::default()
            };
            let cfg_p95 = AdmissionConfig {
                quantile: AdmissionQuantile::P95,
                ..cfg_mean
            };
            let (d_mean, fb_mean) = cfg_mean.decide_predictive(
                q, 30.0, isolated, slack, inflation, factor);
            let (d_p95, fb_p95) = cfg_p95.decide_predictive(
                q, 30.0, isolated, slack, inflation, factor);
            if fb_mean != fb_p95 {
                return Err(format!(
                    "quantiles disagree on fallback: {fb_mean} vs {fb_p95}"));
            }
            if d_mean.is_err() && d_p95.is_ok() {
                return Err("mean shed but p95 admitted".into());
            }
            // And at the cost level directly: both quantiles agree on
            // whether a prediction exists, and p95 never under-prices.
            let mean = predicted_batch_cost_ms(isolated, inflation, factor,
                                               AdmissionQuantile::Mean);
            let p95 = predicted_batch_cost_ms(isolated, inflation, factor,
                                              AdmissionQuantile::P95);
            match (mean, p95) {
                (Some(m), Some(p)) if p < m => {
                    Err(format!("p95 cost {p} below mean {m}"))
                }
                (Some(_), None) | (None, Some(_)) => {
                    Err("quantiles disagree on predictor coldness".into())
                }
                _ => Ok(()),
            }
        },
    );
}

/// The fallback contract, exactly: `decide_predictive` reports a
/// snapshot fallback iff the predictor's cost is `None` (cold/NaN/
/// non-positive inflation or a non-finite product) — and a dead-on-
/// arrival request sheds on both paths without counting as a fallback.
#[test]
fn fallback_engages_iff_predictor_is_cold() {
    check_with(
        Config { cases: 512, ..Default::default() },
        &|rng: &mut Pcg32| {
            let (q, rb, _, _, slack) = decision_point(rng);
            (q, rb, 1.0 + rng.f64() * 99.0, inflation_like(rng),
             p95_factor_like(rng), slack, 5.0 + rng.f64() * 95.0)
        },
        |&(q, rb, isolated, inflation, factor, slack, mean_batch)| {
            let cfg = AdmissionConfig {
                mode: AdmissionMode::Predictive,
                ref_batch: rb,
                ..Default::default()
            };
            let (d, fell_back) = cfg.decide_predictive(
                q, mean_batch, isolated, slack, inflation, factor);
            if slack <= 0.0 {
                return if d.is_err() && !fell_back {
                    Ok(())
                } else {
                    Err("DOA must shed without a fallback".into())
                };
            }
            let cold = predicted_batch_cost_ms(isolated, inflation, factor,
                                               cfg.quantile)
                .is_none();
            if fell_back != cold {
                return Err(format!(
                    "fallback {fell_back} but predictor cold = {cold}"));
            }
            if fell_back {
                // The fallback IS the snapshot oracle, decision-for-
                // decision.
                let snap = cfg.decide(q, mean_batch, isolated, slack);
                if d != snap {
                    return Err(format!(
                        "fallback decision {d:?} != snapshot {snap:?}"));
                }
            }
            Ok(())
        },
    );
}

/// Gauge-lane aggregation feeding the ingress fast path: the pool-wide
/// inflation is the finite-positive-lane mean (NaN iff every lane is
/// cold — e.g. a pool of ex-drainers publishing NaN), the p95 factor the
/// finite-lane max, and the aggregate triggers the fallback iff no lane
/// is live.
#[test]
fn nan_lanes_aggregate_to_the_fallback_trigger() {
    check_with(
        Config { cases: 512, ..Default::default() },
        &|rng: &mut Pcg32| {
            let lanes: Vec<(f64, f64)> = (0..MAX_POOL)
                .map(|_| (inflation_like(rng), p95_factor_like(rng)))
                .collect();
            lanes
        },
        |lanes: &Vec<(f64, f64)>| {
            let g = SharedGauges::new();
            let model = ModelId::Res;
            for (w, &(inflation, factor)) in lanes.iter().enumerate() {
                g.publish_prediction(model, w, inflation, factor);
            }
            let live: Vec<f64> = lanes
                .iter()
                .map(|&(i, _)| i)
                .filter(|i| i.is_finite() && *i > 0.0)
                .collect();
            let agg = g.predicted_inflation(model);
            if live.is_empty() {
                if !agg.is_nan() {
                    return Err(format!("all-cold lanes aggregated to {agg}"));
                }
                // ...and NaN is exactly what forces the snapshot fallback.
                if predicted_batch_cost_ms(20.0, agg, g.p95_factor(),
                                           AdmissionQuantile::P95)
                    .is_some()
                {
                    return Err("cold aggregate did not trigger fallback"
                        .into());
                }
            } else {
                let mean = live.iter().sum::<f64>() / live.len() as f64;
                if (agg - mean).abs() > 1e-9 * mean.abs().max(1.0) {
                    return Err(format!(
                        "aggregate {agg} != finite-lane mean {mean}"));
                }
                if predicted_batch_cost_ms(20.0, agg, g.p95_factor(),
                                           AdmissionQuantile::P95)
                    .is_none()
                {
                    return Err("live aggregate fell back anyway".into());
                }
            }
            let finite_factors: Vec<f64> = lanes
                .iter()
                .map(|&(_, f)| f)
                .filter(|f| f.is_finite())
                .collect();
            let p95 = g.p95_factor();
            match finite_factors
                .iter()
                .copied()
                .fold(None::<f64>, |m, f| Some(m.map_or(f, |m| m.max(f))))
            {
                None if p95.is_nan() => Ok(()),
                None => Err(format!("no finite factor lane but p95 {p95}")),
                Some(max) if (p95 - max).abs() < 1e-12 => Ok(()),
                Some(max) => {
                    Err(format!("p95 factor {p95} != lane max {max}"))
                }
            }
        },
    );
}
