//! Integration tests: end-to-end simulated serving runs across every
//! scheduler, conservation invariants, and failure injection.

use bcedge::coordinator::baselines::{self, DeepRtScheduler, FixedScheduler};
use bcedge::coordinator::harness::{Experiment, SchedKind};
use bcedge::coordinator::sac_sched;
use bcedge::coordinator::{Engine, EngineConfig, Scheduler};
use bcedge::platform::{PlatformSim, PlatformSpec};
use bcedge::rl::ActionSpace;
use bcedge::runtime::executor::SimDispatcher;
use bcedge::util::rng::Pcg32;
use bcedge::util::time::VirtualClock;
use bcedge::workload::models::ModelId;
use bcedge::workload::request::Request;
use bcedge::workload::{PoissonGenerator, Trace};

fn sim_engine(cfg: EngineConfig) -> Engine<SimDispatcher> {
    Engine::new(
        SimDispatcher::new(PlatformSim::xavier_nx(), VirtualClock::new()),
        cfg,
    )
}

/// Every scheduler serves a moderate workload without losing requests.
#[test]
fn all_schedulers_conserve_requests() {
    let space = ActionSpace::standard();
    let mut rng = Pcg32::seeded(77);
    let schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(sac_sched::sac(space.clone(), &mut rng)),
        Box::new(baselines::tac(space.clone(), &mut rng)),
        Box::new(baselines::ddqn(space.clone(), &mut rng)),
        Box::new(baselines::ppo(space.clone(), &mut rng)),
        Box::new(DeepRtScheduler::default()),
        Box::new(FixedScheduler { batch: 4, m_c: 2 }),
    ];
    for mut sched in schedulers {
        let mut engine = sim_engine(EngineConfig::default());
        let mut gen = PoissonGenerator::new(60.0, 5);
        let reqs = gen.generate_horizon(20_000.0);
        let n = reqs.len();
        engine.submit(reqs);
        engine.run(sched.as_mut(), 120_000.0);
        assert_eq!(
            engine.metrics.outcomes().len() + engine.total_queued(),
            n,
            "{} lost/duplicated requests",
            sched.name()
        );
        assert!(
            engine.metrics.completed() > n / 2,
            "{} served too little: {}/{n}",
            sched.name(),
            engine.metrics.completed()
        );
        // Latency accounting is self-consistent.
        for o in engine.metrics.outcomes() {
            assert!(o.e2e_ms > 0.0 && o.e2e_ms.is_finite());
            assert!(o.completed_ms >= o.arrival_ms);
            assert_eq!(o.violated, o.e2e_ms > o.slo_ms);
        }
    }
}

/// Burst injection: a large spike must not wedge or lose requests.
#[test]
fn burst_arrivals_drain() {
    let mut engine = sim_engine(EngineConfig::default());
    // 600 requests arriving in the same millisecond.
    let burst: Vec<Request> = (0..600)
        .map(|i| Request::new(i, ModelId::from_index(i as usize % 6), 10.0))
        .collect();
    engine.submit(burst);
    let mut sched = FixedScheduler { batch: 16, m_c: 2 };
    engine.run(&mut sched, 600_000.0);
    assert_eq!(engine.metrics.outcomes().len(), 600);
    assert_eq!(engine.total_queued(), 0);
}

/// OOM-prone actions must be survivable: requests re-queue and finish.
#[test]
fn oom_actions_recover() {
    let mut engine = sim_engine(EngineConfig {
        action_space: ActionSpace::sim_wide(),
        use_predictor: false,
        ..Default::default()
    });
    let reqs: Vec<Request> = (0..256)
        .map(|i| Request::new(i, ModelId::Yolo, i as f64))
        .collect();
    engine.submit(reqs);
    // A scheduler that always demands the OOM corner.
    struct Greedy;
    impl Scheduler for Greedy {
        fn decide(&mut self, _ctx: &bcedge::coordinator::SchedCtx,
                  _rng: &mut Pcg32) -> (usize, usize) {
            (128, 8)
        }
        fn name(&self) -> &'static str {
            "greedy-oom"
        }
    }
    let mut sched = Greedy;
    engine.run(&mut sched, 3_600_000.0);
    // Everything eventually completes (admissible prefix executes each
    // round even when the tail OOMs).
    assert_eq!(engine.metrics.outcomes().len(), 256);
    assert_eq!(engine.total_queued(), 0);
}

/// The experiment harness's scheduler matrix is reproducible seed-to-seed.
#[test]
fn harness_deterministic() {
    let run = || {
        let mut e = Experiment::new(SchedKind::DeepRt);
        e.horizon_s = 30.0;
        e.rps = 10.0;
        let m = e.run();
        (m.completed(), m.violation_rate())
    };
    assert_eq!(run(), run());
}

/// Trace record/replay: a saved trace replays to identical outcomes.
#[test]
fn trace_replay_identical() {
    let mut gen = PoissonGenerator::new(40.0, 99);
    let trace = Trace::from_requests(gen.generate_horizon(10_000.0));
    let path = std::env::temp_dir().join("bcedge_trace_test.json");
    trace.save(path.to_str().unwrap()).unwrap();
    let loaded = Trace::load(path.to_str().unwrap()).unwrap();
    assert_eq!(trace, loaded);

    let run = |reqs: Vec<Request>| {
        let mut engine = sim_engine(EngineConfig::default());
        engine.submit(reqs);
        let mut sched = FixedScheduler { batch: 8, m_c: 2 };
        engine.run(&mut sched, 60_000.0);
        engine.metrics.completed()
    };
    assert_eq!(run(trace.requests.clone()), run(loaded.requests));
}

/// Real-backend smoke (skips when artifacts are absent): the full
/// coordinator over PJRT serves a small workload.
#[test]
fn real_backend_smoke() {
    use bcedge::runtime::{PjrtRuntime, RealDispatcher};
    use std::sync::Arc;
    let Ok(rt) = PjrtRuntime::load("artifacts") else {
        eprintln!("skipping real_backend_smoke: artifacts/ not built");
        return;
    };
    let runtime = Arc::new(rt);
    let mut dispatcher = RealDispatcher::new(runtime.clone(), 2);
    dispatcher.warm_all(&[1, 2]).unwrap();
    dispatcher.reset_origin();
    let mut engine = Engine::new(
        dispatcher,
        EngineConfig {
            pad_to_artifacts: true,
            learn: false,
            use_predictor: false,
            ..Default::default()
        },
    );
    let mut gen = PoissonGenerator::new(40.0, 3);
    engine.submit(gen.generate_horizon(1_500.0));
    let mut sched = FixedScheduler { batch: 2, m_c: 2 };
    engine.run(&mut sched, 30_000.0);
    assert!(engine.metrics.completed() > 0, "nothing served over PJRT");
    for o in engine.metrics.outcomes() {
        assert!(o.e2e_ms > 0.0 && o.e2e_ms.is_finite());
    }
}

/// Tentpole acceptance: under a 70 %-hot-model skew at overload, dynamic
/// resharding strictly beats the static modulo shard map on violation
/// rate, with full request conservation in both runs.
///
/// Scenario: yolo (the heaviest model) carries 70 % of the traffic and
/// statically shares worker 0 with res and inc, which carry the rest.
/// Every co-resident model dispatches in the same concurrent group, so
/// the hot model's long, interference-inflated spans tax its siblings'
/// latency directly — res (58 ms SLO) and inc (66 ms) structurally blow
/// their deadlines behind yolo's ~90 ms rounds, while worker 1 idles.
/// The rebalance controller reads exactly that from the gauges and peels
/// the siblings off; after the handoff both sides meet their SLOs the
/// static map cannot.
#[test]
fn rebalance_beats_static_shard_under_hot_model() {
    use bcedge::serve::{ClockKind, RebalanceConfig, SchedulerSpec,
                        ServeConfig, Server};
    use bcedge::workload::models::{ModelSpec, N_MODELS};
    use std::time::Duration;

    // Self-calibrate the load to the simulator: one (batch 2, m_c 2)
    // round serves 4 yolo per isolated span, so load the hot model to
    // ~65 % of that bound (comfortable alone, drowning once co-residents
    // inflate and lengthen its rounds).
    let sim = PlatformSim::xavier_nx();
    let hot_span_s = sim.latency.isolated_ms(ModelId::Yolo, 2) / 1e3;
    let hot_capacity_rps = 4.0 / hot_span_s;
    let hot_rps = 0.65 * hot_capacity_rps;
    let cold_rps = hot_rps * 3.0 / 7.0; // 70/30 request split
    let mut mix = [0.0f64; N_MODELS];
    mix[ModelId::Yolo as usize] = hot_rps;
    mix[ModelId::Res as usize] = cold_rps / 2.0;
    mix[ModelId::Inc as usize] = cold_rps / 2.0;
    let total_rps = hot_rps + cold_rps;
    let horizon_ms = 2_500.0;

    let run = |rebalance: Option<RebalanceConfig>| {
        let cfg = ServeConfig {
            workers: 2,
            clock: ClockKind::Wall,
            scheduler: SchedulerSpec::Fixed { batch: 2, m_c: 2 },
            admission: None,
            queue_capacity: 2048,
            rebalance,
            ..Default::default()
        };
        let server = Server::start(&cfg, None);
        let mut gen = PoissonGenerator::new(total_rps, 4242).with_mix(mix);
        let trace = gen.generate_horizon(horizon_ms);
        let mut attempts = 0u64;
        for r in &trace {
            let wait_ms = r.arrival_ms - server.now_ms();
            if wait_ms > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait_ms / 1e3));
            }
            let _ = server.submit(r.model, r.slo_ms, r.transmission_ms);
            attempts += 1;
        }
        let report = server.shutdown();
        // Conservation: every attempt completed, shed, or leftover.
        assert_eq!(report.metrics.outcomes().len() as u64
                       + report.metrics.shed_total()
                       + report.leftover as u64,
                   attempts,
                   "requests lost or double-counted");
        report
    };

    let static_rep = run(None);
    let dynamic_rep = run(Some(RebalanceConfig {
        epoch_ms: 40,
        ratio: 1.3,
        min_gap_ms: 20.0,
        // Pin the MIGRATION mechanism: with replication enabled the
        // controller may widen the hot model's replica set instead,
        // and this test's `migrations() > 0` assertion is about the
        // sibling-isolation path specifically.
        max_replicas: 1,
        ..Default::default()
    }));

    // Both runs served real traffic.
    assert!(static_rep.metrics.completed() > 0);
    assert!(dynamic_rep.metrics.completed() > 0);
    for model in [ModelId::Yolo, ModelId::Res, ModelId::Inc] {
        assert!(dynamic_rep
                    .metrics
                    .outcomes()
                    .iter()
                    .any(|o| o.model == model),
                "{} starved after resharding", ModelSpec::get(model).name);
    }
    // The controller actually migrated ownership.
    assert!(dynamic_rep.metrics.migrations() > 0,
            "no migrations under a 70% hot-model skew");
    // The static map is genuinely hurting. The structural expectation is
    // ~0.7+ (cold models behind the hot model's rounds violate nearly
    // always); the bound is deliberately loose so scheduler jitter on a
    // loaded CI runner cannot flake it. Note the arrival pacing targets
    // ABSOLUTE timestamps: a slow submitter degrades to bursty load,
    // never lighter load, so slowness pushes this rate up, not down.
    assert!(static_rep.metrics.violation_rate() > 0.15,
            "static sharding not overloaded enough: viol {:.3}",
            static_rep.metrics.violation_rate());
    // The headline: dynamic resharding strictly lowers the violation
    // rate over accepted requests.
    assert!(dynamic_rep.metrics.violation_rate()
                < static_rep.metrics.violation_rate(),
            "resharding did not help: dynamic {:.3} vs static {:.3}",
            dynamic_rep.metrics.violation_rate(),
            static_rep.metrics.violation_rate());
    // And the cold models specifically are rescued: their combined
    // violation rate drops against the static map.
    let cold_viol = |m: &bcedge::metrics::Metrics| {
        let cold: Vec<_> = m
            .outcomes()
            .iter()
            .filter(|o| o.model != ModelId::Yolo)
            .collect();
        assert!(!cold.is_empty());
        cold.iter().filter(|o| o.violated).count() as f64 / cold.len() as f64
    };
    assert!(cold_viol(&dynamic_rep.metrics) < cold_viol(&static_rep.metrics),
            "cold models saw no benefit from isolation");
}

/// Tentpole acceptance (PR 4): when ONE model is offered ~2× a single
/// worker's sustainable rate, hot-model replication — several workers
/// concurrently draining the same model's intake — strictly beats the
/// one-owner-per-model map (`--no-replication`) on SLO violation rate,
/// with full request conservation while replica sets scale up AND back
/// down.
///
/// The one-owner baseline cannot be saved by migration: a lone hot model
/// is already isolated (plan_migration's no-op case), so its queue melts
/// on one worker while the other idles. With replication, the controller
/// widens the replica set as soon as the priced backlog outruns one
/// worker's drain rate, the ingress stripes deliveries across the set,
/// the loaded replica sheds surplus through the handoff slot — and after
/// the offered load stops, the subsided backlog collapses the set again.
#[test]
fn replication_beats_single_owner_under_hot_overload() {
    use bcedge::serve::{ClockKind, RebalanceConfig, SchedulerSpec,
                        ServeConfig, Server};
    use std::time::Duration;

    // Sustainable bound for a yolo-only load on one fixed (8, 2) worker:
    // two instance-batches of 8 per isolated span. Interference is
    // ignored, so this over-estimates one worker's capacity and the 2×
    // multiplier is conservative — the single owner is genuinely beyond
    // saturation, two replicas are near it.
    let sim = PlatformSim::xavier_nx();
    let batch_ms = sim.latency.isolated_ms(ModelId::Yolo, 8);
    let sustainable_rps = 2.0 * 8.0 / (batch_ms / 1e3);
    let rps = 2.0 * sustainable_rps;
    let horizon_ms = 1_500.0;

    let run = |max_replicas: usize| {
        let cfg = ServeConfig {
            workers: 2,
            clock: ClockKind::Wall,
            scheduler: SchedulerSpec::Fixed { batch: 8, m_c: 2 },
            admission: None,
            queue_capacity: 8192,
            rebalance: Some(RebalanceConfig {
                epoch_ms: 25,
                max_replicas,
                scale_up_backlog_ms: 60.0,
                scale_down_backlog_ms: 15.0,
                ..Default::default()
            }),
            ..Default::default()
        };
        let server = Server::start(&cfg, None);
        let mut gen = PoissonGenerator::new(rps, 2_024)
            .with_models(&[ModelId::Yolo]);
        let trace = gen.generate_horizon(horizon_ms);
        let mut attempts = 0u64;
        let mut accepted = std::collections::HashSet::new();
        for r in &trace {
            let wait_ms = r.arrival_ms - server.now_ms();
            if wait_ms > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait_ms / 1e3));
            }
            attempts += 1;
            if let Ok(id) = server.submit(r.model, r.slo_ms,
                                          r.transmission_ms) {
                assert!(accepted.insert(id), "ingress reused a request id");
            }
        }
        // Cool-down (replicated runs): the offered load stops, the
        // backlog drains, and the subsided replica set collapses. Poll
        // rather than sleep a fixed span — drain time depends on how
        // much interference inflated the spans — with a hard cap so a
        // wedged drain still fails loudly instead of hanging.
        if max_replicas > 1 && server.scale_ups() > 0 {
            let t0 = std::time::Instant::now();
            while server.scale_downs() == 0
                && t0.elapsed() < Duration::from_secs(20)
            {
                std::thread::sleep(Duration::from_millis(25));
            }
        }
        let report = server.shutdown();
        // Conservation through every scale-up/scale-down handoff: every
        // attempt is accounted exactly once...
        assert_eq!(report.metrics.outcomes().len() as u64
                       + report.metrics.shed_total()
                       + report.leftover as u64,
                   attempts,
                   "requests lost or double-counted (max_replicas \
                    {max_replicas})");
        // ...and no request was served twice by two replicas.
        let mut seen = std::collections::HashSet::new();
        for o in report.metrics.outcomes() {
            assert!(seen.insert(o.id),
                    "request {} served twice (max_replicas {max_replicas})",
                    o.id);
            assert!(accepted.contains(&o.id));
        }
        report
    };

    let single = run(1);
    let replicated = run(2);

    // Both runs served real traffic.
    assert!(single.metrics.completed() > 0);
    assert!(replicated.metrics.completed() > 0);
    // The overload is real: the sole owner drowns (loose bound so CI
    // scheduler jitter cannot flake it; pacing targets absolute
    // timestamps, so a slow submitter degrades to burstier — never
    // lighter — load).
    assert!(single.metrics.violation_rate() > 0.2,
            "single owner not overloaded enough: viol {:.3}",
            single.metrics.violation_rate());
    // One-owner runs must never replicate; replicated runs must.
    assert_eq!(single.metrics.scale_ups(), 0);
    assert!(replicated.metrics.scale_ups() > 0,
            "hot model never gained a replica at 2× overload");
    assert!(replicated.metrics.peak_replicas() > 1);
    // The set also collapsed once the backlog subsided.
    assert!(replicated.metrics.scale_downs() > 0,
            "replica set never collapsed after the load stopped");
    // The headline: replication strictly lowers the violation rate.
    assert!(replicated.metrics.violation_rate()
                < single.metrics.violation_rate(),
            "replication did not help: {:.3} vs single-owner {:.3}",
            replicated.metrics.violation_rate(),
            single.metrics.violation_rate());
}

/// Session-tier conservation on the virtual arm, and bit-identical
/// replay: every admitted head opens a session whose decode steps are
/// re-enqueued by the fabric itself, so the one-shot identity extends to
/// `outcomes + sheds + leftover == (sessions started + heads shed at
/// admission) + decode steps spawned` — attempts GROW with spawned
/// steps, and nothing is lost or double-counted across rounds.
#[test]
fn virtual_sessions_conserve_and_replay_bit_identically() {
    use bcedge::serve::{loadgen, ClockKind, SchedulerSpec, ServeConfig};
    use bcedge::workload::SessionSpec;

    let serve = ServeConfig::builder()
        .clock(ClockKind::Virtual)
        .scheduler(SchedulerSpec::Fixed { batch: 4, m_c: 2 })
        .admission(None)
        .queue_capacity(4096)
        .build()
        .unwrap();
    let load = bcedge::serve::LoadGenConfig::builder()
        .rps(80.0)
        .seconds(10.0)
        .seed(9)
        .slo_scale(3.0)
        .session(Some(SessionSpec {
            decode_steps: 3,
            ttft_slo_scale: 2.0,
            tpot_ms: 250.0,
        }))
        .build()
        .unwrap();
    let run = || loadgen::run(&serve, &load).unwrap();
    let a = run();

    let m = &a.metrics;
    assert!(m.sessions_started() > 0, "no sessions opened");
    assert!(m.session_steps_spawned() > 0, "no decode steps spawned");
    let heads = m.sessions_started()
        + m.shed_by_reason(bcedge::metrics::ShedReason::SessionAbort);
    assert_eq!(m.outcomes().len() as u64 + m.shed_total()
                   + a.leftover as u64,
               heads + m.session_steps_spawned(),
               "session conservation broken");
    // Step ids never collide with head ids or each other.
    let mut seen = std::collections::HashSet::new();
    for o in m.outcomes() {
        assert!(seen.insert(o.id), "outcome id {} duplicated", o.id);
    }
    // Dual-SLO counters stay within their denominators.
    assert!(m.ttft_misses() <= m.sessions_started());
    assert!(m.tpot_misses() <= m.session_steps_spawned());

    // Same seed, same fabric → bit-identical replay, spawns included.
    let b = run();
    assert_eq!(a.metrics.outcomes().len(), b.metrics.outcomes().len());
    for (x, y) in a.metrics.outcomes().iter().zip(b.metrics.outcomes()) {
        assert_eq!((x.id, x.violated), (y.id, y.violated));
        assert_eq!(x.completed_ms.to_bits(), y.completed_ms.to_bits());
    }
    assert_eq!(
        (a.metrics.sessions_started(), a.metrics.session_steps_spawned(),
         a.metrics.ttft_misses(), a.metrics.tpot_misses()),
        (b.metrics.sessions_started(), b.metrics.session_steps_spawned(),
         b.metrics.ttft_misses(), b.metrics.tpot_misses()),
    );
}

/// Feasible sessions are never starved past their TPOT cadence: at a
/// light offered load with a generous per-step budget, every decode
/// step completes inside its flat TPOT deadline — scheduling, batching,
/// and step re-enqueue overhead never push a feasible session's rounds
/// late, and no head is turned away at the cadence gate.
#[test]
fn feasible_sessions_never_miss_tpot() {
    use bcedge::serve::{loadgen, ClockKind, SchedulerSpec, ServeConfig};
    use bcedge::workload::SessionSpec;

    let serve = ServeConfig::builder()
        .clock(ClockKind::Virtual)
        .scheduler(SchedulerSpec::Fixed { batch: 4, m_c: 2 })
        .admission(None)
        .queue_capacity(4096)
        .build()
        .unwrap();
    let load = bcedge::serve::LoadGenConfig::builder()
        .rps(40.0)
        .seconds(10.0)
        .seed(5)
        .slo_scale(3.0)
        .session(Some(SessionSpec {
            decode_steps: 4,
            ttft_slo_scale: 2.0,
            tpot_ms: 800.0,
        }))
        .build()
        .unwrap();
    let report = loadgen::run(&serve, &load).unwrap();
    let m = &report.metrics;
    assert!(m.sessions_started() > 0);
    assert!(m.session_steps_spawned() > 0);
    assert_eq!(m.shed_by_reason(bcedge::metrics::ShedReason::SessionAbort),
               0,
               "cadence gate rejected a feasible head");
    assert_eq!(m.tpot_misses(), 0,
               "a feasible session was starved past its TPOT cadence");
}
