//! Property-based invariants on the coordinator (routing, batching,
//! queues, memory, action spaces) via the `util::prop` mini-framework —
//! the "L3 proptest on coordinator invariants" suite.

use bcedge::coordinator::batcher::Batcher;
use bcedge::coordinator::queue::{ModelQueue, Router};
use bcedge::platform::MemoryPool;
use bcedge::rl::ActionSpace;
use bcedge::util::prop::{check, check_with, Config};
use bcedge::util::rng::Pcg32;
use bcedge::workload::models::ModelId;
use bcedge::workload::request::Request;

fn random_requests(rng: &mut Pcg32, n: usize) -> Vec<Request> {
    (0..n as u64)
        .map(|id| {
            let model = ModelId::from_index(rng.range(0, 6));
            let mut r = Request::new(id, model, rng.f64() * 1000.0);
            r.slo_ms = 20.0 + rng.f64() * 150.0;
            r
        })
        .collect()
}

#[test]
fn router_conserves_requests() {
    check(
        &|rng: &mut Pcg32| {
            let n = rng.range(0, 200);
            random_requests(rng, n)
        },
        |reqs: &Vec<Request>| {
            let mut router = Router::new();
            for r in reqs {
                router.route(r.clone());
            }
            if router.total_queued() != reqs.len() {
                return Err(format!(
                    "queued {} != routed {}",
                    router.total_queued(),
                    reqs.len()
                ));
            }
            // Drain everything; ids must be a permutation of the input.
            let mut ids = Vec::new();
            for m in ModelId::all() {
                let q = router.queue_mut(m);
                while let Some(r) = q.pop() {
                    if r.model != m {
                        return Err(format!("{:?} in {:?} queue", r.model, m));
                    }
                    ids.push(r.id);
                }
            }
            ids.sort_unstable();
            let mut want: Vec<u64> = reqs.iter().map(|r| r.id).collect();
            want.sort_unstable();
            if ids != want {
                return Err("drain is not a permutation of input".into());
            }
            Ok(())
        },
    );
}

#[test]
fn queue_pops_in_slo_order() {
    check(
        &|rng: &mut Pcg32| {
            let n = rng.range(1, 100);
            random_requests(rng, n)
        },
        |reqs: &Vec<Request>| {
            let mut q = ModelQueue::new();
            for r in reqs {
                q.push(r.clone());
            }
            let mut last_slo = f64::NEG_INFINITY;
            while let Some(r) = q.pop() {
                if r.slo_ms < last_slo - 1e-9 {
                    return Err(format!(
                        "SLO order violated: {} after {last_slo}",
                        r.slo_ms
                    ));
                }
                last_slo = r.slo_ms;
            }
            Ok(())
        },
    );
}

#[test]
fn batcher_respects_bounds_and_conserves() {
    check(
        &|rng: &mut Pcg32| {
            let n = rng.range(0, 120);
            (
                random_requests(rng, n),
                rng.range(1, 130),  // b
                rng.range(1, 9),    // m_c
                rng.below(2) == 0,  // pad to artifacts?
            )
        },
        |(reqs, b, m_c, pad): &(Vec<Request>, usize, usize, bool)| {
            let mut q = ModelQueue::new();
            for r in reqs {
                q.push(r.clone());
            }
            let before = q.len();
            let batcher = if *pad {
                Batcher::for_artifacts()
            } else {
                Batcher::exact()
            };
            let batches = batcher.assemble(&mut q, *b, *m_c);
            if batches.len() > *m_c {
                return Err(format!("{} batches > m_c {}", batches.len(), m_c));
            }
            let mut total = 0;
            for batch in &batches {
                if batch.n_real() == 0 {
                    return Err("empty assembled batch".into());
                }
                if batch.n_real() > *b {
                    return Err(format!("batch {} > b {}", batch.n_real(), b));
                }
                if batch.padded < batch.n_real() {
                    return Err("padding below real count".into());
                }
                total += batch.n_real();
            }
            if total + q.len() != before {
                return Err(format!(
                    "conservation: {total} drained + {} left != {before}",
                    q.len()
                ));
            }
            Ok(())
        },
    );
}

/// Hot-path PR #1: the O(1) rolling queue aggregates must equal the
/// seed's O(n) scans after any interleaving of pushes and priority pops.
#[test]
fn queue_rolling_aggregates_match_naive_recomputation() {
    check(
        &|rng: &mut Pcg32| {
            let ops: Vec<(bool, f64, f64)> = (0..rng.range(1, 120))
                .map(|_| (rng.below(3) > 0, 20.0 + rng.f64() * 150.0,
                          rng.f64() * 1000.0))
                .collect();
            ops
        },
        |ops: &Vec<(bool, f64, f64)>| {
            let mut q = ModelQueue::new();
            for (i, (push, slo, arrival)) in ops.iter().enumerate() {
                if *push || q.is_empty() {
                    let mut r = Request::new(i as u64, ModelId::Res, *arrival);
                    r.slo_ms = *slo;
                    q.push(r);
                } else {
                    q.pop();
                }
                if q.min_deadline_ms() != q.min_deadline_naive_ms() {
                    return Err(format!(
                        "deadline: rolling {:?} != naive {:?} after op {i}",
                        q.min_deadline_ms(),
                        q.min_deadline_naive_ms()
                    ));
                }
                if q.oldest_arrival_ms() != q.oldest_arrival_naive_ms() {
                    return Err(format!(
                        "arrival: rolling {:?} != naive {:?} after op {i}",
                        q.oldest_arrival_ms(),
                        q.oldest_arrival_naive_ms()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Hot-path PR #1: under Poisson traffic with forced OOM/requeue churn,
/// the buffer-reusing engine must conserve every request and be
/// bit-deterministic — the same seed yields the exact same `SlotOutcome`
/// stream on a fresh engine. (Bit-equivalence against a faithful port of
/// the SEED implementation is proven in `coordinator::engine`'s
/// `seed_equivalence` module, which needs private access.)
#[test]
fn engine_conserves_and_repeats_under_requeue_churn() {
    use bcedge::coordinator::SlotOutcome;
    use bcedge::runtime::executor::SimDispatcher;
    use bcedge::util::time::VirtualClock;

    /// Deterministically alternates sane actions with the Fig. 1 OOM
    /// corner on the heavy model, so move-based requeue churns while the
    /// rest of the zoo serves normally. (Keyed to the model, not a global
    /// call counter: with a stable 6-model round-robin a global counter
    /// mod 3 would pin each model to a fixed residue and could starve
    /// yolo of the OOM action entirely.)
    struct Churn {
        yolo_calls: usize,
    }
    impl bcedge::coordinator::Scheduler for Churn {
        fn decide(&mut self, ctx: &bcedge::coordinator::SchedCtx,
                  _rng: &mut Pcg32) -> (usize, usize) {
            if ctx.model == ModelId::Yolo {
                self.yolo_calls += 1;
                if self.yolo_calls % 2 == 0 {
                    return (128, 8); // Fig. 1 OOM corner
                }
            }
            (8, 2)
        }
        fn name(&self) -> &'static str {
            "churn"
        }
    }

    check_with(
        Config { cases: 6, seed: 0xC0DE },
        &|rng: &mut Pcg32| (rng.next_u64(), 40.0 + rng.f64() * 200.0),
        |&(seed, rps): &(u64, f64)| {
            use bcedge::workload::PoissonGenerator;
            let run = || -> (Vec<SlotOutcome>, usize, usize) {
                let mut engine = bcedge::coordinator::Engine::new(
                    SimDispatcher::new(
                        bcedge::platform::PlatformSim::xavier_nx(),
                        VirtualClock::new(),
                    ),
                    bcedge::coordinator::EngineConfig {
                        use_predictor: false,
                        learn: false,
                        action_space: ActionSpace::sim_wide(),
                        ..Default::default()
                    },
                );
                // A deep yolo backlog at t=0 guarantees the (128, 8)
                // decisions below actually assemble OOM-sized groups,
                // independent of the random Poisson draw.
                let mut reqs: Vec<Request> = (0..400)
                    .map(|i| Request::new(i, ModelId::Yolo, 0.0))
                    .collect();
                let mut gen = PoissonGenerator::new(rps, seed);
                reqs.extend(gen.generate_horizon(8_000.0));
                let n = reqs.len();
                engine.submit(reqs);
                let mut sched = Churn { yolo_calls: 0 };
                let mut outcomes = Vec::new();
                for _ in 0..60 {
                    match engine.step(&mut sched) {
                        Some(round) => outcomes.extend(round),
                        None => break,
                    }
                }
                let accounted =
                    engine.metrics.outcomes().len() + engine.total_queued();
                (outcomes, accounted, n)
            };
            let (out_a, accounted_a, n_a) = run();
            let (out_b, accounted_b, n_b) = run();
            if accounted_a != n_a {
                return Err(format!(
                    "conservation broken: {accounted_a} accounted of {n_a}"
                ));
            }
            if n_a != n_b || accounted_a != accounted_b {
                return Err("runs generated different workloads".into());
            }
            if out_a != out_b {
                return Err("SlotOutcome stream not deterministic".into());
            }
            if !out_a.iter().any(|o| o.oom) {
                return Err("churn scheduler never hit the OOM path".into());
            }
            Ok(())
        },
    );
}

#[test]
fn memory_pool_never_over_commits() {
    check(
        &|rng: &mut Pcg32| {
            let ops: Vec<(bool, f64)> = (0..rng.range(1, 64))
                .map(|_| (rng.below(3) > 0, rng.f64() * 400.0))
                .collect();
            ops
        },
        |ops: &Vec<(bool, f64)>| {
            let mut pool = MemoryPool::new(1000.0);
            let mut tickets = Vec::new();
            for (reserve, mb) in ops {
                if *reserve {
                    if let Ok(t) = pool.reserve(*mb) {
                        tickets.push(t);
                    }
                } else if !tickets.is_empty() {
                    pool.release(tickets.remove(0));
                }
                if pool.used_mb() > pool.capacity_mb() + 1e-9 {
                    return Err(format!("over-commit: {}", pool.used_mb()));
                }
                if pool.used_mb() < -1e-9 {
                    return Err("negative usage".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn action_space_decode_encode_bijection() {
    check_with(
        Config { cases: 64, seed: 99 },
        &|rng: &mut Pcg32| {
            let nb = rng.range(1, 9);
            let nc = rng.range(1, 9);
            let batches: Vec<usize> = (0..nb).map(|i| 1 << i).collect();
            let concs: Vec<usize> = (1..=nc).collect();
            ActionSpace::new(batches, concs)
        },
        |space: &ActionSpace| {
            for idx in 0..space.len() {
                let (b, c) = space.decode(idx);
                if space.encode(b, c) != Some(idx) {
                    return Err(format!("{idx} -> ({b},{c}) not invertible"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sac_policy_remains_distribution_under_random_updates() {
    use bcedge::rl::env::{Agent, Transition};
    use bcedge::rl::sac::{DiscreteSac, SacConfig};
    check_with(
        Config { cases: 8, seed: 7 },
        &|rng: &mut Pcg32| rng.next_u64(),
        |&seed: &u64| {
            let mut rng = Pcg32::seeded(seed);
            let cfg = SacConfig { warmup: 16, batch_size: 16, ..Default::default() };
            let mut sac = DiscreteSac::new(6, 5, cfg, &mut rng);
            for _ in 0..80 {
                let s: Vec<f32> = (0..6).map(|_| rng.f32() * 2.0 - 1.0).collect();
                let s2: Vec<f32> = (0..6).map(|_| rng.f32() * 2.0 - 1.0).collect();
                let a = sac.act(&s, &mut rng, false);
                sac.observe(Transition {
                    state: s,
                    action: a,
                    reward: rng.f32() * 10.0 - 5.0,
                    next_state: s2,
                    done: rng.below(10) == 0,
                });
                sac.update(&mut rng);
            }
            let p = sac.policy_probs(&[0.0, 0.1, -0.2, 0.5, -1.0, 2.0]);
            let sum: f32 = p.iter().sum();
            if (sum - 1.0).abs() > 1e-4 {
                return Err(format!("policy not normalized: {sum}"));
            }
            if p.iter().any(|x| !x.is_finite() || *x < 0.0) {
                return Err(format!("invalid probs: {p:?}"));
            }
            if !sac.alpha().is_finite() || sac.alpha() <= 0.0 {
                return Err(format!("bad alpha {}", sac.alpha()));
            }
            Ok(())
        },
    );
}

#[test]
fn poisson_generator_monotone_arrivals_any_seed() {
    use bcedge::workload::PoissonGenerator;
    check_with(
        Config { cases: 32, seed: 3 },
        &|rng: &mut Pcg32| (rng.next_u64(), 1.0 + rng.f64() * 200.0),
        |&(seed, rps): &(u64, f64)| {
            let mut g = PoissonGenerator::new(rps, seed);
            let reqs = g.generate_horizon(2_000.0);
            let mut last = 0.0;
            for r in &reqs {
                if r.arrival_ms < last {
                    return Err("non-monotone arrivals".into());
                }
                last = r.arrival_ms;
                if r.slo_ms <= 0.0 {
                    return Err("non-positive SLO".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn virtual_clock_monotone_under_random_ops() {
    use bcedge::util::time::{Clock, VirtualClock};
    check(
        &|rng: &mut Pcg32| {
            (0..rng.range(1, 100))
                .map(|_| (rng.below(2) == 0, rng.f64() * 50.0))
                .collect::<Vec<_>>()
        },
        |ops: &Vec<(bool, f64)>| {
            let c = VirtualClock::new();
            let mut last = 0.0;
            for (advance_to, dt) in ops {
                if *advance_to {
                    c.advance_to_ms(last + dt);
                } else {
                    c.advance_ms(*dt);
                }
                let now = c.now_ms();
                if now + 1e-9 < last {
                    return Err(format!("time went backwards: {now} < {last}"));
                }
                last = now;
            }
            Ok(())
        },
    );
}
