//! # BCEdge — SLO-aware DNN inference serving with adaptive batching and
//! concurrent model instances on edge platforms.
//!
//! Reproduction of Zhang et al., *"BCEdge: SLO-Aware DNN Inference Services
//! with Adaptive Batching on Edge Platforms"* (2023). The crate is the
//! Layer-3 rust coordinator of a three-layer rust + JAX + Pallas stack:
//! JAX/Pallas author the model zoo at build time (`python/compile/`), AOT
//! lowering emits HLO-text artifacts, and this crate loads and executes them
//! through the PJRT C API (`runtime`) while owning the entire serving
//! control plane:
//!
//! * [`workload`] — request model, Poisson arrivals, the Table-IV zoo;
//! * [`coordinator`] — per-model SLO-priority queues, dynamic batching
//!   (paper Fig. 3), concurrent instances (Fig. 4), the scheduling slot of
//!   Eq. (1), the utility of Eq. (3), and the serving engine;
//! * [`rl`] — discrete Soft Actor-Critic scheduler (Eqs. 5–12) plus the
//!   PPO / DDQN / actor-critic / genetic-algorithm baselines of §V-B;
//! * [`predictor`] — the SLO-aware NN interference predictor (§IV-F) and
//!   its linear-regression baseline;
//! * [`platform`] — calibrated edge-platform model (Xavier NX / TX2 / Nano)
//!   with memory accounting and ground-truth interference;
//! * [`runtime`] — PJRT execution of the AOT artifacts + a virtual-time
//!   simulation backend behind one trait;
//! * [`serve`] — the concurrent serving runtime: bounded ingress with
//!   SLO-aware admission control, a multi-worker engine pool (virtual or
//!   wall clock) with dynamic resharding and hot-model replication,
//!   drain/shutdown, and the open/closed-loop load generator behind
//!   `bcedge bench-serve`;
//! * [`cluster`] — the heterogeneous edge-cluster tier: each node a full
//!   serving runtime on its own Table-V platform behind its own network
//!   link, with pluggable SLO-aware front-end routing, edge shedding,
//!   and a node drain/rejoin lifecycle behind `bcedge bench-cluster`;
//! * [`profiler`], [`metrics`] — §IV-E performance profiler and experiment
//!   instrumentation;
//! * [`telemetry`] — request-lifecycle span tracing (deterministic
//!   id-keyed sampling into bounded rings, JSON-lines out) and streaming
//!   telemetry (mergeable log-bucket latency/slack histograms, live
//!   counter snapshots) behind a zero-cost-when-off `TelemetryConfig`;
//! * [`sim`] — the virtual-time fabric: one discrete-event heap of
//!   timestamped logical-process events with deterministic tie-breaking
//!   `(time, pid, seq)`, driving the serve and cluster virtual arms so
//!   the full dynamic stack (migration, replication, gauge-driven
//!   routing, drain/rejoin) replays bit-identically from a seed;
//! * [`nn`], [`util`] — from-scratch substrates (tensor/MLP/Adam, RNG,
//!   JSON, CLI, stats, clocks, thread pool, property testing): the offline
//!   build environment provides no third-party crates beyond `xla`.
//!
//! See `rust/ARCHITECTURE.md` for the module ↔ paper-section map, the
//! serving request lifecycle, the pinned invariants (and the tests that
//! enforce them), and the consolidated CLI flags table.

pub mod util;
pub mod nn;
pub mod rl;
pub mod platform;
pub mod workload;
pub mod runtime;
pub mod coordinator;
pub mod predictor;
pub mod profiler;
pub mod metrics;
pub mod telemetry;
pub mod sim;
pub mod serve;
pub mod cluster;

/// Crate version (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
