//! Leveled stderr logger with a global verbosity switch.
//!
//! Deliberately tiny: the serving hot path must never allocate or lock for
//! a disabled log level, so the level check is a relaxed atomic load.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log levels, ascending verbosity.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

#[inline]
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Log a message at `level` (callers normally use the macros).
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{:5}] {args}", format!("{level:?}").to_lowercase());
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
