//! Descriptive statistics: streaming moments, percentiles, CDFs,
//! fixed-bucket histograms.
//!
//! Backs the metrics module and every figure bench: the paper reports
//! means, p-percentiles, violation-rate CDFs (Figs. 13/14) and timeline
//! aggregates (Figs. 8/9).

/// Streaming mean/variance/min/max (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentile over a sorted copy. `q` in [0, 1]; linear
/// interpolation between order statistics.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q));
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile on an already-sorted slice.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Empirical CDF evaluated at the sample points: returns
/// (sorted values, cumulative fraction ≤ value) — the form Figs. 13/14 plot.
pub fn ecdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    v.into_iter()
        .enumerate()
        .map(|(i, x)| (x, (i + 1) as f64 / n))
        .collect()
}

/// Fixed-width histogram over [lo, hi); out-of-range clamps to end buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_buckets: usize) -> Self {
        assert!(hi > lo && n_buckets > 0);
        Histogram { lo, hi, buckets: vec![0; n_buckets] }
    }

    pub fn add(&mut self, x: f64) {
        let n = self.buckets.len();
        let frac = (x - self.lo) / (self.hi - self.lo);
        let idx = ((frac * n as f64) as isize).clamp(0, n as isize - 1);
        self.buckets[idx as usize] += 1;
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Approximate quantile from bucket midpoints.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.total();
        if total == 0 {
            return f64::NAN;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut cum = 0;
        let width = (self.hi - self.lo) / self.buckets.len() as f64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target.max(1) {
                return self.lo + (i as f64 + 0.5) * width;
            }
        }
        self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn summary_merge_matches_bulk() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Summary::new();
        xs.iter().for_each(|&x| whole.add(x));
        let mut a = Summary::new();
        let mut b = Summary::new();
        xs[..37].iter().for_each(|&x| a.add(x));
        xs[37..].iter().for_each(|&x| b.add(x));
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.var() - whole.var()).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 1.0) - 100.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.5) - 50.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.99) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn ecdf_fractions() {
        let cdf = ecdf(&[3.0, 1.0, 2.0, 4.0]);
        assert_eq!(cdf[0], (1.0, 0.25));
        assert_eq!(cdf[3], (4.0, 1.0));
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..1000 {
            h.add((i % 100) as f64);
        }
        assert_eq!(h.total(), 1000);
        assert!((h.quantile(0.5) - 50.0).abs() < 2.0);
        h.add(-5.0);
        h.add(500.0); // clamps, no panic
        assert_eq!(h.total(), 1002);
    }
}
