//! Fixed-size worker thread pool with a shared injector queue.
//!
//! Replaces the async runtime we would otherwise pull in: the coordinator's
//! executor needs "run these batch jobs on up to N OS threads and tell me
//! when each finishes", which a condvar-backed queue does with less
//! machinery (and more deterministic behaviour) than an async reactor.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<(VecDeque<Job>, bool)>, // (jobs, shutting_down)
    available: Condvar,
    in_flight: AtomicUsize,
    idle: Condvar,
}

/// A fixed pool of worker threads.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n_threads` workers (≥ 1 enforced).
    pub fn new(n_threads: usize) -> Self {
        let n = n_threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new((VecDeque::new(), false)),
            available: Condvar::new(),
            in_flight: AtomicUsize::new(0),
            idle: Condvar::new(),
        });
        let workers = (0..n)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("bcedge-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        let mut q = self.shared.queue.lock().unwrap();
        assert!(!q.1, "execute on shut-down pool");
        q.0.push_back(Box::new(job));
        drop(q);
        self.shared.available.notify_one();
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while !q.0.is_empty() || self.shared.in_flight.load(Ordering::SeqCst) > 0
        {
            q = self.shared.idle.wait(q).unwrap();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.0.pop_front() {
                    break job;
                }
                if q.1 {
                    return;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        job();
        if shared.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Possibly the last job: wake any wait_idle() callers.
            let _guard = shared.queue.lock().unwrap();
            shared.idle.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.1 = true;
        }
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU32::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn jobs_run_concurrently() {
        use std::time::{Duration, Instant};
        let pool = ThreadPool::new(4);
        let t0 = Instant::now();
        for _ in 0..4 {
            pool.execute(|| std::thread::sleep(Duration::from_millis(50)));
        }
        pool.wait_idle();
        // 4 × 50 ms on 4 threads should take ~50 ms, not 200 ms.
        assert!(t0.elapsed() < Duration::from_millis(160));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU32::new(0));
        for _ in 0..10 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang; workers drain or exit cleanly
    }
}
