//! Tiny command-line parser (`--key value`, `--flag`, positional args)
//! for the `bcedge` launcher, examples, and benches. No clap offline.

use std::collections::BTreeMap;

/// Parsed arguments: options, flags, and positionals, in declaration order.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    /// `flag_names` lists valueless switches; anything else starting with
    /// `--` consumes the next token as its value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        flag_names: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{name} needs a value"))?;
                    out.opts.insert(name.to_string(), v);
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env(flag_names: &[&str]) -> Result<Args, String> {
        Self::parse(std::env::args().skip(1), flag_names)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: cannot parse {v:?}")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn options_flags_positionals() {
        let a = Args::parse(
            v(&["serve", "--rps", "30", "--verbose", "--out=x.csv", "tail"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["serve".to_string(), "tail".to_string()]);
        assert_eq!(a.get("rps"), Some("30"));
        assert_eq!(a.get("out"), Some("x.csv"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_access_and_defaults() {
        let a = Args::parse(v(&["--n", "5"]), &[]).unwrap();
        assert_eq!(a.get_parse("n", 0usize).unwrap(), 5);
        assert_eq!(a.get_parse("m", 7usize).unwrap(), 7);
        assert!(a.get_parse::<usize>("n", 0).is_ok());
        let b = Args::parse(v(&["--n", "xyz"]), &[]).unwrap();
        assert!(b.get_parse::<usize>("n", 0).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(v(&["--rps"]), &[]).is_err());
    }
}
