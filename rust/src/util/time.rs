//! Clock abstraction: real wall-clock vs virtual (simulated) time.
//!
//! The paper's long-horizon experiments (Figs. 8/9/14 run 3000 s of
//! traffic) are infeasible in wall-clock CI, so the serving engine is
//! generic over a [`Clock`]. The real backend uses [`RealClock`]; the
//! simulation backend drives a [`VirtualClock`] forward as events complete,
//! preserving every queueing/ordering interaction while running thousands
//! of times faster.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Monotonic time source, in milliseconds since an arbitrary origin.
pub trait Clock: Send + Sync {
    fn now_ms(&self) -> f64;
}

/// Wall-clock time.
pub struct RealClock {
    origin: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock { origin: Instant::now() }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now_ms(&self) -> f64 {
        self.origin.elapsed().as_secs_f64() * 1e3
    }
}

/// Shareable wall-clock time source: like [`RealClock`] but `Copy`, so
/// every worker thread in the serving runtime measures against the SAME
/// origin (per-worker origins would skew cross-worker latency
/// accounting). `Instant` is `Copy` and immutable — copying the value IS
/// sharing the origin, no `Arc` needed.
#[derive(Clone, Copy)]
pub struct WallClock {
    origin: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { origin: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now_ms(&self) -> f64 {
        self.origin.elapsed().as_secs_f64() * 1e3
    }
}

/// Simulated time, advanced explicitly by the discrete-event loop.
/// Stored as microseconds in an atomic so readers never lock.
#[derive(Clone)]
pub struct VirtualClock {
    micros: Arc<AtomicU64>,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { micros: Arc::new(AtomicU64::new(0)) }
    }

    /// Advance to an absolute time (monotonicity enforced). Rounds UP to
    /// the next microsecond: callers advance to an event's timestamp and
    /// then expect `now_ms() >= t_ms` — flooring would leave the clock an
    /// epsilon short and spin event loops forever.
    pub fn advance_to_ms(&self, t_ms: f64) {
        let target = (t_ms * 1e3).ceil() as u64;
        let mut cur = self.micros.load(Ordering::Relaxed);
        while cur < target {
            match self.micros.compare_exchange_weak(
                cur,
                target,
                Ordering::SeqCst,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Advance to an absolute time on the raw microsecond timeline
    /// (monotonicity enforced). The fabric ([`crate::sim`]) schedules in
    /// integer µs; driving the clock in the same unit avoids a
    /// µs→ms→µs float round-trip re-quantizing event times.
    pub fn advance_to_us(&self, target: u64) {
        let mut cur = self.micros.load(Ordering::Relaxed);
        while cur < target {
            match self.micros.compare_exchange_weak(
                cur,
                target,
                Ordering::SeqCst,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Raw microsecond reading — the exact integer the clock stores, for
    /// callers (the fabric) that schedule on the µs timeline.
    pub fn now_us(&self) -> u64 {
        self.micros.load(Ordering::SeqCst)
    }

    /// Advance by a delta.
    pub fn advance_ms(&self, dt_ms: f64) {
        assert!(dt_ms >= 0.0, "time cannot flow backwards");
        self.micros
            .fetch_add((dt_ms * 1e3) as u64, Ordering::SeqCst);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now_ms(&self) -> f64 {
        self.micros.load(Ordering::SeqCst) as f64 / 1e3
    }
}

/// A time source the discrete-event engine can *drive*: virtual time
/// jumps instantly (tests/benches, thousands× real time), wall time
/// actually elapses (the serving runtime's workers pace real execution).
/// The virtual arm delegates verbatim to [`VirtualClock`], so engines on
/// `ClockSource::Virtual` behave bit-identically to engines on a bare
/// `VirtualClock`.
#[derive(Clone)]
pub enum ClockSource {
    Virtual(VirtualClock),
    Wall(WallClock),
}

impl ClockSource {
    pub fn virtual_() -> Self {
        ClockSource::Virtual(VirtualClock::new())
    }

    pub fn wall() -> Self {
        ClockSource::Wall(WallClock::new())
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, ClockSource::Virtual(_))
    }

    /// Advance by `dt_ms`: a jump in virtual time, a real sleep in wall
    /// time (the span a dispatched group occupies the platform).
    pub fn advance_ms(&self, dt_ms: f64) {
        match self {
            ClockSource::Virtual(c) => c.advance_ms(dt_ms),
            ClockSource::Wall(_) => sleep_ms(dt_ms),
        }
    }

    /// Advance to an absolute time; past targets are a no-op in both arms.
    pub fn advance_to_ms(&self, t_ms: f64) {
        match self {
            ClockSource::Virtual(c) => c.advance_to_ms(t_ms),
            ClockSource::Wall(c) => {
                let now = c.now_ms();
                if t_ms > now {
                    sleep_ms(t_ms - now);
                }
            }
        }
    }
}

impl Clock for ClockSource {
    fn now_ms(&self) -> f64 {
        match self {
            ClockSource::Virtual(c) => c.now_ms(),
            ClockSource::Wall(c) => c.now_ms(),
        }
    }
}

fn sleep_ms(dt_ms: f64) {
    if dt_ms > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(dt_ms / 1e3));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_is_monotonic() {
        let c = RealClock::new();
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now_ms(), 0.0);
        c.advance_ms(12.5);
        assert!((c.now_ms() - 12.5).abs() < 1e-3);
        c.advance_to_ms(100.0);
        assert!((c.now_ms() - 100.0).abs() < 1e-3);
        // advance_to to the past is a no-op
        c.advance_to_ms(50.0);
        assert!((c.now_ms() - 100.0).abs() < 1e-3);
    }

    #[test]
    fn virtual_clock_shared_across_clones() {
        let c = VirtualClock::new();
        let c2 = c.clone();
        c.advance_ms(5.0);
        assert!((c2.now_ms() - 5.0).abs() < 1e-3);
    }

    #[test]
    fn wall_clock_shares_origin_across_clones() {
        let c = WallClock::new();
        let c2 = c.clone();
        let (a, b) = (c.now_ms(), c2.now_ms());
        // Same origin: readings are within scheduling noise of each other.
        assert!((a - b).abs() < 50.0, "origins diverged: {a} vs {b}");
    }

    #[test]
    fn clock_source_virtual_matches_bare_virtual() {
        let bare = VirtualClock::new();
        let src = ClockSource::Virtual(bare.clone());
        assert!(src.is_virtual());
        src.advance_ms(12.5);
        assert_eq!(src.now_ms(), bare.now_ms());
        src.advance_to_ms(100.0);
        assert_eq!(src.now_ms(), bare.now_ms());
        src.advance_to_ms(50.0); // past target: no-op
        assert_eq!(src.now_ms(), bare.now_ms());
    }

    #[test]
    fn clock_source_wall_advances_in_real_time() {
        let src = ClockSource::wall();
        assert!(!src.is_virtual());
        let t0 = src.now_ms();
        src.advance_ms(5.0);
        let t1 = src.now_ms();
        assert!(t1 - t0 >= 4.0, "wall advance slept too little: {}", t1 - t0);
        src.advance_to_ms(t1 - 100.0); // past target: returns immediately
        assert!(src.now_ms() - t1 < 50.0);
    }
}
