//! From-scratch substrates: RNG, JSON, CLI, statistics, clocks, thread
//! pool, property testing, bench timing.
//!
//! The offline build environment ships no general-purpose crates (no rand /
//! serde / tokio / clap / criterion / proptest), so BCEdge implements the
//! slices it needs. Each submodule is deliberately small, documented, and
//! unit-tested — they are part of the reproduction surface, not throwaway
//! glue.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod time;
