//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! A [`Gen`] produces random values from a [`Pcg32`]; [`check`] runs a
//! property over many generated cases and, on failure, reports the seed and
//! a debug dump of the offending input so the case can be replayed
//! deterministically. Used by the coordinator invariants suite
//! (`rust/tests/prop_*.rs`).

use super::rng::Pcg32;

/// A generator of random test inputs.
pub trait Gen {
    type Output;
    fn generate(&self, rng: &mut Pcg32) -> Self::Output;
}

impl<T, F: Fn(&mut Pcg32) -> T> Gen for F {
    type Output = T;
    fn generate(&self, rng: &mut Pcg32) -> T {
        self(rng)
    }
}

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Env var lets CI vary the seed; a fixed default keeps local runs
        // reproducible.
        let seed = std::env::var("BCEDGE_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xBCED_6E00);
        Config { cases: 256, seed }
    }
}

/// Run `prop` over `cfg.cases` generated inputs; panic with a replayable
/// report on the first failure (either a returned `Err` or a panic inside
/// the property).
pub fn check_with<G, F>(cfg: Config, gen: &G, prop: F)
where
    G: Gen,
    G::Output: std::fmt::Debug,
    F: Fn(&G::Output) -> Result<(), String> + std::panic::RefUnwindSafe,
    G::Output: std::panic::RefUnwindSafe,
{
    let mut rng = Pcg32::seeded(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen.generate(&mut rng);
        let outcome = std::panic::catch_unwind(|| prop(&input));
        let failed = match &outcome {
            Ok(Ok(())) => None,
            Ok(Err(msg)) => Some(msg.clone()),
            Err(_) => Some("property panicked".to_string()),
        };
        if let Some(msg) = failed {
            panic!(
                "property failed at case {case}/{} (seed {:#x}):\n  input: {:?}\n  reason: {msg}\n  replay: BCEDGE_PROP_SEED={}",
                cfg.cases, cfg.seed, input, cfg.seed
            );
        }
    }
}

/// `check` with the default configuration.
pub fn check<G, F>(gen: &G, prop: F)
where
    G: Gen,
    G::Output: std::fmt::Debug + std::panic::RefUnwindSafe,
    F: Fn(&G::Output) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    check_with(Config::default(), gen, prop)
}

// ---------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------

/// Vec of `len ∈ [0, max_len]` values from an element generator closure.
pub fn vec_of<T>(
    max_len: usize,
    elem: impl Fn(&mut Pcg32) -> T + Copy,
) -> impl Fn(&mut Pcg32) -> Vec<T> {
    move |rng: &mut Pcg32| {
        let len = rng.below(max_len as u32 + 1) as usize;
        (0..len).map(|_| elem(rng)).collect()
    }
}

/// Uniform f64 in [lo, hi).
pub fn f64_in(lo: f64, hi: f64) -> impl Fn(&mut Pcg32) -> f64 {
    move |rng: &mut Pcg32| lo + rng.f64() * (hi - lo)
}

/// Uniform usize in [lo, hi).
pub fn usize_in(lo: usize, hi: usize) -> impl Fn(&mut Pcg32) -> usize {
    move |rng: &mut Pcg32| rng.range(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(&vec_of(20, |r| r.f64()), |xs: &Vec<f64>| {
            if xs.iter().all(|x| (0.0..1.0).contains(x)) {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        check_with(
            Config { cases: 50, seed: 1 },
            &usize_in(0, 100),
            |&x: &usize| if x < 90 { Ok(()) } else { Err(format!("{x} too big")) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn panicking_property_is_caught() {
        check_with(
            Config { cases: 10, seed: 2 },
            &usize_in(0, 10),
            |&x: &usize| {
                assert!(x < 5, "boom");
                Ok(())
            },
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let mut rng = Pcg32::seeded(seed);
            let gen = vec_of(5, |r| r.below(100));
            (0..10).map(|_| gen(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }
}
