//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//!
//! Used for the AOT `artifacts/manifest.json`, metrics/CSV-adjacent
//! exports, and policy checkpoints. Covers the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, bools, null); no serde in the
//! offline crate set.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Object keys are sorted (BTreeMap) so output is
/// deterministic — experiment artifacts diff cleanly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access; `None` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

/// Parse error with byte offset for debuggability.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 char.
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Str("x".into())
        );
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"a\"b"},"t":true}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape_and_raw() {
        let v = parse("\"\\u00e9 caf\u{00e9}\"").unwrap();
        assert_eq!(v, Json::Str("\u{e9} caf\u{e9}".into()));
    }

    #[test]
    fn errors_have_positions() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("[1] junk").is_err());
    }

    #[test]
    fn builders() {
        let v = obj(vec![("k", arr([num(1.0), s("x")])), ("b", Json::Bool(false))]);
        assert_eq!(v.to_string(), r#"{"b":false,"k":[1,"x"]}"#);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(num(5.0).to_string(), "5");
        assert_eq!(num(5.25).to_string(), "5.25");
    }
}
