//! Deterministic pseudo-random numbers: PCG32 core plus the samplers the
//! serving stack needs (uniform, normal, exponential, Poisson, categorical).
//!
//! Everything in BCEdge that rolls dice — workload arrivals, NN init, SAC
//! exploration, the GA — takes an explicit `Pcg32` so experiments are
//! reproducible from a seed printed in the bench header.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0xda3e39cb94b95bdb).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent generator (for per-component streams).
    pub fn split(&mut self) -> Self {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        let stream = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Self::new(seed, stream)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// statelessness — sampling cost is irrelevant off the hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival times of a Poisson
    /// process — the paper's request model, §V-A).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Poisson count with mean `lambda` (Knuth for small, normal approx for
    /// large means).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = lambda + lambda.sqrt() * self.normal();
            x.max(0.0).round() as u64
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive mass");
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut rng = Pcg32::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg32::seeded(3);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).abs() < 600, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut rng = Pcg32::seeded(13);
        for lambda in [0.5, 5.0, 30.0, 120.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| rng.poisson(lambda)).sum::<u64>() as f64
                / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg32::seeded(17);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| rng.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg32::seeded(19);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[rng.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0], "{counts:?}");
        assert!((counts[2] as f64 / 30_000.0 - 0.7).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(23);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg32::seeded(29);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
