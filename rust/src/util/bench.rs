//! Micro-bench timing harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timed runs with mean / p50 / p99 reporting,
//! and a tiny CSV writer the figure benches share. Each bench binary under
//! `rust/benches/` is `harness = false` and drives this module directly.

use std::io::Write as _;
use std::time::Instant;

/// Result of a timed measurement.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub min_us: f64,
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn time_fn<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Timing {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / iters as f64;
    Timing {
        name: name.to_string(),
        iters,
        mean_us: mean,
        p50_us: super::stats::percentile_sorted(&samples, 0.5),
        p99_us: super::stats::percentile_sorted(&samples, 0.99),
        min_us: samples[0],
    }
}

impl Timing {
    /// Human-readable one-liner (the bench binaries print a table of these).
    pub fn row(&self) -> String {
        format!(
            "{:<40} {:>8} it  mean {:>10.2} µs  p50 {:>10.2} µs  p99 {:>10.2} µs",
            self.name, self.iters, self.mean_us, self.p50_us, self.p99_us
        )
    }
}

/// Minimal CSV writer: header once, then rows; creates parent dirs.
pub struct Csv {
    file: std::fs::File,
}

impl Csv {
    pub fn create(path: &str, header: &str) -> std::io::Result<Csv> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{header}")?;
        Ok(Csv { file })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        writeln!(self.file, "{}", fields.join(","))
    }

    pub fn rowf(&mut self, fields: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|x| format!("{x}")).collect();
        self.row(&strs)
    }
}

/// Format helper used by bench mains: section banner.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_something() {
        let t = time_fn("spin", 2, 20, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
        });
        assert!(t.mean_us > 0.0);
        assert!(t.p99_us >= t.p50_us);
        assert!(t.p50_us >= t.min_us);
        assert_eq!(t.iters, 20);
    }

    #[test]
    fn csv_writes_rows() {
        let path = std::env::temp_dir().join("bcedge_csv_test.csv");
        let path = path.to_str().unwrap();
        let mut csv = Csv::create(path, "a,b").unwrap();
        csv.row(&["1".into(), "2".into()]).unwrap();
        csv.rowf(&[3.5, 4.5]).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3.5,4.5\n");
    }
}
