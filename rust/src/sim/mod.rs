//! The virtual-time fabric: one discrete-event scheduler for the whole
//! distributed system.
//!
//! Before this module existed, every virtual-clock arm coordinated time
//! its own way — the bare engine self-advanced a `VirtualClock`, each
//! serve worker ran its trace shard to completion on a private clock,
//! and the virtual cluster arm priced routing against a leaky-bucket
//! backlog estimate because no live gauges existed at routing time. None
//! of the dynamic machinery (migration, replication, gauge-driven
//! routing) could run deterministically, because nothing interleaved the
//! components in a defined order.
//!
//! The fabric fixes that with the classic discrete-event simulation
//! contract:
//!
//! * **Logical processes.** Every active component — a worker, the
//!   rebalancer's epoch ticker, a gossip publisher, the node lifecycle,
//!   the arrival stream — is a logical process identified by a small
//!   integer `pid`.
//! * **One event heap.** All processes schedule timestamped events into
//!   a single [`EventHeap`]. Timestamps are integer **microseconds**
//!   (`ceil(ms × 1000)`, exactly the quantization
//!   [`VirtualClock::advance_to_ms`] applies), so heap order and clock
//!   readings can never disagree by a rounding epsilon.
//! * **Deterministic tie-breaking.** Events fire in `(time_us, pid,
//!   seq)` order — time first, then process id, then scheduling
//!   sequence. Two events at the same instant always fire in the same
//!   order on every run, which is what makes the full dynamic stack
//!   bit-reproducible from a seed.
//! * **The clock is a view.** A [`SimFabric`] owns a [`VirtualClock`]
//!   that is advanced to each popped event's timestamp. Components read
//!   it; only the fabric writes it. (Engine-local clocks still
//!   self-advance *within* one activation — the fabric decides *when*
//!   each activation happens, which preserves the bare engine's
//!   bit-exact behavior for a single worker.)
//!
//! The serve tier ([`crate::serve`]) and the cluster tier
//! ([`crate::cluster`]) both drive their virtual arms from this module;
//! the wall arms keep real threads and real clocks. See
//! `rust/ARCHITECTURE.md` § "Virtual-time fabric" for the
//! process-id map of each tier.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::time::{Clock, VirtualClock};

/// Convert a millisecond timestamp to the fabric's integer-microsecond
/// timeline. Rounds UP, exactly like [`VirtualClock::advance_to_ms`]:
/// after advancing to an event's time, `now_ms() >= t_ms` must hold or
/// event loops would spin on an epsilon forever.
#[inline]
pub fn us_of_ms(t_ms: f64) -> u64 {
    (t_ms * 1e3).ceil() as u64
}

/// One scheduled event: fire `event` for process `pid` at `time_us`.
/// Ordering ignores the payload entirely — `(time_us, pid, seq)` is the
/// whole contract, so payload types never need `Ord`.
struct Entry<E> {
    time_us: u64,
    pid: u32,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time_us == other.time_us
            && self.pid == other.pid
            && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse so the EARLIEST
        // (time, pid, seq) triple is popped first.
        (other.time_us, other.pid, other.seq)
            .cmp(&(self.time_us, self.pid, self.seq))
    }
}

/// A popped event, with its timestamp in both units.
pub struct Firing<E> {
    /// Fabric time of the event, integer microseconds.
    pub time_us: u64,
    /// The logical process the event belongs to.
    pub pid: u32,
    /// The event payload.
    pub event: E,
}

impl<E> Firing<E> {
    /// Event time in milliseconds (µs / 1000 — the same reading a
    /// [`VirtualClock`] advanced to this event would report).
    pub fn time_ms(&self) -> f64 {
        self.time_us as f64 / 1e3
    }
}

/// The single event heap at the heart of the fabric: a priority queue of
/// timestamped logical-process events with deterministic tie-breaking.
///
/// `E` is the (per-tier) event payload enum. The heap itself knows
/// nothing about workers or nodes — it only guarantees the ordering
/// contract: events fire in ascending `(time_us, pid, seq)` order, where
/// `seq` is the global scheduling sequence number (assigned at
/// `schedule_*` time), so insertion order breaks any remaining tie.
pub struct EventHeap<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

impl<E> EventHeap<E> {
    pub fn new() -> Self {
        EventHeap { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `event` for process `pid` at `t_ms` (quantized to the
    /// microsecond timeline via [`us_of_ms`]).
    pub fn schedule_ms(&mut self, t_ms: f64, pid: u32, event: E) {
        self.schedule_us(us_of_ms(t_ms), pid, event);
    }

    /// Schedule at an exact microsecond timestamp. Use this when the
    /// timestamp came from a clock reading ([`VirtualClock::now_us`]) —
    /// round-tripping through milliseconds could re-quantize it upward
    /// and skew the timeline by a microsecond per hop.
    pub fn schedule_us(&mut self, time_us: u64, pid: u32, event: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time_us, pid, seq, event });
    }

    /// Pop the next event in `(time_us, pid, seq)` order.
    pub fn pop(&mut self) -> Option<Firing<E>> {
        self.heap.pop().map(|e| Firing {
            time_us: e.time_us,
            pid: e.pid,
            event: e.event,
        })
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time_us(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.time_us)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E> Default for EventHeap<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// An [`EventHeap`] plus the fabric clock: a [`VirtualClock`] advanced
/// to each popped event's timestamp, making it a *view* of fabric
/// progress rather than a counter any component bumps on its own.
///
/// Drivers loop `while let Some(firing) = fabric.pop()` and dispatch on
/// the payload; everything that needs "now" (gauge publication stamps,
/// staleness measurements, lifecycle checks) reads `fabric.clock()`.
pub struct SimFabric<E> {
    heap: EventHeap<E>,
    clock: VirtualClock,
}

impl<E> SimFabric<E> {
    pub fn new() -> Self {
        SimFabric { heap: EventHeap::new(), clock: VirtualClock::new() }
    }

    /// The fabric clock. Read-only by convention: only [`SimFabric::pop`]
    /// advances it.
    pub fn clock(&self) -> &VirtualClock {
        &self.clock
    }

    /// Current fabric time, ms.
    pub fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }

    pub fn schedule_ms(&mut self, t_ms: f64, pid: u32, event: E) {
        self.heap.schedule_ms(t_ms, pid, event);
    }

    pub fn schedule_us(&mut self, time_us: u64, pid: u32, event: E) {
        self.heap.schedule_us(time_us, pid, event);
    }

    /// Pop the next event and advance the fabric clock to its timestamp.
    pub fn pop(&mut self) -> Option<Firing<E>> {
        let firing = self.heap.pop()?;
        self.clock.advance_to_us(firing.time_us);
        Some(firing)
    }

    pub fn peek_time_us(&self) -> Option<u64> {
        self.heap.peek_time_us()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E> Default for SimFabric<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut h = EventHeap::new();
        h.schedule_ms(5.0, 0, "late");
        h.schedule_ms(1.0, 0, "early");
        h.schedule_ms(3.0, 0, "mid");
        let order: Vec<&str> = std::iter::from_fn(|| h.pop())
            .map(|f| f.event)
            .collect();
        assert_eq!(order, ["early", "mid", "late"]);
    }

    #[test]
    fn equal_times_break_on_pid_then_seq() {
        let mut h = EventHeap::new();
        // Same timestamp, different pids, scheduled out of pid order.
        h.schedule_ms(2.0, 3, "w3");
        h.schedule_ms(2.0, 0, "deliver");
        h.schedule_ms(2.0, 1, "w1-a");
        h.schedule_ms(2.0, 1, "w1-b"); // same pid: seq breaks the tie
        let order: Vec<&str> = std::iter::from_fn(|| h.pop())
            .map(|f| f.event)
            .collect();
        assert_eq!(order, ["deliver", "w1-a", "w1-b", "w3"]);
    }

    #[test]
    fn microsecond_quantization_matches_virtual_clock() {
        // schedule_ms must quantize exactly like advance_to_ms, or an
        // engine advanced to an event's time could read an earlier µs
        // than the heap thinks the event fired at.
        let mut h = EventHeap::new();
        let t = 123.456_789; // not µs-aligned
        h.schedule_ms(t, 0, ());
        let fired = h.pop().unwrap();
        let clock = VirtualClock::new();
        clock.advance_to_ms(t);
        assert_eq!(fired.time_us, clock.now_us());
        assert!(fired.time_ms() >= t);
    }

    #[test]
    fn fabric_clock_tracks_popped_events() {
        let mut f = SimFabric::new();
        f.schedule_ms(10.0, 1, "a");
        f.schedule_ms(4.0, 2, "b");
        assert_eq!(f.now_ms(), 0.0);
        let b = f.pop().unwrap();
        assert_eq!(b.event, "b");
        assert_eq!(f.now_ms(), 4.0);
        let a = f.pop().unwrap();
        assert_eq!(a.event, "a");
        assert_eq!(f.now_ms(), 10.0);
        assert!(f.pop().is_none());
        // Draining never rewinds the view.
        assert_eq!(f.now_ms(), 10.0);
    }

    #[test]
    fn schedule_us_is_exact() {
        let mut h = EventHeap::new();
        h.schedule_us(1_000_001, 0, ());
        assert_eq!(h.peek_time_us(), Some(1_000_001));
        assert_eq!(h.pop().unwrap().time_us, 1_000_001);
        assert!(h.is_empty());
    }

    #[test]
    fn identical_schedules_replay_identically() {
        // Two heaps fed the same schedule pop the same sequence — the
        // determinism the cluster fabric's bit-identity tests lean on.
        let feed = |h: &mut EventHeap<u32>| {
            for i in 0..100u32 {
                h.schedule_ms(((i * 7) % 13) as f64, i % 5, i);
            }
        };
        let (mut a, mut b) = (EventHeap::new(), EventHeap::new());
        feed(&mut a);
        feed(&mut b);
        let drain = |h: &mut EventHeap<u32>| -> Vec<(u64, u32, u32)> {
            std::iter::from_fn(|| h.pop())
                .map(|f| (f.time_us, f.pid, f.event))
                .collect()
        };
        assert_eq!(drain(&mut a), drain(&mut b));
    }
}
