//! Experiment instrumentation: per-request outcomes, per-model
//! throughput/latency timelines (Figs. 8/9), SLO-violation accounting
//! (Figs. 14/15), utility tracking (Figs. 7/11), CSV export.

use crate::telemetry::LogHistogram;
use crate::util::stats::{percentile, Summary};
use crate::workload::models::{ModelId, N_MODELS};

/// Why the admission controller refused a request (serving runtime).
/// Typed so shed accounting is queryable per cause — a request shed at
/// ingress is NOT an SLO violation and must never be folded into one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum ShedReason {
    /// The model's bounded ingress queue was full (backpressure).
    QueueFull = 0,
    /// Queue depth × profiled batch latency already exceeds the request's
    /// remaining slack: its deadline is provably unmeetable.
    DeadlineUnmeetable = 1,
    /// The server is draining; intake is closed.
    Shutdown = 2,
    /// The cluster router found no node that could meet the request's
    /// deadline (estimated network RTT + queue backlog + batch latency
    /// exceeded the remaining slack on every candidate), so the request
    /// was shed at the edge before ever crossing a node boundary.
    NoFeasibleNode = 3,
    /// An autoregressive session was cut short: either its whole-session
    /// cadence was priced infeasible at admission (no node can sustain
    /// the per-step TPOT budget) or a decode step could not be
    /// re-enqueued (its pinned node left the cluster between steps). The
    /// shed counts the step that failed; unspawned later steps were
    /// never attempts.
    SessionAbort = 4,
}

/// Number of [`ShedReason`] variants (sizes the per-reason counters).
pub const N_SHED_REASONS: usize = 5;

impl ShedReason {
    pub fn all() -> [ShedReason; N_SHED_REASONS] {
        [
            ShedReason::QueueFull,
            ShedReason::DeadlineUnmeetable,
            ShedReason::Shutdown,
            ShedReason::NoFeasibleNode,
            ShedReason::SessionAbort,
        ]
    }

    pub fn label(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::DeadlineUnmeetable => "deadline-unmeetable",
            ShedReason::Shutdown => "shutdown",
            ShedReason::NoFeasibleNode => "no-feasible-node",
            ShedReason::SessionAbort => "session-abort",
        }
    }
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Terminal record for one request.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestOutcome {
    pub id: u64,
    pub model: ModelId,
    pub arrival_ms: f64,
    pub completed_ms: f64,
    /// End-to-end latency per Eq. (2): transmission + serialization +
    /// queueing + inference (+ result return).
    pub e2e_ms: f64,
    pub slo_ms: f64,
    /// SLO violated (late completion or drop).
    pub violated: bool,
    /// Dropped without execution (OOM / dead on arrival).
    pub dropped: bool,
}

/// Aggregated serving metrics for one run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    outcomes: Vec<RequestOutcome>,
    utility_samples: Vec<(f64, ModelId, f64)>,
    /// Requests refused by admission control, per model × reason.
    /// Separate from `outcomes`: sheds never execute, never violate, and
    /// are reported as their own rate.
    shed: [[u64; N_SHED_REASONS]; N_MODELS],
    /// Shard migrations performed by the serving runtime's rebalance
    /// controller (0 outside the live worker pool).
    migrations: u64,
    /// Rebalance-controller epochs observed (gauge reads, migrated or not).
    rebalance_epochs: u64,
    /// Worst cross-worker backlog spread seen by the controller, ms
    /// (max-backlog worker minus min-backlog worker).
    peak_imbalance_ms: f64,
    /// Hot-model replica scale-ups performed (a worker added to a
    /// model's replica set because its backlog outran one worker's
    /// drain rate).
    scale_ups: u64,
    /// Replica scale-downs performed (sets collapsing as backlog
    /// subsides).
    scale_downs: u64,
    /// Widest replica set any model reached (0 outside the live worker
    /// pool; 1 = replication never triggered).
    peak_replicas: u64,
    /// Admission/routing decisions priced under the predictive headroom
    /// mode (0 in snapshot mode). Conservation-neutral: every decision
    /// still lands in exactly one of outcomes/sheds/cache/leftover.
    headroom_decisions: u64,
    /// Among `headroom_decisions`, those where a cold/NaN predictor made
    /// the station fall back to the snapshot formula.
    headroom_fallbacks: u64,
    /// Autoregressive sessions admitted (one per accepted head request
    /// in an llm workload; 0 for one-shot workloads).
    sessions_started: u64,
    /// Decode steps the session manager re-enqueued after a completed
    /// step (the head itself is not counted — it arrives via the trace).
    /// Every spawned step is a fresh attempt, so conservation extends to
    /// `outcomes + sheds + cache_served + leftover == heads + spawned`.
    session_steps_spawned: u64,
    /// Session head requests that completed past their TTFT deadline
    /// (first-step completion vs the head SLO).
    ttft_misses: u64,
    /// Decode steps that completed past their per-step TPOT budget.
    tpot_misses: u64,
    /// Streaming counters maintained alongside `outcomes` so every rate
    /// the reports print is recomputable in O(1) without walking (or
    /// even keeping) the outcome vec. The vec itself survives as the
    /// exact-percentile / bit-identity test oracle.
    recorded: u64,
    dropped: u64,
    violated_total: u64,
    per_model_outcomes: [u64; N_MODELS],
    per_model_violated: [u64; N_MODELS],
    /// Log-bucket e2e latency histogram over completed requests
    /// (mergeable; constant memory; ≈26 % bucket width — see
    /// [`crate::telemetry::LogHistogram`]).
    latency_hist: LogHistogram,
    /// Log-bucket slack histogram (`slo − e2e` at completion, completed
    /// requests); violated requests clamp into bucket 0.
    slack_hist: LogHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record(&mut self, o: RequestOutcome) {
        self.recorded += 1;
        let m = o.model as usize;
        self.per_model_outcomes[m] += 1;
        if o.violated {
            self.violated_total += 1;
            self.per_model_violated[m] += 1;
        }
        if o.dropped {
            self.dropped += 1;
        } else {
            self.latency_hist.add(o.e2e_ms);
            self.slack_hist.add(o.slo_ms - o.e2e_ms);
        }
        self.outcomes.push(o);
    }

    /// Account one request shed by admission control.
    pub fn record_shed(&mut self, model: ModelId, reason: ShedReason) {
        self.record_shed_n(model, reason, 1);
    }

    /// Bulk shed accounting (folding ingress-side counters into a report).
    pub fn record_shed_n(&mut self, model: ModelId, reason: ShedReason,
                         n: u64) {
        self.shed[model as usize][reason as usize] += n;
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.iter().flatten().sum()
    }

    pub fn shed_for(&self, model: ModelId) -> u64 {
        self.shed[model as usize].iter().sum()
    }

    pub fn shed_by_reason(&self, reason: ShedReason) -> u64 {
        self.shed.iter().map(|per_model| per_model[reason as usize]).sum()
    }

    /// Total requests that reached the server: executed + shed.
    pub fn offered(&self) -> u64 {
        self.outcomes.len() as u64 + self.shed_total()
    }

    /// Fraction of offered requests shed by admission control.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.offered();
        if offered == 0 {
            0.0
        } else {
            self.shed_total() as f64 / offered as f64
        }
    }

    /// Account one rebalance-controller run: epochs observed, migrations
    /// performed, and the worst cross-worker backlog spread seen (ms).
    pub fn record_rebalance(&mut self, epochs: u64, migrations: u64,
                            peak_imbalance_ms: f64) {
        self.rebalance_epochs += epochs;
        self.migrations += migrations;
        if peak_imbalance_ms.is_finite() {
            self.peak_imbalance_ms =
                self.peak_imbalance_ms.max(peak_imbalance_ms);
        }
    }

    /// Shard migrations performed by the rebalance controller.
    pub fn migrations(&self) -> u64 {
        self.migrations
    }

    pub fn rebalance_epochs(&self) -> u64 {
        self.rebalance_epochs
    }

    /// Worst observed cross-worker backlog spread, ms.
    pub fn peak_imbalance_ms(&self) -> f64 {
        self.peak_imbalance_ms
    }

    /// Account one serving run's hot-model replication activity.
    pub fn record_replication(&mut self, scale_ups: u64, scale_downs: u64,
                              peak_replicas: u64) {
        self.scale_ups += scale_ups;
        self.scale_downs += scale_downs;
        self.peak_replicas = self.peak_replicas.max(peak_replicas);
    }

    /// Replica scale-ups performed by the rebalance controller.
    pub fn scale_ups(&self) -> u64 {
        self.scale_ups
    }

    /// Replica scale-downs performed by the rebalance controller.
    pub fn scale_downs(&self) -> u64 {
        self.scale_downs
    }

    /// Widest replica set any model reached.
    pub fn peak_replicas(&self) -> u64 {
        self.peak_replicas
    }

    /// Account one station's predictive-headroom decisions and the
    /// snapshot fallbacks among them (`fallbacks <= decisions`).
    pub fn record_headroom(&mut self, decisions: u64, fallbacks: u64) {
        debug_assert!(fallbacks <= decisions);
        self.headroom_decisions += decisions;
        self.headroom_fallbacks += fallbacks;
    }

    /// Decisions priced under the predictive headroom mode.
    pub fn headroom_decisions(&self) -> u64 {
        self.headroom_decisions
    }

    /// Cold/NaN-predictor snapshot fallbacks among headroom decisions.
    pub fn headroom_fallbacks(&self) -> u64 {
        self.headroom_fallbacks
    }

    /// Account one admitted autoregressive session (its head request).
    pub fn record_session_start(&mut self) {
        self.sessions_started += 1;
    }

    /// Account one decode step re-enqueued by the session manager.
    pub fn record_session_step(&mut self) {
        self.session_steps_spawned += 1;
    }

    /// Account one terminal session-step outcome against the dual SLOs:
    /// the head (`step == 0`) misses TTFT, later steps miss TPOT.
    pub fn record_dual_slo(&mut self, step: u64, violated: bool) {
        if !violated {
            return;
        }
        if step == 0 {
            self.ttft_misses += 1;
        } else {
            self.tpot_misses += 1;
        }
    }

    /// Sessions admitted (heads accepted under an llm workload).
    pub fn sessions_started(&self) -> u64 {
        self.sessions_started
    }

    /// Decode steps re-enqueued by the session manager.
    pub fn session_steps_spawned(&self) -> u64 {
        self.session_steps_spawned
    }

    /// Session heads that blew their TTFT deadline.
    pub fn ttft_misses(&self) -> u64 {
        self.ttft_misses
    }

    /// Decode steps that blew their TPOT cadence budget.
    pub fn tpot_misses(&self) -> u64 {
        self.tpot_misses
    }

    /// Dual-SLO violation rate over recorded outcomes: TTFT + TPOT
    /// misses per completed-or-dropped request (0 when nothing ran).
    pub fn dual_slo_violation_rate(&self) -> f64 {
        if self.recorded == 0 {
            return 0.0;
        }
        (self.ttft_misses + self.tpot_misses) as f64 / self.recorded as f64
    }

    /// Fold another run's (or worker's) metrics into this one by
    /// reference (clones the outcome/utility vecs). Prefer
    /// [`Metrics::absorb`] when the other side is owned — report folding
    /// on the worker/node paths moves instead of cloning.
    pub fn merge(&mut self, other: &Metrics) {
        self.absorb(other.clone());
    }

    /// Fold another metrics value in by value: outcome and utility vecs
    /// are appended (moved, no per-element clones), counters are summed,
    /// peaks are maxed, histograms merge element-wise. `a.absorb(b)` is
    /// observationally identical to `a.merge(&b)`.
    pub fn absorb(&mut self, mut other: Metrics) {
        self.outcomes.append(&mut other.outcomes);
        self.utility_samples.append(&mut other.utility_samples);
        for (dst, src) in self.shed.iter_mut().zip(&other.shed) {
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        self.migrations += other.migrations;
        self.rebalance_epochs += other.rebalance_epochs;
        self.peak_imbalance_ms =
            self.peak_imbalance_ms.max(other.peak_imbalance_ms);
        self.scale_ups += other.scale_ups;
        self.scale_downs += other.scale_downs;
        self.peak_replicas = self.peak_replicas.max(other.peak_replicas);
        self.headroom_decisions += other.headroom_decisions;
        self.headroom_fallbacks += other.headroom_fallbacks;
        self.sessions_started += other.sessions_started;
        self.session_steps_spawned += other.session_steps_spawned;
        self.ttft_misses += other.ttft_misses;
        self.tpot_misses += other.tpot_misses;
        self.recorded += other.recorded;
        self.dropped += other.dropped;
        self.violated_total += other.violated_total;
        for (d, s) in self
            .per_model_outcomes
            .iter_mut()
            .zip(&other.per_model_outcomes)
        {
            *d += s;
        }
        for (d, s) in self
            .per_model_violated
            .iter_mut()
            .zip(&other.per_model_violated)
        {
            *d += s;
        }
        self.latency_hist.merge(&other.latency_hist);
        self.slack_hist.merge(&other.slack_hist);
    }

    pub fn record_utility(&mut self, t_ms: f64, model: ModelId, u: f64) {
        if u.is_finite() {
            self.utility_samples.push((t_ms, model, u));
        }
    }

    pub fn outcomes(&self) -> &[RequestOutcome] {
        &self.outcomes
    }

    pub fn completed(&self) -> usize {
        (self.recorded - self.dropped) as usize
    }

    /// Total recorded outcomes (completed + dropped) — O(1), no vec walk.
    pub fn recorded_outcomes(&self) -> u64 {
        self.recorded
    }

    /// Total SLO violations (late + dropped) across all models — O(1).
    pub fn violations_total(&self) -> u64 {
        self.violated_total
    }

    /// Recorded outcomes for one model — O(1).
    pub fn outcomes_for(&self, model: ModelId) -> u64 {
        self.per_model_outcomes[model as usize]
    }

    /// SLO violations for one model — O(1).
    pub fn violations_for(&self, model: ModelId) -> u64 {
        self.per_model_violated[model as usize]
    }

    /// Overall SLO violation rate (violations + drops) / total. O(1)
    /// from the streaming counters.
    pub fn violation_rate(&self) -> f64 {
        if self.recorded == 0 {
            return 0.0;
        }
        self.violated_total as f64 / self.recorded as f64
    }

    /// Violation rate per model — one counter read, no per-call
    /// allocation or outcome-vec scan.
    pub fn violation_rate_for(&self, model: ModelId) -> f64 {
        let of_model = self.per_model_outcomes[model as usize];
        if of_model == 0 {
            return 0.0;
        }
        self.per_model_violated[model as usize] as f64 / of_model as f64
    }

    /// The streaming e2e latency histogram (completed requests).
    pub fn latency_hist(&self) -> &LogHistogram {
        &self.latency_hist
    }

    /// The streaming slack histogram (`slo − e2e`, completed requests;
    /// violations clamp into bucket 0).
    pub fn slack_hist(&self) -> &LogHistogram {
        &self.slack_hist
    }

    /// Mean end-to-end latency, optionally per model.
    pub fn mean_latency_ms(&self, model: Option<ModelId>) -> f64 {
        let mut s = Summary::new();
        for o in &self.outcomes {
            if !o.dropped && model.map(|m| m == o.model).unwrap_or(true) {
                s.add(o.e2e_ms);
            }
        }
        s.mean()
    }

    /// Exact latency percentile over completed requests (sorts a copy of
    /// the outcome vec — kept as the test oracle for the streaming
    /// histogram path; reports use
    /// [`Metrics::latency_percentile_streaming`]).
    pub fn latency_percentile(&self, q: f64) -> f64 {
        let xs: Vec<f64> = self
            .outcomes
            .iter()
            .filter(|o| !o.dropped)
            .map(|o| o.e2e_ms)
            .collect();
        percentile(&xs, q)
    }

    /// Streaming latency percentile from the log-bucket histogram — O(1)
    /// memory, no allocation, within one bucket width (≈26 %) of
    /// [`Metrics::latency_percentile`].
    pub fn latency_percentile_streaming(&self, q: f64) -> f64 {
        self.latency_hist.quantile(q)
    }

    /// Aggregate throughput over [0, horizon_ms], requests/s.
    pub fn throughput_rps(&self, horizon_ms: f64) -> f64 {
        assert!(horizon_ms > 0.0);
        self.completed() as f64 / (horizon_ms / 1e3)
    }

    /// Mean utility, optionally per model (Figs. 7/11 bars).
    pub fn mean_utility(&self, model: Option<ModelId>) -> f64 {
        let mut s = Summary::new();
        for &(_, m, u) in &self.utility_samples {
            if model.map(|mm| mm == m).unwrap_or(true) {
                s.add(u);
            }
        }
        s.mean()
    }

    /// Per-second series of (completions, mean e2e latency) per model —
    /// the Fig. 8 stacked-throughput / Fig. 9 latency timelines.
    pub fn timeline(&self, bucket_s: f64, horizon_ms: f64)
                    -> Vec<TimelineBucket> {
        let n_buckets = (horizon_ms / 1e3 / bucket_s).ceil() as usize;
        let mut buckets = vec![TimelineBucket::default(); n_buckets.max(1)];
        for o in &self.outcomes {
            if o.dropped {
                continue;
            }
            let idx = ((o.completed_ms / 1e3 / bucket_s) as usize)
                .min(buckets.len() - 1);
            let b = &mut buckets[idx];
            b.completed[o.model as usize] += 1;
            b.latency_sum_ms[o.model as usize] += o.e2e_ms;
        }
        buckets
    }

    /// Per-window (bucketed) violation fractions — the Fig. 14 CDF input.
    pub fn windowed_violation_rates(&self, window_s: f64, horizon_ms: f64)
                                    -> Vec<f64> {
        let n = (horizon_ms / 1e3 / window_s).ceil() as usize;
        let mut total = vec![0u64; n.max(1)];
        let mut bad = vec![0u64; n.max(1)];
        for o in &self.outcomes {
            let idx =
                ((o.completed_ms / 1e3 / window_s) as usize).min(total.len() - 1);
            total[idx] += 1;
            if o.violated {
                bad[idx] += 1;
            }
        }
        total
            .iter()
            .zip(&bad)
            .filter(|(t, _)| **t > 0)
            .map(|(t, b)| *b as f64 / *t as f64)
            .collect()
    }
}

/// One timeline bucket (per-model completion count + latency sum).
#[derive(Clone, Debug)]
pub struct TimelineBucket {
    pub completed: [u64; N_MODELS],
    pub latency_sum_ms: [f64; N_MODELS],
}

impl Default for TimelineBucket {
    fn default() -> Self {
        TimelineBucket {
            completed: [0; N_MODELS],
            latency_sum_ms: [0.0; N_MODELS],
        }
    }
}

impl TimelineBucket {
    pub fn mean_latency(&self, model: ModelId) -> f64 {
        let c = self.completed[model as usize];
        if c == 0 {
            f64::NAN
        } else {
            self.latency_sum_ms[model as usize] / c as f64
        }
    }

    pub fn total_completed(&self) -> u64 {
        self.completed.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(model: ModelId, completed_ms: f64, e2e: f64, slo: f64)
               -> RequestOutcome {
        RequestOutcome {
            id: 0,
            model,
            arrival_ms: completed_ms - e2e,
            completed_ms,
            e2e_ms: e2e,
            slo_ms: slo,
            violated: e2e > slo,
            dropped: false,
        }
    }

    #[test]
    fn violation_rate_counts_late() {
        let mut m = Metrics::new();
        m.record(outcome(ModelId::Res, 100.0, 30.0, 58.0));
        m.record(outcome(ModelId::Res, 200.0, 90.0, 58.0));
        assert_eq!(m.violation_rate(), 0.5);
        assert_eq!(m.violation_rate_for(ModelId::Res), 0.5);
        assert_eq!(m.violation_rate_for(ModelId::Mob), 0.0);
    }

    #[test]
    fn timeline_buckets_by_completion() {
        let mut m = Metrics::new();
        m.record(outcome(ModelId::Res, 500.0, 10.0, 58.0));
        m.record(outcome(ModelId::Res, 1500.0, 20.0, 58.0));
        m.record(outcome(ModelId::Yolo, 1700.0, 40.0, 138.0));
        let tl = m.timeline(1.0, 2000.0);
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[0].completed[ModelId::Res as usize], 1);
        assert_eq!(tl[1].total_completed(), 2);
        assert!((tl[1].mean_latency(ModelId::Yolo) - 40.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_and_utility() {
        let mut m = Metrics::new();
        for i in 0..30 {
            m.record(outcome(ModelId::Mob, i as f64 * 100.0, 10.0, 86.0));
        }
        assert!((m.throughput_rps(3000.0) - 10.0).abs() < 1e-9);
        m.record_utility(0.0, ModelId::Mob, 2.0);
        m.record_utility(1.0, ModelId::Mob, 4.0);
        m.record_utility(1.0, ModelId::Res, 8.0);
        assert!((m.mean_utility(Some(ModelId::Mob)) - 3.0).abs() < 1e-9);
        assert!((m.mean_utility(None) - 14.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn sheds_are_separate_from_violations() {
        let mut m = Metrics::new();
        m.record(outcome(ModelId::Res, 100.0, 30.0, 58.0)); // on time
        m.record_shed(ModelId::Res, ShedReason::DeadlineUnmeetable);
        m.record_shed(ModelId::Res, ShedReason::QueueFull);
        m.record_shed(ModelId::Yolo, ShedReason::DeadlineUnmeetable);
        // Violation rate covers EXECUTED requests only.
        assert_eq!(m.violation_rate(), 0.0);
        assert_eq!(m.completed(), 1);
        assert_eq!(m.shed_total(), 3);
        assert_eq!(m.shed_for(ModelId::Res), 2);
        assert_eq!(m.shed_for(ModelId::Yolo), 1);
        assert_eq!(m.shed_by_reason(ShedReason::DeadlineUnmeetable), 2);
        assert_eq!(m.shed_by_reason(ShedReason::Shutdown), 0);
        assert_eq!(m.offered(), 4);
        assert!((m.shed_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn merge_combines_outcomes_utilities_and_sheds() {
        let mut a = Metrics::new();
        a.record(outcome(ModelId::Res, 100.0, 30.0, 58.0));
        a.record_utility(0.0, ModelId::Res, 2.0);
        a.record_shed(ModelId::Res, ShedReason::QueueFull);
        let mut b = Metrics::new();
        b.record(outcome(ModelId::Mob, 200.0, 90.0, 86.0)); // violated
        b.record_utility(1.0, ModelId::Mob, 4.0);
        b.record_shed_n(ModelId::Res, ShedReason::QueueFull, 2);
        a.record_rebalance(10, 2, 40.0);
        b.record_rebalance(5, 1, 75.0);
        a.record_replication(3, 1, 2);
        b.record_replication(1, 2, 3);
        a.merge(&b);
        assert_eq!(a.outcomes().len(), 2);
        assert_eq!(a.completed(), 2);
        assert_eq!(a.violation_rate(), 0.5);
        assert_eq!(a.shed_total(), 3);
        assert_eq!(a.shed_by_reason(ShedReason::QueueFull), 3);
        assert!((a.mean_utility(None) - 3.0).abs() < 1e-12);
        assert_eq!(a.offered(), 5);
        // Rebalance counters: sums, except the spread peak which is a max.
        assert_eq!(a.rebalance_epochs(), 15);
        assert_eq!(a.migrations(), 3);
        assert!((a.peak_imbalance_ms() - 75.0).abs() < 1e-12);
        // Replication counters: sums, except the set-width peak (a max).
        assert_eq!(a.scale_ups(), 4);
        assert_eq!(a.scale_downs(), 3);
        assert_eq!(a.peak_replicas(), 3);
    }

    #[test]
    fn absorb_matches_merge_and_is_associative() {
        let mk = |seed: u64| -> Metrics {
            let mut m = Metrics::new();
            for i in 0..40u64 {
                let model = ModelId::from_index(((seed + i) % 6) as usize);
                let e2e = 10.0 + ((seed * 37 + i * 13) % 90) as f64;
                m.record(outcome(model, 100.0 + i as f64 * 10.0, e2e, 58.0));
            }
            m.record_shed(ModelId::Res, ShedReason::QueueFull);
            m.record_utility(0.0, ModelId::Res, seed as f64);
            m
        };
        let (a, b, c) = (mk(1), mk(2), mk(3));
        // absorb ≡ merge.
        let mut via_merge = a.clone();
        via_merge.merge(&b);
        let mut via_absorb = a.clone();
        via_absorb.absorb(b.clone());
        assert_eq!(via_merge.outcomes(), via_absorb.outcomes());
        assert_eq!(via_merge.violations_total(),
                   via_absorb.violations_total());
        assert_eq!(via_merge.latency_hist().count(),
                   via_absorb.latency_hist().count());
        assert_eq!(via_merge.latency_percentile_streaming(0.99),
                   via_absorb.latency_percentile_streaming(0.99));
        // Associativity across a worker/node fold: (a+b)+c vs a+(b+c).
        let mut left = a.clone();
        left.absorb(b.clone());
        left.absorb(c.clone());
        let mut bc = b.clone();
        bc.absorb(c.clone());
        let mut right = a.clone();
        right.absorb(bc);
        assert_eq!(left.recorded_outcomes(), right.recorded_outcomes());
        assert_eq!(left.violations_total(), right.violations_total());
        assert_eq!(left.shed_total(), right.shed_total());
        assert_eq!(left.latency_percentile_streaming(0.5),
                   right.latency_percentile_streaming(0.5));
        assert_eq!(left.slack_hist().count(), right.slack_hist().count());
        for m in ModelId::all() {
            assert_eq!(left.outcomes_for(m), right.outcomes_for(m));
            assert_eq!(left.violations_for(m), right.violations_for(m));
            assert!((left.violation_rate_for(m) - right.violation_rate_for(m))
                        .abs() < 1e-12);
        }
    }

    #[test]
    fn streaming_counters_match_outcome_vec_recompute() {
        let mut m = Metrics::new();
        for i in 0..500u64 {
            let model = ModelId::from_index((i % 6) as usize);
            let e2e = 5.0 + (i * 31 % 200) as f64;
            m.record(outcome(model, i as f64, e2e, 58.0));
        }
        // O(1) counters vs the vec the old implementation walked.
        assert_eq!(m.recorded_outcomes(), m.outcomes().len() as u64);
        let violated =
            m.outcomes().iter().filter(|o| o.violated).count() as u64;
        assert_eq!(m.violations_total(), violated);
        for model in ModelId::all() {
            let of_model =
                m.outcomes().iter().filter(|o| o.model == model).count();
            assert_eq!(m.outcomes_for(model), of_model as u64);
            let expect = if of_model == 0 {
                0.0
            } else {
                m.outcomes()
                    .iter()
                    .filter(|o| o.model == model && o.violated)
                    .count() as f64 / of_model as f64
            };
            assert!((m.violation_rate_for(model) - expect).abs() < 1e-12);
        }
        // Streaming percentile within one bucket width of the exact
        // oracle (the histogram's documented error bound).
        let g = LogHistogram::growth();
        for q in [0.5, 0.9, 0.99] {
            let exact = m.latency_percentile(q);
            let (lo, hi) = m.latency_hist().quantile_bounds(q);
            assert!(exact >= lo / g - 1e-9 && exact <= hi * g + 1e-9,
                    "q={q}: exact {exact} outside [{lo}, {hi}] ± one bucket");
        }
    }

    #[test]
    fn session_counters_split_ttft_from_tpot_and_absorb() {
        let mut a = Metrics::new();
        a.record(outcome(ModelId::Bert, 100.0, 30.0, 114.0));
        a.record_session_start();
        a.record_dual_slo(0, true); // head late -> TTFT
        a.record_dual_slo(0, false); // on-time head counts nothing
        let mut b = Metrics::new();
        b.record(outcome(ModelId::Bert, 200.0, 90.0, 40.0));
        b.record_session_step();
        b.record_session_step();
        b.record_dual_slo(1, true); // decode step late -> TPOT
        b.record_dual_slo(3, true);
        b.record_shed(ModelId::Bert, ShedReason::SessionAbort);
        a.absorb(b);
        assert_eq!(a.sessions_started(), 1);
        assert_eq!(a.session_steps_spawned(), 2);
        assert_eq!(a.ttft_misses(), 1);
        assert_eq!(a.tpot_misses(), 2);
        assert_eq!(a.shed_by_reason(ShedReason::SessionAbort), 1);
        assert!((a.dual_slo_violation_rate() - 3.0 / 2.0).abs() < 1e-12);
        // The new reason is part of the typed enumeration contract.
        assert_eq!(ShedReason::all().len(), N_SHED_REASONS);
        assert_eq!(ShedReason::SessionAbort.label(), "session-abort");
    }

    #[test]
    fn windowed_rates_skip_empty_windows() {
        let mut m = Metrics::new();
        m.record(outcome(ModelId::Res, 100.0, 100.0, 58.0)); // violated
        m.record(outcome(ModelId::Res, 9_900.0, 10.0, 58.0));
        let rates = m.windowed_violation_rates(1.0, 10_000.0);
        assert_eq!(rates.len(), 2);
        assert_eq!(rates[0], 1.0);
        assert_eq!(rates[1], 0.0);
    }
}
