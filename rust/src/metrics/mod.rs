//! Experiment instrumentation: per-request outcomes, per-model
//! throughput/latency timelines (Figs. 8/9), SLO-violation accounting
//! (Figs. 14/15), utility tracking (Figs. 7/11), CSV export.

use crate::util::stats::{percentile, Summary};
use crate::workload::models::{ModelId, N_MODELS};

/// Why the admission controller refused a request (serving runtime).
/// Typed so shed accounting is queryable per cause — a request shed at
/// ingress is NOT an SLO violation and must never be folded into one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum ShedReason {
    /// The model's bounded ingress queue was full (backpressure).
    QueueFull = 0,
    /// Queue depth × profiled batch latency already exceeds the request's
    /// remaining slack: its deadline is provably unmeetable.
    DeadlineUnmeetable = 1,
    /// The server is draining; intake is closed.
    Shutdown = 2,
    /// The cluster router found no node that could meet the request's
    /// deadline (estimated network RTT + queue backlog + batch latency
    /// exceeded the remaining slack on every candidate), so the request
    /// was shed at the edge before ever crossing a node boundary.
    NoFeasibleNode = 3,
}

/// Number of [`ShedReason`] variants (sizes the per-reason counters).
pub const N_SHED_REASONS: usize = 4;

impl ShedReason {
    pub fn all() -> [ShedReason; N_SHED_REASONS] {
        [
            ShedReason::QueueFull,
            ShedReason::DeadlineUnmeetable,
            ShedReason::Shutdown,
            ShedReason::NoFeasibleNode,
        ]
    }

    pub fn label(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::DeadlineUnmeetable => "deadline-unmeetable",
            ShedReason::Shutdown => "shutdown",
            ShedReason::NoFeasibleNode => "no-feasible-node",
        }
    }
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Terminal record for one request.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestOutcome {
    pub id: u64,
    pub model: ModelId,
    pub arrival_ms: f64,
    pub completed_ms: f64,
    /// End-to-end latency per Eq. (2): transmission + serialization +
    /// queueing + inference (+ result return).
    pub e2e_ms: f64,
    pub slo_ms: f64,
    /// SLO violated (late completion or drop).
    pub violated: bool,
    /// Dropped without execution (OOM / dead on arrival).
    pub dropped: bool,
}

/// Aggregated serving metrics for one run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    outcomes: Vec<RequestOutcome>,
    utility_samples: Vec<(f64, ModelId, f64)>,
    /// Requests refused by admission control, per model × reason.
    /// Separate from `outcomes`: sheds never execute, never violate, and
    /// are reported as their own rate.
    shed: [[u64; N_SHED_REASONS]; N_MODELS],
    /// Shard migrations performed by the serving runtime's rebalance
    /// controller (0 outside the live worker pool).
    migrations: u64,
    /// Rebalance-controller epochs observed (gauge reads, migrated or not).
    rebalance_epochs: u64,
    /// Worst cross-worker backlog spread seen by the controller, ms
    /// (max-backlog worker minus min-backlog worker).
    peak_imbalance_ms: f64,
    /// Hot-model replica scale-ups performed (a worker added to a
    /// model's replica set because its backlog outran one worker's
    /// drain rate).
    scale_ups: u64,
    /// Replica scale-downs performed (sets collapsing as backlog
    /// subsides).
    scale_downs: u64,
    /// Widest replica set any model reached (0 outside the live worker
    /// pool; 1 = replication never triggered).
    peak_replicas: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record(&mut self, o: RequestOutcome) {
        self.outcomes.push(o);
    }

    /// Account one request shed by admission control.
    pub fn record_shed(&mut self, model: ModelId, reason: ShedReason) {
        self.record_shed_n(model, reason, 1);
    }

    /// Bulk shed accounting (folding ingress-side counters into a report).
    pub fn record_shed_n(&mut self, model: ModelId, reason: ShedReason,
                         n: u64) {
        self.shed[model as usize][reason as usize] += n;
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.iter().flatten().sum()
    }

    pub fn shed_for(&self, model: ModelId) -> u64 {
        self.shed[model as usize].iter().sum()
    }

    pub fn shed_by_reason(&self, reason: ShedReason) -> u64 {
        self.shed.iter().map(|per_model| per_model[reason as usize]).sum()
    }

    /// Total requests that reached the server: executed + shed.
    pub fn offered(&self) -> u64 {
        self.outcomes.len() as u64 + self.shed_total()
    }

    /// Fraction of offered requests shed by admission control.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.offered();
        if offered == 0 {
            0.0
        } else {
            self.shed_total() as f64 / offered as f64
        }
    }

    /// Account one rebalance-controller run: epochs observed, migrations
    /// performed, and the worst cross-worker backlog spread seen (ms).
    pub fn record_rebalance(&mut self, epochs: u64, migrations: u64,
                            peak_imbalance_ms: f64) {
        self.rebalance_epochs += epochs;
        self.migrations += migrations;
        if peak_imbalance_ms.is_finite() {
            self.peak_imbalance_ms =
                self.peak_imbalance_ms.max(peak_imbalance_ms);
        }
    }

    /// Shard migrations performed by the rebalance controller.
    pub fn migrations(&self) -> u64 {
        self.migrations
    }

    pub fn rebalance_epochs(&self) -> u64 {
        self.rebalance_epochs
    }

    /// Worst observed cross-worker backlog spread, ms.
    pub fn peak_imbalance_ms(&self) -> f64 {
        self.peak_imbalance_ms
    }

    /// Account one serving run's hot-model replication activity.
    pub fn record_replication(&mut self, scale_ups: u64, scale_downs: u64,
                              peak_replicas: u64) {
        self.scale_ups += scale_ups;
        self.scale_downs += scale_downs;
        self.peak_replicas = self.peak_replicas.max(peak_replicas);
    }

    /// Replica scale-ups performed by the rebalance controller.
    pub fn scale_ups(&self) -> u64 {
        self.scale_ups
    }

    /// Replica scale-downs performed by the rebalance controller.
    pub fn scale_downs(&self) -> u64 {
        self.scale_downs
    }

    /// Widest replica set any model reached.
    pub fn peak_replicas(&self) -> u64 {
        self.peak_replicas
    }

    /// Fold another run's (or worker's) metrics into this one.
    pub fn merge(&mut self, other: &Metrics) {
        self.outcomes.extend(other.outcomes.iter().cloned());
        self.utility_samples.extend(other.utility_samples.iter().copied());
        for (dst, src) in self.shed.iter_mut().zip(&other.shed) {
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        self.migrations += other.migrations;
        self.rebalance_epochs += other.rebalance_epochs;
        self.peak_imbalance_ms =
            self.peak_imbalance_ms.max(other.peak_imbalance_ms);
        self.scale_ups += other.scale_ups;
        self.scale_downs += other.scale_downs;
        self.peak_replicas = self.peak_replicas.max(other.peak_replicas);
    }

    pub fn record_utility(&mut self, t_ms: f64, model: ModelId, u: f64) {
        if u.is_finite() {
            self.utility_samples.push((t_ms, model, u));
        }
    }

    pub fn outcomes(&self) -> &[RequestOutcome] {
        &self.outcomes
    }

    pub fn completed(&self) -> usize {
        self.outcomes.iter().filter(|o| !o.dropped).count()
    }

    /// Overall SLO violation rate (violations + drops) / total.
    pub fn violation_rate(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().filter(|o| o.violated).count() as f64
            / self.outcomes.len() as f64
    }

    /// Violation rate per model.
    pub fn violation_rate_for(&self, model: ModelId) -> f64 {
        let of_model: Vec<_> =
            self.outcomes.iter().filter(|o| o.model == model).collect();
        if of_model.is_empty() {
            return 0.0;
        }
        of_model.iter().filter(|o| o.violated).count() as f64
            / of_model.len() as f64
    }

    /// Mean end-to-end latency, optionally per model.
    pub fn mean_latency_ms(&self, model: Option<ModelId>) -> f64 {
        let mut s = Summary::new();
        for o in &self.outcomes {
            if !o.dropped && model.map(|m| m == o.model).unwrap_or(true) {
                s.add(o.e2e_ms);
            }
        }
        s.mean()
    }

    /// Latency percentile over completed requests.
    pub fn latency_percentile(&self, q: f64) -> f64 {
        let xs: Vec<f64> = self
            .outcomes
            .iter()
            .filter(|o| !o.dropped)
            .map(|o| o.e2e_ms)
            .collect();
        percentile(&xs, q)
    }

    /// Aggregate throughput over [0, horizon_ms], requests/s.
    pub fn throughput_rps(&self, horizon_ms: f64) -> f64 {
        assert!(horizon_ms > 0.0);
        self.completed() as f64 / (horizon_ms / 1e3)
    }

    /// Mean utility, optionally per model (Figs. 7/11 bars).
    pub fn mean_utility(&self, model: Option<ModelId>) -> f64 {
        let mut s = Summary::new();
        for &(_, m, u) in &self.utility_samples {
            if model.map(|mm| mm == m).unwrap_or(true) {
                s.add(u);
            }
        }
        s.mean()
    }

    /// Per-second series of (completions, mean e2e latency) per model —
    /// the Fig. 8 stacked-throughput / Fig. 9 latency timelines.
    pub fn timeline(&self, bucket_s: f64, horizon_ms: f64)
                    -> Vec<TimelineBucket> {
        let n_buckets = (horizon_ms / 1e3 / bucket_s).ceil() as usize;
        let mut buckets = vec![TimelineBucket::default(); n_buckets.max(1)];
        for o in &self.outcomes {
            if o.dropped {
                continue;
            }
            let idx = ((o.completed_ms / 1e3 / bucket_s) as usize)
                .min(buckets.len() - 1);
            let b = &mut buckets[idx];
            b.completed[o.model as usize] += 1;
            b.latency_sum_ms[o.model as usize] += o.e2e_ms;
        }
        buckets
    }

    /// Per-window (bucketed) violation fractions — the Fig. 14 CDF input.
    pub fn windowed_violation_rates(&self, window_s: f64, horizon_ms: f64)
                                    -> Vec<f64> {
        let n = (horizon_ms / 1e3 / window_s).ceil() as usize;
        let mut total = vec![0u64; n.max(1)];
        let mut bad = vec![0u64; n.max(1)];
        for o in &self.outcomes {
            let idx =
                ((o.completed_ms / 1e3 / window_s) as usize).min(total.len() - 1);
            total[idx] += 1;
            if o.violated {
                bad[idx] += 1;
            }
        }
        total
            .iter()
            .zip(&bad)
            .filter(|(t, _)| **t > 0)
            .map(|(t, b)| *b as f64 / *t as f64)
            .collect()
    }
}

/// One timeline bucket (per-model completion count + latency sum).
#[derive(Clone, Debug)]
pub struct TimelineBucket {
    pub completed: [u64; N_MODELS],
    pub latency_sum_ms: [f64; N_MODELS],
}

impl Default for TimelineBucket {
    fn default() -> Self {
        TimelineBucket {
            completed: [0; N_MODELS],
            latency_sum_ms: [0.0; N_MODELS],
        }
    }
}

impl TimelineBucket {
    pub fn mean_latency(&self, model: ModelId) -> f64 {
        let c = self.completed[model as usize];
        if c == 0 {
            f64::NAN
        } else {
            self.latency_sum_ms[model as usize] / c as f64
        }
    }

    pub fn total_completed(&self) -> u64 {
        self.completed.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(model: ModelId, completed_ms: f64, e2e: f64, slo: f64)
               -> RequestOutcome {
        RequestOutcome {
            id: 0,
            model,
            arrival_ms: completed_ms - e2e,
            completed_ms,
            e2e_ms: e2e,
            slo_ms: slo,
            violated: e2e > slo,
            dropped: false,
        }
    }

    #[test]
    fn violation_rate_counts_late() {
        let mut m = Metrics::new();
        m.record(outcome(ModelId::Res, 100.0, 30.0, 58.0));
        m.record(outcome(ModelId::Res, 200.0, 90.0, 58.0));
        assert_eq!(m.violation_rate(), 0.5);
        assert_eq!(m.violation_rate_for(ModelId::Res), 0.5);
        assert_eq!(m.violation_rate_for(ModelId::Mob), 0.0);
    }

    #[test]
    fn timeline_buckets_by_completion() {
        let mut m = Metrics::new();
        m.record(outcome(ModelId::Res, 500.0, 10.0, 58.0));
        m.record(outcome(ModelId::Res, 1500.0, 20.0, 58.0));
        m.record(outcome(ModelId::Yolo, 1700.0, 40.0, 138.0));
        let tl = m.timeline(1.0, 2000.0);
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[0].completed[ModelId::Res as usize], 1);
        assert_eq!(tl[1].total_completed(), 2);
        assert!((tl[1].mean_latency(ModelId::Yolo) - 40.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_and_utility() {
        let mut m = Metrics::new();
        for i in 0..30 {
            m.record(outcome(ModelId::Mob, i as f64 * 100.0, 10.0, 86.0));
        }
        assert!((m.throughput_rps(3000.0) - 10.0).abs() < 1e-9);
        m.record_utility(0.0, ModelId::Mob, 2.0);
        m.record_utility(1.0, ModelId::Mob, 4.0);
        m.record_utility(1.0, ModelId::Res, 8.0);
        assert!((m.mean_utility(Some(ModelId::Mob)) - 3.0).abs() < 1e-9);
        assert!((m.mean_utility(None) - 14.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn sheds_are_separate_from_violations() {
        let mut m = Metrics::new();
        m.record(outcome(ModelId::Res, 100.0, 30.0, 58.0)); // on time
        m.record_shed(ModelId::Res, ShedReason::DeadlineUnmeetable);
        m.record_shed(ModelId::Res, ShedReason::QueueFull);
        m.record_shed(ModelId::Yolo, ShedReason::DeadlineUnmeetable);
        // Violation rate covers EXECUTED requests only.
        assert_eq!(m.violation_rate(), 0.0);
        assert_eq!(m.completed(), 1);
        assert_eq!(m.shed_total(), 3);
        assert_eq!(m.shed_for(ModelId::Res), 2);
        assert_eq!(m.shed_for(ModelId::Yolo), 1);
        assert_eq!(m.shed_by_reason(ShedReason::DeadlineUnmeetable), 2);
        assert_eq!(m.shed_by_reason(ShedReason::Shutdown), 0);
        assert_eq!(m.offered(), 4);
        assert!((m.shed_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn merge_combines_outcomes_utilities_and_sheds() {
        let mut a = Metrics::new();
        a.record(outcome(ModelId::Res, 100.0, 30.0, 58.0));
        a.record_utility(0.0, ModelId::Res, 2.0);
        a.record_shed(ModelId::Res, ShedReason::QueueFull);
        let mut b = Metrics::new();
        b.record(outcome(ModelId::Mob, 200.0, 90.0, 86.0)); // violated
        b.record_utility(1.0, ModelId::Mob, 4.0);
        b.record_shed_n(ModelId::Res, ShedReason::QueueFull, 2);
        a.record_rebalance(10, 2, 40.0);
        b.record_rebalance(5, 1, 75.0);
        a.record_replication(3, 1, 2);
        b.record_replication(1, 2, 3);
        a.merge(&b);
        assert_eq!(a.outcomes().len(), 2);
        assert_eq!(a.completed(), 2);
        assert_eq!(a.violation_rate(), 0.5);
        assert_eq!(a.shed_total(), 3);
        assert_eq!(a.shed_by_reason(ShedReason::QueueFull), 3);
        assert!((a.mean_utility(None) - 3.0).abs() < 1e-12);
        assert_eq!(a.offered(), 5);
        // Rebalance counters: sums, except the spread peak which is a max.
        assert_eq!(a.rebalance_epochs(), 15);
        assert_eq!(a.migrations(), 3);
        assert!((a.peak_imbalance_ms() - 75.0).abs() < 1e-12);
        // Replication counters: sums, except the set-width peak (a max).
        assert_eq!(a.scale_ups(), 4);
        assert_eq!(a.scale_downs(), 3);
        assert_eq!(a.peak_replicas(), 3);
    }

    #[test]
    fn windowed_rates_skip_empty_windows() {
        let mut m = Metrics::new();
        m.record(outcome(ModelId::Res, 100.0, 100.0, 58.0)); // violated
        m.record(outcome(ModelId::Res, 9_900.0, 10.0, 58.0));
        let rates = m.windowed_violation_rates(1.0, 10_000.0);
        assert_eq!(rates.len(), 2);
        assert_eq!(rates[0], 1.0);
        assert_eq!(rates[1], 0.0);
    }
}
