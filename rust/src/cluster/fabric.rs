//! Fabric-backed virtual arm of the cluster tier: drain/rejoin
//! lifecycle, gossip publisher ticks, arrival routing, and every node's
//! serving pool as logical processes on ONE [`EventHeap`]
//! (see [`crate::sim`]).
//!
//! This retires the leaky-bucket backlog estimator the old virtual
//! driver routed against. Routers now read the SAME live gauges a
//! node's own admission path exports — published into the shared
//! [`ClusterView`] at gossip ticks via
//! [`ServeFabric::gauge_snapshot`] — and the wall arm's real
//! [`Router`] / [`ViewReader`] / [`ResultCache`] stack runs unchanged.
//! Because every side effect is a timestamped event on the heap, the
//! whole dynamic stack (migration, replication, drain/rejoin, sharded
//! cached routing) replays bit-identically from a seed.
//!
//! Process-id map (ties at one timestamp fire in pid order):
//!
//! | pid           | process                                           |
//! |---------------|---------------------------------------------------|
//! | `0`           | drain/rejoin lifecycle                            |
//! | `1`           | gossip publisher tick                             |
//! | `2`           | arrival routing (the trace, one event at a time)  |
//! | `B_i`         | node `i`'s rebalance controller                   |
//! | `B_i + 1 + w` | node `i`, worker `w` activation                   |
//!
//! with `B_i = 3 + Σ_{j<i} (1 + workers_j)`. The order encodes the
//! semantics: lifecycle before gossip (a drain at a tick's instant
//! publishes as inactive), gossip before arrivals (a boundary arrival
//! routes on the fresh view), arrivals before worker activations (the
//! serve fabric's deliver-then-activate order, node pids all ≥ 3).
//!
//! The drain window gates ROUTING only, exactly like the old virtual
//! semantics: a drained node's pool keeps serving everything it was
//! dealt (truth-offline picks from a stale view count as misroutes and
//! re-route; nothing is lost). Conservation therefore extends across
//! the tiers unchanged:
//! `outcomes + sheds + cache_served + leftover == attempts` and
//! `dispatched + router_sheds + cache_served == attempts`.

use super::cache::{digest_for, CacheLookup, ResultCache};
use super::netmodel::{payload_bytes, token_payload_bytes, LinkLoad};
use super::node::FinishedNode;
use super::router::{NodeView, Router};
use super::view::{ClusterView, StalenessStat, ViewReader};
use super::{count_routing_fallback, merge_node, predicted_e2e,
            predictive_quantile, ClusterConfig, ClusterReport,
            FrontEndReport};
use crate::metrics::{Metrics, ShedReason};
use crate::workload::session::step_of;
use crate::serve::fabric::ServeFabric;
use crate::serve::{ClockKind, GaugeSnapshot, LoadGenConfig, ServeConfig};
use crate::sim::EventHeap;
use crate::telemetry::{RequestTrace, TraceReport, TraceRing, TraceVerdict,
                       TRACE_RING_CAP};
use crate::util::rng::Pcg32;
use crate::workload::request::Request;

/// Drain/rejoin lifecycle process id.
const PID_LIFECYCLE: u32 = 0;
/// Gossip publisher process id.
const PID_GOSSIP: u32 = 1;
/// Arrival-routing process id.
const PID_ARRIVAL: u32 = 2;

/// Event payloads of the cluster tier's fabric.
enum Ev {
    /// Flip the drained node's truth state (false = drain, true =
    /// rejoin).
    Lifecycle { rejoin: bool },
    /// Gossip tick `j` (fires at `j × gossip_ms` for `j ≥ 0`).
    Gossip { j: u64 },
    /// Route trace request `idx` (the arrival stream keeps exactly one
    /// Arrival in the heap; the trace is already in timestamp order).
    Arrival { idx: u64, r: Request },
    /// Node `node`'s rebalance epoch `k`.
    Rebalance { node: usize, k: u64 },
    /// Run one scheduling round on node `node`, worker `w`.
    Activate { node: usize, w: usize },
}

/// Front-end-terminal trace record (cache dispositions, edge sheds),
/// sampled by trace index exactly like the wall arm's shards.
fn record_fe(ring: &mut TraceRing, sample: u64, idx: u64, shard: usize,
             r: &Request, verdict: TraceVerdict) {
    if sample == 0 || idx % sample != 0 {
        return;
    }
    let mut tr = RequestTrace::stub(idx, r.model, verdict);
    tr.shard = shard as u32;
    tr.arrival_ms = r.arrival_ms;
    tr.slo_ms = r.slo_ms;
    tr.net_ms = r.transmission_ms;
    ring.push(tr);
}

/// Open loop on the virtual clock: the whole cluster as one
/// discrete-event simulation. Same seed (and shard count) ⇒ identical
/// report, bit for bit — including migration, replication, drain/rejoin,
/// and cached sharded routing, all live on the heap.
pub(crate) fn run_virtual_open(cfg: &ClusterConfig, load: &LoadGenConfig,
                               horizon_ms: f64) -> ClusterReport {
    let n = cfg.nodes.len();
    let k = cfg.frontend.router_shards;
    let gossip_ms = cfg.frontend.gossip_ms;
    let trace = load.head_trace(horizon_ms);
    // Sessions grow the attempt count as they spawn decode steps: every
    // spawned step is a genuine offered request, so conservation stays
    // `outcomes + sheds + cache_served + leftover == attempts`.
    let mut attempts = trace.len() as u64;
    let session = load.session;

    // One serve fabric per node: the node's whole dynamic pool
    // (workers, rebalancer, replication) as logical processes.
    let mut fabrics: Vec<ServeFabric> = cfg
        .nodes
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let mut node_cfg = ServeConfig {
                platform: spec.platform.clone(),
                workers: spec.workers,
                clock: ClockKind::Virtual,
                ..cfg.serve.clone()
            };
            node_cfg.telemetry.node_label = i as u32;
            ServeFabric::new(&node_cfg, horizon_ms)
        })
        .collect();
    // Per-node pid bases (see the module-doc pid map).
    let pid_base: Vec<u32> = {
        let mut bases = Vec::with_capacity(n);
        let mut next = 3u32;
        for f in &fabrics {
            bases.push(next);
            next += 1 + f.worker_count() as u32;
        }
        bases
    };

    // The wall arm's front-end stack, verbatim: shared view, per-shard
    // readers/routers/link RNGs (same seed split), shared result cache.
    let view = ClusterView::new(n);
    let mut readers: Vec<ViewReader> =
        (0..k).map(|_| ViewReader::new(&view)).collect();
    let mut routers: Vec<Router> = (0..k)
        .map(|s| Router::with_stream(cfg.policy, load.seed ^ 0xC1_05_7E,
                                     s as u64))
        .collect();
    let mut link_rngs: Vec<Pcg32> = (0..k)
        .map(|s| Pcg32::new(load.seed ^ 0x11_4E, s as u64))
        .collect();
    // Per-node shared-link contention state. With the default infinite
    // bandwidth every base transfer time is 0, so the trackers are never
    // written and pre-existing runs stay bit-identical.
    let mut links: Vec<LinkLoad> = (0..n).map(|_| LinkLoad::new()).collect();
    let cache = cfg.frontend.cache.map(ResultCache::new);

    let mut heap: EventHeap<Ev> = EventHeap::new();
    let mut trace_iter = trace.into_iter();
    if let Some(first) = trace_iter.next() {
        heap.schedule_ms(first.arrival_ms, PID_ARRIVAL,
                         Ev::Arrival { idx: 0, r: first });
    }
    if horizon_ms > 0.0 {
        heap.schedule_ms(0.0, PID_GOSSIP, Ev::Gossip { j: 0 });
    }
    if let Some(d) = cfg.drain {
        if d.at_ms < horizon_ms {
            heap.schedule_ms(d.at_ms, PID_LIFECYCLE,
                             Ev::Lifecycle { rejoin: false });
            if d.rejoin_at_ms < horizon_ms {
                heap.schedule_ms(d.rejoin_at_ms, PID_LIFECYCLE,
                                 Ev::Lifecycle { rejoin: true });
            }
        }
    }
    let epoch_ms = cfg
        .serve
        .rebalance
        .map(|r| r.epoch_ms.max(1))
        .unwrap_or(u64::MAX);
    for (i, f) in fabrics.iter().enumerate() {
        if f.has_rebalancer() && (epoch_ms as f64) < horizon_ms {
            heap.schedule_ms(epoch_ms as f64, pid_base[i],
                             Ev::Rebalance { node: i, k: 1 });
        }
    }

    let mut truth_active = vec![true; n];
    let mut drains = 0u32;
    let mut rejoins = 0u32;
    let mut dispatched = vec![0u64; n];
    let mut router_metrics = Metrics::new();
    let mut misroutes = 0u64;
    let mut staleness = StalenessStat::default();
    let mut views: Vec<NodeView> = Vec::with_capacity(n);
    let mut wake: Vec<usize> = Vec::new();
    let mut session_buf: Vec<Request> = Vec::new();
    let trace_sample = cfg.serve.telemetry.trace_sample;
    let mut fe_ring = TraceRing::new(TRACE_RING_CAP);
    let quantile = predictive_quantile(cfg);
    let mut headroom_decisions = 0u64;
    let mut headroom_fallbacks = 0u64;

    while let Some(firing) = heap.pop() {
        match firing.event {
            Ev::Lifecycle { rejoin } => {
                let d = cfg.drain.expect("lifecycle event without scenario");
                truth_active[d.node] = rejoin;
                if rejoin {
                    rejoins += 1;
                } else {
                    drains += 1;
                }
            }
            Ev::Gossip { j } => {
                let t = j as f64 * gossip_ms;
                for i in 0..n {
                    if truth_active[i] {
                        view.publish(i, true, fabrics[i].gauge_snapshot(), t);
                    } else {
                        view.publish(i, false, GaugeSnapshot::default(), t);
                    }
                }
                let next = (j + 1) as f64 * gossip_ms;
                if gossip_ms > 0.0 && next < horizon_ms {
                    heap.schedule_ms(next, PID_GOSSIP, Ev::Gossip { j: j + 1 });
                }
            }
            Ev::Arrival { idx, r } => {
                let t = r.arrival_ms;
                let model = r.model;
                let shard = (idx as usize) % k;
                // Cache front: hits and coalesces never reach a router.
                let mut lead_digest = None;
                let mut cache_served = false;
                if let Some(c) = cache.as_ref() {
                    let digest = digest_for(load.seed, idx,
                                            load.repeat_fraction);
                    match c.lookup(model, digest, t) {
                        CacheLookup::Hit => {
                            record_fe(&mut fe_ring, trace_sample, idx, shard,
                                      &r, TraceVerdict::CacheHit);
                            cache_served = true;
                        }
                        CacheLookup::Coalesced => {
                            record_fe(&mut fe_ring, trace_sample, idx, shard,
                                      &r, TraceVerdict::CacheCoalesced);
                            cache_served = true;
                        }
                        CacheLookup::Lead => lead_digest = Some(digest),
                    }
                }
                if !cache_served {
                    // Route from the gossiped view, mirroring the wall
                    // arm's `route_and_dispatch`: sync, record staleness,
                    // price every node from its published snapshot, and
                    // mask + re-route on truth-offline misroutes.
                    readers[shard].sync(&view);
                    staleness
                        .record(t - readers[shard].oldest_published_ms());
                    views.clear();
                    for i in 0..n {
                        let p = readers[shard].get(i);
                        views.push(if p.active {
                            NodeView {
                                active: true,
                                rtt_ms: cfg.nodes[i].net.rtt_ms,
                                backlog_ms: p.gauges.total_backlog_ms,
                                service_est_ms: p.gauges.service_est_ms(model),
                                predicted_e2e_ms: predicted_e2e(
                                    quantile, &p.gauges, model,
                                    cfg.nodes[i].net.rtt_ms),
                                tx_est_ms: if cfg.frontend.contention_pricing {
                                    links[i].estimate_ms(
                                        t,
                                        cfg.nodes[i]
                                            .net
                                            .transfer_ms(payload_bytes(model)),
                                    )
                                } else {
                                    0.0
                                },
                            }
                        } else {
                            NodeView {
                                active: false,
                                rtt_ms: cfg.nodes[i].net.rtt_ms,
                                backlog_ms: f64::INFINITY,
                                service_est_ms: f64::INFINITY,
                                predicted_e2e_ms: f64::NAN,
                                tx_est_ms: 0.0,
                            }
                        });
                    }
                    if quantile.is_some() {
                        headroom_decisions += 1;
                        if count_routing_fallback(&views) {
                            headroom_fallbacks += 1;
                        }
                    }
                    loop {
                        match routers[shard]
                            .route(&views, r.slo_ms - r.transmission_ms)
                        {
                            Ok(i) if !truth_active[i] => {
                                // The published view lags the drain
                                // event: a real node would refuse this
                                // dispatch. Count the misroute and
                                // re-route on the corrected set.
                                misroutes += 1;
                                views[i].active = false;
                            }
                            Ok(i) => {
                                // A session whose per-round estimate on
                                // the chosen node cannot hold cadence is
                                // aborted at admission: every decode step
                                // would be born late, so the head's slots
                                // are better spent elsewhere.
                                if let Some(spec) = session {
                                    if !spec.cadence_feasible(
                                        views[i].service_est_ms,
                                    ) {
                                        router_metrics.record_shed(
                                            model,
                                            ShedReason::SessionAbort,
                                        );
                                        record_fe(
                                            &mut fe_ring, trace_sample, idx,
                                            shard, &r,
                                            TraceVerdict::Shed(
                                                ShedReason::SessionAbort,
                                            ),
                                        );
                                        break;
                                    }
                                    router_metrics.record_session_start();
                                }
                                let mut routed = r.clone();
                                // Physical charges: RTT (+jitter), then
                                // the payload's contention-inflated link
                                // time. Charged on BOTH pricing modes —
                                // pricing changes what routing sees, not
                                // what the wire costs.
                                routed.transmission_ms += cfg.nodes[i]
                                    .net
                                    .delay_ms(&mut link_rngs[shard])
                                    + links[i].charge_ms(
                                        t,
                                        cfg.nodes[i]
                                            .net
                                            .transfer_ms(payload_bytes(model)),
                                    );
                                if let (Some(c), Some(digest)) =
                                    (cache.as_ref(), lead_digest)
                                {
                                    c.commit_leader(model, digest, routed.id);
                                }
                                dispatched[i] += 1;
                                fabrics[i].deliver(routed, &mut wake);
                                for w in wake.drain(..) {
                                    heap.schedule_us(
                                        firing.time_us,
                                        pid_base[i] + 1 + w as u32,
                                        Ev::Activate { node: i, w },
                                    );
                                }
                                break;
                            }
                            Err(reason) => {
                                // A shed leader leaves no cache entry:
                                // the next identical request leads
                                // afresh.
                                router_metrics.record_shed(model, reason);
                                if let (Some(c), Some(digest)) =
                                    (cache.as_ref(), lead_digest)
                                {
                                    c.abort_leader(model, digest);
                                }
                                record_fe(&mut fe_ring, trace_sample, idx,
                                          shard, &r,
                                          TraceVerdict::Shed(reason));
                                break;
                            }
                        }
                    }
                }
                if let Some(next) = trace_iter.next() {
                    heap.schedule_ms(next.arrival_ms, PID_ARRIVAL,
                                     Ev::Arrival { idx: idx + 1, r: next });
                }
            }
            Ev::Rebalance { node, k: ek } => {
                fabrics[node].rebalance_tick(&mut wake);
                for w in wake.drain(..) {
                    heap.schedule_us(firing.time_us,
                                     pid_base[node] + 1 + w as u32,
                                     Ev::Activate { node, w });
                }
                let next = (ek + 1).saturating_mul(epoch_ms);
                if (next as f64) < horizon_ms {
                    heap.schedule_ms(next as f64, pid_base[node],
                                     Ev::Rebalance { node, k: ek + 1 });
                }
            }
            Ev::Activate { node, w } => {
                if let Some(at_us) = fabrics[node].activate(w) {
                    heap.schedule_us(at_us, pid_base[node] + 1 + w as u32,
                                     Ev::Activate { node, w });
                }
                // Completion feed. Sessions and the result cache are
                // mutually exclusive (`run_cluster` rejects the combo),
                // so each consumes the outcome stream alone.
                if let Some(spec) = session {
                    // Completed rounds spawn their successors on the
                    // SAME node (decode state lives where the head ran —
                    // re-routing a step would re-ship it). The step pays
                    // the token payload's contention-inflated link time.
                    fabrics[node].for_new_outcomes(|o| {
                        router_metrics.record_dual_slo(
                            step_of(o.id), o.violated);
                        if !o.dropped {
                            if let Some(next) = spec.next_step(
                                o.id, o.model, o.completed_ms, 0.0)
                            {
                                session_buf.push(next);
                            }
                        }
                    });
                    for mut s in session_buf.drain(..) {
                        attempts += 1;
                        router_metrics.record_session_step();
                        if truth_active[node] {
                            s.transmission_ms += links[node].charge_ms(
                                s.arrival_ms,
                                cfg.nodes[node]
                                    .net
                                    .transfer_ms(token_payload_bytes(s.model)),
                            );
                            dispatched[node] += 1;
                            fabrics[node].deliver(s, &mut wake);
                        } else {
                            // The node drained mid-session: the step has
                            // nowhere to go (state is node-local), so
                            // the session ends as an edge shed.
                            router_metrics.record_shed(
                                s.model, ShedReason::SessionAbort);
                        }
                    }
                    for w2 in wake.drain(..) {
                        heap.schedule_us(
                            firing.time_us,
                            pid_base[node] + 1 + w2 as u32,
                            Ev::Activate { node, w: w2 },
                        );
                    }
                } else if let Some(c) = cache.as_ref() {
                    // Resolve pending cache leaders at their ACTUAL
                    // completion times (the wall arm's collector,
                    // without the thread).
                    fabrics[node].for_new_outcomes(|o| {
                        c.on_completed(o.id, o.completed_ms);
                    });
                }
            }
        }
    }

    // Fold the nodes in index order — a fixed merge order keeps the
    // report bit-stable.
    let mut metrics = router_metrics;
    metrics.record_headroom(headroom_decisions, headroom_fallbacks);
    let mut telemetry = TraceReport {
        traces: fe_ring.drain(),
        dropped: fe_ring.dropped(),
        ..Default::default()
    };
    let mut leftover = 0usize;
    let mut slots = 0u64;
    let mut per_node = Vec::with_capacity(n);
    for (i, fab) in fabrics.into_iter().enumerate() {
        let report = fab.finish(horizon_ms);
        merge_node(&mut metrics, &mut leftover, &mut slots, &mut per_node,
                   &mut telemetry,
                   FinishedNode {
                       spec: cfg.nodes[i].clone(),
                       dispatched: dispatched[i],
                       segments: vec![report],
                   });
    }
    let session_steps = metrics.session_steps_spawned();
    let session_aborts = metrics.shed_by_reason(ShedReason::SessionAbort);
    ClusterReport {
        metrics,
        horizon_ms,
        attempts,
        leftover,
        slots,
        drains,
        rejoins,
        policy: cfg.policy,
        frontend: FrontEndReport {
            shards: k,
            gossip_ms,
            decisions: staleness.decisions,
            misroutes,
            staleness_mean_ms: staleness.mean_ms(),
            staleness_max_ms: staleness.max_ms,
            headroom_decisions,
            headroom_fallbacks,
            session_steps,
            session_aborts,
            cache: cache.map(|c| c.stats()),
        },
        per_node,
        telemetry,
    }
}

#[cfg(test)]
mod tests {
    use super::super::{run_cluster, FrontEndConfig, NodeSpec, RoutePolicy};
    use super::*;
    use crate::platform::{PlatformSim, PlatformSpec};
    use crate::serve::SchedulerSpec;
    use crate::workload::models::N_MODELS;

    /// The RETIRED leaky-bucket backlog estimator, kept briefly as a
    /// test oracle: until this PR it was the virtual router's only load
    /// signal (dispatch adds per-request work, the bucket drains one ms
    /// of work per worker per ms of trace time). The decision path now
    /// reads live gauges; the oracle survives only to cross-check that
    /// live-gauge routing still sees the heterogeneity the bucket
    /// modeled.
    struct LeakyBucket {
        level_ms: f64,
        last_ms: f64,
        drain_rate: f64,
    }

    impl LeakyBucket {
        fn new(drain_rate: f64) -> Self {
            LeakyBucket { level_ms: 0.0, last_ms: 0.0, drain_rate }
        }

        fn decay_to(&mut self, t: f64) {
            self.level_ms =
                (self.level_ms - (t - self.last_ms) * self.drain_rate)
                    .max(0.0);
            self.last_ms = t;
        }

        fn push(&mut self, work_ms: f64) {
            self.level_ms += work_ms;
        }
    }

    /// Differential oracle: replay the same trace through the retired
    /// leaky-bucket model under greedy join-shortest-backlog, and check
    /// the live-gauge fabric agrees with it on load ORDERING — the fast
    /// NX node carries more than the Nano. (The bucket is gone from the
    /// decision path; this pins that removing it did not invert what
    /// the routing layer knows about node heterogeneity.)
    #[test]
    fn retired_leaky_bucket_oracle_agrees_with_live_gauge_routing() {
        let cfg = ClusterConfig {
            nodes: vec![
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
                NodeSpec::new(PlatformSpec::jetson_nano(), 1, 12.0),
            ],
            policy: RoutePolicy::JoinShortestBacklog,
            serve: ServeConfig {
                clock: ClockKind::Virtual,
                scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 2 },
                admission: None,
                queue_capacity: 4096,
                ..Default::default()
            },
            drain: None,
            frontend: FrontEndConfig::default(),
        };
        let load = LoadGenConfig {
            rps: 120.0,
            seconds: 10.0,
            seed: 21,
            slo_scale: 3.0,
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();

        let trace = load.generator().generate_horizon(load.seconds * 1e3);
        let sims: Vec<PlatformSim> = cfg
            .nodes
            .iter()
            .map(|s| PlatformSim::new(s.platform.clone()))
            .collect();
        let ref_batch = cfg.ref_batch();
        let mut buckets: Vec<LeakyBucket> = cfg
            .nodes
            .iter()
            .map(|s| LeakyBucket::new(s.workers.clamp(1, N_MODELS) as f64))
            .collect();
        let mut oracle = vec![0u64; cfg.nodes.len()];
        for r in &trace {
            for b in buckets.iter_mut() {
                b.decay_to(r.arrival_ms);
            }
            let mut pick = 0usize;
            for i in 1..buckets.len() {
                if buckets[i].level_ms < buckets[pick].level_ms {
                    pick = i;
                }
            }
            buckets[pick].push(
                sims[pick].latency.isolated_ms(r.model, ref_batch)
                    / ref_batch as f64,
            );
            oracle[pick] += 1;
        }
        assert!(oracle[0] > oracle[1],
                "the oracle itself lost the heterogeneity: {oracle:?}");
        assert!(report.per_node[0].dispatched > report.per_node[1].dispatched,
                "live-gauge routing disagrees with the retired oracle: \
                 fabric {:?} vs oracle {oracle:?}",
                report.per_node.iter().map(|p| p.dispatched)
                    .collect::<Vec<_>>());
    }
}
