//! Gossiped cluster state: epoch-stamped, atomically-published gauge
//! snapshots the sharded front-end routes from.
//!
//! The old front-end read `Server::gauge_snapshot()` live, per request,
//! per node — one serial loop touching every node's gauges on every
//! decision, the last single-threaded bottleneck in the system (ROADMAP
//! open item 3). Related edge-serving work routes from per-node
//! *summaries* instead of synchronous state, accepting bounded staleness
//! in exchange for a lock-free dispatch path. This module is that
//! contract:
//!
//! * A background publisher refreshes one [`ClusterView`] slot per node
//!   every `--gossip-ms` (the gossip period). Each publish bumps the
//!   slot's epoch.
//! * Routers hold a private [`ViewReader`] that caches the last `Arc`
//!   it saw per slot keyed by epoch: syncing is one relaxed atomic load
//!   per node in steady state, and only takes the slot's `RwLock` on the
//!   (rare) epoch change. No lock is held while routing.
//! * Staleness is *bounded and observable*: every snapshot carries the
//!   cluster-clock time it was published, so each routing decision can
//!   record exactly how old its view was. A stale view can route to a
//!   node that has since begun draining — the node refuses
//!   (`EdgeNode::try_dispatch` returns `None`), the front-end counts a
//!   **misroute**, masks the node, and re-routes. Nothing is lost; the
//!   cost of gossip is counted, not hidden.
//!
//! `ArcSwap` would be the off-the-shelf shape here; this is the std-only
//! equivalent (epoch atomic + `RwLock<Arc<_>>` with reader-side epoch
//! caching), which is lock-free on the serving path whenever the epoch
//! has not moved — i.e. for every request between two gossip ticks.

use crate::serve::GaugeSnapshot;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// One node's published state: what the front-end knows, as of
/// `published_ms` on the cluster clock.
#[derive(Clone, Debug)]
pub struct NodePublished {
    /// Monotone per-slot publish counter (0 = never published).
    pub epoch: u64,
    /// Cluster-clock time this snapshot was taken, ms.
    pub published_ms: f64,
    /// Was the node accepting dispatch when published?
    pub active: bool,
    /// The node's pool-wide gauges (meaningless when `!active`).
    pub gauges: GaugeSnapshot,
}

impl Default for NodePublished {
    fn default() -> Self {
        NodePublished {
            epoch: 0,
            published_ms: 0.0,
            active: false,
            gauges: GaugeSnapshot::default(),
        }
    }
}

/// One atomically-published slot. Writers replace the `Arc` under the
/// write lock *first*, then advance the epoch with `Release`: a reader
/// that observes the new epoch (`Acquire`) is guaranteed to find a
/// snapshot at least that new behind the lock.
struct Slot {
    epoch: AtomicU64,
    snap: RwLock<Arc<NodePublished>>,
}

/// The shared, epoch-stamped view of every node, written by the gossip
/// publisher and read by every router shard.
pub struct ClusterView {
    slots: Vec<Slot>,
}

impl ClusterView {
    /// A view over `nodes` slots, all at epoch 0 (never published,
    /// inactive) — routers see nothing until the first gossip tick.
    pub fn new(nodes: usize) -> Self {
        ClusterView {
            slots: (0..nodes)
                .map(|_| Slot {
                    epoch: AtomicU64::new(0),
                    snap: RwLock::new(Arc::new(NodePublished::default())),
                })
                .collect(),
        }
    }

    /// Number of node slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Publish node `i`'s state as of `now_ms`, returning the new epoch.
    pub fn publish(&self, i: usize, active: bool, gauges: GaugeSnapshot,
                   now_ms: f64) -> u64 {
        let slot = &self.slots[i];
        let epoch = slot.epoch.load(Ordering::Relaxed) + 1;
        *slot.snap.write().unwrap() = Arc::new(NodePublished {
            epoch,
            published_ms: now_ms,
            active,
            gauges,
        });
        slot.epoch.store(epoch, Ordering::Release);
        epoch
    }

    /// Node `i`'s current publish epoch (0 = never published).
    pub fn epoch(&self, i: usize) -> u64 {
        self.slots[i].epoch.load(Ordering::Acquire)
    }
}

/// A router shard's private, epoch-cached handle on the shared view.
/// [`ViewReader::sync`] is one `Acquire` load per slot when nothing
/// changed — the slot lock is only touched on an epoch move, i.e. once
/// per gossip tick, not once per request.
pub struct ViewReader {
    cached: Vec<(u64, Arc<NodePublished>)>,
}

impl ViewReader {
    /// A reader over `view`, pre-synced to its current state.
    pub fn new(view: &ClusterView) -> Self {
        let mut r = ViewReader {
            cached: view
                .slots
                .iter()
                .map(|_| (0, Arc::new(NodePublished::default())))
                .collect(),
        };
        r.sync(view);
        r
    }

    /// Pull any slots whose epoch moved since the last sync. Key the
    /// cache by the *snapshot's* own epoch (not the atomic we read): a
    /// racing publisher may install epoch N+1 between our epoch load and
    /// our lock acquisition, and caching the newer snapshot under the
    /// older key would re-read it forever.
    pub fn sync(&mut self, view: &ClusterView) {
        for (i, slot) in view.slots.iter().enumerate() {
            let e = slot.epoch.load(Ordering::Acquire);
            if e != self.cached[i].0 {
                let snap = slot.snap.read().unwrap().clone();
                self.cached[i] = (snap.epoch, snap);
            }
        }
    }

    /// Number of node slots.
    pub fn len(&self) -> usize {
        self.cached.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cached.is_empty()
    }

    /// Node `i`'s last-synced published state.
    pub fn get(&self, i: usize) -> &NodePublished {
        &self.cached[i].1
    }

    /// The oldest `published_ms` across all slots — the staleness bound
    /// for a decision made at `now` is `now - oldest_published_ms()`.
    pub fn oldest_published_ms(&self) -> f64 {
        self.cached
            .iter()
            .map(|(_, s)| s.published_ms)
            .fold(f64::INFINITY, f64::min)
    }
}

/// Per-shard staleness accounting: how old the gossiped view was at each
/// routing decision.
#[derive(Clone, Copy, Debug, Default)]
pub struct StalenessStat {
    /// Decisions measured.
    pub decisions: u64,
    /// Sum of per-decision staleness, ms.
    pub sum_ms: f64,
    /// Worst per-decision staleness, ms.
    pub max_ms: f64,
}

impl StalenessStat {
    /// Record one decision made `age_ms` after the oldest slot publish.
    pub fn record(&mut self, age_ms: f64) {
        let age = age_ms.max(0.0);
        self.decisions += 1;
        self.sum_ms += age;
        if age > self.max_ms {
            self.max_ms = age;
        }
    }

    /// Mean per-decision staleness, ms (0 with no decisions).
    pub fn mean_ms(&self) -> f64 {
        if self.decisions == 0 {
            0.0
        } else {
            self.sum_ms / self.decisions as f64
        }
    }

    /// Fold another shard's accounting into this one.
    pub fn merge(&mut self, other: &StalenessStat) {
        self.decisions += other.decisions;
        self.sum_ms += other.sum_ms;
        self.max_ms = self.max_ms.max(other.max_ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_bumps_epoch_and_readers_see_it() {
        let view = ClusterView::new(2);
        assert_eq!(view.epoch(0), 0);
        let mut reader = ViewReader::new(&view);
        assert!(!reader.get(0).active, "unpublished slot reads active");

        let mut snap = GaugeSnapshot::default();
        snap.total_backlog_ms = 42.0;
        assert_eq!(view.publish(0, true, snap, 10.0), 1);
        assert_eq!(view.epoch(0), 1);

        reader.sync(&view);
        let p = reader.get(0);
        assert!(p.active);
        assert_eq!(p.epoch, 1);
        assert_eq!(p.published_ms, 10.0);
        assert_eq!(p.gauges.total_backlog_ms, 42.0);
        // Slot 1 untouched.
        assert!(!reader.get(1).active);
    }

    #[test]
    fn sync_is_idempotent_and_tracks_latest_publish() {
        let view = ClusterView::new(1);
        let mut reader = ViewReader::new(&view);
        view.publish(0, true, GaugeSnapshot::default(), 1.0);
        view.publish(0, false, GaugeSnapshot::default(), 2.0);
        reader.sync(&view);
        assert_eq!(reader.get(0).epoch, 2);
        assert!(!reader.get(0).active);
        // No new publish: sync keeps the same snapshot.
        reader.sync(&view);
        assert_eq!(reader.get(0).epoch, 2);
    }

    #[test]
    fn readers_are_independent_and_concurrent_with_publishes() {
        let view = Arc::new(ClusterView::new(3));
        let publisher = {
            let view = Arc::clone(&view);
            std::thread::spawn(move || {
                for round in 0..200u64 {
                    for i in 0..3 {
                        view.publish(i, true, GaugeSnapshot::default(),
                                     round as f64);
                    }
                }
            })
        };
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let view = Arc::clone(&view);
                std::thread::spawn(move || {
                    let mut r = ViewReader::new(&view);
                    let mut last = [0u64; 3];
                    for _ in 0..500 {
                        r.sync(&view);
                        for i in 0..3 {
                            let e = r.get(i).epoch;
                            assert!(e >= last[i], "epoch went backwards");
                            last[i] = e;
                        }
                    }
                })
            })
            .collect();
        publisher.join().unwrap();
        for h in readers {
            h.join().unwrap();
        }
        let mut r = ViewReader::new(&view);
        r.sync(&view);
        assert_eq!(r.get(0).epoch, 200);
    }

    #[test]
    fn staleness_stat_records_mean_and_max() {
        let mut s = StalenessStat::default();
        assert_eq!(s.mean_ms(), 0.0);
        s.record(2.0);
        s.record(6.0);
        s.record(-1.0); // clock skew clamps to 0, never negative
        assert_eq!(s.decisions, 3);
        assert!((s.mean_ms() - 8.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.max_ms, 6.0);
        let mut t = StalenessStat::default();
        t.record(10.0);
        t.merge(&s);
        assert_eq!(t.decisions, 4);
        assert_eq!(t.max_ms, 10.0);
    }

    #[test]
    fn oldest_published_tracks_the_laggiest_slot() {
        let view = ClusterView::new(2);
        view.publish(0, true, GaugeSnapshot::default(), 5.0);
        view.publish(1, true, GaugeSnapshot::default(), 9.0);
        let reader = ViewReader::new(&view);
        assert_eq!(reader.oldest_published_ms(), 5.0);
    }
}
