//! The heterogeneous edge-cluster tier: SLO-aware routing across
//! multi-node serving pools, behind a sharded, cached front-end.
//!
//! BCEdge evaluates on a zoo of heterogeneous edge platforms (Table V:
//! Xavier NX / TX2 / Nano); this module crosses the node boundary the
//! same way the serving runtime crossed the worker boundary. Each
//! [`EdgeNode`] owns a full [`crate::serve::Server`] — workers, admission,
//! rebalancer, hot-model replication — configured with its own
//! [`crate::platform::PlatformSpec`] and network link, so nodes genuinely
//! differ in drain rate and distance. The front-end places every request
//! under a pluggable [`Router`] policy (round-robin,
//! join-shortest-backlog, power-of-two-choices, SLO-aware); the
//! SLO-aware policy prices estimated RTT + queue backlog + batch latency
//! against remaining slack and sheds at the edge
//! ([`crate::metrics::ShedReason::NoFeasibleNode`]) when no node can make
//! the deadline.
//!
//! The front-end itself is three layers (ROADMAP open item 3):
//!
//! * **Gossiped views** ([`view`]) — a publisher refreshes an
//!   epoch-stamped [`ClusterView`] slot per node every
//!   [`FrontEndConfig::gossip_ms`]; routing reads a lock-free cached
//!   copy instead of touching live gauges, with per-decision staleness
//!   recorded. A stale view can pick a node that has since begun
//!   draining: the node refuses, the front-end counts a **misroute**
//!   and re-routes — gossip's cost is counted, never lost.
//! * **Router shards** — [`FrontEndConfig::router_shards`] independent
//!   [`Router`]s (per-client-group), each with its own round-robin
//!   cursor and PCG stream split by shard id, all routing from the one
//!   shared view. The virtual arm stays bit-deterministic for any fixed
//!   `(seed, shards)`.
//! * **Result cache** ([`cache`]) — a TTL'd, single-flight cache keyed
//!   by `(model, input digest)` in front of routing: hits return
//!   instantly (zero slack spent — RTT is charged into the e2e budget,
//!   Eq. 2), identical in-flight requests coalesce onto one upstream
//!   outcome.
//!
//! Two clock arms, mirroring the serving runtime:
//!
//! * **wall** — live: every node is a real [`crate::serve::Server`];
//!   shard threads route from the gossiped view; a [`DrainScenario`] can
//!   take a node out mid-run (routing stops, the node flushes through
//!   the existing drain protocol, its accounted requests fold into
//!   cluster totals) and bring it back (a fresh server incarnation in a
//!   disjoint request-id window).
//! * **virtual** — deterministic: the whole cluster runs as ONE
//!   discrete-event simulation on the fabric ([`crate::sim`]) — the
//!   drain/rejoin lifecycle, gossip publisher ticks, arrival routing,
//!   and every node's serving pool (workers, rebalancer, replication)
//!   are logical processes on a single event heap. Routing reads the
//!   SAME live gauges a node's admission path exports, published at
//!   gossip ticks; the wall arm's router/view/cache stack runs
//!   unchanged. Same seed, same shard count, same report, bit for bit
//!   (`fabric`).
//!
//! Conservation holds cluster-wide through every drain/rejoin, extended
//! for the cache tier:
//! `outcomes + sheds + cache_served + leftover == attempts`, and
//! `dispatched + router_sheds + cache_served == attempts`, with outcome
//! ids unique across nodes (each node incarnation stamps ids in its own
//! window).
//!
//! Entry point: [`run_cluster`], surfaced as `bcedge bench-cluster`.

pub mod cache;
mod fabric;
pub mod netmodel;
pub mod node;
pub mod router;
pub mod view;

pub use cache::{CacheConfig, CacheLookup, CacheStats, ResultCache,
                VirtualCache, digest_for};
pub use netmodel::{LinkLoad, NetModel};
pub use node::{EdgeNode, FinishedNode, NodeSpec, NodeState};
pub use router::{NodeView, RoutePolicy, Router};
pub use view::{ClusterView, NodePublished, StalenessStat, ViewReader};

use netmodel::{payload_bytes, token_payload_bytes};

use crate::metrics::{Metrics, ShedReason};
use crate::predictor::{AdmissionMode, AdmissionQuantile};
use crate::telemetry::{RequestTrace, TraceReport, TraceRing, TraceVerdict,
                       TRACE_RING_CAP};
use crate::serve::worker::ServeEvent;
use crate::serve::{ClockKind, GaugeSnapshot, LoadGenConfig, LoadMode,
                   ServeConfig, INCARNATION_ID_STRIDE, NODE_ID_STRIDE};
use crate::util::rng::Pcg32;
use crate::util::time::WallClock;
use crate::workload::models::ModelId;
use crate::workload::request::Request;
use crate::workload::session::SessionSpec;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// Take one node out of the cluster mid-run and bring it back: routing
/// to `node` stops at `at_ms`, the node flushes through the drain
/// protocol, and a fresh incarnation rejoins at `rejoin_at_ms` (cluster
/// timebase, ms). On the virtual clock the window gates routing only —
/// the node's single simulation serves everything it was dealt.
#[derive(Clone, Copy, Debug)]
pub struct DrainScenario {
    /// Index into [`ClusterConfig::nodes`].
    pub node: usize,
    /// When routing to the node stops and its drain begins, ms.
    pub at_ms: f64,
    /// When the node rejoins (must be > `at_ms`), ms. A rejoin time past
    /// the horizon means the node stays out.
    pub rejoin_at_ms: f64,
}

/// Front-end tier knobs: router sharding, gossip cadence, result cache.
#[derive(Clone, Copy, Debug)]
pub struct FrontEndConfig {
    /// Independent router shards (client groups). Each shard routes from
    /// the shared gossiped view with its own cursor and PCG stream.
    pub router_shards: usize,
    /// Gossip period: how often each node's gauge snapshot is
    /// republished into the shared [`ClusterView`], ms. Bounds routing
    /// staleness.
    pub gossip_ms: f64,
    /// Optional deduplicating result cache in front of routing.
    pub cache: Option<CacheConfig>,
    /// Price each candidate node's link contention into routing
    /// (`--net-pricing contention`, the default): SLO-aware and
    /// predictive routing add the payload's contention-inflated
    /// transfer time to the node's cost. `false` is static-RTT pricing:
    /// the wire is still CHARGED per dispatch (physics doesn't change),
    /// but routing only sees the base RTT — the baseline the acceptance
    /// experiment compares against. No effect on infinite-bandwidth
    /// links, where every transfer term is 0 either way.
    pub contention_pricing: bool,
}

impl Default for FrontEndConfig {
    fn default() -> Self {
        FrontEndConfig {
            router_shards: 1,
            gossip_ms: 5.0,
            cache: None,
            contention_pricing: true,
        }
    }
}

/// Cluster-tier configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// The nodes, heterogeneous in platform, worker count, and link.
    pub nodes: Vec<NodeSpec>,
    /// Front-end routing policy.
    pub policy: RoutePolicy,
    /// Per-node serving template: scheduler, admission, queue capacity,
    /// rebalance/replication, gauge hints, and the clock arm. Platform
    /// and worker count are overridden per node from its [`NodeSpec`].
    pub serve: ServeConfig,
    /// Optional mid-run node drain/rejoin.
    pub drain: Option<DrainScenario>,
    /// Front-end tier: router shards, gossip cadence, result cache.
    pub frontend: FrontEndConfig,
}

impl Default for ClusterConfig {
    /// The paper's Table-V trio behind LAN-ish links, SLO-aware routing.
    fn default() -> Self {
        use crate::platform::PlatformSpec;
        ClusterConfig {
            nodes: vec![
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
                NodeSpec::new(PlatformSpec::jetson_tx2(), 2, 6.0),
                NodeSpec::new(PlatformSpec::jetson_nano(), 1, 12.0),
            ],
            policy: RoutePolicy::SloAware,
            serve: ServeConfig { clock: ClockKind::Wall, ..Default::default() },
            drain: None,
            frontend: FrontEndConfig::default(),
        }
    }
}

impl ClusterConfig {
    /// Start a validated-construction builder seeded with the defaults.
    /// [`ClusterConfigBuilder::build`] runs every check `run_cluster`
    /// performs plus the cross-tier ones only a builder can see early:
    /// per-node spec sanity, the request-id window grid, and trace-sample
    /// divisibility against the id-window stride.
    pub fn builder() -> ClusterConfigBuilder {
        ClusterConfigBuilder { cfg: ClusterConfig::default() }
    }

    fn validate(&self) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("cluster needs at least one node".into());
        }
        if let Some(d) = &self.drain {
            if d.node >= self.nodes.len() {
                return Err(format!(
                    "--drain-node {} out of range (cluster has {} nodes)",
                    d.node,
                    self.nodes.len()
                ));
            }
            if d.at_ms < 0.0 || d.rejoin_at_ms <= d.at_ms {
                return Err("drain window needs 0 <= drain-at < rejoin-at"
                    .into());
            }
        }
        if self.frontend.router_shards == 0 {
            return Err("--router-shards must be >= 1".into());
        }
        if !(self.frontend.gossip_ms > 0.0)
            || !self.frontend.gossip_ms.is_finite()
        {
            return Err("--gossip-ms must be a positive number".into());
        }
        if let Some(c) = &self.frontend.cache {
            if !(c.ttl_ms > 0.0) || !c.ttl_ms.is_finite() {
                return Err("--cache-ttl-ms must be a positive number".into());
            }
            if c.capacity == 0 {
                return Err("--cache-capacity must be >= 1".into());
            }
        }
        Ok(())
    }

    /// The admission reference batch every estimate is priced at.
    fn ref_batch(&self) -> usize {
        self.serve.admission.map(|a| a.ref_batch).unwrap_or(8).max(1)
    }
}

/// Validated constructor for [`ClusterConfig`]: chain setters, then
/// [`build`](Self::build).
#[derive(Clone, Debug)]
pub struct ClusterConfigBuilder {
    cfg: ClusterConfig,
}

impl ClusterConfigBuilder {
    /// Replace the node set (the default Table-V trio).
    pub fn nodes(mut self, nodes: Vec<NodeSpec>) -> Self {
        self.cfg.nodes = nodes;
        self
    }

    /// Front-end routing policy.
    pub fn policy(mut self, policy: RoutePolicy) -> Self {
        self.cfg.policy = policy;
        self
    }

    /// Per-node serving template (platform/workers overridden per node).
    pub fn serve(mut self, serve: ServeConfig) -> Self {
        self.cfg.serve = serve;
        self
    }

    /// Optional mid-run node drain/rejoin.
    pub fn drain(mut self, drain: Option<DrainScenario>) -> Self {
        self.cfg.drain = drain;
        self
    }

    /// Front-end tier: router shards, gossip cadence, result cache.
    pub fn frontend(mut self, frontend: FrontEndConfig) -> Self {
        self.cfg.frontend = frontend;
        self
    }

    /// Validate and return the configuration.
    pub fn build(self) -> Result<ClusterConfig, String> {
        let cfg = self.cfg;
        cfg.validate()?;
        for (i, n) in cfg.nodes.iter().enumerate() {
            if n.workers == 0 {
                return Err(format!("node {i} needs >= 1 worker"));
            }
            if !n.net.rtt_ms.is_finite() || n.net.rtt_ms < 0.0 {
                return Err(format!(
                    "node {i} needs a non-negative finite RTT"
                ));
            }
        }
        // The cluster tier owns request-id window assignment: every
        // (node, incarnation) claims `(n+1) * NODE_ID_STRIDE + inc *
        // INCARNATION_ID_STRIDE`, so a nonzero template base would
        // collide with some node's window.
        if cfg.serve.request_id_base != 0 {
            return Err(
                "cluster serve template must keep request_id_base 0 — \
                 nodes assign their own disjoint id windows"
                    .into(),
            );
        }
        // Same divisibility rule ServeConfigBuilder enforces for custom
        // bases, applied unconditionally here because cluster ids are
        // always windowed.
        let sample = cfg.serve.telemetry.trace_sample;
        if sample > 0 && INCARNATION_ID_STRIDE % sample != 0 {
            return Err(format!(
                "--trace-sample {sample} does not divide the id-window \
                 stride 2^32 (use a power of two) — per-node trace \
                 density would skew"
            ));
        }
        Ok(cfg)
    }
}

/// One node's line in the cluster report.
#[derive(Clone, Debug)]
pub struct NodeBreakdown {
    /// Platform name (Table V).
    pub platform: &'static str,
    /// Worker threads in the node's pool.
    pub workers: usize,
    /// Base link RTT, ms.
    pub rtt_ms: f64,
    /// Requests the router dispatched here.
    pub dispatched: u64,
    /// Requests the node completed.
    pub completed: usize,
    /// SLO violation rate over the node's executed requests.
    pub violation_rate: f64,
    /// Requests the node's own admission/backpressure shed.
    pub sheds: u64,
    /// Requests left queued at the node's horizon.
    pub leftover: usize,
    /// Serving segments (1 normally; 2 after a drain/rejoin cycle).
    pub segments: usize,
}

/// Front-end tier accounting, folded across every router shard.
#[derive(Clone, Copy, Debug)]
pub struct FrontEndReport {
    /// Router shards the run used.
    pub shards: usize,
    /// Gossip period, ms.
    pub gossip_ms: f64,
    /// Routing decisions made (requests that entered a router — cache-
    /// served requests never do; misroute re-routes don't re-count).
    pub decisions: u64,
    /// Stale-view dispatches refused by a non-active node and re-routed.
    pub misroutes: u64,
    /// Mean view staleness per routing decision, ms.
    pub staleness_mean_ms: f64,
    /// Worst view staleness any decision routed on, ms.
    pub staleness_max_ms: f64,
    /// Routing decisions priced by the gossiped predictor lanes (0 in
    /// snapshot mode or under non-SLO-aware policies).
    pub headroom_decisions: u64,
    /// Predictive decisions where ≥ 1 active candidate had no finite
    /// prediction and was priced by the snapshot oracle instead.
    pub headroom_fallbacks: u64,
    /// Decode steps the session tier spawned back into the cluster
    /// (0 for one-shot workloads). Every one is an extra attempt.
    pub session_steps: u64,
    /// Sessions ended by the tier itself: heads aborted at admission
    /// (cadence infeasible on the chosen node) plus steps orphaned by a
    /// mid-session drain.
    pub session_aborts: u64,
    /// Cache dispositions (None when the cache was off).
    pub cache: Option<CacheStats>,
}

impl FrontEndReport {
    /// Requests terminated at the cache (hits + coalesced): the
    /// `cache_served` term of the conservation identity.
    pub fn cache_served(&self) -> u64 {
        self.cache.map(|c| c.served()).unwrap_or(0)
    }
}

/// Final report of a cluster run: merged metrics plus per-node
/// breakdowns, front-end tier accounting, and the router's edge-shed
/// accounting.
pub struct ClusterReport {
    /// Cluster-merged metrics: every node's outcomes and sheds plus the
    /// router's [`ShedReason::NoFeasibleNode`] edge sheds.
    pub metrics: Metrics,
    /// Cluster serving horizon, ms (wall or virtual, matching the run).
    pub horizon_ms: f64,
    /// Requests the load generator offered to the cluster.
    pub attempts: u64,
    /// Requests still queued anywhere when the run ended.
    pub leftover: usize,
    /// Scheduling slots executed across every node.
    pub slots: u64,
    /// Node drains performed (the scenario fired).
    pub drains: u32,
    /// Node rejoins performed.
    pub rejoins: u32,
    /// The routing policy the run used.
    pub policy: RoutePolicy,
    /// Front-end tier accounting (shards, gossip staleness, misroutes,
    /// cache dispositions).
    pub frontend: FrontEndReport,
    /// Per-node accounting, in [`ClusterConfig::nodes`] order.
    pub per_node: Vec<NodeBreakdown>,
    /// Sampled request-lifecycle traces from every tier — engine spans
    /// (per node/worker) plus front-end-terminal records (cache
    /// dispositions, edge sheds) — and the folded SAC action histogram.
    /// Empty unless `--trace-sample` > 0.
    pub telemetry: TraceReport,
}

impl ClusterReport {
    /// Completed requests per second over the horizon.
    pub fn achieved_rps(&self) -> f64 {
        self.metrics.completed() as f64 / (self.horizon_ms / 1e3).max(1e-9)
    }

    /// Requests the router shed at the edge (no feasible node).
    pub fn router_sheds(&self) -> u64 {
        self.metrics.shed_by_reason(ShedReason::NoFeasibleNode)
    }

    /// Requests the front-end cache terminated (hits + coalesced).
    pub fn cache_served(&self) -> u64 {
        self.frontend.cache_served()
    }

    /// Human-readable summary (the `bcedge bench-cluster` output).
    pub fn print(&self) {
        let m = &self.metrics;
        println!(
            "cluster {} nodes | {} routing | {} slots | horizon {:.1}s",
            self.per_node.len(),
            self.policy.name(),
            self.slots,
            self.horizon_ms / 1e3
        );
        println!(
            "achieved {:.1} rps | e2e p50 {:.2} ms p99 {:.2} ms | \
             SLO violations {:.2}% | shed {:.2}% ({} at the edge)",
            self.achieved_rps(),
            m.latency_percentile(0.5),
            m.latency_percentile(0.99),
            100.0 * m.violation_rate(),
            100.0 * m.shed_rate(),
            self.router_sheds(),
        );
        println!(
            "front-end: {} shard(s) | gossip {:.1} ms | staleness mean \
             {:.2} ms max {:.2} ms | {} decisions | {} misroutes",
            self.frontend.shards,
            self.frontend.gossip_ms,
            self.frontend.staleness_mean_ms,
            self.frontend.staleness_max_ms,
            self.frontend.decisions,
            self.frontend.misroutes,
        );
        if self.frontend.headroom_decisions > 0 {
            println!(
                "headroom routing: {} decisions | {} snapshot fallbacks",
                self.frontend.headroom_decisions,
                self.frontend.headroom_fallbacks,
            );
        }
        if m.sessions_started() > 0 {
            println!(
                "sessions: {} started | {} decode steps spawned | \
                 {} aborted | TTFT misses {} | TPOT misses {}",
                m.sessions_started(),
                self.frontend.session_steps,
                self.frontend.session_aborts,
                m.ttft_misses(),
                m.tpot_misses(),
            );
        }
        if let Some(c) = &self.frontend.cache {
            println!(
                "cache: {:.1}% hit-rate | {} hits | {} coalesced | \
                 {} stale | {} orphaned | {} evicted",
                100.0 * c.hit_rate(),
                c.hits,
                c.coalesced,
                c.stale,
                c.orphaned,
                c.evictions,
            );
        }
        if self.drains > 0 {
            println!("lifecycle: {} drain(s), {} rejoin(s)", self.drains,
                     self.rejoins);
        }
        for (i, n) in self.per_node.iter().enumerate() {
            println!(
                "  node {i}: {:<12} ×{} workers | rtt {:>5.1} ms | \
                 dispatched {:>6} | completed {:>6} | viol {:>6.2}% | \
                 shed {:>5} | leftover {:>4} | segments {}",
                n.platform,
                n.workers,
                n.rtt_ms,
                n.dispatched,
                n.completed,
                100.0 * n.violation_rate,
                n.sheds,
                n.leftover,
                n.segments,
            );
        }
        if self.leftover > 0 {
            println!("leftover across the cluster: {}", self.leftover);
        }
    }
}

/// Run the load generator against a cluster configuration. Open loop on
/// either clock; closed loop needs the wall clock (real completions),
/// exactly like single-node serving.
pub fn run_cluster(cfg: &ClusterConfig, load: &LoadGenConfig)
                   -> Result<ClusterReport, String> {
    cfg.validate()?;
    if load.session.is_some() && cfg.frontend.cache.is_some() {
        return Err(
            "--workload llm cannot run with the result cache — session \
             rounds are stateful (each step extends its own context) and \
             never dedupe"
                .into(),
        );
    }
    let horizon_ms = load.seconds * 1e3;
    match (load.mode, cfg.serve.clock) {
        (LoadMode::Open, ClockKind::Virtual) => {
            Ok(fabric::run_virtual_open(cfg, load, horizon_ms))
        }
        (LoadMode::Open, ClockKind::Wall) => match load.session {
            Some(spec) => {
                if cfg.frontend.router_shards != 1 {
                    return Err(
                        "--workload llm on the wall clock runs one router \
                         shard (the completion loop is the only submitter \
                         of decode steps) — drop --router-shards"
                            .into(),
                    );
                }
                Ok(run_wall_llm(cfg, load, horizon_ms, spec))
            }
            None => Ok(run_wall_open(cfg, load, horizon_ms)),
        },
        (LoadMode::Closed { .. }, _) if load.session.is_some() => Err(
            "--workload llm needs the open loop (sessions are their own \
             feedback loop)"
                .into(),
        ),
        (LoadMode::Closed { concurrency }, ClockKind::Wall) => Ok(
            run_wall_closed(cfg, load, horizon_ms, concurrency.max(1)),
        ),
        (LoadMode::Closed { .. }, ClockKind::Virtual) => Err(
            "closed-loop cluster serving needs --clock wall (the feedback \
             loop runs on real completions)"
                .into(),
        ),
    }
}

// ---------------------------------------------------------------------
// Wall-clock (live) driver
// ---------------------------------------------------------------------

/// What the front-end did with one offered request.
enum FrontEndOutcome {
    /// Routed and accepted by a node's ingress as this request id.
    Dispatched(u64),
    /// Terminated at the cache (hit or coalesced) — never routed.
    CacheServed,
    /// Refused: at the edge (no feasible node, recorded in the shard's
    /// router metrics) or by the chosen node's own admission gate
    /// (recorded in the node's metrics).
    Shed(ShedReason),
}

/// One router shard of the live front-end: a private [`ViewReader`] over
/// the shared gossiped view, its own policy state (cursor, PCG stream),
/// its own link-jitter stream, and its own accounting. No lock is taken
/// on the routing path; dispatch touches only the chosen node.
struct FrontEndShard<'a> {
    nodes: &'a [EdgeNode],
    cluster_view: &'a ClusterView,
    reader: ViewReader,
    router: Router,
    /// Link-jitter draws only (routing itself uses the router's stream).
    link_rng: Pcg32,
    cache: Option<&'a ResultCache>,
    clock: WallClock,
    digest_seed: u64,
    repeat_fraction: f64,
    /// Edge sheds (no feasible node), folded into the final metrics.
    router_metrics: Metrics,
    attempts: u64,
    misroutes: u64,
    staleness: StalenessStat,
    shard_id: u32,
    /// Trace-index sampling stride for front-end span records (0 = off).
    /// Requests terminated before a node assigns an id — cache hits,
    /// coalesces, edge sheds — are sampled by trace index instead.
    trace_sample: u64,
    fe_ring: TraceRing,
    /// Reusable per-request routing views (the dispatch path allocates
    /// nothing in steady state).
    view_scratch: Vec<NodeView>,
    /// `Some(quantile)` iff SLO-aware routing should price nodes by
    /// their gossiped predictor lanes (predictive admission on).
    predictive_quantile: Option<AdmissionQuantile>,
    /// Predictive routing decisions and per-decision snapshot fallbacks
    /// (≥ 1 active candidate had no finite prediction).
    headroom_decisions: u64,
    headroom_fallbacks: u64,
    /// Per-node link-contention trackers, shared across shards (`None`
    /// when every link has infinite bandwidth — the lock is never taken
    /// on pre-existing configurations).
    links: Option<&'a [Mutex<LinkLoad>]>,
    /// Price link contention into routing (vs static-RTT pricing). The
    /// dispatch-side CHARGE happens either way.
    contention_pricing: bool,
    /// `Some` for LLM workloads: heads whose chosen node cannot hold
    /// TPOT cadence are aborted at admission instead of dispatched.
    session: Option<SessionSpec>,
}

impl<'a> FrontEndShard<'a> {
    fn new(shard: usize, cfg: &ClusterConfig, load: &LoadGenConfig,
           nodes: &'a [EdgeNode], cluster_view: &'a ClusterView,
           cache: Option<&'a ResultCache>, clock: WallClock,
           links: Option<&'a [Mutex<LinkLoad>]>)
           -> FrontEndShard<'a> {
        FrontEndShard {
            links,
            contention_pricing: cfg.frontend.contention_pricing,
            session: load.session,
            nodes,
            cluster_view,
            reader: ViewReader::new(cluster_view),
            router: Router::with_stream(cfg.policy, load.seed ^ 0xC1_05_7E,
                                        shard as u64),
            link_rng: Pcg32::new(load.seed ^ 0x11_4E, shard as u64),
            cache,
            clock,
            digest_seed: load.seed,
            repeat_fraction: load.repeat_fraction,
            router_metrics: Metrics::new(),
            attempts: 0,
            misroutes: 0,
            staleness: StalenessStat::default(),
            shard_id: shard as u32,
            trace_sample: cfg.serve.telemetry.trace_sample,
            fe_ring: TraceRing::new(TRACE_RING_CAP),
            view_scratch: Vec::with_capacity(nodes.len()),
            predictive_quantile: predictive_quantile(cfg),
            headroom_decisions: 0,
            headroom_fallbacks: 0,
        }
    }

    /// Record a front-end-terminal span (cache hit/coalesce, edge or
    /// node-ingress shed) when the trace index is sampled in. These
    /// requests never reach an engine, so the front-end is the only
    /// place they can be traced.
    fn record_frontend(&mut self, index: u64, model: ModelId,
                       verdict: TraceVerdict, arrival_ms: f64, slo_ms: f64,
                       net_ms: f64) {
        if self.trace_sample == 0 || index % self.trace_sample != 0 {
            return;
        }
        let mut t = RequestTrace::stub(index, model, verdict);
        t.shard = self.shard_id;
        t.arrival_ms = arrival_ms;
        t.slo_ms = slo_ms;
        t.net_ms = net_ms;
        self.fe_ring.push(t);
    }

    /// Offer one request (trace index `index`, for its input digest):
    /// cache first, then route from the gossiped view, charge the link,
    /// dispatch — re-routing around stale-view misroutes — or shed at
    /// the edge with a typed reason.
    fn submit(&mut self, index: u64, model: ModelId, slo_ms: f64,
              transmission_ms: f64) -> FrontEndOutcome {
        self.attempts += 1;
        let now = self.clock.now_ms();
        let lead_digest = match self.cache {
            Some(cache) => {
                let digest =
                    digest_for(self.digest_seed, index, self.repeat_fraction);
                match cache.lookup(model, digest, now) {
                    CacheLookup::Hit => {
                        self.record_frontend(index, model,
                                             TraceVerdict::CacheHit, now,
                                             slo_ms, transmission_ms);
                        return FrontEndOutcome::CacheServed;
                    }
                    CacheLookup::Coalesced => {
                        self.record_frontend(index, model,
                                             TraceVerdict::CacheCoalesced,
                                             now, slo_ms, transmission_ms);
                        return FrontEndOutcome::CacheServed;
                    }
                    CacheLookup::Lead => Some(digest),
                }
            }
            None => None,
        };
        match self.route_and_dispatch(model, slo_ms, transmission_ms, now) {
            Ok(id) => {
                if let (Some(cache), Some(digest)) = (self.cache, lead_digest)
                {
                    cache.commit_leader(model, digest, id);
                }
                FrontEndOutcome::Dispatched(id)
            }
            Err(reason) => {
                if let (Some(cache), Some(digest)) = (self.cache, lead_digest)
                {
                    cache.abort_leader(model, digest);
                }
                self.record_frontend(index, model,
                                     TraceVerdict::Shed(reason), now, slo_ms,
                                     transmission_ms);
                FrontEndOutcome::Shed(reason)
            }
        }
    }

    fn route_and_dispatch(&mut self, model: ModelId, slo_ms: f64,
                          transmission_ms: f64, now: f64)
                          -> Result<u64, ShedReason> {
        self.reader.sync(self.cluster_view);
        self.staleness.record(now - self.reader.oldest_published_ms());
        self.view_scratch.clear();
        for (i, node) in self.nodes.iter().enumerate() {
            let p = self.reader.get(i);
            self.view_scratch.push(if p.active {
                NodeView {
                    active: true,
                    rtt_ms: node.spec.net.rtt_ms,
                    backlog_ms: p.gauges.total_backlog_ms,
                    service_est_ms: p.gauges.service_est_ms(model),
                    predicted_e2e_ms: predicted_e2e(
                        self.predictive_quantile, &p.gauges, model,
                        node.spec.net.rtt_ms),
                    tx_est_ms: match self.links {
                        Some(links) if self.contention_pricing => links[i]
                            .lock()
                            .unwrap()
                            .estimate_ms(
                                now,
                                node.spec.net.transfer_ms(payload_bytes(model)),
                            ),
                        _ => 0.0,
                    },
                }
            } else {
                NodeView {
                    active: false,
                    rtt_ms: node.spec.net.rtt_ms,
                    backlog_ms: f64::INFINITY,
                    service_est_ms: f64::INFINITY,
                    predicted_e2e_ms: f64::NAN,
                    tx_est_ms: 0.0,
                }
            });
        }
        if self.predictive_quantile.is_some() {
            self.headroom_decisions += 1;
            if count_routing_fallback(&self.view_scratch) {
                self.headroom_fallbacks += 1;
            }
        }
        loop {
            match self
                .router
                .route(&self.view_scratch, slo_ms - transmission_ms)
            {
                Ok(i) => {
                    // A session whose per-round estimate on the chosen
                    // node cannot hold cadence is aborted at admission
                    // (every decode step would be born late).
                    if let Some(spec) = self.session {
                        if !spec.cadence_feasible(
                            self.view_scratch[i].service_est_ms,
                        ) {
                            self.router_metrics.record_shed(
                                model, ShedReason::SessionAbort);
                            return Err(ShedReason::SessionAbort);
                        }
                    }
                    let delay =
                        self.nodes[i].spec.net.delay_ms(&mut self.link_rng);
                    // Charge the payload's contention-inflated transfer
                    // time — on BOTH pricing modes, and before the node
                    // answers: the bytes ship before a refusal (or a
                    // stale-view misroute) can be learned.
                    let transfer = match self.links {
                        Some(links) => links[i].lock().unwrap().charge_ms(
                            now,
                            self.nodes[i]
                                .spec
                                .net
                                .transfer_ms(payload_bytes(model)),
                        ),
                        None => 0.0,
                    };
                    match self.nodes[i].try_dispatch(
                        model, slo_ms, transmission_ms + delay + transfer)
                    {
                        Some(res) => return res,
                        None => {
                            // Stale view: the node left Active after the
                            // last gossip tick. Count it, mask it, and
                            // re-route on the corrected candidate set.
                            self.misroutes += 1;
                            self.view_scratch[i].active = false;
                        }
                    }
                }
                Err(reason) => {
                    self.router_metrics.record_shed(model, reason);
                    return Err(reason);
                }
            }
        }
    }
}

/// The routing tier prices nodes by their gossiped predictor lanes only
/// when the serve template runs predictive admission AND the policy is
/// SLO-aware (the only policy that reads e2e estimates). Returns the
/// quantile to price at, `None` for pure snapshot routing.
fn predictive_quantile(cfg: &ClusterConfig) -> Option<AdmissionQuantile> {
    if cfg.policy != RoutePolicy::SloAware {
        return None;
    }
    cfg.serve
        .admission
        .filter(|a| matches!(a.mode, AdmissionMode::Predictive))
        .map(|a| a.quantile)
}

/// Predicted end-to-end completion for one candidate node (RTT charged
/// in), or NaN when predictive routing is off or the node's gossiped
/// predictor lanes are cold — `estimated_e2e_ms` then falls back to the
/// snapshot price for that node.
fn predicted_e2e(quantile: Option<AdmissionQuantile>, gauges: &GaugeSnapshot,
                 model: ModelId, rtt_ms: f64) -> f64 {
    match quantile {
        Some(q) => gauges
            .predicted_service_ms(model, q)
            .map(|s| rtt_ms + s)
            .unwrap_or(f64::NAN),
        None => f64::NAN,
    }
}

/// One routing decision counts as a snapshot fallback when any active
/// candidate lacked a finite prediction — some node was priced by the
/// snapshot oracle instead of the predictor.
fn count_routing_fallback(views: &[NodeView]) -> bool {
    views.iter().any(|v| v.active && !v.predicted_e2e_ms.is_finite())
}

/// Drain/rejoin scenario bookkeeping, driven from the (single) cluster
/// lifecycle thread.
struct Lifecycle {
    drain: Option<DrainScenario>,
    drains: u32,
    rejoins: u32,
}

impl Lifecycle {
    fn new(drain: Option<DrainScenario>) -> Self {
        Lifecycle { drain, drains: 0, rejoins: 0 }
    }

    /// Advance the scenario against the cluster clock.
    fn tick(&mut self, nodes: &[EdgeNode], now_ms: f64) {
        let Some(d) = self.drain else { return };
        let node = &nodes[d.node];
        match node.state() {
            NodeState::Active => {
                if self.drains == 0 && now_ms >= d.at_ms {
                    node.begin_drain();
                    self.drains += 1;
                }
            }
            NodeState::Draining => {
                node.poll_drained();
            }
            NodeState::Drained => {
                if self.drains > 0 && self.rejoins == 0
                    && now_ms >= d.rejoin_at_ms
                {
                    node.rejoin();
                    self.rejoins += 1;
                }
            }
        }
    }
}

/// Publish every node's current state into the shared view (one gossip
/// tick).
fn publish_all(view: &ClusterView, nodes: &[EdgeNode], clock: &WallClock) {
    for (i, n) in nodes.iter().enumerate() {
        let now = clock.now_ms();
        match n.snapshot() {
            Some(g) => view.publish(i, true, g, now),
            None => view.publish(i, false, GaugeSnapshot::default(), now),
        };
    }
}

/// Per-node link-contention trackers for the wall drivers, or `None`
/// when every link has infinite bandwidth — the common case, which then
/// never takes a lock on the routing path.
fn link_loads(cfg: &ClusterConfig) -> Option<Vec<Mutex<LinkLoad>>> {
    if cfg.nodes.iter().any(|n| n.net.bw_mbps.is_finite()) {
        Some(cfg.nodes.iter().map(|_| Mutex::new(LinkLoad::new())).collect())
    } else {
        None
    }
}

/// Build and start the cluster's nodes.
fn start_nodes(cfg: &ClusterConfig,
               events_tx: Option<mpsc::Sender<ServeEvent>>) -> Vec<EdgeNode> {
    let nodes: Vec<EdgeNode> = cfg
        .nodes
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            EdgeNode::new(spec.clone(), &cfg.serve, i, events_tx.clone())
        })
        .collect();
    for node in &nodes {
        node.start();
    }
    nodes
}

/// Fold the per-shard front-end accounting into one report (shard-index
/// order, so the merge is deterministic). Consumes the shard structs —
/// they borrow the nodes, and the nodes cannot be shut down and merged
/// until those borrows end.
fn merge_shards(cfg: &ClusterConfig, shards: Vec<FrontEndShard<'_>>)
                -> (Metrics, u64, FrontEndReport, TraceReport) {
    let mut metrics = Metrics::new();
    let mut attempts = 0u64;
    let mut misroutes = 0u64;
    let mut headroom_decisions = 0u64;
    let mut headroom_fallbacks = 0u64;
    let mut staleness = StalenessStat::default();
    let mut telemetry = TraceReport::default();
    let shard_count = shards.len();
    for mut fe in shards {
        telemetry.traces.extend(fe.fe_ring.drain());
        telemetry.dropped += fe.fe_ring.dropped();
        metrics.absorb(fe.router_metrics);
        attempts += fe.attempts;
        misroutes += fe.misroutes;
        headroom_decisions += fe.headroom_decisions;
        headroom_fallbacks += fe.headroom_fallbacks;
        staleness.merge(&fe.staleness);
    }
    metrics.record_headroom(headroom_decisions, headroom_fallbacks);
    let frontend = FrontEndReport {
        shards: shard_count,
        gossip_ms: cfg.frontend.gossip_ms,
        decisions: staleness.decisions,
        misroutes,
        staleness_mean_ms: staleness.mean_ms(),
        staleness_max_ms: staleness.max_ms,
        headroom_decisions,
        headroom_fallbacks,
        session_steps: metrics.session_steps_spawned(),
        session_aborts: metrics.shed_by_reason(ShedReason::SessionAbort),
        cache: None, // filled by finish_wall once the collector drains
    };
    (metrics, attempts, frontend, telemetry)
}

/// Fold one finished node into the cluster totals and breakdown rows.
fn merge_node(metrics: &mut Metrics, leftover: &mut usize, slots: &mut u64,
              per_node: &mut Vec<NodeBreakdown>,
              telemetry: &mut TraceReport, fin: FinishedNode) {
    let mut nm = Metrics::new();
    let mut node_leftover = 0usize;
    let mut node_slots = 0u64;
    let segments = fin.segments.len();
    for seg in fin.segments {
        nm.absorb(seg.metrics);
        telemetry.merge(seg.telemetry);
        node_leftover += seg.leftover;
        node_slots += seg.slots;
    }
    per_node.push(NodeBreakdown {
        platform: fin.spec.platform.name,
        workers: fin.spec.workers,
        rtt_ms: fin.spec.net.rtt_ms,
        dispatched: fin.dispatched,
        completed: nm.completed(),
        violation_rate: nm.violation_rate(),
        sheds: nm.shed_total(),
        leftover: node_leftover,
        segments,
    });
    metrics.absorb(nm);
    *leftover += node_leftover;
    *slots += node_slots;
}

/// Spawn the cache-fill collector: completion events from every node
/// resolve pending cache leaders. Joined after the nodes shut down (all
/// event senders dropped ends the loop).
fn spawn_cache_collector(cache: &Arc<ResultCache>,
                         rx: mpsc::Receiver<ServeEvent>, clock: WallClock)
                         -> std::thread::JoinHandle<()> {
    let cache = Arc::clone(cache);
    std::thread::Builder::new()
        .name("bcedge-cache-fill".into())
        .spawn(move || {
            for ev in rx {
                if let ServeEvent::Completed(c) = ev {
                    cache.on_completed(c.id, clock.now_ms());
                }
            }
        })
        .expect("spawn cache-fill collector")
}

/// Open loop on the wall clock: the trace is dealt round-robin across
/// `router_shards` submitter threads, each pacing its slice against the
/// shared cluster clock and routing from the gossiped view; a publisher
/// thread refreshes the view every gossip period, and the main thread
/// drives the drain/rejoin lifecycle.
fn run_wall_open(cfg: &ClusterConfig, load: &LoadGenConfig,
                 horizon_ms: f64) -> ClusterReport {
    let trace = load.generator().generate_horizon(horizon_ms);
    let k = cfg.frontend.router_shards;
    let mut slices: Vec<Vec<(u64, Request)>> =
        (0..k).map(|_| Vec::new()).collect();
    for (i, r) in trace.into_iter().enumerate() {
        slices[i % k].push((i as u64, r));
    }

    let cache = cfg.frontend.cache.map(|c| Arc::new(ResultCache::new(c)));
    let (events_tx, events_rx) = match &cache {
        Some(_) => {
            let (tx, rx) = mpsc::channel();
            (Some(tx), Some(rx))
        }
        None => (None, None),
    };
    let nodes = start_nodes(cfg, events_tx.clone());
    let clock = WallClock::new();
    let collector = match (&cache, events_rx) {
        (Some(cache), Some(rx)) => {
            Some(spawn_cache_collector(cache, rx, clock))
        }
        _ => None,
    };
    let cluster_view = ClusterView::new(nodes.len());
    publish_all(&cluster_view, &nodes, &clock);
    let links = link_loads(cfg);

    let stop_gossip = AtomicBool::new(false);
    let mut lifecycle = Lifecycle::new(cfg.drain);
    let shard_results: Vec<FrontEndShard> = std::thread::scope(|s| {
        let gossip = s.spawn(|| {
            while !stop_gossip.load(Ordering::Relaxed) {
                publish_all(&cluster_view, &nodes, &clock);
                std::thread::sleep(Duration::from_secs_f64(
                    cfg.frontend.gossip_ms / 1e3,
                ));
            }
        });
        let handles: Vec<_> = slices
            .into_iter()
            .enumerate()
            .map(|(shard, slice)| {
                let mut fe = FrontEndShard::new(
                    shard, cfg, load, &nodes, &cluster_view,
                    cache.as_deref(), clock, links.as_deref());
                s.spawn(move || {
                    for (index, r) in slice {
                        let wait_ms = r.arrival_ms - fe.clock.now_ms();
                        if wait_ms > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(
                                wait_ms / 1e3,
                            ));
                        }
                        // Rejections are accounted (router edge sheds in
                        // the shard, node ingress sheds at the node);
                        // nothing more to do.
                        let _ = fe.submit(index, r.model, r.slo_ms,
                                          r.transmission_ms);
                    }
                    fe
                })
            })
            .collect();
        // The main thread owns the lifecycle: capped sleeps so the
        // drain/rejoin scenario fires on time even through an arrival
        // lull, ticking to the horizon so a rejoin scheduled after the
        // last arrival still happens inside the run.
        loop {
            lifecycle.tick(&nodes, clock.now_ms());
            let wait_ms = horizon_ms - clock.now_ms();
            if wait_ms <= 0.0 {
                break;
            }
            std::thread::sleep(Duration::from_secs_f64(
                wait_ms.min(5.0) / 1e3,
            ));
        }
        let results = handles
            .into_iter()
            .map(|h| h.join().expect("front-end shard panicked"))
            .collect();
        stop_gossip.store(true, Ordering::Relaxed);
        gossip.join().expect("gossip publisher panicked");
        results
    });

    let horizon_actual = clock.now_ms();
    drop(events_tx);
    let (metrics, attempts, frontend, telemetry) =
        merge_shards(cfg, shard_results);
    finish_wall(cfg, nodes, metrics, attempts, frontend, telemetry, cache,
                collector, lifecycle, horizon_actual)
}

/// Closed loop on the wall clock: keep `concurrency` requests in flight
/// across the whole cluster, launching the next the moment one
/// terminates anywhere (completion or engine-gate shed — every node
/// streams its terminal events into one channel). The feedback loop is
/// inherently serial, so it runs one front-end shard and folds gossip
/// publishing into the loop itself; cache hits complete instantly and
/// never occupy an in-flight slot.
fn run_wall_closed(cfg: &ClusterConfig, load: &LoadGenConfig,
                   horizon_ms: f64, concurrency: usize) -> ClusterReport {
    let (tx, rx) = mpsc::channel();
    let cache = cfg.frontend.cache.map(|c| Arc::new(ResultCache::new(c)));
    let nodes = start_nodes(cfg, Some(tx.clone()));
    let clock = WallClock::new();
    let cluster_view = ClusterView::new(nodes.len());
    publish_all(&cluster_view, &nodes, &clock);
    let links = link_loads(cfg);
    let mut fe = FrontEndShard::new(0, cfg, load, &nodes, &cluster_view,
                                    cache.as_deref(), clock,
                                    links.as_deref());
    let mut lifecycle = Lifecycle::new(cfg.drain);
    let mut rng = Pcg32::seeded(load.seed);
    let mut rr = 0usize;
    let slo_scale = load.slo_scale;
    // The SAME closed-loop client model as single-node bench-serve
    // (shared launcher: model rotation, transmission stamp, SLO scale),
    // submitting through the front-end instead of one ingress. Requests
    // every node refuses — or the router edge-sheds — free their slot;
    // cache-served requests are terminal instantly, so the launcher
    // immediately offers the next one.
    fn launch_one(fe: &mut FrontEndShard<'_>, rng: &mut Pcg32,
                  rr: &mut usize, slo_scale: f64) -> Option<bool> {
        let mut cache_served = false;
        let accepted = crate::serve::loadgen::launch_round_robin(
            rng, rr, slo_scale,
            |m, slo, tx_ms| {
                let index = fe.attempts;
                match fe.submit(index, m, slo, tx_ms) {
                    FrontEndOutcome::Dispatched(id) => Ok(id),
                    FrontEndOutcome::CacheServed => {
                        cache_served = true;
                        Ok(u64::MAX)
                    }
                    FrontEndOutcome::Shed(reason) => Err(reason),
                }
            });
        if accepted { Some(!cache_served) } else { None }
    }
    // Launch until one request actually occupies a slot (cache-served
    // ones are already terminal), or until everything is refused.
    let mut pump = |fe: &mut FrontEndShard<'_>, rng: &mut Pcg32,
                    rr: &mut usize| -> bool {
        loop {
            match launch_one(fe, rng, rr, slo_scale) {
                Some(true) => return true,
                Some(false) => continue,
                None => return false,
            }
        }
    };
    let mut in_flight = 0usize;
    for _ in 0..concurrency {
        if pump(&mut fe, &mut rng, &mut rr) {
            in_flight += 1;
        }
    }
    let mut last_gossip = clock.now_ms();
    while clock.now_ms() < horizon_ms {
        lifecycle.tick(&nodes, clock.now_ms());
        let now = clock.now_ms();
        if now - last_gossip >= cfg.frontend.gossip_ms {
            publish_all(&cluster_view, &nodes, &clock);
            last_gossip = now;
        }
        match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(event) => {
                if let (Some(cache), ServeEvent::Completed(c)) =
                    (&cache, &event)
                {
                    cache.on_completed(c.id, clock.now_ms());
                }
                in_flight = in_flight.saturating_sub(1);
                if pump(&mut fe, &mut rng, &mut rr) {
                    in_flight += 1;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // Top back up (e.g. every node was refusing earlier).
                while in_flight < concurrency
                    && pump(&mut fe, &mut rng, &mut rr)
                {
                    in_flight += 1;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    let horizon_actual = clock.now_ms();
    drop(tx);
    let (metrics, attempts, frontend, telemetry) =
        merge_shards(cfg, vec![fe]);
    finish_wall(cfg, nodes, metrics, attempts, frontend, telemetry, cache,
                None, lifecycle, horizon_actual)
}

/// Open loop, LLM-style sessions on the wall clock. Heads are paced from
/// the arrival trace through the (single) front-end shard — routed,
/// cadence-gated, link-charged like any other request — and the cluster
/// completion stream drives the decode loops: each completed round
/// re-submits the next step DIRECTLY to the node that served it (decode
/// state is node-local; re-routing a step would re-ship it), paying the
/// token payload's contention-inflated link time and the node's own
/// admission gate. The serving node is recovered from the completion id
/// itself — cluster ids are windowed per `(node, incarnation)`, so
/// `id / NODE_ID_STRIDE - 1` names the node with no side table.
///
/// Single-threaded like [`run_wall_closed`] (one shard, in-loop gossip
/// and lifecycle): the completion loop is the only submitter of steps,
/// so shard fan-out has nothing to parallelize.
fn run_wall_llm(cfg: &ClusterConfig, load: &LoadGenConfig, horizon_ms: f64,
                spec: SessionSpec) -> ClusterReport {
    let trace = load.head_trace(horizon_ms);
    let (tx, rx) = mpsc::channel();
    let nodes = start_nodes(cfg, Some(tx.clone()));
    let clock = WallClock::new();
    let cluster_view = ClusterView::new(nodes.len());
    publish_all(&cluster_view, &nodes, &clock);
    let links = link_loads(cfg);
    let mut fe = FrontEndShard::new(0, cfg, load, &nodes, &cluster_view,
                                    None, clock, links.as_deref());
    let mut lifecycle = Lifecycle::new(cfg.drain);
    // Live ingress id of every in-flight round → its step index.
    let mut steps: HashMap<u64, u64> = HashMap::new();
    let on_event = |ev: ServeEvent, fe: &mut FrontEndShard<'_>,
                    steps: &mut HashMap<u64, u64>| {
        let ServeEvent::Completed(c) = ev else { return };
        let Some(k) = steps.remove(&c.id) else { return };
        fe.router_metrics.record_dual_slo(k, c.violated);
        if k >= spec.decode_steps as u64 {
            return; // session complete
        }
        let node = (c.id / NODE_ID_STRIDE) as usize;
        if node == 0 || node > nodes.len() {
            return; // not a node-windowed id; nothing to re-dispatch to
        }
        let node = node - 1;
        fe.attempts += 1;
        fe.router_metrics.record_session_step();
        let tx_ms = match &links {
            Some(l) => l[node].lock().unwrap().charge_ms(
                fe.clock.now_ms(),
                cfg.nodes[node]
                    .net
                    .transfer_ms(token_payload_bytes(c.model)),
            ),
            None => 0.0,
        };
        match nodes[node].try_dispatch(c.model, spec.tpot_ms, tx_ms) {
            Some(Ok(id)) => {
                steps.insert(id, k + 1);
            }
            // The node's own admission gate accounted the shed.
            Some(Err(_)) => {}
            // Node draining/drained mid-session: the step has nowhere to
            // go (decode state is node-local) — the session ends here.
            None => {
                fe.router_metrics
                    .record_shed(c.model, ShedReason::SessionAbort);
            }
        }
    };
    let mut last_gossip = clock.now_ms();
    for (index, r) in trace.into_iter().enumerate() {
        loop {
            lifecycle.tick(&nodes, clock.now_ms());
            let now = clock.now_ms();
            if now - last_gossip >= cfg.frontend.gossip_ms {
                publish_all(&cluster_view, &nodes, &clock);
                last_gossip = now;
            }
            let wait_ms = r.arrival_ms - clock.now_ms();
            if wait_ms <= 0.0 {
                break;
            }
            match rx.recv_timeout(Duration::from_secs_f64(
                (wait_ms / 1e3).min(0.005),
            )) {
                Ok(ev) => on_event(ev, &mut fe, &mut steps),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        if let FrontEndOutcome::Dispatched(id) =
            fe.submit(index as u64, r.model, r.slo_ms, r.transmission_ms)
        {
            fe.router_metrics.record_session_start();
            steps.insert(id, 0);
        }
    }
    // Past the last head: keep the decode loops running to the horizon.
    while clock.now_ms() < horizon_ms {
        lifecycle.tick(&nodes, clock.now_ms());
        let now = clock.now_ms();
        if now - last_gossip >= cfg.frontend.gossip_ms {
            publish_all(&cluster_view, &nodes, &clock);
            last_gossip = now;
        }
        match rx.recv_timeout(Duration::from_millis(5)) {
            Ok(ev) => on_event(ev, &mut fe, &mut steps),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    let horizon_actual = clock.now_ms();
    drop(tx);
    drop(on_event);
    let (metrics, attempts, frontend, telemetry) =
        merge_shards(cfg, vec![fe]);
    finish_wall(cfg, nodes, metrics, attempts, frontend, telemetry, None,
                None, lifecycle, horizon_actual)
}

/// Stop every node (draining live servers, waiting out any pending
/// background drain), join the cache collector, and merge the cluster
/// report. Callers fold their shards via [`merge_shards`] first — the
/// shard structs borrow the nodes this function consumes.
#[allow(clippy::too_many_arguments)]
fn finish_wall(cfg: &ClusterConfig, nodes: Vec<EdgeNode>,
               mut metrics: Metrics, attempts: u64,
               mut frontend: FrontEndReport,
               mut telemetry: TraceReport,
               cache: Option<Arc<ResultCache>>,
               collector: Option<std::thread::JoinHandle<()>>,
               lifecycle: Lifecycle, horizon_ms: f64) -> ClusterReport {
    let mut leftover = 0usize;
    let mut slots = 0u64;
    let mut per_node = Vec::with_capacity(nodes.len());
    for node in nodes {
        let fin = node.finish();
        merge_node(&mut metrics, &mut leftover, &mut slots, &mut per_node,
                   &mut telemetry, fin);
    }
    // Every event sender is gone once the nodes are down: the collector
    // drains its queue and exits; its final counters are authoritative.
    if let Some(h) = collector {
        h.join().expect("cache-fill collector panicked");
    }
    if let Some(c) = &cache {
        frontend.cache = Some(c.stats());
    }
    ClusterReport {
        metrics,
        horizon_ms,
        attempts,
        leftover,
        slots,
        drains: lifecycle.drains,
        rejoins: lifecycle.rejoins,
        policy: cfg.policy,
        frontend,
        per_node,
        telemetry,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::PlatformSpec;
    use crate::serve::SchedulerSpec;
    use std::collections::HashSet;

    fn hetero_cfg(policy: RoutePolicy, clock: ClockKind,
                  drain: Option<DrainScenario>) -> ClusterConfig {
        ClusterConfig {
            nodes: vec![
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
                NodeSpec::new(PlatformSpec::jetson_tx2(), 2, 6.0),
                NodeSpec::new(PlatformSpec::jetson_nano(), 1, 12.0),
            ],
            policy,
            serve: ServeConfig {
                clock,
                scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 2 },
                admission: None,
                queue_capacity: 4096,
                ..Default::default()
            },
            drain,
            frontend: FrontEndConfig::default(),
        }
    }

    fn assert_conserved(report: &ClusterReport) {
        // Extended identity: the cache is a third terminal disposition.
        assert_eq!(report.metrics.outcomes().len() as u64
                       + report.metrics.shed_total()
                       + report.cache_served()
                       + report.leftover as u64,
                   report.attempts,
                   "requests lost or double-counted cluster-wide");
        let mut seen = HashSet::new();
        for o in report.metrics.outcomes() {
            assert!(seen.insert(o.id),
                    "request {} served twice across the cluster", o.id);
        }
        // Router edge sheds + cache-served + per-node dispatch cover
        // every attempt (misroutes re-route, so they never leak).
        let dispatched: u64 =
            report.per_node.iter().map(|n| n.dispatched).sum();
        assert_eq!(dispatched + report.router_sheds()
                       + report.cache_served(),
                   report.attempts);
    }

    /// The builder accepts the defaults and rejects empty clusters,
    /// malformed drain windows, degenerate front-end knobs, template
    /// configs that fight the id-window grid, and sampling rates that
    /// skew per-node trace density.
    #[test]
    fn cluster_builder_validates() {
        assert!(ClusterConfig::builder().build().is_ok());
        assert!(ClusterConfig::builder().nodes(vec![]).build().is_err());
        assert!(ClusterConfig::builder()
            .drain(Some(DrainScenario {
                node: 9,
                at_ms: 1.0,
                rejoin_at_ms: 2.0,
            }))
            .build()
            .is_err());
        assert!(ClusterConfig::builder()
            .drain(Some(DrainScenario {
                node: 0,
                at_ms: 5.0,
                rejoin_at_ms: 5.0,
            }))
            .build()
            .is_err());
        assert!(ClusterConfig::builder()
            .frontend(FrontEndConfig {
                router_shards: 0,
                ..Default::default()
            })
            .build()
            .is_err());
        assert!(ClusterConfig::builder()
            .frontend(FrontEndConfig {
                gossip_ms: 0.0,
                ..Default::default()
            })
            .build()
            .is_err());
        // Nodes assign their own id windows; a nonzero template base
        // would collide with one of them.
        assert!(ClusterConfig::builder()
            .serve(ServeConfig {
                request_id_base: INCARNATION_ID_STRIDE,
                ..Default::default()
            })
            .build()
            .is_err());
        // Cluster ids are always windowed: 1/N sampling must divide the
        // stride even though the template base is 0.
        let mut sampled = ServeConfig::default();
        sampled.telemetry.trace_sample = 100;
        assert!(ClusterConfig::builder().serve(sampled).build().is_err());
        let mut pow2 = ServeConfig::default();
        pow2.telemetry.trace_sample = 64;
        assert!(ClusterConfig::builder().serve(pow2).build().is_ok());
    }

    /// Satellite acceptance: virtual-clock cluster runs are conserved and
    /// bit-deterministic from the seed — identical outcomes, slots, and
    /// per-node dispatch counts across two runs — with unique outcome ids
    /// across nodes and the drain window gating routing mid-trace.
    #[test]
    fn virtual_cluster_conserves_and_is_deterministic() {
        let drain = DrainScenario {
            node: 1,
            at_ms: 5_000.0,
            rejoin_at_ms: 10_000.0,
        };
        let cfg = hetero_cfg(RoutePolicy::JoinShortestBacklog,
                             ClockKind::Virtual, Some(drain));
        let load = LoadGenConfig {
            rps: 150.0,
            seconds: 20.0,
            seed: 42,
            slo_scale: 3.0,
            ..Default::default()
        };
        let a = run_cluster(&cfg, &load).unwrap();
        let b = run_cluster(&cfg, &load).unwrap();
        assert!(a.attempts > 1_000, "trace too small to mean anything");
        assert_conserved(&a);
        assert_conserved(&b);
        assert_eq!(a.metrics.outcomes(), b.metrics.outcomes(),
                   "virtual cluster runs diverged on the same seed");
        assert_eq!(a.slots, b.slots);
        let dispatched = |r: &ClusterReport| -> Vec<u64> {
            r.per_node.iter().map(|n| n.dispatched).collect()
        };
        assert_eq!(dispatched(&a), dispatched(&b));
        // The drain window was honored and the node came back.
        assert_eq!(a.drains, 1);
        assert_eq!(a.rejoins, 1);
        // The fast node carries the bulk under join-shortest-backlog
        // (its gossiped backlog gauge drains ~9× faster than the Nano's
        // fills).
        assert!(a.per_node[0].dispatched > a.per_node[2].dispatched,
                "routing ignored the heterogeneity: {:?}", dispatched(&a));
        assert!(a.metrics.completed() > 0);
    }

    /// Tentpole acceptance (cluster tracing): with `--trace-sample` on,
    /// the virtual cached run emits engine spans AND front-end-terminal
    /// records (cache dispositions), bit-identically across runs, without
    /// perturbing the outcome stream; completed spans sum to e2e and stay
    /// attributable per node through the merge.
    #[test]
    fn virtual_cluster_traces_cover_every_tier_deterministically() {
        use crate::telemetry::TraceVerdict;
        let mut cfg =
            hetero_cfg(RoutePolicy::SloAware, ClockKind::Virtual, None);
        cfg.frontend.cache =
            Some(CacheConfig { ttl_ms: 500.0, capacity: 4096 });
        cfg.frontend.router_shards = 2;
        let load = LoadGenConfig {
            rps: 150.0,
            seconds: 15.0,
            seed: 7,
            slo_scale: 3.0,
            repeat_fraction: 0.5,
            ..Default::default()
        };
        let plain = run_cluster(&cfg, &load).unwrap();
        assert!(plain.telemetry.traces.is_empty(),
                "tracing on without --trace-sample");
        cfg.serve.telemetry.trace_sample = 8;
        let a = run_cluster(&cfg, &load).unwrap();
        let b = run_cluster(&cfg, &load).unwrap();
        assert_conserved(&a);
        assert_eq!(a.metrics.outcomes(), plain.metrics.outcomes(),
                   "tracing perturbed the cluster run");
        assert_eq!(a.telemetry.traces, b.telemetry.traces,
                   "traced cluster runs diverged on the same seed");
        let completed = a.telemetry.traces.iter()
            .filter(|t| t.verdict == TraceVerdict::Completed)
            .count();
        let cache_records = a.telemetry.traces.iter()
            .filter(|t| matches!(t.verdict, TraceVerdict::CacheHit
                                 | TraceVerdict::CacheCoalesced))
            .count();
        assert!(completed > 0, "no engine spans sampled");
        assert!(cache_records > 0, "no cache dispositions sampled");
        for t in &a.telemetry.traces {
            if t.verdict == TraceVerdict::Completed {
                assert!((t.span_sum_ms() - t.e2e_ms).abs() < 1e-6,
                        "spans don't sum to e2e for id {}", t.id);
            }
        }
        let nodes: HashSet<u32> = a.telemetry.traces.iter()
            .filter(|t| t.verdict == TraceVerdict::Completed)
            .map(|t| t.node)
            .collect();
        assert!(nodes.len() > 1, "all spans from one node: {nodes:?}");
    }

    /// Tentpole acceptance (virtual arm): sharded routing from the
    /// gossiped view is bit-deterministic for any fixed `(seed, K)` —
    /// every policy's state (cursor, PCG stream) is shard-local — and
    /// the extended conservation identity holds with the cache on and a
    /// repeat-heavy workload.
    #[test]
    fn virtual_sharded_cached_runs_are_bit_deterministic_per_shard_count() {
        let mut cfg = hetero_cfg(RoutePolicy::PowerOfTwoChoices,
                                 ClockKind::Virtual, None);
        cfg.frontend.cache =
            Some(CacheConfig { ttl_ms: 500.0, capacity: 4096 });
        let load = LoadGenConfig {
            rps: 200.0,
            seconds: 10.0,
            seed: 9,
            slo_scale: 3.0,
            repeat_fraction: 0.5,
            ..Default::default()
        };
        let run_k = |k: usize| -> ClusterReport {
            let mut c = cfg.clone();
            c.frontend.router_shards = k;
            run_cluster(&c, &load).unwrap()
        };
        for k in [1usize, 4] {
            let a = run_k(k);
            let b = run_k(k);
            assert_conserved(&a);
            assert_conserved(&b);
            assert_eq!(a.metrics.outcomes(), b.metrics.outcomes(),
                       "diverged on the same (seed, {k} shards)");
            assert_eq!(a.frontend.cache, b.frontend.cache);
            assert_eq!(a.slots, b.slots);
            assert_eq!(a.frontend.shards, k);
            // The repeat-heavy workload actually exercised the cache.
            let cache = a.frontend.cache.unwrap();
            assert!(cache.served() > 0, "cache never served ({k} shards)");
            assert!(cache.hit_rate() > 0.1,
                    "hit rate implausibly low: {}", cache.hit_rate());
        }
        // Every attempt either terminated at the cache or made exactly
        // one routing decision — no request slipped between the tiers.
        let one = run_k(1);
        assert_eq!(one.frontend.cache.unwrap().served()
                       + one.frontend.decisions,
                   one.attempts);
    }

    /// Cache TTL semantics on the deterministic arm: with a TTL shorter
    /// than the popular digests' re-arrival gap, entries expire and the
    /// repeats return to routing (stale > 0) instead of being served
    /// forever — and conservation still holds exactly.
    #[test]
    fn virtual_cache_ttl_expiry_returns_requests_to_routing() {
        let mut cfg = hetero_cfg(RoutePolicy::JoinShortestBacklog,
                                 ClockKind::Virtual, None);
        let load = LoadGenConfig {
            rps: 100.0,
            seconds: 10.0,
            seed: 5,
            slo_scale: 3.0,
            repeat_fraction: 0.9,
            ..Default::default()
        };
        // Long TTL: popular digests mostly hit.
        cfg.frontend.cache =
            Some(CacheConfig { ttl_ms: 60_000.0, capacity: 4096 });
        let long = run_cluster(&cfg, &load).unwrap();
        assert_conserved(&long);
        let long_stats = long.frontend.cache.unwrap();
        assert!(long_stats.served() > 0);
        assert_eq!(long_stats.stale, 0, "nothing should expire in 60s TTL");
        // Short TTL: the same workload sees expiries, and every expired
        // lookup re-routed (conservation would break if one were lost).
        cfg.frontend.cache =
            Some(CacheConfig { ttl_ms: 50.0, capacity: 4096 });
        let short = run_cluster(&cfg, &load).unwrap();
        assert_conserved(&short);
        let short_stats = short.frontend.cache.unwrap();
        assert!(short_stats.stale > 0,
                "50ms TTL never expired under a 10s repeat-heavy trace");
        assert!(short_stats.served() < long_stats.served(),
                "shorter TTL cannot serve more");
    }

    /// Staleness injection: with a gossip period far larger than the
    /// drain event's position in it, the published view keeps the
    /// drained node active for up to a full epoch — every stale pick is
    /// counted as a misroute and re-routed, none are lost, and the
    /// recorded per-decision staleness actually reflects the lag.
    #[test]
    fn virtual_stale_view_counts_misroutes_across_a_drain() {
        let drain = DrainScenario {
            node: 0,
            at_ms: 2_500.0,
            rejoin_at_ms: 1e12,
        };
        let mut cfg = hetero_cfg(RoutePolicy::RoundRobin,
                                 ClockKind::Virtual, Some(drain));
        cfg.frontend.gossip_ms = 1_000.0;
        let load = LoadGenConfig {
            rps: 100.0,
            seconds: 5.0,
            seed: 13,
            slo_scale: 3.0,
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();
        assert_conserved(&report);
        // Node 0 drains at 2.5s but stays published-active until the 3s
        // epoch: round-robin keeps picking it for ~0.5s of arrivals.
        assert!(report.frontend.misroutes > 10,
                "no misroutes despite a 500ms stale window: {}",
                report.frontend.misroutes);
        assert!(report.per_node[0].dispatched > 0);
        // Staleness is recorded per decision and bounded by the period.
        assert!(report.frontend.staleness_max_ms <= 1_000.0 + 1e-9);
        assert!(report.frontend.staleness_mean_ms > 0.0);
        // And with gossip at the default 5ms the same scenario misroutes
        // at most a handful of requests.
        cfg.frontend.gossip_ms = 5.0;
        let tight = run_cluster(&cfg, &load).unwrap();
        assert_conserved(&tight);
        assert!(tight.frontend.misroutes < report.frontend.misroutes / 4,
                "tight gossip should shrink misroutes: {} vs {}",
                tight.frontend.misroutes, report.frontend.misroutes);
    }

    /// The drain window really gates routing: draining a node for the
    /// whole horizon leaves it with zero dispatched requests, and the
    /// remaining nodes absorb (or edge-shed) the full offered load.
    #[test]
    fn virtual_drain_window_stops_dispatch_entirely() {
        let drain = DrainScenario {
            node: 0,
            at_ms: 0.0,
            rejoin_at_ms: 1e12,
        };
        let cfg = hetero_cfg(RoutePolicy::RoundRobin, ClockKind::Virtual,
                             Some(drain));
        let load = LoadGenConfig {
            rps: 60.0,
            seconds: 5.0,
            seed: 7,
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();
        assert_conserved(&report);
        assert_eq!(report.per_node[0].dispatched, 0,
                   "router dispatched to a drained node");
        assert!(report.per_node[1].dispatched > 0);
        assert!(report.per_node[2].dispatched > 0);
    }

    /// SLO-aware routing on the virtual arm sheds hopeless requests at
    /// the edge instead of feeding them to an infeasible node: with ONLY
    /// a Nano in the cluster (12× slower than the SLOs were budgeted
    /// for), everything sheds NoFeasibleNode and nothing is dispatched.
    #[test]
    fn virtual_slo_aware_sheds_at_the_edge_when_no_node_is_feasible() {
        let cfg = ClusterConfig {
            nodes: vec![NodeSpec::new(PlatformSpec::jetson_nano(), 2, 5.0)],
            policy: RoutePolicy::SloAware,
            serve: ServeConfig {
                clock: ClockKind::Virtual,
                scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 2 },
                admission: None,
                ..Default::default()
            },
            drain: None,
            frontend: FrontEndConfig::default(),
        };
        let load = LoadGenConfig {
            rps: 40.0,
            seconds: 5.0,
            seed: 3,
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();
        assert!(report.attempts > 0);
        assert_conserved(&report);
        assert_eq!(report.router_sheds(), report.attempts,
                   "infeasible node still received dispatch");
        assert_eq!(report.metrics.outcomes().len(), 0);
    }

    /// Live sharded front-end smoke: four submitter threads route from
    /// the gossiped view with the cache on; the cluster serves, the
    /// extended identity holds, and the repeat-heavy workload produces
    /// real cache service.
    #[test]
    fn wall_sharded_open_loop_with_cache_conserves() {
        let mut cfg = hetero_cfg(RoutePolicy::JoinShortestBacklog,
                                 ClockKind::Wall, None);
        cfg.frontend.router_shards = 4;
        cfg.frontend.gossip_ms = 2.0;
        cfg.frontend.cache =
            Some(CacheConfig { ttl_ms: 300.0, capacity: 4096 });
        let load = LoadGenConfig {
            rps: 400.0,
            seconds: 0.5,
            seed: 17,
            slo_scale: 3.0,
            repeat_fraction: 0.6,
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();
        assert!(report.attempts > 100, "trace too small");
        assert_conserved(&report);
        assert!(report.metrics.completed() > 0, "cluster served nothing");
        assert_eq!(report.frontend.shards, 4);
        let cache = report.frontend.cache.unwrap();
        assert!(cache.served() > 0, "repeat-heavy load never hit the cache");
        // Every attempt either terminated at the cache or made exactly
        // one routing decision.
        assert_eq!(report.frontend.decisions + cache.served(),
                   report.attempts);
    }

    /// Closed-loop wall-clock cluster smoke: terminal events from every
    /// node feed one in-flight loop, and conservation holds at shutdown.
    #[test]
    fn closed_loop_wall_cluster_serves_and_conserves() {
        let cfg = ClusterConfig {
            nodes: vec![
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 1.0),
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 3.0),
            ],
            policy: RoutePolicy::PowerOfTwoChoices,
            serve: ServeConfig {
                clock: ClockKind::Wall,
                scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 1 },
                admission: None,
                queue_capacity: 256,
                ..Default::default()
            },
            drain: None,
            frontend: FrontEndConfig::default(),
        };
        let load = LoadGenConfig {
            seconds: 0.3,
            seed: 11,
            mode: LoadMode::Closed { concurrency: 8 },
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();
        assert!(report.metrics.completed() > 0, "cluster served nothing");
        assert_conserved(&report);
        assert_eq!(report.leftover, 0, "drain protocol left requests queued");
        // Closed loop on the virtual clock is rejected, as single-node.
        let mut bad = cfg;
        bad.serve.clock = ClockKind::Virtual;
        assert!(run_cluster(&bad, &load).is_err());
    }
}
