//! The heterogeneous edge-cluster tier: SLO-aware routing across
//! multi-node serving pools.
//!
//! BCEdge evaluates on a zoo of heterogeneous edge platforms (Table V:
//! Xavier NX / TX2 / Nano); this module crosses the node boundary the
//! same way the serving runtime crossed the worker boundary. Each
//! [`EdgeNode`] owns a full [`crate::serve::Server`] — workers, admission,
//! rebalancer, hot-model replication — configured with its own
//! [`crate::platform::PlatformSpec`] and network link, so nodes genuinely
//! differ in drain rate and distance. A front-end [`Router`] places every
//! request under a pluggable policy (round-robin,
//! join-shortest-backlog, power-of-two-choices, SLO-aware), reading the
//! per-node [`crate::serve::GaugeSnapshot`]s the nodes' workers publish;
//! the SLO-aware policy prices estimated RTT + queue backlog + batch
//! latency against remaining slack and sheds at the edge
//! ([`crate::metrics::ShedReason::NoFeasibleNode`]) when no node can make
//! the deadline.
//!
//! Two clock arms, mirroring the serving runtime:
//!
//! * **wall** — live: every node is a real [`crate::serve::Server`];
//!   routing reads live gauge snapshots; a [`DrainScenario`] can take a
//!   node out mid-run (routing stops, the node flushes through the
//!   existing drain protocol, its accounted requests fold into cluster
//!   totals) and bring it back (a fresh server incarnation in a disjoint
//!   request-id window).
//! * **virtual** — deterministic: the router places a pre-generated trace
//!   using a leaky-bucket backlog model (per-node estimated work, drained
//!   at the node's worker count), then each node serves its shard as its
//!   own discrete-event simulation — same seed, same report, bit for bit.
//!
//! Conservation holds cluster-wide through every drain/rejoin:
//! `outcomes + sheds + leftover == attempts`, outcome ids unique across
//! nodes (each node incarnation stamps ids in its own window).
//!
//! Entry point: [`run_cluster`], surfaced as `bcedge bench-cluster`.

pub mod netmodel;
pub mod node;
pub mod router;

pub use netmodel::NetModel;
pub use node::{EdgeNode, FinishedNode, NodeSpec, NodeState};
pub use router::{NodeView, RoutePolicy, Router};

use crate::metrics::{Metrics, ShedReason};
use crate::platform::PlatformSim;
use crate::serve::worker::ServeEvent;
use crate::serve::{ClockKind, LoadGenConfig, LoadMode, ServeConfig,
                   run_trace};
use crate::util::rng::Pcg32;
use crate::util::time::WallClock;
use crate::workload::models::{ModelId, N_MODELS};
use std::sync::mpsc;
use std::time::Duration;

/// Take one node out of the cluster mid-run and bring it back: routing
/// to `node` stops at `at_ms`, the node flushes through the drain
/// protocol, and a fresh incarnation rejoins at `rejoin_at_ms` (cluster
/// timebase, ms). On the virtual clock the window gates routing only —
/// the node's single simulation serves everything it was dealt.
#[derive(Clone, Copy, Debug)]
pub struct DrainScenario {
    /// Index into [`ClusterConfig::nodes`].
    pub node: usize,
    /// When routing to the node stops and its drain begins, ms.
    pub at_ms: f64,
    /// When the node rejoins (must be > `at_ms`), ms. A rejoin time past
    /// the horizon means the node stays out.
    pub rejoin_at_ms: f64,
}

/// Cluster-tier configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// The nodes, heterogeneous in platform, worker count, and link.
    pub nodes: Vec<NodeSpec>,
    /// Front-end routing policy.
    pub policy: RoutePolicy,
    /// Per-node serving template: scheduler, admission, queue capacity,
    /// rebalance/replication, gauge hints, and the clock arm. Platform
    /// and worker count are overridden per node from its [`NodeSpec`].
    pub serve: ServeConfig,
    /// Optional mid-run node drain/rejoin.
    pub drain: Option<DrainScenario>,
}

impl Default for ClusterConfig {
    /// The paper's Table-V trio behind LAN-ish links, SLO-aware routing.
    fn default() -> Self {
        use crate::platform::PlatformSpec;
        ClusterConfig {
            nodes: vec![
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
                NodeSpec::new(PlatformSpec::jetson_tx2(), 2, 6.0),
                NodeSpec::new(PlatformSpec::jetson_nano(), 1, 12.0),
            ],
            policy: RoutePolicy::SloAware,
            serve: ServeConfig { clock: ClockKind::Wall, ..Default::default() },
            drain: None,
        }
    }
}

impl ClusterConfig {
    fn validate(&self) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("cluster needs at least one node".into());
        }
        if let Some(d) = &self.drain {
            if d.node >= self.nodes.len() {
                return Err(format!(
                    "--drain-node {} out of range (cluster has {} nodes)",
                    d.node,
                    self.nodes.len()
                ));
            }
            if d.at_ms < 0.0 || d.rejoin_at_ms <= d.at_ms {
                return Err("drain window needs 0 <= drain-at < rejoin-at"
                    .into());
            }
        }
        Ok(())
    }

    /// The admission reference batch every estimate is priced at.
    fn ref_batch(&self) -> usize {
        self.serve.admission.map(|a| a.ref_batch).unwrap_or(8).max(1)
    }
}

/// One node's line in the cluster report.
#[derive(Clone, Debug)]
pub struct NodeBreakdown {
    /// Platform name (Table V).
    pub platform: &'static str,
    /// Worker threads in the node's pool.
    pub workers: usize,
    /// Base link RTT, ms.
    pub rtt_ms: f64,
    /// Requests the router dispatched here.
    pub dispatched: u64,
    /// Requests the node completed.
    pub completed: usize,
    /// SLO violation rate over the node's executed requests.
    pub violation_rate: f64,
    /// Requests the node's own admission/backpressure shed.
    pub sheds: u64,
    /// Requests left queued at the node's horizon.
    pub leftover: usize,
    /// Serving segments (1 normally; 2 after a drain/rejoin cycle).
    pub segments: usize,
}

/// Final report of a cluster run: merged metrics plus per-node
/// breakdowns and the router's edge-shed accounting.
pub struct ClusterReport {
    /// Cluster-merged metrics: every node's outcomes and sheds plus the
    /// router's [`ShedReason::NoFeasibleNode`] edge sheds.
    pub metrics: Metrics,
    /// Cluster serving horizon, ms (wall or virtual, matching the run).
    pub horizon_ms: f64,
    /// Requests the load generator offered to the cluster.
    pub attempts: u64,
    /// Requests still queued anywhere when the run ended.
    pub leftover: usize,
    /// Scheduling slots executed across every node.
    pub slots: u64,
    /// Node drains performed (the scenario fired).
    pub drains: u32,
    /// Node rejoins performed.
    pub rejoins: u32,
    /// The routing policy the run used.
    pub policy: RoutePolicy,
    /// Per-node accounting, in [`ClusterConfig::nodes`] order.
    pub per_node: Vec<NodeBreakdown>,
}

impl ClusterReport {
    /// Completed requests per second over the horizon.
    pub fn achieved_rps(&self) -> f64 {
        self.metrics.completed() as f64 / (self.horizon_ms / 1e3).max(1e-9)
    }

    /// Requests the router shed at the edge (no feasible node).
    pub fn router_sheds(&self) -> u64 {
        self.metrics.shed_by_reason(ShedReason::NoFeasibleNode)
    }

    /// Human-readable summary (the `bcedge bench-cluster` output).
    pub fn print(&self) {
        let m = &self.metrics;
        println!(
            "cluster {} nodes | {} routing | {} slots | horizon {:.1}s",
            self.per_node.len(),
            self.policy.name(),
            self.slots,
            self.horizon_ms / 1e3
        );
        println!(
            "achieved {:.1} rps | e2e p50 {:.2} ms p99 {:.2} ms | \
             SLO violations {:.2}% | shed {:.2}% ({} at the edge)",
            self.achieved_rps(),
            m.latency_percentile(0.5),
            m.latency_percentile(0.99),
            100.0 * m.violation_rate(),
            100.0 * m.shed_rate(),
            self.router_sheds(),
        );
        if self.drains > 0 {
            println!("lifecycle: {} drain(s), {} rejoin(s)", self.drains,
                     self.rejoins);
        }
        for (i, n) in self.per_node.iter().enumerate() {
            println!(
                "  node {i}: {:<12} ×{} workers | rtt {:>5.1} ms | \
                 dispatched {:>6} | completed {:>6} | viol {:>6.2}% | \
                 shed {:>5} | leftover {:>4} | segments {}",
                n.platform,
                n.workers,
                n.rtt_ms,
                n.dispatched,
                n.completed,
                100.0 * n.violation_rate,
                n.sheds,
                n.leftover,
                n.segments,
            );
        }
        if self.leftover > 0 {
            println!("leftover across the cluster: {}", self.leftover);
        }
    }
}

/// Run the load generator against a cluster configuration. Open loop on
/// either clock; closed loop needs the wall clock (real completions),
/// exactly like single-node serving.
pub fn run_cluster(cfg: &ClusterConfig, load: &LoadGenConfig)
                   -> Result<ClusterReport, String> {
    cfg.validate()?;
    let horizon_ms = load.seconds * 1e3;
    match (load.mode, cfg.serve.clock) {
        (LoadMode::Open, ClockKind::Virtual) => {
            Ok(run_virtual_open(cfg, load, horizon_ms))
        }
        (LoadMode::Open, ClockKind::Wall) => {
            Ok(run_wall_open(cfg, load, horizon_ms))
        }
        (LoadMode::Closed { concurrency }, ClockKind::Wall) => Ok(
            run_wall_closed(cfg, load, horizon_ms, concurrency.max(1)),
        ),
        (LoadMode::Closed { .. }, ClockKind::Virtual) => Err(
            "closed-loop cluster serving needs --clock wall (the feedback \
             loop runs on real completions)"
                .into(),
        ),
    }
}

// ---------------------------------------------------------------------
// Wall-clock (live) driver
// ---------------------------------------------------------------------

/// The live cluster front-end: nodes + router + lifecycle bookkeeping.
struct WallCluster {
    nodes: Vec<EdgeNode>,
    router: Router,
    /// Link-jitter draws only (routing itself uses the router's stream).
    link_rng: Pcg32,
    clock: WallClock,
    drain: Option<DrainScenario>,
    drains: u32,
    rejoins: u32,
    /// Edge sheds (no feasible node), folded into the final metrics.
    router_metrics: Metrics,
    attempts: u64,
    /// Reusable per-request routing views (the dispatch path allocates
    /// nothing in steady state).
    view_scratch: Vec<NodeView>,
}

impl WallCluster {
    fn start(cfg: &ClusterConfig, seed: u64,
             events_tx: Option<mpsc::Sender<ServeEvent>>) -> WallCluster {
        let mut nodes: Vec<EdgeNode> = cfg
            .nodes
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                EdgeNode::new(spec.clone(), &cfg.serve, i, events_tx.clone())
            })
            .collect();
        for node in &mut nodes {
            node.start();
        }
        WallCluster {
            nodes,
            router: Router::new(cfg.policy, seed ^ 0xC1_05_7E),
            link_rng: Pcg32::seeded(seed ^ 0x11_4E),
            clock: WallClock::new(),
            drain: cfg.drain,
            drains: 0,
            rejoins: 0,
            router_metrics: Metrics::new(),
            attempts: 0,
            view_scratch: Vec::with_capacity(cfg.nodes.len()),
        }
    }

    fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }

    /// Advance the drain/rejoin scenario against the cluster clock.
    fn tick_lifecycle(&mut self) {
        let Some(d) = self.drain else { return };
        let now = self.clock.now_ms();
        let node = &mut self.nodes[d.node];
        match node.state() {
            NodeState::Active => {
                if self.drains == 0 && now >= d.at_ms {
                    node.begin_drain();
                    self.drains += 1;
                }
            }
            NodeState::Draining => {
                node.poll_drained();
            }
            NodeState::Drained => {
                if self.drains > 0 && self.rejoins == 0
                    && now >= d.rejoin_at_ms
                {
                    node.rejoin();
                    self.rejoins += 1;
                }
            }
        }
    }

    /// Refresh the per-request routing views from the nodes' live gauge
    /// snapshots into the reusable scratch buffer.
    fn refresh_views(&mut self, model: ModelId) {
        self.view_scratch.clear();
        for n in &self.nodes {
            self.view_scratch.push(match n.snapshot() {
                Some(snap) => NodeView {
                    active: true,
                    rtt_ms: n.spec.net.rtt_ms,
                    backlog_ms: snap.total_backlog_ms,
                    service_est_ms: snap.service_est_ms(model),
                },
                None => NodeView {
                    active: false,
                    rtt_ms: n.spec.net.rtt_ms,
                    backlog_ms: f64::INFINITY,
                    service_est_ms: f64::INFINITY,
                },
            });
        }
    }

    /// Offer one request to the cluster: route, charge the link, dispatch
    /// — or shed at the edge with a typed reason.
    fn submit(&mut self, model: ModelId, slo_ms: f64, transmission_ms: f64)
              -> Result<u64, ShedReason> {
        self.attempts += 1;
        self.refresh_views(model);
        match self.router.route(&self.view_scratch, slo_ms - transmission_ms) {
            Ok(i) => {
                let delay = self.nodes[i].spec.net.delay_ms(&mut self.link_rng);
                self.nodes[i].dispatch(model, slo_ms,
                                       transmission_ms + delay)
            }
            Err(reason) => {
                self.router_metrics.record_shed(model, reason);
                Err(reason)
            }
        }
    }

    /// Stop every node (draining live servers, waiting out any pending
    /// background drain) and merge the cluster report.
    fn finish(self) -> ClusterReport {
        let horizon_ms = self.clock.now_ms();
        let policy = self.router.policy();
        let mut metrics = self.router_metrics;
        let mut leftover = 0usize;
        let mut slots = 0u64;
        let mut per_node = Vec::with_capacity(self.nodes.len());
        for node in self.nodes {
            let fin = node.finish();
            merge_node(&mut metrics, &mut leftover, &mut slots,
                       &mut per_node, fin);
        }
        ClusterReport {
            metrics,
            horizon_ms,
            attempts: self.attempts,
            leftover,
            slots,
            drains: self.drains,
            rejoins: self.rejoins,
            policy,
            per_node,
        }
    }
}

/// Fold one finished node into the cluster totals and breakdown rows.
fn merge_node(metrics: &mut Metrics, leftover: &mut usize, slots: &mut u64,
              per_node: &mut Vec<NodeBreakdown>, fin: FinishedNode) {
    let mut nm = Metrics::new();
    let mut node_leftover = 0usize;
    let mut node_slots = 0u64;
    for seg in &fin.segments {
        nm.merge(&seg.metrics);
        node_leftover += seg.leftover;
        node_slots += seg.slots;
    }
    per_node.push(NodeBreakdown {
        platform: fin.spec.platform.name,
        workers: fin.spec.workers,
        rtt_ms: fin.spec.net.rtt_ms,
        dispatched: fin.dispatched,
        completed: nm.completed(),
        violation_rate: nm.violation_rate(),
        sheds: nm.shed_total(),
        leftover: node_leftover,
        segments: fin.segments.len(),
    });
    metrics.merge(&nm);
    *leftover += node_leftover;
    *slots += node_slots;
}

/// Open loop on the wall clock: pace the pre-drawn arrival process
/// against the cluster clock, routing each request as it arrives. Sleeps
/// are capped so the drain/rejoin scenario fires on time even through an
/// arrival lull; late submission degrades to burstier — never lighter —
/// offered load.
fn run_wall_open(cfg: &ClusterConfig, load: &LoadGenConfig,
                 horizon_ms: f64) -> ClusterReport {
    let trace = load.generator().generate_horizon(horizon_ms);
    let mut cluster = WallCluster::start(cfg, load.seed, None);
    for r in &trace {
        loop {
            cluster.tick_lifecycle();
            let wait_ms = r.arrival_ms - cluster.now_ms();
            if wait_ms <= 0.0 {
                break;
            }
            std::thread::sleep(Duration::from_secs_f64(
                wait_ms.min(5.0) / 1e3,
            ));
        }
        // Rejections are accounted (router edge sheds here, node ingress
        // sheds at the node); nothing more to do.
        let _ = cluster.submit(r.model, r.slo_ms, r.transmission_ms);
    }
    // Keep the lifecycle ticking to the horizon so a rejoin scheduled
    // after the last arrival still happens inside the run.
    loop {
        cluster.tick_lifecycle();
        let wait_ms = horizon_ms - cluster.now_ms();
        if wait_ms <= 0.0 {
            break;
        }
        std::thread::sleep(Duration::from_secs_f64(wait_ms.min(5.0) / 1e3));
    }
    cluster.finish()
}

/// Closed loop on the wall clock: keep `concurrency` requests in flight
/// across the whole cluster, launching the next the moment one
/// terminates anywhere (completion or engine-gate shed — every node
/// streams its terminal events into one channel).
fn run_wall_closed(cfg: &ClusterConfig, load: &LoadGenConfig,
                   horizon_ms: f64, concurrency: usize) -> ClusterReport {
    let (tx, rx) = mpsc::channel();
    let mut cluster = WallCluster::start(cfg, load.seed, Some(tx));
    let mut rng = Pcg32::seeded(load.seed);
    let mut rr = 0usize;
    let slo_scale = load.slo_scale;
    // The SAME closed-loop client model as single-node bench-serve
    // (shared launcher: model rotation, transmission stamp, SLO scale),
    // submitting through the router instead of one ingress. Requests
    // every node refuses — or the router edge-sheds — free their slot.
    let launch = |cluster: &mut WallCluster, rng: &mut Pcg32,
                  rr: &mut usize| {
        crate::serve::loadgen::launch_round_robin(
            rng, rr, slo_scale,
            |m, slo, tx_ms| cluster.submit(m, slo, tx_ms))
    };
    let mut in_flight = 0usize;
    for _ in 0..concurrency {
        if launch(&mut cluster, &mut rng, &mut rr) {
            in_flight += 1;
        }
    }
    while cluster.now_ms() < horizon_ms {
        cluster.tick_lifecycle();
        match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(_terminal_event) => {
                in_flight = in_flight.saturating_sub(1);
                if launch(&mut cluster, &mut rng, &mut rr) {
                    in_flight += 1;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // Top back up (e.g. every node was refusing earlier).
                while in_flight < concurrency
                    && launch(&mut cluster, &mut rng, &mut rr)
                {
                    in_flight += 1;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    cluster.finish()
}

// ---------------------------------------------------------------------
// Virtual-clock (deterministic) driver
// ---------------------------------------------------------------------

/// Open loop on the virtual clock: route the pre-generated trace with a
/// deterministic per-node backlog model, then serve each node's shard as
/// its own discrete-event simulation. Same seed ⇒ identical report.
///
/// The backlog model is a leaky bucket per node: dispatching a request
/// adds its estimated per-request work (the platform's isolated latency
/// at the reference batch, amortized over the batch), and the bucket
/// drains at one ms of work per worker per millisecond of trace time —
/// so a Nano node fills ~12× faster than a Xavier NX node and the
/// gauge-driven policies see the heterogeneity without live feedback.
fn run_virtual_open(cfg: &ClusterConfig, load: &LoadGenConfig,
                    horizon_ms: f64) -> ClusterReport {
    let n = cfg.nodes.len();
    let trace = load.generator().generate_horizon(horizon_ms);
    let attempts = trace.len() as u64;
    let mut router = Router::new(cfg.policy, load.seed ^ 0xC1_05_7E);
    let mut link_rng = Pcg32::seeded(load.seed ^ 0x11_4E);
    let ref_batch = cfg.ref_batch();
    let sims: Vec<PlatformSim> = cfg
        .nodes
        .iter()
        .map(|s| PlatformSim::new(s.platform.clone()))
        .collect();
    // Match the serving pool's own clamp ([`ServeConfig`] runs at most
    // N_MODELS workers), so the routing model never credits a node with
    // more drain rate than its simulation will actually have.
    let drain_rate: Vec<f64> = cfg
        .nodes
        .iter()
        .map(|s| s.workers.clamp(1, N_MODELS) as f64)
        .collect();
    let mut est_backlog = vec![0.0f64; n];
    let mut last_ms = vec![0.0f64; n];
    let mut shards: Vec<Vec<crate::workload::request::Request>> =
        (0..n).map(|_| Vec::new()).collect();
    let mut router_metrics = Metrics::new();
    for r in &trace {
        for i in 0..n {
            est_backlog[i] = (est_backlog[i]
                - (r.arrival_ms - last_ms[i]) * drain_rate[i])
                .max(0.0);
            last_ms[i] = r.arrival_ms;
        }
        let offline = cfg
            .drain
            .filter(|d| r.arrival_ms >= d.at_ms && r.arrival_ms < d.rejoin_at_ms)
            .map(|d| d.node);
        let views: Vec<NodeView> = (0..n)
            .map(|i| NodeView {
                active: offline != Some(i),
                rtt_ms: cfg.nodes[i].net.rtt_ms,
                backlog_ms: est_backlog[i],
                service_est_ms: est_backlog[i] / drain_rate[i]
                    + sims[i].latency.isolated_ms(r.model, ref_batch),
            })
            .collect();
        match router.route(&views, r.slo_ms - r.transmission_ms) {
            Ok(i) => {
                let mut routed = r.clone();
                routed.transmission_ms +=
                    cfg.nodes[i].net.delay_ms(&mut link_rng);
                est_backlog[i] += sims[i]
                    .latency
                    .isolated_ms(r.model, ref_batch)
                    / ref_batch as f64;
                shards[i].push(routed);
            }
            Err(reason) => router_metrics.record_shed(r.model, reason),
        }
    }
    // Serve the shards sequentially: each node is its own deterministic
    // simulation, and a fixed merge order keeps the report bit-stable.
    let mut metrics = router_metrics;
    let mut leftover = 0usize;
    let mut slots = 0u64;
    let mut per_node = Vec::with_capacity(n);
    for (i, shard) in shards.into_iter().enumerate() {
        let node_cfg = ServeConfig {
            platform: cfg.nodes[i].platform.clone(),
            workers: cfg.nodes[i].workers,
            clock: ClockKind::Virtual,
            ..cfg.serve.clone()
        };
        let dispatched = shard.len() as u64;
        let report = run_trace(&node_cfg, shard, horizon_ms);
        merge_node(&mut metrics, &mut leftover, &mut slots, &mut per_node,
                   FinishedNode {
                       spec: cfg.nodes[i].clone(),
                       dispatched,
                       segments: vec![report],
                   });
    }
    let (drains, rejoins) = match cfg.drain {
        Some(d) if d.at_ms < horizon_ms => {
            (1, u32::from(d.rejoin_at_ms < horizon_ms))
        }
        _ => (0, 0),
    };
    ClusterReport {
        metrics,
        horizon_ms,
        attempts,
        leftover,
        slots,
        drains,
        rejoins,
        policy: cfg.policy,
        per_node,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::PlatformSpec;
    use crate::serve::SchedulerSpec;
    use std::collections::HashSet;

    fn hetero_cfg(policy: RoutePolicy, clock: ClockKind,
                  drain: Option<DrainScenario>) -> ClusterConfig {
        ClusterConfig {
            nodes: vec![
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 2.0),
                NodeSpec::new(PlatformSpec::jetson_tx2(), 2, 6.0),
                NodeSpec::new(PlatformSpec::jetson_nano(), 1, 12.0),
            ],
            policy,
            serve: ServeConfig {
                clock,
                scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 2 },
                admission: None,
                queue_capacity: 4096,
                ..Default::default()
            },
            drain,
        }
    }

    fn assert_conserved(report: &ClusterReport) {
        assert_eq!(report.metrics.outcomes().len() as u64
                       + report.metrics.shed_total()
                       + report.leftover as u64,
                   report.attempts,
                   "requests lost or double-counted cluster-wide");
        let mut seen = HashSet::new();
        for o in report.metrics.outcomes() {
            assert!(seen.insert(o.id),
                    "request {} served twice across the cluster", o.id);
        }
        // Router edge sheds + per-node dispatch cover every attempt.
        let dispatched: u64 =
            report.per_node.iter().map(|n| n.dispatched).sum();
        assert_eq!(dispatched + report.router_sheds(), report.attempts);
    }

    /// Satellite acceptance: virtual-clock cluster runs are conserved and
    /// bit-deterministic from the seed — identical outcomes, slots, and
    /// per-node dispatch counts across two runs — with unique outcome ids
    /// across nodes and the drain window gating routing mid-trace.
    #[test]
    fn virtual_cluster_conserves_and_is_deterministic() {
        let drain = DrainScenario {
            node: 1,
            at_ms: 5_000.0,
            rejoin_at_ms: 10_000.0,
        };
        let cfg = hetero_cfg(RoutePolicy::JoinShortestBacklog,
                             ClockKind::Virtual, Some(drain));
        let load = LoadGenConfig {
            rps: 150.0,
            seconds: 20.0,
            seed: 42,
            slo_scale: 3.0,
            ..Default::default()
        };
        let a = run_cluster(&cfg, &load).unwrap();
        let b = run_cluster(&cfg, &load).unwrap();
        assert!(a.attempts > 1_000, "trace too small to mean anything");
        assert_conserved(&a);
        assert_conserved(&b);
        assert_eq!(a.metrics.outcomes(), b.metrics.outcomes(),
                   "virtual cluster runs diverged on the same seed");
        assert_eq!(a.slots, b.slots);
        let dispatched = |r: &ClusterReport| -> Vec<u64> {
            r.per_node.iter().map(|n| n.dispatched).collect()
        };
        assert_eq!(dispatched(&a), dispatched(&b));
        // The drain window was honored and the node came back.
        assert_eq!(a.drains, 1);
        assert_eq!(a.rejoins, 1);
        // The fast node carries the bulk under join-shortest-backlog
        // (its leaky bucket drains ~9× faster than the Nano's fills).
        assert!(a.per_node[0].dispatched > a.per_node[2].dispatched,
                "routing ignored the heterogeneity: {:?}", dispatched(&a));
        assert!(a.metrics.completed() > 0);
    }

    /// The drain window really gates routing: draining a node for the
    /// whole horizon leaves it with zero dispatched requests, and the
    /// remaining nodes absorb (or edge-shed) the full offered load.
    #[test]
    fn virtual_drain_window_stops_dispatch_entirely() {
        let drain = DrainScenario {
            node: 0,
            at_ms: 0.0,
            rejoin_at_ms: 1e12,
        };
        let cfg = hetero_cfg(RoutePolicy::RoundRobin, ClockKind::Virtual,
                             Some(drain));
        let load = LoadGenConfig {
            rps: 60.0,
            seconds: 5.0,
            seed: 7,
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();
        assert_conserved(&report);
        assert_eq!(report.per_node[0].dispatched, 0,
                   "router dispatched to a drained node");
        assert!(report.per_node[1].dispatched > 0);
        assert!(report.per_node[2].dispatched > 0);
    }

    /// SLO-aware routing on the virtual arm sheds hopeless requests at
    /// the edge instead of feeding them to an infeasible node: with ONLY
    /// a Nano in the cluster (12× slower than the SLOs were budgeted
    /// for), everything sheds NoFeasibleNode and nothing is dispatched.
    #[test]
    fn virtual_slo_aware_sheds_at_the_edge_when_no_node_is_feasible() {
        let cfg = ClusterConfig {
            nodes: vec![NodeSpec::new(PlatformSpec::jetson_nano(), 2, 5.0)],
            policy: RoutePolicy::SloAware,
            serve: ServeConfig {
                clock: ClockKind::Virtual,
                scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 2 },
                admission: None,
                ..Default::default()
            },
            drain: None,
        };
        let load = LoadGenConfig {
            rps: 40.0,
            seconds: 5.0,
            seed: 3,
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();
        assert!(report.attempts > 0);
        assert_conserved(&report);
        assert_eq!(report.router_sheds(), report.attempts,
                   "infeasible node still received dispatch");
        assert_eq!(report.metrics.outcomes().len(), 0);
    }

    /// Closed-loop wall-clock cluster smoke: terminal events from every
    /// node feed one in-flight loop, and conservation holds at shutdown.
    #[test]
    fn closed_loop_wall_cluster_serves_and_conserves() {
        let cfg = ClusterConfig {
            nodes: vec![
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 1.0),
                NodeSpec::new(PlatformSpec::xavier_nx(), 2, 3.0),
            ],
            policy: RoutePolicy::PowerOfTwoChoices,
            serve: ServeConfig {
                clock: ClockKind::Wall,
                scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 1 },
                admission: None,
                queue_capacity: 256,
                ..Default::default()
            },
            drain: None,
        };
        let load = LoadGenConfig {
            seconds: 0.3,
            seed: 11,
            mode: LoadMode::Closed { concurrency: 8 },
            ..Default::default()
        };
        let report = run_cluster(&cfg, &load).unwrap();
        assert!(report.metrics.completed() > 0, "cluster served nothing");
        assert_conserved(&report);
        assert_eq!(report.leftover, 0, "drain protocol left requests queued");
        // Closed loop on the virtual clock is rejected, as single-node.
        let mut bad = cfg;
        bad.serve.clock = ClockKind::Virtual;
        assert!(run_cluster(&bad, &load).is_err());
    }
}
