//! Per-node network model for the edge-cluster tier.
//!
//! A request routed to a remote node pays the link before any queue does:
//! the round-trip delay (request out, result back) is charged to the
//! request's transmission time, which Eq. (2) counts inside end-to-end
//! latency — so routing to a far node genuinely spends SLO slack, and the
//! SLO-aware policy prices exactly that trade (a fast-but-far node can
//! lose to a slower-but-near one).
//!
//! The model is deliberately small: a fixed base RTT per node plus an
//! optional uniform jitter term. Base RTT is what routing feasibility is
//! priced with (deterministic, so policy decisions are reproducible from
//! a seed); jitter only perturbs what a dispatched request is charged.

use crate::util::rng::Pcg32;

/// One node's link as seen from the cluster front-end.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetModel {
    /// Base round-trip time to the node, ms (request + result return).
    pub rtt_ms: f64,
    /// Uniform jitter bound, ms: each dispatched request is charged
    /// `rtt_ms + U[0, jitter_ms)`. Zero (the default) keeps the link
    /// fully deterministic.
    pub jitter_ms: f64,
}

impl NetModel {
    /// A jitter-free link with the given round-trip time.
    pub fn fixed(rtt_ms: f64) -> Self {
        assert!(rtt_ms >= 0.0);
        NetModel { rtt_ms, jitter_ms: 0.0 }
    }

    /// Round-trip delay charged to one dispatched request, ms. Draws
    /// from `rng` only when the link has jitter, so jitter-free
    /// configurations consume no randomness (routing stays bit-stable
    /// when jitter is switched off).
    pub fn delay_ms(&self, rng: &mut Pcg32) -> f64 {
        if self.jitter_ms > 0.0 {
            self.rtt_ms + self.jitter_ms * rng.f64()
        } else {
            self.rtt_ms
        }
    }
}

impl Default for NetModel {
    /// A LAN-ish 5 ms round trip, no jitter.
    fn default() -> Self {
        NetModel::fixed(5.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_link_charges_base_rtt_without_touching_rng() {
        let link = NetModel::fixed(8.0);
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(1);
        assert_eq!(link.delay_ms(&mut a), 8.0);
        // RNG untouched: both streams still agree.
        assert_eq!(a.f64().to_bits(), b.f64().to_bits());
    }

    #[test]
    fn jitter_stays_in_bounds_and_is_seed_deterministic() {
        let link = NetModel { rtt_ms: 10.0, jitter_ms: 4.0 };
        let mut rng = Pcg32::seeded(7);
        let mut rng2 = Pcg32::seeded(7);
        for _ in 0..100 {
            let d = link.delay_ms(&mut rng);
            assert!((10.0..14.0).contains(&d), "delay {d} out of bounds");
            assert_eq!(d.to_bits(), link.delay_ms(&mut rng2).to_bits());
        }
    }
}
