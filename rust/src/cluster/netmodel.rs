//! Per-node network model for the edge-cluster tier.
//!
//! A request routed to a remote node pays the link before any queue does:
//! the round-trip delay (request out, result back) is charged to the
//! request's transmission time, which Eq. (2) counts inside end-to-end
//! latency — so routing to a far node genuinely spends SLO slack, and the
//! SLO-aware policy prices exactly that trade (a fast-but-far node can
//! lose to a slower-but-near one).
//!
//! The model has two layers. The base layer is a fixed RTT per node plus
//! an optional uniform jitter term: base RTT is what routing feasibility
//! is priced with (deterministic, so policy decisions are reproducible
//! from a seed); jitter only perturbs what a dispatched request is
//! charged. The contention layer ([`NetModel::bw_mbps`] + [`LinkLoad`])
//! models the link as a shared fair-share pipe: each transfer's base
//! time is `payload / bandwidth`, and transfers overlapping in time
//! inflate each other proportionally to how many share the link — so a
//! heavy-payload dogpile on one node genuinely slows every transfer on
//! that link, and contention-aware routing has something real to price.
//! Bandwidth defaults to infinite, which keeps every pre-existing
//! configuration (transfer time 0, no load tracking) bit-identical.

use crate::util::rng::Pcg32;
use crate::workload::models::{ModelId, ModelSpec};

/// Bytes per input element (f32) — sizes a request's upload payload.
const BYTES_PER_ELEM: f64 = 4.0;

/// Per-request upload payload for one model, bytes (its input tensor).
pub fn payload_bytes(model: ModelId) -> f64 {
    ModelSpec::get(model).input_elems as f64 * BYTES_PER_ELEM
}

/// Per-step token payload for an autoregressive session, bytes (the
/// decoded output streamed back each step — small next to the head's
/// input upload, but it still shares the link).
pub fn token_payload_bytes(model: ModelId) -> f64 {
    ModelSpec::get(model).output_elems as f64 * BYTES_PER_ELEM
}

/// One node's link as seen from the cluster front-end.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetModel {
    /// Base round-trip time to the node, ms (request + result return).
    pub rtt_ms: f64,
    /// Uniform jitter bound, ms: each dispatched request is charged
    /// `rtt_ms + U[0, jitter_ms)`. Zero (the default) keeps the link
    /// fully deterministic.
    pub jitter_ms: f64,
    /// Shared link capacity, Mbit/s. Finite bandwidth makes every
    /// dispatched payload pay `payload / bw`, inflated by concurrent
    /// transfers on the same link (see [`LinkLoad`]). The default
    /// (`f64::INFINITY`) zeroes the term entirely.
    pub bw_mbps: f64,
}

impl NetModel {
    /// A jitter-free link with the given round-trip time.
    pub fn fixed(rtt_ms: f64) -> Self {
        assert!(rtt_ms >= 0.0);
        NetModel { rtt_ms, jitter_ms: 0.0, bw_mbps: f64::INFINITY }
    }

    /// The same link with a finite shared capacity, Mbit/s.
    pub fn with_bandwidth(mut self, bw_mbps: f64) -> Self {
        assert!(bw_mbps > 0.0);
        self.bw_mbps = bw_mbps;
        self
    }

    /// Round-trip delay charged to one dispatched request, ms. Draws
    /// from `rng` only when the link has jitter, so jitter-free
    /// configurations consume no randomness (routing stays bit-stable
    /// when jitter is switched off).
    pub fn delay_ms(&self, rng: &mut Pcg32) -> f64 {
        if self.jitter_ms > 0.0 {
            self.rtt_ms + self.jitter_ms * rng.f64()
        } else {
            self.rtt_ms
        }
    }

    /// Uncontended transmission time for `payload` bytes, ms. Zero on an
    /// infinite-bandwidth link.
    pub fn transfer_ms(&self, payload: f64) -> f64 {
        if self.bw_mbps.is_finite() {
            // bytes * 8 bits / (mbps * 1e6 bit/s) seconds -> ms.
            payload * 8.0 / (self.bw_mbps * 1e3)
        } else {
            0.0
        }
    }
}

impl Default for NetModel {
    /// A LAN-ish 5 ms round trip, no jitter, infinite bandwidth.
    fn default() -> Self {
        NetModel::fixed(5.0)
    }
}

/// Fair-share contention tracker for one node's link.
///
/// Each in-flight transfer is remembered by its finish time. Charging a
/// new transfer of base duration `b` at time `t` prunes finished
/// transfers, counts the `k` still in flight, and charges
/// `b × (k + 1)` — the fair-share approximation where `k + 1` streams
/// each get `1/(k+1)` of the pipe. (In-flight transfers keep their
/// original finish times: the model inflates newcomers, which is what
/// routing needs to see, and stays strictly deterministic.) A zero base
/// duration — infinite bandwidth — charges nothing and records nothing,
/// so pre-contention configurations never touch the tracker state.
#[derive(Clone, Debug, Default)]
pub struct LinkLoad {
    /// Finish times (ms) of transfers still considered in flight.
    ends: Vec<f64>,
}

impl LinkLoad {
    pub fn new() -> Self {
        LinkLoad::default()
    }

    /// Transfers still in flight at `now_ms` (after pruning).
    pub fn in_flight(&self, now_ms: f64) -> usize {
        self.ends.iter().filter(|&&e| e > now_ms).count()
    }

    /// Price a prospective transfer WITHOUT admitting it: the inflated
    /// duration a `base_ms` transfer starting at `now_ms` would see.
    /// This is the term contention-aware routing adds to a node's cost.
    pub fn estimate_ms(&self, now_ms: f64, base_ms: f64) -> f64 {
        if base_ms <= 0.0 {
            return 0.0;
        }
        base_ms * (self.in_flight(now_ms) + 1) as f64
    }

    /// Admit a transfer at `now_ms` and return the inflated duration
    /// actually charged. Prunes finished transfers first.
    pub fn charge_ms(&mut self, now_ms: f64, base_ms: f64) -> f64 {
        if base_ms <= 0.0 {
            return 0.0;
        }
        self.ends.retain(|&e| e > now_ms);
        let d = base_ms * (self.ends.len() + 1) as f64;
        self.ends.push(now_ms + d);
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_link_charges_base_rtt_without_touching_rng() {
        let link = NetModel::fixed(8.0);
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(1);
        assert_eq!(link.delay_ms(&mut a), 8.0);
        // RNG untouched: both streams still agree.
        assert_eq!(a.f64().to_bits(), b.f64().to_bits());
    }

    #[test]
    fn jitter_stays_in_bounds_and_is_seed_deterministic() {
        let link = NetModel { rtt_ms: 10.0, jitter_ms: 4.0, bw_mbps: f64::INFINITY };
        let mut rng = Pcg32::seeded(7);
        let mut rng2 = Pcg32::seeded(7);
        for _ in 0..100 {
            let d = link.delay_ms(&mut rng);
            assert!((10.0..14.0).contains(&d), "delay {d} out of bounds");
            assert_eq!(d.to_bits(), link.delay_ms(&mut rng2).to_bits());
        }
    }

    #[test]
    fn infinite_bandwidth_transfers_are_free_and_leave_no_load() {
        let link = NetModel::fixed(5.0);
        assert_eq!(link.transfer_ms(1_000_000.0), 0.0);
        let mut load = LinkLoad::new();
        assert_eq!(load.charge_ms(0.0, link.transfer_ms(1_000_000.0)), 0.0);
        assert_eq!(load.in_flight(0.0), 0);
        assert_eq!(load.estimate_ms(0.0, 0.0), 0.0);
    }

    #[test]
    fn finite_bandwidth_prices_payload_bits() {
        // 12_288 bytes at 2 Mbps: 98_304 bits / 2_000 bits-per-ms ≈ 49.15 ms.
        let link = NetModel::fixed(5.0).with_bandwidth(2.0);
        let t = link.transfer_ms(12_288.0);
        assert!((t - 49.152).abs() < 1e-9, "got {t}");
    }

    #[test]
    fn concurrent_transfers_inflate_each_other_fair_share() {
        let mut load = LinkLoad::new();
        // Three back-to-back 10 ms transfers at t=0: 1×, 2×, 3×.
        assert_eq!(load.charge_ms(0.0, 10.0), 10.0);
        assert_eq!(load.charge_ms(0.0, 10.0), 20.0);
        assert_eq!(load.charge_ms(0.0, 10.0), 30.0);
        assert_eq!(load.in_flight(0.0), 3);
        // Past every finish time the link is idle again.
        assert_eq!(load.charge_ms(31.0, 10.0), 10.0);
        assert_eq!(load.in_flight(31.0), 1);
    }

    #[test]
    fn estimate_matches_charge_without_admitting() {
        let mut load = LinkLoad::new();
        load.charge_ms(0.0, 10.0);
        load.charge_ms(0.0, 10.0);
        let est = load.estimate_ms(0.0, 10.0);
        assert_eq!(est, 30.0);
        // Estimating twice is idempotent; charging then matches.
        assert_eq!(load.estimate_ms(0.0, 10.0), est);
        assert_eq!(load.charge_ms(0.0, 10.0), est);
    }

    #[test]
    fn payload_sizes_follow_model_tensors() {
        // Yolo uploads its 3*32*32 input tensor: 3072 elems * 4 bytes.
        assert_eq!(payload_bytes(ModelId::Yolo), 12_288.0);
        // Token payloads stream back the output tensor.
        assert_eq!(token_payload_bytes(ModelId::Yolo), 192.0 * 15.0 * 4.0);
        assert!(payload_bytes(ModelId::Bert) < payload_bytes(ModelId::Yolo));
    }
}
