//! Cluster front-end routing: which node serves the next request.
//!
//! Castellano et al. and EdgeServing both observe that on heterogeneous
//! edge clusters, *where* a request lands dominates SLO attainment —
//! routing sits above admission, resharding, and replication as the
//! outermost control loop. Four policies are provided, each a pure
//! function over per-node [`NodeView`]s so the decision logic is
//! unit-testable without servers or threads:
//!
//! * **round-robin** — rotate over active nodes (the heterogeneity-blind
//!   baseline the SLO-aware policy must beat);
//! * **join-shortest-backlog** — the node with the least estimated total
//!   backlog, read from the gauge snapshots each node's workers publish;
//! * **power-of-two-choices** — sample two distinct active nodes, take
//!   the less backlogged (classic load-balancing variance reduction at
//!   O(1) state);
//! * **slo-aware** — price every candidate's estimated completion
//!   (network RTT + queue backlog + profiled batch latency) against the
//!   request's remaining slack; dispatch to the cheapest *feasible* node
//!   and shed at the edge ([`ShedReason::NoFeasibleNode`]) when no node
//!   can make the deadline — a hopeless request should not spend a slow
//!   node's capacity proving it.

use crate::metrics::ShedReason;
use crate::util::rng::Pcg32;

/// Routing policy selector (see the module docs for semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rotate over active nodes, heterogeneity-blind.
    RoundRobin,
    /// Least estimated total backlog (gauge snapshots).
    JoinShortestBacklog,
    /// Two random candidates, keep the less backlogged.
    PowerOfTwoChoices,
    /// Cheapest node whose estimated completion fits the slack; shed at
    /// the edge when none does.
    SloAware,
}

impl RoutePolicy {
    /// Parse a CLI name. Accepts the canonical hyphenated names plus the
    /// common short forms (`jsb`, `p2c`).
    pub fn from_name(name: &str) -> Option<RoutePolicy> {
        match name {
            "round-robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "join-shortest-backlog" | "jsb" => {
                Some(RoutePolicy::JoinShortestBacklog)
            }
            "power-of-two" | "power-of-two-choices" | "p2c" => {
                Some(RoutePolicy::PowerOfTwoChoices)
            }
            "slo-aware" => Some(RoutePolicy::SloAware),
            _ => None,
        }
    }

    /// Canonical CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::JoinShortestBacklog => "join-shortest-backlog",
            RoutePolicy::PowerOfTwoChoices => "power-of-two",
            RoutePolicy::SloAware => "slo-aware",
        }
    }
}

/// What the router knows about one node when placing one request. Built
/// per request by the cluster driver — from live gauge snapshots on the
/// wall clock, from the deterministic backlog model on the virtual clock
/// — so the policies themselves never touch a server.
#[derive(Clone, Copy, Debug)]
pub struct NodeView {
    /// Is the node accepting dispatch right now (false while draining or
    /// drained)?
    pub active: bool,
    /// Base round-trip time to the node, ms (deterministic part of the
    /// link; jitter is charged at dispatch, not priced here).
    pub rtt_ms: f64,
    /// Estimated total backlog across the node's whole zoo, ms — the
    /// load-balancing signal (join-shortest-backlog, power-of-two).
    pub backlog_ms: f64,
    /// Estimated completion time for THIS request's model on this node,
    /// excluding the network: queue-ahead batches × per-batch latency
    /// (profiled, or the platform's isolated estimate before any profile
    /// — heterogeneous drain rates show up here).
    pub service_est_ms: f64,
    /// Predicted end-to-end completion (RTT + interference-predicted
    /// service), ms — filled only under predictive admission, from the
    /// node's gossiped predictor lanes. NaN when no prediction exists
    /// (snapshot mode, cold predictor, ex-drainer lanes), in which case
    /// the snapshot estimate above prices the node as before.
    pub predicted_e2e_ms: f64,
    /// Estimated transmission time for THIS request's payload on this
    /// node's shared link, ms — the contention-inflated
    /// `LinkLoad::estimate_ms` under contention-aware pricing, 0 under
    /// static-RTT pricing or infinite bandwidth. Additive on top of
    /// either pricing branch (predictions cover compute, not the wire).
    pub tx_est_ms: f64,
}

/// Estimated end-to-end cost of placing the request on `view`'s node, ms:
/// the predictor's headroom estimate when the node published one, the
/// snapshot estimate (RTT + gauge-priced service) otherwise, plus the
/// link's transmission estimate in both cases. The per-decision fallback
/// mirrors `AdmissionConfig::decide_predictive`.
pub fn estimated_e2e_ms(view: &NodeView) -> f64 {
    let base = if view.predicted_e2e_ms.is_finite()
        && view.predicted_e2e_ms > 0.0
    {
        view.predicted_e2e_ms
    } else {
        view.rtt_ms + view.service_est_ms
    };
    base + view.tx_est_ms
}

/// Round-robin over active nodes: the first active node at or after the
/// cursor, advancing it past the pick. `None` when no node is active.
pub fn route_round_robin(views: &[NodeView], cursor: &mut usize)
                         -> Option<usize> {
    let n = views.len();
    if n == 0 {
        return None;
    }
    for k in 0..n {
        let i = (*cursor + k) % n;
        if views[i].active {
            *cursor = (i + 1) % n;
            return Some(i);
        }
    }
    None
}

/// The active node with the least total backlog; ties go to the lowest
/// index (deterministic).
pub fn route_shortest_backlog(views: &[NodeView]) -> Option<usize> {
    views
        .iter()
        .enumerate()
        .filter(|(_, v)| v.active)
        .min_by(|(_, a), (_, b)| {
            a.backlog_ms
                .partial_cmp(&b.backlog_ms)
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .map(|(i, _)| i)
}

/// Power-of-two-choices: sample two distinct active nodes, keep the one
/// with less backlog (ties: the first sample). One active node is picked
/// outright; with exactly two this degenerates to join-shortest-backlog.
pub fn route_power_of_two(views: &[NodeView], rng: &mut Pcg32)
                          -> Option<usize> {
    let active: Vec<usize> = views
        .iter()
        .enumerate()
        .filter(|(_, v)| v.active)
        .map(|(i, _)| i)
        .collect();
    match active.len() {
        0 => None,
        1 => Some(active[0]),
        n => {
            let a = active[rng.below(n as u32) as usize];
            let b = loop {
                let c = active[rng.below(n as u32) as usize];
                if c != a {
                    break c;
                }
            };
            if views[b].backlog_ms < views[a].backlog_ms {
                Some(b)
            } else {
                Some(a)
            }
        }
    }
}

/// SLO-aware placement: among active nodes whose estimated completion
/// (RTT + service estimate) fits within `slack_ms`, the cheapest one;
/// ties go to the lowest index. `None` when no node is feasible — the
/// caller sheds at the edge with [`ShedReason::NoFeasibleNode`].
pub fn route_slo_aware(views: &[NodeView], slack_ms: f64) -> Option<usize> {
    views
        .iter()
        .enumerate()
        .filter(|(_, v)| v.active)
        .map(|(i, v)| (i, estimated_e2e_ms(v)))
        .filter(|(_, est)| *est <= slack_ms)
        .min_by(|(_, a), (_, b)| {
            a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
        })
        .map(|(i, _)| i)
}

/// The stateful front-end router: one policy plus the small state it
/// needs (round-robin cursor, power-of-two sampling stream).
pub struct Router {
    policy: RoutePolicy,
    cursor: usize,
    rng: Pcg32,
}

impl Router {
    /// A router for `policy`; `seed` drives only power-of-two sampling.
    pub fn new(policy: RoutePolicy, seed: u64) -> Self {
        Router { policy, cursor: 0, rng: Pcg32::seeded(seed) }
    }

    /// A router on an explicit PCG stream: shard `stream` of a sharded
    /// front-end. Each shard gets its own independent sampling sequence
    /// from the same seed (and its own round-robin cursor), so routing
    /// is deterministic for any fixed `(seed, shard count)` regardless
    /// of how shards interleave in real time.
    pub fn with_stream(policy: RoutePolicy, seed: u64, stream: u64) -> Self {
        Router { policy, cursor: 0, rng: Pcg32::new(seed, stream) }
    }

    /// The policy this router runs.
    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Place one request with `slack_ms` of deadline budget left
    /// (SLO − transmission already spent). `Err(NoFeasibleNode)` when the
    /// policy finds no candidate — for the non-SLO-aware policies that
    /// means no node is active at all (e.g. a one-node cluster mid-drain).
    pub fn route(&mut self, views: &[NodeView], slack_ms: f64)
                 -> Result<usize, ShedReason> {
        let pick = match self.policy {
            RoutePolicy::RoundRobin => {
                route_round_robin(views, &mut self.cursor)
            }
            RoutePolicy::JoinShortestBacklog => route_shortest_backlog(views),
            RoutePolicy::PowerOfTwoChoices => {
                route_power_of_two(views, &mut self.rng)
            }
            RoutePolicy::SloAware => route_slo_aware(views, slack_ms),
        };
        pick.ok_or(ShedReason::NoFeasibleNode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(active: bool, rtt: f64, backlog: f64, service: f64) -> NodeView {
        NodeView { active, rtt_ms: rtt, backlog_ms: backlog,
                   service_est_ms: service, predicted_e2e_ms: f64::NAN,
                   tx_est_ms: 0.0 }
    }

    #[test]
    fn transmission_estimate_prices_the_wire_on_both_branches() {
        // Snapshot branch: node 0 is cheaper on compute (2 + 20 = 22 vs
        // 2 + 30 = 32), but a congested link adds 15 ms and flips the
        // ordering.
        let mut views = [view(true, 2.0, 0.0, 20.0),
                         view(true, 2.0, 0.0, 30.0)];
        views[0].tx_est_ms = 15.0;
        assert_eq!(route_slo_aware(&views, 100.0), Some(1));
        // The wire also gates feasibility: 34 ms slack fits node 1 only.
        assert_eq!(route_slo_aware(&views, 34.0), Some(1));
        // Predicted branch: the prediction covers compute, the wire is
        // still additive on top of it.
        views[1].predicted_e2e_ms = 30.0;
        views[1].tx_est_ms = 40.0;
        assert_eq!(estimated_e2e_ms(&views[1]), 70.0);
        assert_eq!(route_slo_aware(&views, 100.0), Some(0));
    }

    #[test]
    fn slo_aware_prefers_predicted_e2e_when_published() {
        // Snapshot pricing says node 0 is cheapest (2 + 20 = 22 vs 62),
        // but its predictor says interference pushes it to 90 ms.
        let mut views = [view(true, 2.0, 0.0, 20.0),
                         view(true, 2.0, 0.0, 60.0)];
        views[0].predicted_e2e_ms = 90.0;
        assert_eq!(route_slo_aware(&views, 100.0), Some(1));
        // The prediction also gates feasibility: with 70 ms slack node 0
        // is predicted-infeasible, node 1 (snapshot-priced) still fits.
        assert_eq!(route_slo_aware(&views, 70.0), Some(1));
        // Non-finite or non-positive predictions fall back per node to
        // the snapshot estimate — never poison the comparison.
        views[0].predicted_e2e_ms = f64::NAN;
        views[1].predicted_e2e_ms = -1.0;
        assert_eq!(route_slo_aware(&views, 100.0), Some(0));
    }

    #[test]
    fn round_robin_rotates_and_skips_inactive() {
        let views = [view(true, 1.0, 0.0, 10.0),
                     view(false, 1.0, 0.0, 10.0),
                     view(true, 1.0, 0.0, 10.0)];
        let mut cursor = 0;
        assert_eq!(route_round_robin(&views, &mut cursor), Some(0));
        assert_eq!(route_round_robin(&views, &mut cursor), Some(2));
        assert_eq!(route_round_robin(&views, &mut cursor), Some(0));
        // Nothing active: no pick.
        let dark = [view(false, 1.0, 0.0, 1.0); 3];
        assert_eq!(route_round_robin(&dark, &mut cursor), None);
        assert_eq!(route_round_robin(&[], &mut cursor), None);
    }

    #[test]
    fn shortest_backlog_prefers_least_and_breaks_ties_low() {
        let views = [view(true, 1.0, 40.0, 10.0),
                     view(true, 1.0, 10.0, 10.0),
                     view(true, 1.0, 25.0, 10.0)];
        assert_eq!(route_shortest_backlog(&views), Some(1));
        // Exact tie: lowest index wins (deterministic).
        let tied = [view(true, 1.0, 10.0, 10.0),
                    view(true, 1.0, 10.0, 10.0)];
        assert_eq!(route_shortest_backlog(&tied), Some(0));
        // Inactive nodes are invisible even when emptiest.
        let drained = [view(false, 1.0, 0.0, 10.0),
                       view(true, 1.0, 99.0, 10.0)];
        assert_eq!(route_shortest_backlog(&drained), Some(1));
        assert_eq!(route_shortest_backlog(&[]), None);
    }

    #[test]
    fn power_of_two_picks_the_less_loaded_of_its_samples() {
        let mut rng = Pcg32::seeded(3);
        // One active node: picked outright.
        let solo = [view(false, 1.0, 0.0, 1.0), view(true, 1.0, 50.0, 1.0)];
        assert_eq!(route_power_of_two(&solo, &mut rng), Some(1));
        // Two active nodes: both are always sampled, so the pick IS the
        // less backlogged one, every draw.
        let pair = [view(true, 1.0, 80.0, 1.0), view(true, 1.0, 5.0, 1.0)];
        for _ in 0..50 {
            assert_eq!(route_power_of_two(&pair, &mut rng), Some(1));
        }
        // Three nodes, one inactive: the inactive one is never sampled.
        let trio = [view(true, 1.0, 10.0, 1.0),
                    view(false, 1.0, 0.0, 1.0),
                    view(true, 1.0, 20.0, 1.0)];
        for _ in 0..50 {
            let pick = route_power_of_two(&trio, &mut rng).unwrap();
            assert_ne!(pick, 1, "sampled a draining node");
        }
        assert_eq!(route_power_of_two(&[], &mut rng), None);
    }

    #[test]
    fn slo_aware_prices_rtt_plus_service_against_slack() {
        // Node 0: near but slow (2 + 120 = 122); node 1: far but fast
        // (30 + 40 = 70); node 2: nearest and fastest but draining.
        let views = [view(true, 2.0, 0.0, 120.0),
                     view(true, 30.0, 0.0, 40.0),
                     view(false, 1.0, 0.0, 10.0)];
        // 100 ms slack: only node 1 is feasible.
        assert_eq!(route_slo_aware(&views, 100.0), Some(1));
        // 200 ms slack: both feasible; the cheaper estimate wins.
        assert_eq!(route_slo_aware(&views, 200.0), Some(1));
        // 60 ms slack: nobody can make it — shed at the edge.
        assert_eq!(route_slo_aware(&views, 60.0), None);
        // Exact tie on the estimate: lowest index wins.
        let tied = [view(true, 10.0, 0.0, 40.0), view(true, 20.0, 0.0, 30.0)];
        assert_eq!(route_slo_aware(&tied, 100.0), Some(0));
        // One-node cluster: feasible → routed, infeasible → shed.
        let one = [view(true, 5.0, 0.0, 50.0)];
        assert_eq!(route_slo_aware(&one, 100.0), Some(0));
        assert_eq!(route_slo_aware(&one, 40.0), None);
    }

    #[test]
    fn shard_streams_are_deterministic_and_independent() {
        let views = [view(true, 1.0, 10.0, 1.0),
                     view(true, 1.0, 11.0, 1.0),
                     view(true, 1.0, 12.0, 1.0),
                     view(true, 1.0, 13.0, 1.0)];
        let draw = |r: &mut Router| -> Vec<usize> {
            (0..64).map(|_| r.route(&views, 1e9).unwrap()).collect()
        };
        // Same (seed, stream): identical pick sequence, run to run.
        let a = draw(&mut Router::with_stream(
            RoutePolicy::PowerOfTwoChoices, 42, 3));
        let b = draw(&mut Router::with_stream(
            RoutePolicy::PowerOfTwoChoices, 42, 3));
        assert_eq!(a, b);
        // Different streams from the same seed: diverged sequences (the
        // shards are not sampling in lockstep).
        let c = draw(&mut Router::with_stream(
            RoutePolicy::PowerOfTwoChoices, 42, 4));
        assert_ne!(a, c, "shard streams collided");
        // Round-robin cursors are shard-local: each shard starts at 0.
        let mut s0 = Router::with_stream(RoutePolicy::RoundRobin, 1, 0);
        let mut s1 = Router::with_stream(RoutePolicy::RoundRobin, 1, 1);
        assert_eq!(s0.route(&views, 1e9), Ok(0));
        assert_eq!(s1.route(&views, 1e9), Ok(0));
        assert_eq!(s0.route(&views, 1e9), Ok(1));
    }

    #[test]
    fn router_converts_no_pick_into_typed_shed() {
        let mut r = Router::new(RoutePolicy::SloAware, 1);
        let views = [view(true, 50.0, 0.0, 100.0)];
        assert_eq!(r.route(&views, 500.0), Ok(0));
        assert_eq!(r.route(&views, 10.0), Err(ShedReason::NoFeasibleNode));
        let mut rr = Router::new(RoutePolicy::RoundRobin, 1);
        assert_eq!(rr.route(&[view(false, 1.0, 0.0, 1.0)], 100.0),
                   Err(ShedReason::NoFeasibleNode));
    }
}
