//! One edge node of the cluster: a full serving runtime (worker pool,
//! admission, rebalancer, hot-model replication) on its own platform,
//! behind its own network link, with a drain/rejoin lifecycle.
//!
//! The node boundary deliberately reuses the single-node stack whole: an
//! [`EdgeNode`] owns a [`Server`] configured with its own
//! [`PlatformSpec`], so the cluster tier is heterogeneous in drain rate
//! exactly the way the paper's Table V platforms are — a Nano node really
//! is ~12× slower per batch than a Xavier NX node, and the router has to
//! price that.
//!
//! Lifecycle: `Active` (router may dispatch) → `begin_drain` moves the
//! server into a background thread running the existing drain protocol
//! (stop intake → flush queues → join workers) while the router stops
//! dispatching → `Drained` once the flushed segment's report is
//! collected → `rejoin` starts a fresh server incarnation and dispatch
//! resumes. Every incarnation gets a disjoint request-id window, so
//! outcome ids stay unique cluster-wide through any number of rejoins.
//!
//! Concurrency contract: the node is `Sync` — many router shards
//! dispatch through `&self` concurrently while the gossip publisher
//! reads gauges — but *lifecycle transitions* (`start` / `begin_drain` /
//! `poll_drained` / `rejoin`) are driven from the single cluster
//! lifecycle thread. Dispatchers racing a drain are expected and safe:
//! [`EdgeNode::try_dispatch`] refuses (returns `None`) once the state
//! leaves `Active`, which the front-end counts as a stale-view misroute
//! and re-routes.

use crate::metrics::ShedReason;
use crate::platform::PlatformSpec;
use crate::serve::worker::ServeEvent;
use crate::serve::{GaugeSnapshot, ServeConfig, ServeReport, Server};
use crate::workload::models::ModelId;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Mutex, RwLock};

use super::netmodel::NetModel;

/// Everything needed to stand up one serving node.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    /// The node's hardware (reuse the Table-V presets —
    /// [`PlatformSpec::xavier_nx`] / [`PlatformSpec::jetson_tx2`] /
    /// [`PlatformSpec::jetson_nano`] — for a genuinely heterogeneous
    /// cluster).
    pub platform: PlatformSpec,
    /// Worker threads inside the node's serving pool.
    pub workers: usize,
    /// The node's link as seen from the cluster front-end.
    pub net: NetModel,
}

impl NodeSpec {
    /// A node on `platform` with 2 workers behind a fixed-RTT link.
    pub fn new(platform: PlatformSpec, workers: usize, rtt_ms: f64) -> Self {
        NodeSpec { platform, workers, net: NetModel::fixed(rtt_ms) }
    }
}

/// Router-facing lifecycle state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeState {
    /// Serving; the router may dispatch here.
    Active,
    /// Flushing its backlog through the drain protocol; the router must
    /// not dispatch, but already-accepted requests still complete.
    Draining,
    /// Fully drained and stopped; may rejoin.
    Drained,
}

const STATE_ACTIVE: u8 = 0;
const STATE_DRAINING: u8 = 1;
const STATE_DRAINED: u8 = 2;

fn decode_state(v: u8) -> NodeState {
    match v {
        STATE_ACTIVE => NodeState::Active,
        STATE_DRAINING => NodeState::Draining,
        _ => NodeState::Drained,
    }
}

// Id-window strides (bits 40.. encode the node, bits 32..40 the
// incarnation) live next to `ServeConfig::request_id_base`, whose
// builder validates custom bases against the same grid.
use crate::serve::{INCARNATION_ID_STRIDE, NODE_ID_STRIDE};

/// One live (or drained) cluster node.
pub struct EdgeNode {
    /// The node's static description.
    pub spec: NodeSpec,
    /// Requests the router dispatched here (including any the node's own
    /// ingress then shed — those are accounted in the node's metrics).
    dispatched: AtomicU64,
    cfg: ServeConfig,
    state: AtomicU8,
    server: RwLock<Option<Server>>,
    drain_rx: Mutex<Option<Receiver<ServeReport>>>,
    /// Reports of completed serving segments (one per drain, plus the
    /// final shutdown).
    segments: Mutex<Vec<ServeReport>>,
    events_tx: Option<Sender<ServeEvent>>,
    node_index: usize,
    incarnations: AtomicU64,
}

impl EdgeNode {
    /// Build (but do not start) a node: `base` supplies the shared
    /// serving knobs (scheduler, admission, queue capacity, rebalance,
    /// hints); the spec's platform and worker count override it.
    pub fn new(spec: NodeSpec, base: &ServeConfig, node_index: usize,
               events_tx: Option<Sender<ServeEvent>>) -> Self {
        let mut cfg = ServeConfig {
            platform: spec.platform.clone(),
            workers: spec.workers,
            ..base.clone()
        };
        // Trace records and metrics snapshots carry the node id, so the
        // front-end's merged stream stays attributable per node.
        cfg.telemetry.node_label = node_index as u32;
        EdgeNode {
            spec,
            dispatched: AtomicU64::new(0),
            cfg,
            state: AtomicU8::new(STATE_DRAINED),
            server: RwLock::new(None),
            drain_rx: Mutex::new(None),
            segments: Mutex::new(Vec::new()),
            events_tx,
            node_index,
            incarnations: AtomicU64::new(0),
        }
    }

    /// Current lifecycle state.
    pub fn state(&self) -> NodeState {
        decode_state(self.state.load(Ordering::Acquire))
    }

    /// Requests the router dispatched here so far.
    pub fn dispatched(&self) -> u64 {
        self.dispatched.load(Ordering::Relaxed)
    }

    /// Serving segments completed so far (drains; the live segment is
    /// not counted until [`EdgeNode::finish`]).
    pub fn segments_done(&self) -> usize {
        self.segments.lock().unwrap().len()
    }

    /// The per-node trace-mode serving configuration (virtual-clock
    /// cluster runs drive [`crate::serve::run_trace`] with this).
    pub fn serve_config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Start (or restart) the node's server. Each incarnation claims a
    /// fresh request-id window so ids never collide across nodes or
    /// across a drain/rejoin cycle. Lifecycle-thread only.
    pub fn start(&self) {
        let mut server = self.server.write().unwrap();
        assert!(server.is_none(), "node already running");
        let incarnation = self.incarnations.fetch_add(1, Ordering::Relaxed);
        let cfg = ServeConfig {
            request_id_base: (self.node_index as u64 + 1) * NODE_ID_STRIDE
                + incarnation * INCARNATION_ID_STRIDE,
            ..self.cfg.clone()
        };
        *server = Some(Server::start(&cfg, self.events_tx.clone()));
        self.state.store(STATE_ACTIVE, Ordering::Release);
    }

    /// Export the node's live gauge snapshot (`None` unless active).
    pub fn snapshot(&self) -> Option<GaugeSnapshot> {
        let server = self.server.read().unwrap();
        if self.state() != NodeState::Active {
            return None;
        }
        server.as_ref().map(|s| s.gauge_snapshot())
    }

    /// Dispatch one request to the node's ingress — `None` when the node
    /// is not accepting (draining/drained: the caller routed from a
    /// stale view and should count a misroute and re-route). The caller
    /// has already charged the link delay into `transmission_ms`;
    /// `Some(Err(_))` rejections (admission, backpressure) are typed and
    /// accounted in the node's own metrics. Safe from any thread.
    pub fn try_dispatch(&self, model: ModelId, slo_ms: f64,
                        transmission_ms: f64)
                        -> Option<Result<u64, ShedReason>> {
        // State is re-checked under the read guard: `begin_drain` flips
        // it before taking the write lock, so a dispatcher that gets the
        // guard with state still Active holds a live server.
        let server = self.server.read().unwrap();
        if self.state() != NodeState::Active {
            return None;
        }
        let server = server.as_ref()?;
        self.dispatched.fetch_add(1, Ordering::Relaxed);
        Some(server.submit(model, slo_ms, transmission_ms))
    }

    /// Take the node out of the cluster: dispatch stops immediately (the
    /// state flips to `Draining`), and the server runs the existing drain
    /// protocol on a background thread — accepted backlog is flushed, not
    /// dropped. Poll [`EdgeNode::poll_drained`] for completion.
    /// Lifecycle-thread only.
    pub fn begin_drain(&self) {
        assert_eq!(self.state(), NodeState::Active,
                   "can only drain an active node");
        // Refuse new dispatch BEFORE taking the server, so in-flight
        // `try_dispatch` read guards either finish against the live
        // server or observe the state change and misroute.
        self.state.store(STATE_DRAINING, Ordering::Release);
        let server = self
            .server
            .write()
            .unwrap()
            .take()
            .expect("active node without a server");
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::Builder::new()
            .name(format!("bcedge-node-drain-{}", self.node_index))
            .spawn(move || {
                // A dropped receiver cannot happen before `finish`, which
                // blocks on this send's result.
                let _ = tx.send(server.shutdown());
            })
            .expect("spawn node drain thread");
        *self.drain_rx.lock().unwrap() = Some(rx);
    }

    /// Has an in-progress drain finished? Folds the flushed segment's
    /// report into the node's accounting when it has. Idempotent; `true`
    /// once the node is `Drained`. Lifecycle-thread only.
    pub fn poll_drained(&self) -> bool {
        match self.state() {
            NodeState::Drained => true,
            NodeState::Active => false,
            NodeState::Draining => {
                let mut drain_rx = self.drain_rx.lock().unwrap();
                match drain_rx
                    .as_ref()
                    .expect("draining node without a report channel")
                    .try_recv()
                {
                    Ok(report) => {
                        self.segments.lock().unwrap().push(report);
                        *drain_rx = None;
                        self.state.store(STATE_DRAINED, Ordering::Release);
                        true
                    }
                    Err(TryRecvError::Empty) => false,
                    Err(TryRecvError::Disconnected) => {
                        panic!("node drain thread died before reporting")
                    }
                }
            }
        }
    }

    /// Bring a drained node back: a fresh server incarnation starts and
    /// the router may dispatch again. Lifecycle-thread only.
    pub fn rejoin(&self) {
        assert_eq!(self.state(), NodeState::Drained,
                   "can only rejoin a drained node");
        self.start();
    }

    /// Stop the node and hand back every serving segment it completed
    /// (any live server is shut down through the drain protocol; an
    /// unfinished background drain is waited for). Conservation: the
    /// segments jointly account every dispatched request as outcome,
    /// shed, or leftover.
    pub fn finish(self) -> FinishedNode {
        let mut segments = self.segments.into_inner().unwrap();
        if let Some(rx) = self.drain_rx.into_inner().unwrap() {
            segments.push(rx.recv().expect("node drain thread died"));
        }
        if let Some(server) = self.server.into_inner().unwrap() {
            segments.push(server.shutdown());
        }
        FinishedNode {
            spec: self.spec,
            dispatched: self.dispatched.into_inner(),
            segments,
        }
    }
}

/// A stopped node's full accounting, returned by [`EdgeNode::finish`].
pub struct FinishedNode {
    /// The node's static description.
    pub spec: NodeSpec,
    /// Requests the router dispatched to the node over its lifetime.
    pub dispatched: u64,
    /// One report per completed serving segment (≥ 1; a drain/rejoin
    /// cycle leaves two).
    pub segments: Vec<ServeReport>,
}
