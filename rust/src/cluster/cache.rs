//! Deduplicating result cache in front of the cluster router: TTL'd
//! results plus single-flight coalescing of identical in-flight work.
//!
//! Edge inference traffic repeats: the same frame crop, the same query
//! embedding, the same sensor window arrives at many clients within a
//! short span. Since the paper charges transmission/RTT into the
//! end-to-end budget (Eq. 2), a front-end cache hit is the cheapest
//! possible SLO win — it spends *zero* slack and zero node capacity.
//! CDN-style edge stacks put exactly this in front of their routers;
//! this module is that layer for `bench-cluster`.
//!
//! Keyed by `(model, input_digest)`. Three outcomes per lookup:
//!
//! * **Hit** — a fresh result (within TTL of its fill) is served
//!   instantly; the request never touches the router or any queue.
//! * **Coalesced** — an identical request is already in flight; this one
//!   joins the leader's outcome (single-flight). One upstream dispatch
//!   serves N waiters.
//! * **Lead** — no usable entry; the caller routes upstream as usual and
//!   registers the dispatched request id so the completion event fills
//!   the entry.
//!
//! Conservation: cache-served requests (hits + coalesced) are a third
//! terminal disposition next to node outcomes and sheds, so the cluster
//! identity extends to
//! `outcomes + sheds + cache_served + leftover == attempts` and
//! `dispatched + router_sheds + cache_served == attempts`.
//!
//! One implementation serves BOTH clock arms: [`ResultCache`] is sharded
//! and thread-safe for the live wall-clock driver (per-shard mutexes,
//! atomic counters, a pending-id map filled by the completion event
//! stream), and — driven single-threadedly from the event heap, with
//! leader fills applied at actual completion times — fully deterministic
//! under the virtual fabric ([`super::fabric`]). [`VirtualCache`], which
//! self-estimated the leader's fill time instead of observing it, is
//! retired from the decision path and kept only as a standalone model
//! (its fill-estimation tests double as a TTL/coalescing oracle).

use crate::util::rng::Pcg32;
use crate::workload::models::ModelId;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Front-end cache knobs.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// How long a filled result stays servable, ms. Also bounds how long
    /// an in-flight leader may be coalesced onto before it is presumed
    /// lost (shed upstream) and a new leader is elected.
    pub ttl_ms: f64,
    /// Max resident entries (FIFO eviction past this).
    pub capacity: usize,
}

/// What one lookup decided (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheLookup {
    /// Fresh result present: served instantly, zero slack spent.
    Hit,
    /// Identical request in flight: coalesced onto the leader's outcome.
    Coalesced,
    /// Nothing usable: the caller leads a fill (routes upstream).
    Lead,
}

/// Cache disposition counters, folded into the cluster report.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from a fresh Ready entry.
    pub hits: u64,
    /// Lookups coalesced onto an in-flight leader (single-flight).
    pub coalesced: u64,
    /// Lookups that found nothing and led a fill.
    pub misses: u64,
    /// Ready entries found TTL-expired at lookup (the request returned
    /// to routing and re-led).
    pub stale: u64,
    /// In-flight leaders presumed lost (no completion within TTL —
    /// upstream shed or drain); the waiter re-led.
    pub orphaned: u64,
    /// Entries evicted by the capacity bound.
    pub evictions: u64,
}

impl CacheStats {
    /// Requests the cache terminated (never reached the router):
    /// the `cache_served` term of the conservation identity.
    pub fn served(&self) -> u64 {
        self.hits + self.coalesced
    }

    /// Hit rate over all lookups (served / looked-up).
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.served() + self.misses + self.stale + self.orphaned;
        if lookups == 0 {
            0.0
        } else {
            self.served() as f64 / lookups as f64
        }
    }

    /// Fold another shard's counters into this one.
    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.coalesced += other.coalesced;
        self.misses += other.misses;
        self.stale += other.stale;
        self.orphaned += other.orphaned;
        self.evictions += other.evictions;
    }
}

// ---------------------------------------------------------------------
// Deterministic input digests
// ---------------------------------------------------------------------

/// Digests drawn from this many "popular" repeated inputs per model.
pub const REPEAT_POOL: u32 = 64;

/// Deterministic input digest for trace request `index`: with
/// probability `repeat_fraction` the request carries one of
/// [`REPEAT_POOL`] popular digests (cacheable repeats); otherwise a
/// unique digest no other request shares. Drawn from a PCG stream keyed
/// by `index` itself, so the digest depends only on `(seed, index)` —
/// never on which router shard handles the request — preserving the
/// virtual arm's bit-determinism for any fixed `(seed, shards)`.
pub fn digest_for(seed: u64, index: u64, repeat_fraction: f64) -> u64 {
    const UNIQUE_BASE: u64 = 1 << 48; // disjoint from the popular pool
    if repeat_fraction <= 0.0 {
        return UNIQUE_BASE | index;
    }
    let mut rng = Pcg32::new(seed ^ 0xD1_6E57, index);
    if rng.f64() < repeat_fraction {
        u64::from(rng.below(REPEAT_POOL))
    } else {
        UNIQUE_BASE | index
    }
}

// ---------------------------------------------------------------------
// Live (wall-clock) cache: sharded, thread-safe, single-flight
// ---------------------------------------------------------------------

type Key = (usize, u64); // (model index, digest)

#[derive(Clone, Copy, Debug)]
enum EntryState {
    /// A leader is upstream; `since_ms` bounds how long waiters coalesce.
    InFlight { since_ms: f64 },
    /// Result landed at `filled_ms`; servable until `filled_ms + ttl`.
    Ready { filled_ms: f64 },
}

struct CacheShard {
    map: HashMap<Key, EntryState>,
    /// Insertion order for FIFO capacity eviction.
    order: VecDeque<Key>,
}

/// Number of independent lock shards — router shards contend only when
/// they touch the same digest neighborhood, not on every lookup.
const CACHE_SHARDS: usize = 16;

/// The live, thread-safe front-end cache (see module docs).
pub struct ResultCache {
    ttl_ms: f64,
    capacity_per_shard: usize,
    shards: Vec<Mutex<CacheShard>>,
    /// Dispatched leader request id → cache key, resolved by the
    /// completion event stream.
    pending: Mutex<HashMap<u64, Key>>,
    hits: AtomicU64,
    coalesced: AtomicU64,
    misses: AtomicU64,
    stale: AtomicU64,
    orphaned: AtomicU64,
    evictions: AtomicU64,
}

impl ResultCache {
    pub fn new(cfg: CacheConfig) -> Self {
        ResultCache {
            ttl_ms: cfg.ttl_ms.max(0.0),
            capacity_per_shard: (cfg.capacity / CACHE_SHARDS).max(1),
            shards: (0..CACHE_SHARDS)
                .map(|_| {
                    Mutex::new(CacheShard {
                        map: HashMap::new(),
                        order: VecDeque::new(),
                    })
                })
                .collect(),
            pending: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stale: AtomicU64::new(0),
            orphaned: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard_of(key: &Key) -> usize {
        // Digest low bits spread uniformly (PCG output / unique index).
        (key.1 as usize ^ key.0) % CACHE_SHARDS
    }

    /// Decide one request's disposition at `now_ms`. A `Lead` return has
    /// already installed the in-flight placeholder (single-flight is
    /// committed *atomically with the lookup* — two racing identical
    /// requests cannot both lead). The leader must follow up with
    /// [`ResultCache::commit_leader`] (dispatch accepted) or
    /// [`ResultCache::abort_leader`] (dispatch refused).
    pub fn lookup(&self, model: ModelId, digest: u64, now_ms: f64)
                  -> CacheLookup {
        let key = (model as usize, digest);
        let mut shard = self.shards[Self::shard_of(&key)].lock().unwrap();
        match shard.map.get(&key).copied() {
            Some(EntryState::Ready { filled_ms })
                if now_ms <= filled_ms + self.ttl_ms =>
            {
                self.hits.fetch_add(1, Ordering::Relaxed);
                CacheLookup::Hit
            }
            Some(EntryState::Ready { .. }) => {
                // Expired: this request re-leads a refill in place.
                self.stale.fetch_add(1, Ordering::Relaxed);
                shard.map.insert(key, EntryState::InFlight { since_ms: now_ms });
                CacheLookup::Lead
            }
            Some(EntryState::InFlight { since_ms })
                if now_ms <= since_ms + self.ttl_ms =>
            {
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                CacheLookup::Coalesced
            }
            Some(EntryState::InFlight { .. }) => {
                // The leader never completed within TTL — it was shed or
                // lost upstream (`ServeEvent::Shed` carries no id, so
                // timeout is the only safe signal). Elect a new leader.
                self.orphaned.fetch_add(1, Ordering::Relaxed);
                shard.map.insert(key, EntryState::InFlight { since_ms: now_ms });
                CacheLookup::Lead
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                shard.map.insert(key, EntryState::InFlight { since_ms: now_ms });
                shard.order.push_back(key);
                if shard.order.len() > self.capacity_per_shard {
                    if let Some(old) = shard.order.pop_front() {
                        if shard.map.remove(&old).is_some() {
                            self.evictions.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                CacheLookup::Lead
            }
        }
    }

    /// The leader's dispatch was accepted upstream as request `id`: the
    /// completion event for `id` will fill the entry.
    pub fn commit_leader(&self, model: ModelId, digest: u64, id: u64) {
        self.pending.lock().unwrap().insert(id, (model as usize, digest));
    }

    /// The leader's dispatch was refused (router or node shed): drop the
    /// in-flight placeholder so the next identical request leads afresh
    /// instead of waiting out the orphan TTL.
    pub fn abort_leader(&self, model: ModelId, digest: u64) {
        let key = (model as usize, digest);
        let mut shard = self.shards[Self::shard_of(&key)].lock().unwrap();
        if let Some(EntryState::InFlight { .. }) = shard.map.get(&key) {
            shard.map.remove(&key);
        }
    }

    /// A terminal completion event for request `id` arrived at `now_ms`:
    /// if it was a registered leader, its entry becomes Ready. Events for
    /// non-leader ids are ignored (cheap hash miss).
    pub fn on_completed(&self, id: u64, now_ms: f64) {
        let Some(key) = self.pending.lock().unwrap().remove(&id) else {
            return;
        };
        let mut shard = self.shards[Self::shard_of(&key)].lock().unwrap();
        // Only fill an entry still waiting on a leader — it may have
        // been evicted, or orphan-recycled to a newer leader.
        if let Some(e @ EntryState::InFlight { .. }) = shard.map.get_mut(&key) {
            *e = EntryState::Ready { filled_ms: now_ms };
        }
    }

    /// Current disposition counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stale: self.stale.load(Ordering::Relaxed),
            orphaned: self.orphaned.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------
// Virtual (deterministic) cache
// ---------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct VirtualEntry {
    /// When the leader's modeled result lands (dispatch time + estimated
    /// RTT + service); before this the entry is in flight.
    fill_ms: f64,
}

/// RETIRED from the decision path: the virtual arm now drives the real
/// [`ResultCache`] from the event heap, filling leaders at actual
/// completion times. This standalone model — same disposition semantics,
/// with the leader's fill time *estimated* (RTT + backlog at dispatch)
/// instead of observed — survives only as a self-contained TTL /
/// coalescing / eviction oracle for the unit tests below.
pub struct VirtualCache {
    ttl_ms: f64,
    capacity: usize,
    map: HashMap<Key, VirtualEntry>,
    order: VecDeque<Key>,
    /// Disposition counters (public: the driver folds them directly).
    pub stats: CacheStats,
}

impl VirtualCache {
    pub fn new(cfg: CacheConfig) -> Self {
        VirtualCache {
            ttl_ms: cfg.ttl_ms.max(0.0),
            capacity: cfg.capacity.max(1),
            map: HashMap::new(),
            order: VecDeque::new(),
            stats: CacheStats::default(),
        }
    }

    /// Decide one request's disposition at trace time `now_ms`. Unlike
    /// the live cache, a `Lead` installs nothing — the caller routes and,
    /// if dispatch succeeds, records the modeled fill via
    /// [`VirtualCache::fill`] (a shed leader simply leaves no entry).
    pub fn lookup(&mut self, model: ModelId, digest: u64, now_ms: f64)
                  -> CacheLookup {
        let key = (model as usize, digest);
        match self.map.get(&key).copied() {
            Some(e) if now_ms < e.fill_ms => {
                self.stats.coalesced += 1;
                CacheLookup::Coalesced
            }
            Some(e) if now_ms <= e.fill_ms + self.ttl_ms => {
                self.stats.hits += 1;
                CacheLookup::Hit
            }
            Some(_) => {
                self.stats.stale += 1;
                self.map.remove(&key);
                CacheLookup::Lead
            }
            None => {
                self.stats.misses += 1;
                CacheLookup::Lead
            }
        }
    }

    /// Record a dispatched leader's modeled fill time for `(model,
    /// digest)`.
    pub fn fill(&mut self, model: ModelId, digest: u64, fill_ms: f64) {
        let key = (model as usize, digest);
        if self.map.insert(key, VirtualEntry { fill_ms }).is_none() {
            self.order.push_back(key);
            if self.order.len() > self.capacity {
                if let Some(old) = self.order.pop_front() {
                    if self.map.remove(&old).is_some() {
                        self.stats.evictions += 1;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    const CFG: CacheConfig = CacheConfig { ttl_ms: 100.0, capacity: 1024 };

    #[test]
    fn live_cache_single_flight_one_leader_many_waiters() {
        let cache = ResultCache::new(CFG);
        let m = ModelId::all()[0];
        // First request leads...
        assert_eq!(cache.lookup(m, 7, 0.0), CacheLookup::Lead);
        cache.commit_leader(m, 7, 999);
        // ...N identical in-flight requests all coalesce onto it...
        for t in 1..=5 {
            assert_eq!(cache.lookup(m, 7, t as f64), CacheLookup::Coalesced);
        }
        // ...the ONE upstream completion fills the entry...
        cache.on_completed(999, 10.0);
        // ...and later identical requests are plain hits within TTL.
        assert_eq!(cache.lookup(m, 7, 50.0), CacheLookup::Hit);
        let s = cache.stats();
        assert_eq!((s.misses, s.coalesced, s.hits), (1, 5, 1));
        assert_eq!(s.served(), 6);
    }

    #[test]
    fn live_cache_ttl_expiry_returns_to_routing() {
        let cache = ResultCache::new(CFG);
        let m = ModelId::all()[0];
        assert_eq!(cache.lookup(m, 1, 0.0), CacheLookup::Lead);
        cache.commit_leader(m, 1, 1);
        cache.on_completed(1, 5.0);
        // Fresh within ttl of the fill; stale after.
        assert_eq!(cache.lookup(m, 1, 105.0), CacheLookup::Hit);
        assert_eq!(cache.lookup(m, 1, 105.1), CacheLookup::Lead);
        assert_eq!(cache.stats().stale, 1);
        // The re-lead is itself coalescable again.
        assert_eq!(cache.lookup(m, 1, 106.0), CacheLookup::Coalesced);
    }

    #[test]
    fn live_cache_orphaned_leader_is_recycled_after_ttl() {
        let cache = ResultCache::new(CFG);
        let m = ModelId::all()[0];
        assert_eq!(cache.lookup(m, 3, 0.0), CacheLookup::Lead);
        // Leader was shed upstream (no completion event ever arrives;
        // ServeEvent::Shed carries no id). Within TTL waiters still
        // coalesce; past it, a new leader is elected.
        assert_eq!(cache.lookup(m, 3, 99.0), CacheLookup::Coalesced);
        assert_eq!(cache.lookup(m, 3, 101.0), CacheLookup::Lead);
        assert_eq!(cache.stats().orphaned, 1);
    }

    #[test]
    fn live_cache_abort_leader_clears_the_placeholder() {
        let cache = ResultCache::new(CFG);
        let m = ModelId::all()[0];
        assert_eq!(cache.lookup(m, 9, 0.0), CacheLookup::Lead);
        cache.abort_leader(m, 9); // dispatch refused at the edge
        // Next identical request leads immediately, not after orphan TTL.
        assert_eq!(cache.lookup(m, 9, 1.0), CacheLookup::Lead);
        assert_eq!(cache.stats().orphaned, 0);
    }

    #[test]
    fn live_cache_capacity_evicts_fifo() {
        let cache = ResultCache::new(CacheConfig {
            ttl_ms: 1e9,
            capacity: CACHE_SHARDS, // one entry per shard
        });
        let m = ModelId::all()[0];
        // Two digests landing in the SAME shard: the second insert
        // evicts the first.
        let (a, b) = (0u64, CACHE_SHARDS as u64);
        assert_eq!(ResultCache::shard_of(&(m as usize, a)),
                   ResultCache::shard_of(&(m as usize, b)));
        assert_eq!(cache.lookup(m, a, 0.0), CacheLookup::Lead);
        assert_eq!(cache.lookup(m, b, 0.0), CacheLookup::Lead);
        assert!(cache.stats().evictions >= 1);
        // The evicted digest misses again.
        assert_eq!(cache.lookup(m, a, 1.0), CacheLookup::Lead);
    }

    #[test]
    fn live_cache_is_thread_safe_and_counts_every_lookup() {
        let cache = Arc::new(ResultCache::new(CFG));
        let m = ModelId::all()[1];
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let cache = Arc::clone(&cache);
                std::thread::spawn(move || {
                    for i in 0..250u64 {
                        let digest = (t * 250 + i) % 10; // heavy overlap
                        if cache.lookup(m, digest, i as f64)
                            == CacheLookup::Lead
                        {
                            cache.commit_leader(m, digest, t * 1000 + i);
                            cache.on_completed(t * 1000 + i, i as f64);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = cache.stats();
        assert_eq!(s.served() + s.misses + s.stale + s.orphaned, 1000,
                   "a lookup went uncounted: {s:?}");
        assert!(s.served() > 0, "overlapping digests never deduped");
    }

    #[test]
    fn virtual_cache_models_coalesce_then_hit_then_stale() {
        let mut cache = VirtualCache::new(CFG);
        let m = ModelId::all()[0];
        assert_eq!(cache.lookup(m, 5, 0.0), CacheLookup::Lead);
        cache.fill(m, 5, 20.0); // leader's modeled result lands at 20ms
        // Before the fill: in flight, coalesced.
        assert_eq!(cache.lookup(m, 5, 10.0), CacheLookup::Coalesced);
        // After the fill, within TTL: hit.
        assert_eq!(cache.lookup(m, 5, 30.0), CacheLookup::Hit);
        assert_eq!(cache.lookup(m, 5, 120.0), CacheLookup::Hit);
        // Past fill + TTL: stale, back to routing.
        assert_eq!(cache.lookup(m, 5, 120.1), CacheLookup::Lead);
        assert_eq!(cache.stats.stale, 1);
    }

    #[test]
    fn virtual_cache_capacity_evicts_fifo() {
        let mut cache =
            VirtualCache::new(CacheConfig { ttl_ms: 1e9, capacity: 2 });
        let m = ModelId::all()[0];
        for d in 0..3u64 {
            assert_eq!(cache.lookup(m, d, 0.0), CacheLookup::Lead);
            cache.fill(m, d, 0.0);
        }
        assert_eq!(cache.stats.evictions, 1);
        assert_eq!(cache.lookup(m, 0, 1.0), CacheLookup::Lead, "not evicted");
        assert_eq!(cache.lookup(m, 2, 1.0), CacheLookup::Hit);
    }

    #[test]
    fn digests_are_deterministic_and_repeat_fraction_scales_overlap() {
        // Pure function of (seed, index): identical across calls.
        for i in 0..100 {
            assert_eq!(digest_for(42, i, 0.5), digest_for(42, i, 0.5));
        }
        // repeat_fraction 0: every digest unique.
        let unique: std::collections::HashSet<u64> =
            (0..1000).map(|i| digest_for(7, i, 0.0)).collect();
        assert_eq!(unique.len(), 1000);
        // repeat_fraction 1: every digest from the popular pool.
        assert!((0..1000).all(|i| digest_for(7, i, 1.0) < u64::from(REPEAT_POOL)));
        // Intermediate: repeats happen, uniques survive.
        let mixed: Vec<u64> = (0..1000).map(|i| digest_for(7, i, 0.5)).collect();
        let popular = mixed.iter().filter(|d| **d < u64::from(REPEAT_POOL)).count();
        assert!(popular > 300 && popular < 700,
                "repeat fraction badly skewed: {popular}/1000");
    }
}
