//! Performance profiler (paper §IV-E): periodically collects utilization,
//! throughput, and end-to-end latency per (batch, m_c) configuration and
//! feeds the scheduler + interference predictor.
//!
//! Implemented as a bounded ring of [`ProfileSample`]s with rolling
//! per-model aggregates — the scheduler's state encoder reads the rolling
//! view in O(1).

use crate::workload::models::{ModelId, N_MODELS};
use std::collections::VecDeque;

/// One profiled slot execution.
#[derive(Clone, Copy, Debug)]
pub struct ProfileSample {
    pub t_ms: f64,
    pub model: ModelId,
    pub batch: usize,
    pub concurrency: usize,
    /// Measured batch latency, ms.
    pub latency_ms: f64,
    /// Requests completed in the slot.
    pub completed: usize,
    /// Utilization snapshot at dispatch.
    pub compute_demand: f64,
    pub memory_pressure: f64,
    pub active_instances: usize,
    /// Ground-truth latency inflation vs isolated (simulation) or measured
    /// ratio vs rolling isolated estimate (real backend).
    pub inflation: f64,
}

/// Rolling per-model aggregates maintained incrementally.
#[derive(Clone, Copy, Debug, Default)]
struct Rolling {
    n: u64,
    latency_sum: f64,
    completed_sum: f64,
    span_sum_ms: f64,
}

/// The profiler: bounded history + rolling stats.
#[derive(Clone, Debug)]
pub struct Profiler {
    window: usize,
    samples: VecDeque<ProfileSample>,
    rolling: [Rolling; N_MODELS],
    /// Rolling Σ inflation over the window, so `mean_inflation` — read by
    /// the state encoder on every decision — is O(1) instead of the O(n)
    /// scan the seed used (`mean_inflation_naive` keeps the scan as a
    /// test oracle). Maintained by add-on-record / subtract-on-evict;
    /// drift stays bounded because the window is small (hundreds) and
    /// inflation values are O(1).
    inflation_sum: f64,
}

impl Profiler {
    pub fn new(window: usize) -> Self {
        Profiler {
            window: window.max(1),
            samples: VecDeque::new(),
            rolling: [Rolling::default(); N_MODELS],
            inflation_sum: 0.0,
        }
    }

    pub fn record(&mut self, s: ProfileSample) {
        let r = &mut self.rolling[s.model as usize];
        r.n += 1;
        r.latency_sum += s.latency_ms;
        r.completed_sum += s.completed as f64;
        r.span_sum_ms += s.latency_ms;
        self.inflation_sum += s.inflation;
        self.samples.push_back(s);
        if self.samples.len() > self.window {
            let old = self.samples.pop_front().unwrap();
            let r = &mut self.rolling[old.model as usize];
            r.n -= 1;
            r.latency_sum -= old.latency_ms;
            r.completed_sum -= old.completed as f64;
            r.span_sum_ms -= old.latency_ms;
            self.inflation_sum -= old.inflation;
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn samples(&self) -> impl Iterator<Item = &ProfileSample> {
        self.samples.iter()
    }

    /// Rolling mean batch latency for a model (NaN when unobserved).
    pub fn mean_latency_ms(&self, model: ModelId) -> f64 {
        let r = &self.rolling[model as usize];
        if r.n == 0 {
            f64::NAN
        } else {
            r.latency_sum / r.n as f64
        }
    }

    /// Rolling throughput estimate (completed per second of busy time).
    pub fn throughput_rps(&self, model: ModelId) -> f64 {
        let r = &self.rolling[model as usize];
        if r.span_sum_ms <= 0.0 {
            0.0
        } else {
            r.completed_sum / (r.span_sum_ms / 1e3)
        }
    }

    /// Most recent utilization snapshot (zeros before any sample).
    pub fn utilization(&self) -> (f64, f64, usize) {
        self.samples
            .back()
            .map(|s| (s.compute_demand, s.memory_pressure, s.active_instances))
            .unwrap_or((0.0, 0.0, 0))
    }

    /// Rolling mean inflation across all models (1.0 before any sample).
    /// O(1): maintained sum over the window.
    pub fn mean_inflation(&self) -> f64 {
        if self.samples.is_empty() {
            return 1.0;
        }
        self.inflation_sum / self.samples.len() as f64
    }

    /// O(n) recomputation of [`Profiler::mean_inflation`] — the seed
    /// implementation, kept as a test/bench oracle. Bit-identical to the
    /// rolling value until the first eviction (both are the same
    /// left-to-right sum); within float tolerance afterwards.
    pub fn mean_inflation_naive(&self) -> f64 {
        if self.samples.is_empty() {
            return 1.0;
        }
        self.samples.iter().map(|s| s.inflation).sum::<f64>()
            / self.samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(model: ModelId, latency: f64, completed: usize) -> ProfileSample {
        ProfileSample {
            t_ms: 0.0,
            model,
            batch: completed,
            concurrency: 1,
            latency_ms: latency,
            completed,
            compute_demand: 0.5,
            memory_pressure: 0.2,
            active_instances: 1,
            inflation: 1.1,
        }
    }

    #[test]
    fn rolling_means_track_window() {
        let mut p = Profiler::new(2);
        p.record(sample(ModelId::Res, 10.0, 4));
        p.record(sample(ModelId::Res, 20.0, 4));
        assert!((p.mean_latency_ms(ModelId::Res) - 15.0).abs() < 1e-9);
        p.record(sample(ModelId::Res, 30.0, 4)); // evicts the 10.0 sample
        assert!((p.mean_latency_ms(ModelId::Res) - 25.0).abs() < 1e-9);
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn throughput_from_busy_time() {
        let mut p = Profiler::new(8);
        p.record(sample(ModelId::Mob, 100.0, 10)); // 10 reqs in 100 ms
        assert!((p.throughput_rps(ModelId::Mob) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn unobserved_model_is_nan() {
        let p = Profiler::new(4);
        assert!(p.mean_latency_ms(ModelId::Bert).is_nan());
        assert_eq!(p.utilization(), (0.0, 0.0, 0));
        assert_eq!(p.mean_inflation(), 1.0);
        assert_eq!(p.mean_inflation_naive(), 1.0);
    }

    #[test]
    fn rolling_inflation_matches_naive_before_eviction() {
        let mut p = Profiler::new(64);
        let mut rng = crate::util::rng::Pcg32::seeded(0x1F);
        for i in 0..64 {
            let mut s = sample(ModelId::Res, 10.0 + i as f64, 4);
            s.inflation = 1.0 + rng.f64();
            p.record(s);
            // Pre-eviction both are the same left-to-right sum.
            assert_eq!(p.mean_inflation(), p.mean_inflation_naive());
        }
    }

    #[test]
    fn rolling_inflation_tracks_naive_through_evictions() {
        let mut p = Profiler::new(32);
        let mut rng = crate::util::rng::Pcg32::seeded(0x2F);
        for i in 0..4096 {
            let mut s = sample(ModelId::from_index(i % 6), 10.0, 2);
            s.inflation = 1.0 + rng.f64() * 3.0;
            p.record(s);
            let (roll, naive) = (p.mean_inflation(), p.mean_inflation_naive());
            assert!(
                (roll - naive).abs() < 1e-9,
                "drift at {i}: rolling {roll} naive {naive}"
            );
        }
    }
}
