//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! and the rust runtime (`artifacts/manifest.json`).

use crate::util::json::{self, Json};
use crate::workload::models::ModelId;
use std::collections::BTreeMap;

/// One compiled (model, batch) artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub model: ModelId,
    pub batch: usize,
    /// HLO-text file, relative to the artifact directory.
    pub path: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub param_count: usize,
    pub slo_ms: f64,
}

/// Parsed manifest with (model, batch) lookup.
#[derive(Clone, Debug)]
pub struct ArtifactIndex {
    pub dir: String,
    pub batch_sizes: Vec<usize>,
    entries: BTreeMap<(ModelId, usize), ArtifactEntry>,
}

impl ArtifactIndex {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<ArtifactIndex, String> {
        let text = std::fs::read_to_string(format!("{dir}/manifest.json"))
            .map_err(|e| format!("reading manifest in {dir}: {e}"))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated for tests).
    pub fn parse(dir: &str, text: &str) -> Result<ArtifactIndex, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        if v.get("format").and_then(Json::as_str) != Some("bcedge-aot-v1") {
            return Err("unknown manifest format".into());
        }
        if v.get("return_tuple").and_then(Json::as_bool) != Some(true) {
            return Err("manifest must declare return_tuple=true".into());
        }
        let batch_sizes: Vec<usize> = v
            .get("batch_sizes")
            .and_then(Json::as_arr)
            .ok_or("missing batch_sizes")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let mut entries = BTreeMap::new();
        for e in v.get("entries").and_then(Json::as_arr).ok_or("entries")? {
            let name = e.get("model").and_then(Json::as_str).ok_or("model")?;
            let model = ModelId::from_name(name)
                .ok_or_else(|| format!("unknown model {name}"))?;
            let batch =
                e.get("batch").and_then(Json::as_usize).ok_or("batch")?;
            let shape = |key: &str| -> Result<Vec<usize>, String> {
                Ok(e.get(key)
                    .and_then(Json::as_arr)
                    .ok_or(key.to_string())?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect())
            };
            let entry = ArtifactEntry {
                model,
                batch,
                path: e.get("path").and_then(Json::as_str).ok_or("path")?.into(),
                input_shape: shape("input_shape")?,
                output_shape: shape("output_shape")?,
                param_count: e
                    .get("param_count")
                    .and_then(Json::as_usize)
                    .unwrap_or(0),
                slo_ms: e.get("slo_ms").and_then(Json::as_f64).unwrap_or(0.0),
            };
            if entry.input_shape.first() != Some(&batch) {
                return Err(format!(
                    "{name} b={batch}: input shape {:?} does not lead with batch",
                    entry.input_shape
                ));
            }
            entries.insert((model, batch), entry);
        }
        if entries.is_empty() {
            return Err("manifest has no entries".into());
        }
        Ok(ArtifactIndex { dir: dir.to_string(), batch_sizes, entries })
    }

    pub fn get(&self, model: ModelId, batch: usize) -> Option<&ArtifactEntry> {
        self.entries.get(&(model, batch))
    }

    /// Smallest compiled batch ≥ `want` for `model` (TensorRT-style pad-up;
    /// falls back to the largest compiled batch when `want` exceeds it).
    pub fn batch_for(&self, model: ModelId, want: usize) -> Option<usize> {
        let mut available: Vec<usize> = self
            .entries
            .keys()
            .filter(|(m, _)| *m == model)
            .map(|(_, b)| *b)
            .collect();
        available.sort_unstable();
        available
            .iter()
            .find(|&&b| b >= want)
            .or(available.last())
            .copied()
    }

    pub fn models(&self) -> Vec<ModelId> {
        let mut ms: Vec<ModelId> =
            self.entries.keys().map(|(m, _)| *m).collect();
        ms.dedup();
        ms
    }

    pub fn full_path(&self, entry: &ArtifactEntry) -> String {
        format!("{}/{}", self.dir, entry.path)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "format": "bcedge-aot-v1", "return_tuple": true,
      "batch_sizes": [1, 4],
      "models": ["res"],
      "entries": [
        {"model": "res", "batch": 1, "path": "res_b1.hlo.txt",
         "input_shape": [1, 3, 32, 32], "output_shape": [1, 10],
         "param_count": 100, "slo_ms": 58.0},
        {"model": "res", "batch": 4, "path": "res_b4.hlo.txt",
         "input_shape": [4, 3, 32, 32], "output_shape": [4, 10],
         "param_count": 100, "slo_ms": 58.0}
      ]
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let idx = ArtifactIndex::parse("/tmp/a", MINI).unwrap();
        assert_eq!(idx.len(), 2);
        let e = idx.get(ModelId::Res, 4).unwrap();
        assert_eq!(e.input_shape, vec![4, 3, 32, 32]);
        assert_eq!(idx.full_path(e), "/tmp/a/res_b4.hlo.txt");
    }

    #[test]
    fn batch_for_pads_up_and_clamps() {
        let idx = ArtifactIndex::parse("/tmp/a", MINI).unwrap();
        assert_eq!(idx.batch_for(ModelId::Res, 1), Some(1));
        assert_eq!(idx.batch_for(ModelId::Res, 2), Some(4));
        assert_eq!(idx.batch_for(ModelId::Res, 3), Some(4));
        assert_eq!(idx.batch_for(ModelId::Res, 100), Some(4)); // clamp
        assert_eq!(idx.batch_for(ModelId::Yolo, 1), None);
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(ArtifactIndex::parse("/", "{}").is_err());
        assert!(ArtifactIndex::parse(
            "/",
            r#"{"format":"bcedge-aot-v1","return_tuple":false,"batch_sizes":[],"entries":[]}"#
        )
        .is_err());
        // batch/shape mismatch
        let bad = MINI.replace("\"input_shape\": [4, 3, 32, 32]",
                               "\"input_shape\": [2, 3, 32, 32]");
        assert!(ArtifactIndex::parse("/", &bad).is_err());
    }

    #[test]
    fn loads_repo_manifest_if_built() {
        // Integration against the real AOT output when present.
        if let Ok(idx) = ArtifactIndex::load("artifacts") {
            assert_eq!(idx.models().len(), 6);
            for m in ModelId::all() {
                assert!(idx.get(m, 1).is_some(), "{m:?} b=1 missing");
            }
        }
    }
}
