//! Execution runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and runs them on the PJRT CPU client, plus the
//! virtual-time simulation backend used by the long-horizon experiments.
//!
//! Python never appears here — the artifacts are self-contained HLO with
//! weights baked in as constants, so the request path is pure rust + XLA.

pub mod artifacts;
pub mod executor;
pub mod pjrt;

pub use artifacts::{ArtifactEntry, ArtifactIndex};
pub use executor::{BatchJob, Dispatcher, ExecError, RealDispatcher, SimDispatcher};
pub use pjrt::PjrtRuntime;
