//! PJRT execution of the AOT artifacts — the real inference backend.
//!
//! Pipeline per artifact (see /opt/xla-example/load_hlo and DESIGN.md §1):
//! HLO text → `HloModuleProto::from_text_file` (the text parser reassigns
//! the 64-bit instruction ids jax ≥ 0.5 emits, which xla_extension 0.5.1
//! would otherwise reject) → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → cached `PjRtLoadedExecutable`.
//!
//! Executables are compiled lazily per (model, batch) and cached for the
//! life of the runtime — the TensorRT-engine-per-batch analogue. Inputs
//! are f32 for every model (bert casts ids in-graph), outputs are a
//! 1-tuple (lowered with `return_tuple=True`).

use super::artifacts::{ArtifactEntry, ArtifactIndex};
use crate::workload::models::ModelId;
use std::collections::HashMap;
use std::sync::Mutex;

/// Cached PJRT runtime over an artifact directory.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    index: ArtifactIndex,
    cache: Mutex<HashMap<(ModelId, usize), xla::PjRtLoadedExecutable>>,
}

// SAFETY: the `xla` crate wraps PJRT handles in `Rc`, which makes the
// struct !Send/!Sync even though the underlying PJRT C API specifies that
// `PJRT_LoadedExecutable_Execute` and client queries are thread-safe. We
// uphold the needed discipline manually:
//  * the `Rc` refcounts are only touched at construction (single thread)
//    and drop (single owner via `Arc<PjrtRuntime>` — the Arc serializes
//    the final drop);
//  * compilation (which mutates client state) is serialized under the
//    `cache` mutex (see `warm`);
//  * concurrent `execute` calls only read the raw executable pointer.
unsafe impl Send for PjrtRuntime {}
unsafe impl Sync for PjrtRuntime {}

/// Result of one batch execution.
#[derive(Clone, Debug)]
pub struct ExecOutput {
    /// Flattened f32 outputs, row-major over the artifact's output shape.
    pub data: Vec<f32>,
    pub output_shape: Vec<usize>,
    /// Wall-clock execution latency (compile excluded), ms.
    pub latency_ms: f64,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client over `dir` (must contain manifest.json).
    pub fn load(dir: &str) -> anyhow::Result<PjrtRuntime> {
        let index = ArtifactIndex::load(dir)
            .map_err(|e| anyhow::anyhow!("artifact index: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client, index, cache: Mutex::new(HashMap::new()) })
    }

    pub fn index(&self) -> &ArtifactIndex {
        &self.index
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Ensure the (model, batch) executable is compiled and cached.
    /// Returns the compile time in ms (0 when already cached). The cache
    /// lock is held across compilation on purpose: PJRT compilation is the
    /// one client operation we must serialize (see the SAFETY note above).
    pub fn warm(&self, model: ModelId, batch: usize) -> anyhow::Result<f64> {
        let key = (model, batch);
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(&key) {
            return Ok(0.0);
        }
        let entry = self
            .index
            .get(model, batch)
            .ok_or_else(|| anyhow::anyhow!("no artifact for {model:?} b={batch}"))?
            .clone();
        let t0 = std::time::Instant::now();
        let exe = self.compile_entry(&entry)?;
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        cache.insert(key, exe);
        Ok(dt)
    }

    fn compile_entry(&self, entry: &ArtifactEntry)
                     -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let path = self.index.full_path(entry);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Execute one batch. `input` must contain exactly
    /// `prod(entry.input_shape)` f32 values (padded by the batcher).
    pub fn execute(&self, model: ModelId, batch: usize, input: &[f32])
                   -> anyhow::Result<ExecOutput> {
        self.warm(model, batch)?;
        let entry = self.index.get(model, batch).unwrap();
        let want: usize = entry.input_shape.iter().product();
        anyhow::ensure!(
            input.len() == want,
            "input length {} != expected {want} for {model:?} b={batch}",
            input.len()
        );
        let dims: Vec<i64> =
            entry.input_shape.iter().map(|&d| d as i64).collect();
        let literal = xla::Literal::vec1(input).reshape(&dims)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(&(model, batch)).unwrap();
        let t0 = std::time::Instant::now();
        let result = exe.execute::<xla::Literal>(&[literal])?[0][0]
            .to_literal_sync()?;
        let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        Ok(ExecOutput {
            data,
            output_shape: entry.output_shape.clone(),
            latency_ms,
        })
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end smoke across the PJRT bridge. Skips silently when
    /// `make artifacts` has not run (CI builds artifacts first).
    #[test]
    fn executes_res_artifact() {
        let Ok(rt) = PjrtRuntime::load("artifacts") else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let entry = rt.index().get(ModelId::Res, 1).unwrap().clone();
        let n: usize = entry.input_shape.iter().product();
        let input = vec![0.5f32; n];
        let out = rt.execute(ModelId::Res, 1, &input).unwrap();
        assert_eq!(out.data.len(),
                   entry.output_shape.iter().product::<usize>());
        assert!(out.data.iter().all(|x| x.is_finite()));
        assert!(out.latency_ms > 0.0);
        // Determinism: weights are baked constants.
        let out2 = rt.execute(ModelId::Res, 1, &input).unwrap();
        assert_eq!(out.data, out2.data);
        // Wrong input size is rejected.
        assert!(rt.execute(ModelId::Res, 1, &input[..n - 1]).is_err());
    }
}
