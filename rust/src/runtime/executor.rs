//! The execution dispatcher abstraction: one trait, two backends.
//!
//! The serving engine hands the dispatcher a *slot group* — the m_c
//! instance-batches the scheduler chose for one scheduling slot (paper
//! Fig. 4) — and receives per-batch latencies:
//!
//! * [`SimDispatcher`] prices the group on the [`PlatformSim`] and advances
//!   a [`VirtualClock`] — used for the long-horizon and platform-sweep
//!   experiments;
//! * [`RealDispatcher`] runs each batch's AOT artifact on the PJRT CPU
//!   client across a thread pool, so concurrent instances genuinely
//!   contend for cores — used by the end-to-end examples.
//!
//! Hot path: the engine calls [`Dispatcher::run_group_into`] with a
//! reused result buffer every round, so steady-state dispatch allocates
//! nothing on either backend.

use crate::platform::sim::{BatchHandle, PlatformSim};
use crate::platform::OomError;
use crate::util::pool::ThreadPool;
use crate::util::time::{Clock, ClockSource, VirtualClock};
use crate::workload::models::{ModelId, ModelSpec};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One instance-batch to execute.
#[derive(Clone, Copy, Debug)]
pub struct BatchJob {
    pub model: ModelId,
    /// Compiled batch size (padded).
    pub batch: usize,
    /// Real requests inside the batch (≤ batch).
    pub n_real: usize,
}

/// Execution failure modes.
#[derive(Debug, thiserror::Error)]
pub enum ExecError {
    #[error("out of memory: {0}")]
    Oom(#[from] OomError),
    #[error("backend failure: {0}")]
    Backend(String),
}

/// Backend interface: run a slot group "concurrently", return per-job
/// latency in ms (queue-to-completion inside the backend).
pub trait Dispatcher: Send {
    fn run_group(&mut self, jobs: &[BatchJob]) -> Vec<Result<f64, ExecError>>;

    /// Like [`Dispatcher::run_group`], but writes into a caller-owned
    /// buffer (cleared first) so the scheduling round loop can reuse one
    /// allocation. Backends override this with their native path; the
    /// default delegates for third-party implementations.
    fn run_group_into(&mut self, jobs: &[BatchJob],
                      out: &mut Vec<Result<f64, ExecError>>) {
        out.clear();
        out.extend(self.run_group(jobs));
    }

    /// Observable utilization snapshot for the profiler:
    /// (compute demand, memory pressure ∈ [0,1], active instances).
    fn utilization(&self) -> (f64, f64, usize);

    /// Current time source value, ms (virtual or real).
    fn now_ms(&self) -> f64;

    /// Block (real) or jump (virtual) until `t_ms` — used by the engine
    /// when every queue is empty and the next arrival is in the future.
    fn wait_until(&mut self, t_ms: f64);

    /// Isolated (uncontended) latency estimate for pricing decisions and
    /// inflation ground truth. The simulator answers exactly; the real
    /// backend answers from the calibrated table.
    fn isolated_estimate_ms(&self, model: ModelId, batch: usize) -> f64;
}

// ---------------------------------------------------------------------
// Simulation backend
// ---------------------------------------------------------------------

/// Prices groups on the platform simulator against a [`ClockSource`]:
/// virtual time for tests/benches (the clock jumps by each group's span),
/// wall time for the serving runtime's workers (the dispatcher *sleeps*
/// the span, so concurrent workers genuinely overlap in real time while
/// the platform model prices their latencies).
pub struct SimDispatcher {
    pub sim: PlatformSim,
    pub clock: ClockSource,
    /// Most recent ground-truth inflation (exported for predictor
    /// training / Fig. 13).
    pub last_inflation: f64,
    /// Per-group admission scratch, reused across rounds.
    handles: Vec<(usize, BatchHandle)>,
}

impl SimDispatcher {
    pub fn new(sim: PlatformSim, clock: VirtualClock) -> Self {
        Self::with_clock(sim, ClockSource::Virtual(clock))
    }

    pub fn with_clock(sim: PlatformSim, clock: ClockSource) -> Self {
        SimDispatcher { sim, clock, last_inflation: 1.0, handles: Vec::new() }
    }
}

impl Dispatcher for SimDispatcher {
    fn run_group(&mut self, jobs: &[BatchJob]) -> Vec<Result<f64, ExecError>> {
        let mut out = Vec::with_capacity(jobs.len());
        self.run_group_into(jobs, &mut out);
        out
    }

    fn run_group_into(&mut self, jobs: &[BatchJob],
                      out: &mut Vec<Result<f64, ExecError>>) {
        out.clear();
        self.handles.clear();
        // Admit everything first so each job sees the group's full
        // contention (paper Fig. 4: the GPU hardware scheduler runs the
        // instances simultaneously).
        for (i, job) in jobs.iter().enumerate() {
            match self.sim.begin(job.model, job.batch) {
                Ok(h) => {
                    self.handles.push((i, h));
                    out.push(Ok(0.0)); // placeholder, priced below
                }
                Err(e) => out.push(Err(ExecError::Oom(e))),
            }
        }
        self.last_inflation = self.sim.current_inflation();
        let mut group_span: f64 = 0.0;
        for &(i, _) in &self.handles {
            let job = &jobs[i];
            let d = self.sim.duration_ms(job.model, job.batch);
            group_span = group_span.max(d);
            out[i] = Ok(d);
        }
        for &(_, h) in &self.handles {
            self.sim.end(h);
        }
        self.handles.clear();
        // The slot occupies the platform until its slowest instance
        // finishes (instances run in parallel).
        self.clock.advance_ms(group_span);
    }

    fn utilization(&self) -> (f64, f64, usize) {
        let load = self.sim.current_load();
        (load.compute_demand, load.memory_pressure, load.active_instances)
    }

    fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }

    fn wait_until(&mut self, t_ms: f64) {
        self.clock.advance_to_ms(t_ms);
    }

    fn isolated_estimate_ms(&self, model: ModelId, batch: usize) -> f64 {
        self.sim.latency.isolated_ms(model, batch)
    }
}

// ---------------------------------------------------------------------
// Real PJRT backend
// ---------------------------------------------------------------------

/// Synthetic marshaling buffers keyed by (model, batch).
///
/// The seed cached by buffer LENGTH, so two (model, batch) pairs whose
/// element counts collide (e.g. mob b=2 and res b=2, both 2·3·32·32)
/// aliased each other's entries, and every hit CLONED the whole buffer.
/// Keying by (model, batch) fixes the alias; handing out `Arc<[f32]>`
/// makes a hit a refcount bump instead of a memcpy.
#[derive(Default)]
struct InputCache {
    map: HashMap<(ModelId, usize), Arc<[f32]>>,
}

impl InputCache {
    fn get(&mut self, model: ModelId, batch: usize) -> Arc<[f32]> {
        // Content-agnostic serving: shape matters, values do not (§III-A1).
        let elems = ModelSpec::get(model).input_elems * batch;
        self.map
            .entry((model, batch))
            .or_insert_with(|| vec![0.5f32; elems].into())
            .clone()
    }
}

/// Runs groups on the PJRT CPU client over a thread pool; real CPU
/// contention between instances is the interference mechanism here.
pub struct RealDispatcher {
    runtime: Arc<super::pjrt::PjrtRuntime>,
    pool: ThreadPool,
    origin: std::time::Instant,
    inputs: InputCache,
    /// Per-job result slots shared with the workers. Grown on demand and
    /// reused across rounds — the seed allocated an
    /// `Arc<Mutex<Vec<Option<..>>>>` (one lock for the whole group, one
    /// heap trip per round) on every dispatch.
    slots: Arc<Vec<Mutex<Option<Result<f64, ExecError>>>>>,
}

impl RealDispatcher {
    pub fn new(runtime: Arc<super::pjrt::PjrtRuntime>, threads: usize) -> Self {
        RealDispatcher {
            runtime,
            pool: ThreadPool::new(threads),
            origin: std::time::Instant::now(),
            inputs: InputCache::default(),
            slots: Arc::new(Vec::new()),
        }
    }

    /// Pre-compile every (model, batch) pair (TensorRT engine build
    /// analogue; keeps compile time out of serving latency).
    pub fn warm_all(&self, batches: &[usize]) -> anyhow::Result<f64> {
        let mut total = 0.0;
        for model in ModelId::all() {
            for &b in batches {
                if self.runtime.index().get(model, b).is_some() {
                    total += self.runtime.warm(model, b)?;
                }
            }
        }
        Ok(total)
    }

    /// Restart the wall clock at zero — call after `warm_all` so engine
    /// horizons exclude one-time compilation (TensorRT engine builds are
    /// likewise done before serving starts).
    pub fn reset_origin(&mut self) {
        self.origin = std::time::Instant::now();
    }
}

impl Dispatcher for RealDispatcher {
    fn run_group(&mut self, jobs: &[BatchJob]) -> Vec<Result<f64, ExecError>> {
        let mut out = Vec::with_capacity(jobs.len());
        self.run_group_into(jobs, &mut out);
        out
    }

    fn run_group_into(&mut self, jobs: &[BatchJob],
                      out: &mut Vec<Result<f64, ExecError>>) {
        if self.slots.len() < jobs.len() {
            // Workers from previous rounds have exited (wait_idle), so the
            // old Arc dies with this replacement; allocation only on the
            // largest group seen so far.
            self.slots =
                Arc::new((0..jobs.len()).map(|_| Mutex::new(None)).collect());
        }
        for (i, job) in jobs.iter().enumerate() {
            let rt = self.runtime.clone();
            let slots = self.slots.clone();
            let job = *job;
            let input = self.inputs.get(job.model, job.batch);
            self.pool.execute(move || {
                let t0 = std::time::Instant::now();
                let r = rt
                    .execute(job.model, job.batch, &input)
                    .map(|_| t0.elapsed().as_secs_f64() * 1e3)
                    .map_err(|e| ExecError::Backend(e.to_string()));
                *slots[i].lock().unwrap() = Some(r);
            });
        }
        self.pool.wait_idle();
        out.clear();
        for slot in self.slots.iter().take(jobs.len()) {
            out.push(slot.lock().unwrap().take().expect("job did not run"));
        }
    }

    fn utilization(&self) -> (f64, f64, usize) {
        // Real backend exposes pool width as a proxy for compute demand;
        // memory pressure is not tracked on the host.
        (0.0, 0.0, 0)
    }

    fn now_ms(&self) -> f64 {
        self.origin.elapsed().as_secs_f64() * 1e3
    }

    fn wait_until(&mut self, t_ms: f64) {
        let now = self.now_ms();
        if t_ms > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                (t_ms - now) / 1e3,
            ));
        }
    }

    fn isolated_estimate_ms(&self, model: ModelId, batch: usize) -> f64 {
        // Rolling calibrated table; the engine overrides this with live
        // profiler data for inflation bookkeeping on the real backend.
        crate::platform::LatencyModel::calibrated().isolated_ms(model, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jobs(model: ModelId, batch: usize, n: usize) -> Vec<BatchJob> {
        (0..n).map(|_| BatchJob { model, batch, n_real: batch }).collect()
    }

    #[test]
    fn sim_group_advances_clock_by_span() {
        let clock = VirtualClock::new();
        let mut d = SimDispatcher::new(PlatformSim::xavier_nx(), clock.clone());
        let r = d.run_group(&jobs(ModelId::Res, 4, 2));
        assert_eq!(r.len(), 2);
        let spans: Vec<f64> = r.into_iter().map(|x| x.unwrap()).collect();
        let max = spans.iter().cloned().fold(0.0, f64::max);
        // The virtual clock stores whole microseconds.
        assert!((clock.now_ms() - max).abs() < 2e-3);
    }

    #[test]
    fn sim_oom_fails_individual_jobs() {
        let clock = VirtualClock::new();
        let mut d = SimDispatcher::new(PlatformSim::xavier_nx(), clock);
        let r = d.run_group(&jobs(ModelId::Yolo, 128, 8));
        let ooms = r.iter().filter(|x| x.is_err()).count();
        let oks = r.iter().filter(|x| x.is_ok()).count();
        assert!(ooms > 0, "expected Fig. 1 OOM corner");
        assert!(oks > 0, "admissible prefix should still run");
    }

    #[test]
    fn sim_concurrency_slower_than_isolated() {
        let c1 = VirtualClock::new();
        let mut d1 = SimDispatcher::new(PlatformSim::xavier_nx(), c1);
        let solo = d1.run_group(&jobs(ModelId::Yolo, 16, 1))[0]
            .as_ref()
            .copied()
            .unwrap();
        let c2 = VirtualClock::new();
        let mut d2 = SimDispatcher::new(PlatformSim::xavier_nx(), c2);
        let crowd = d2.run_group(&jobs(ModelId::Yolo, 16, 6))[0]
            .as_ref()
            .copied()
            .unwrap();
        assert!(crowd > solo, "solo {solo} crowd {crowd}");
    }

    #[test]
    fn sim_run_group_into_reuses_buffer() {
        let clock = VirtualClock::new();
        let mut d = SimDispatcher::new(PlatformSim::xavier_nx(), clock);
        let mut out = Vec::new();
        d.run_group_into(&jobs(ModelId::Res, 4, 3), &mut out);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|r| r.is_ok()));
        d.run_group_into(&jobs(ModelId::Mob, 2, 1), &mut out);
        assert_eq!(out.len(), 1, "buffer must be cleared between groups");
    }

    #[test]
    fn input_cache_keys_by_model_and_batch() {
        let mut cache = InputCache::default();
        // mob and res share input_elems, so a length-keyed cache (the
        // seed bug) would alias these two entries.
        assert_eq!(
            ModelSpec::get(ModelId::Mob).input_elems,
            ModelSpec::get(ModelId::Res).input_elems
        );
        let mob = cache.get(ModelId::Mob, 2);
        let res = cache.get(ModelId::Res, 2);
        assert_eq!(mob.len(), res.len());
        assert!(
            !Arc::ptr_eq(&mob, &res),
            "distinct (model, batch) keys must not alias buffers"
        );
        // Same key twice is a refcount bump on the same allocation.
        let mob2 = cache.get(ModelId::Mob, 2);
        assert!(Arc::ptr_eq(&mob, &mob2));
        assert_eq!(
            mob.len(),
            ModelSpec::get(ModelId::Mob).input_elems * 2
        );
        assert!(mob.iter().all(|&x| x == 0.5));
    }
}
