//! SLO-aware admission control: refuse requests whose deadline is
//! provably unmeetable *before* they consume queue space and scheduler
//! attention.
//!
//! The decision is the ISSUE's one-liner made precise: with `q` requests
//! already queued for the model and a profiled per-batch latency `L`, a
//! new request sits behind ⌈(q+1)/b_ref⌉ batches and completes no sooner
//! than that many batch spans from now. If that optimistic bound already
//! exceeds the request's remaining slack, no scheduler decision can save
//! it — admitting it would only waste capacity and then count a
//! violation. Rejections carry a typed [`ShedReason`] and are accounted
//! in [`crate::metrics::Metrics`] separately from violations.
//!
//! The same pure decision function serves two stations:
//!
//! * the **ingress fast path** ([`super::ingress::Ingress::submit`]),
//!   reading lock-free gauges the workers publish each round;
//! * the **engine gate** ([`AdmissionGate`], installed via
//!   [`crate::coordinator::Engine::set_ingress_gate`]), deciding with
//!   exact queue depths as arrivals are routed — the station trace-mode
//!   (virtual-clock) runs exercise.

use crate::coordinator::engine::{IngressGate, IngressSnapshot};
use crate::metrics::ShedReason;
use crate::workload::request::Request;

/// Tunables for the admission decision.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Reference batch size used to turn queue depth into "batches ahead"
    /// and to price the cold-start latency estimate.
    pub ref_batch: usize,
    /// Multiplier on the service estimate. 1.0 sheds only provably-late
    /// requests (optimistic bound); raise it to shed earlier under
    /// overload at the cost of occasional false sheds.
    pub safety: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { ref_batch: 8, safety: 1.0 }
    }
}

impl AdmissionConfig {
    /// Core decision: can a request with `slack_ms` of budget left still
    /// make it, given `queue_len` requests ahead and a per-batch latency
    /// estimate? `mean_batch_ms` is the profiled rolling mean (NaN before
    /// the first observation); `isolated_ref_ms` is the optimistic
    /// cold-start fallback.
    pub fn decide(&self, queue_len: usize, mean_batch_ms: f64,
                  isolated_ref_ms: f64, slack_ms: f64)
                  -> Result<(), ShedReason> {
        if slack_ms <= 0.0 {
            // Dead on arrival (e.g. transmission ate the whole budget).
            return Err(ShedReason::DeadlineUnmeetable);
        }
        let batch_ms = if mean_batch_ms.is_finite() && mean_batch_ms > 0.0 {
            mean_batch_ms
        } else {
            isolated_ref_ms
        };
        let batches_ahead = queue_len / self.ref_batch.max(1) + 1;
        let est_ms = batches_ahead as f64 * batch_ms * self.safety;
        if est_ms > slack_ms {
            Err(ShedReason::DeadlineUnmeetable)
        } else {
            Ok(())
        }
    }

    /// Remaining completion budget for `r` at decision time `now_ms`.
    /// E2e latency is measured from arrival and includes the transmission
    /// already spent (Eq. 2), so the budget shrinks by both.
    pub fn slack_ms(r: &Request, now_ms: f64) -> f64 {
        r.slo_ms - r.transmission_ms - (now_ms - r.arrival_ms)
    }
}

/// [`IngressGate`] adapter: the admission controller as the engine's
/// ingest-time hook, with exact queue state from the snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct AdmissionGate {
    pub cfg: AdmissionConfig,
}

impl AdmissionGate {
    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionGate { cfg }
    }
}

impl IngressGate for AdmissionGate {
    fn ref_batch(&self) -> usize {
        self.cfg.ref_batch
    }

    fn decide(&mut self, r: &Request, snap: &IngressSnapshot)
              -> Option<ShedReason> {
        let slack = AdmissionConfig::slack_ms(r, snap.now_ms);
        self.cfg
            .decide(snap.queue_len, snap.mean_batch_ms, snap.isolated_ref_ms,
                    slack)
            .err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::ModelId;

    #[test]
    fn empty_queue_with_slack_admits() {
        let cfg = AdmissionConfig::default();
        assert!(cfg.decide(0, f64::NAN, 20.0, 100.0).is_ok());
        assert!(cfg.decide(0, 15.0, 20.0, 100.0).is_ok());
    }

    #[test]
    fn deep_queue_times_batch_latency_sheds() {
        let cfg = AdmissionConfig { ref_batch: 8, safety: 1.0 };
        // 40 queued → 6 batches ahead (incl. ours) × 25 ms = 150 ms > 100.
        assert_eq!(cfg.decide(40, 25.0, 20.0, 100.0),
                   Err(ShedReason::DeadlineUnmeetable));
        // Same depth but fast batches fits: 6 × 12 = 72 ≤ 100.
        assert!(cfg.decide(40, 12.0, 20.0, 100.0).is_ok());
    }

    #[test]
    fn cold_start_falls_back_to_isolated_estimate() {
        let cfg = AdmissionConfig { ref_batch: 8, safety: 1.0 };
        // No profile yet: NaN mean → isolated 60 ms per batch, 2 batches.
        assert_eq!(cfg.decide(8, f64::NAN, 60.0, 100.0),
                   Err(ShedReason::DeadlineUnmeetable));
        assert!(cfg.decide(8, f64::NAN, 40.0, 100.0).is_ok());
    }

    #[test]
    fn non_positive_slack_is_dead_on_arrival() {
        let cfg = AdmissionConfig::default();
        assert!(cfg.decide(0, 1.0, 1.0, 0.0).is_err());
        assert!(cfg.decide(0, 1.0, 1.0, -5.0).is_err());
    }

    #[test]
    fn slack_accounts_for_transmission_and_waiting() {
        let mut r = Request::new(1, ModelId::Res, 1_000.0); // slo 58 ms
        r.transmission_ms = 3.0;
        assert!((AdmissionConfig::slack_ms(&r, 1_000.0) - 55.0).abs() < 1e-12);
        // 40 ms after arrival, only 15 ms of budget remains.
        assert!((AdmissionConfig::slack_ms(&r, 1_040.0) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn safety_factor_sheds_earlier() {
        let lax = AdmissionConfig { ref_batch: 8, safety: 1.0 };
        let strict = AdmissionConfig { ref_batch: 8, safety: 2.0 };
        assert!(lax.decide(8, 40.0, 40.0, 100.0).is_ok()); // 80 ≤ 100
        assert!(strict.decide(8, 40.0, 40.0, 100.0).is_err()); // 160 > 100
    }
}
