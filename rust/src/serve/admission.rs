//! SLO-aware admission control: refuse requests whose deadline is
//! provably unmeetable *before* they consume queue space and scheduler
//! attention.
//!
//! The decision is the ISSUE's one-liner made precise: with `q` requests
//! already queued for the model and a profiled per-batch latency `L`, a
//! new request sits behind ⌈(q+1)/b_ref⌉ batches and completes no sooner
//! than that many batch spans from now. If that optimistic bound already
//! exceeds the request's remaining slack, no scheduler decision can save
//! it — admitting it would only waste capacity and then count a
//! violation. Rejections carry a typed [`ShedReason`] and are accounted
//! in [`crate::metrics::Metrics`] separately from violations.
//!
//! The same pure decision function serves two stations:
//!
//! * the **ingress fast path** ([`super::ingress::Ingress::submit`]),
//!   reading lock-free gauges the workers publish each round;
//! * the **engine gate** ([`AdmissionGate`], installed via
//!   [`crate::coordinator::Engine::set_ingress_gate`]), deciding with
//!   exact queue depths as arrivals are routed — the station trace-mode
//!   (virtual-clock) runs exercise.

use crate::coordinator::engine::{IngressGate, IngressSnapshot};
use crate::metrics::ShedReason;
use crate::predictor::{headroom_ms, predicted_batch_cost_ms, AdmissionMode,
                       AdmissionQuantile};
use crate::workload::request::Request;

/// Tunables for the admission decision.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Reference batch size used to turn queue depth into "batches ahead"
    /// and to price the cold-start latency estimate.
    pub ref_batch: usize,
    /// Multiplier on the service estimate. 1.0 sheds only provably-late
    /// requests (optimistic bound); raise it to shed earlier under
    /// overload at the cost of occasional false sheds.
    pub safety: f64,
    /// Snapshot (today's formula) or predictive (headroom from the
    /// interference predictor, snapshot as the per-decision fallback).
    pub mode: AdmissionMode,
    /// Latency quantile predictive pricing targets (ignored under
    /// [`AdmissionMode::Snapshot`]).
    pub quantile: AdmissionQuantile,
    /// Ground-truth samples a worker's predictor must hold before its
    /// predictions are trusted at any decision point; below it, every
    /// station publishes/receives NaN and falls back to the snapshot
    /// formula. `usize::MAX` pins the predictor cold forever (the
    /// differential tests' lever).
    pub predictor_warmup: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            ref_batch: 8,
            safety: 1.0,
            mode: AdmissionMode::Snapshot,
            quantile: AdmissionQuantile::Mean,
            // Matches the engine's own veto threshold: enough samples
            // that the net has trained past its random init.
            predictor_warmup: 128,
        }
    }
}

impl AdmissionConfig {
    /// Core decision: can a request with `slack_ms` of budget left still
    /// make it, given `queue_len` requests ahead and a per-batch latency
    /// estimate? `mean_batch_ms` is the profiled rolling mean (NaN before
    /// the first observation); `isolated_ref_ms` is the optimistic
    /// cold-start fallback.
    pub fn decide(&self, queue_len: usize, mean_batch_ms: f64,
                  isolated_ref_ms: f64, slack_ms: f64)
                  -> Result<(), ShedReason> {
        if slack_ms <= 0.0 {
            // Dead on arrival (e.g. transmission ate the whole budget).
            return Err(ShedReason::DeadlineUnmeetable);
        }
        let batch_ms = if mean_batch_ms.is_finite() && mean_batch_ms > 0.0 {
            mean_batch_ms
        } else {
            isolated_ref_ms
        };
        let batches_ahead = queue_len / self.ref_batch.max(1) + 1;
        let est_ms = batches_ahead as f64 * batch_ms * self.safety;
        if est_ms > slack_ms {
            Err(ShedReason::DeadlineUnmeetable)
        } else {
            Ok(())
        }
    }

    /// Predictive decision (ROADMAP open item 2): price the request's
    /// completion as `batches_ahead × isolated × predicted-inflation`
    /// (widened by the dispersion p95 at the `p95` quantile) and shed
    /// iff headroom > 0. `predicted_inflation` / `p95_factor` come from
    /// the deciding station — the engine's own predictor probe at the
    /// gate, the gossiped gauge lanes at the ingress fast path — with
    /// NaN meaning cold/failed, in which case the decision falls back to
    /// [`AdmissionConfig::decide`], the snapshot oracle, bit-for-bit.
    /// The returned flag reports that fallback (counted,
    /// conservation-neutral). Dead-on-arrival requests (slack ≤ 0) shed
    /// identically on both paths and count as headroom decisions, not
    /// fallbacks.
    pub fn decide_predictive(&self, queue_len: usize, mean_batch_ms: f64,
                             isolated_ref_ms: f64, slack_ms: f64,
                             predicted_inflation: f64, p95_factor: f64)
                             -> (Result<(), ShedReason>, bool) {
        if slack_ms <= 0.0 {
            return (Err(ShedReason::DeadlineUnmeetable), false);
        }
        match predicted_batch_cost_ms(isolated_ref_ms, predicted_inflation,
                                      p95_factor, self.quantile) {
            Some(cost) => {
                let h = headroom_ms(queue_len, self.ref_batch,
                                    cost * self.safety, 0.0, slack_ms);
                let d = if h > 0.0 {
                    Err(ShedReason::DeadlineUnmeetable)
                } else {
                    Ok(())
                };
                (d, false)
            }
            None => (
                self.decide(queue_len, mean_batch_ms, isolated_ref_ms,
                            slack_ms),
                true,
            ),
        }
    }

    /// Remaining completion budget for `r` at decision time `now_ms`.
    /// E2e latency is measured from arrival and includes the transmission
    /// already spent (Eq. 2), so the budget shrinks by both.
    pub fn slack_ms(r: &Request, now_ms: f64) -> f64 {
        r.slo_ms - r.transmission_ms - (now_ms - r.arrival_ms)
    }
}

/// [`IngressGate`] adapter: the admission controller as the engine's
/// ingest-time hook, with exact queue state from the snapshot. Under
/// [`AdmissionMode::Predictive`] it also tallies per-decision headroom
/// usage vs snapshot fallbacks, harvested into
/// [`crate::metrics::Metrics`] at worker teardown.
#[derive(Clone, Debug, Default)]
pub struct AdmissionGate {
    pub cfg: AdmissionConfig,
    headroom_decisions: u64,
    headroom_fallbacks: u64,
}

impl AdmissionGate {
    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionGate { cfg, headroom_decisions: 0, headroom_fallbacks: 0 }
    }
}

impl IngressGate for AdmissionGate {
    fn ref_batch(&self) -> usize {
        self.cfg.ref_batch
    }

    fn predictor_warmup(&self) -> usize {
        match self.cfg.mode {
            // Snapshot mode never consults the predictor: an infinite
            // warmup keeps the engine from probing it at all.
            AdmissionMode::Snapshot => usize::MAX,
            AdmissionMode::Predictive => self.cfg.predictor_warmup,
        }
    }

    fn decide(&mut self, r: &Request, snap: &IngressSnapshot)
              -> Option<ShedReason> {
        let slack = AdmissionConfig::slack_ms(r, snap.now_ms);
        match self.cfg.mode {
            AdmissionMode::Snapshot => self
                .cfg
                .decide(snap.queue_len, snap.mean_batch_ms,
                        snap.isolated_ref_ms, slack)
                .err(),
            AdmissionMode::Predictive => {
                let (d, fell_back) = self.cfg.decide_predictive(
                    snap.queue_len,
                    snap.mean_batch_ms,
                    snap.isolated_ref_ms,
                    slack,
                    snap.predicted_inflation,
                    snap.p95_factor,
                );
                self.headroom_decisions += 1;
                if fell_back {
                    self.headroom_fallbacks += 1;
                }
                d.err()
            }
        }
    }

    fn headroom_stats(&self) -> (u64, u64) {
        (self.headroom_decisions, self.headroom_fallbacks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::ModelId;

    #[test]
    fn empty_queue_with_slack_admits() {
        let cfg = AdmissionConfig::default();
        assert!(cfg.decide(0, f64::NAN, 20.0, 100.0).is_ok());
        assert!(cfg.decide(0, 15.0, 20.0, 100.0).is_ok());
    }

    #[test]
    fn deep_queue_times_batch_latency_sheds() {
        let cfg =
            AdmissionConfig { ref_batch: 8, safety: 1.0, ..Default::default() };
        // 40 queued → 6 batches ahead (incl. ours) × 25 ms = 150 ms > 100.
        assert_eq!(cfg.decide(40, 25.0, 20.0, 100.0),
                   Err(ShedReason::DeadlineUnmeetable));
        // Same depth but fast batches fits: 6 × 12 = 72 ≤ 100.
        assert!(cfg.decide(40, 12.0, 20.0, 100.0).is_ok());
    }

    #[test]
    fn cold_start_falls_back_to_isolated_estimate() {
        let cfg =
            AdmissionConfig { ref_batch: 8, safety: 1.0, ..Default::default() };
        // No profile yet: NaN mean → isolated 60 ms per batch, 2 batches.
        assert_eq!(cfg.decide(8, f64::NAN, 60.0, 100.0),
                   Err(ShedReason::DeadlineUnmeetable));
        assert!(cfg.decide(8, f64::NAN, 40.0, 100.0).is_ok());
    }

    #[test]
    fn non_positive_slack_is_dead_on_arrival() {
        let cfg = AdmissionConfig::default();
        assert!(cfg.decide(0, 1.0, 1.0, 0.0).is_err());
        assert!(cfg.decide(0, 1.0, 1.0, -5.0).is_err());
    }

    #[test]
    fn slack_accounts_for_transmission_and_waiting() {
        let mut r = Request::new(1, ModelId::Res, 1_000.0); // slo 58 ms
        r.transmission_ms = 3.0;
        assert!((AdmissionConfig::slack_ms(&r, 1_000.0) - 55.0).abs() < 1e-12);
        // 40 ms after arrival, only 15 ms of budget remains.
        assert!((AdmissionConfig::slack_ms(&r, 1_040.0) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn predictive_with_warm_predictor_prices_headroom() {
        let cfg = AdmissionConfig {
            mode: AdmissionMode::Predictive,
            ..Default::default()
        };
        // 8 queued → 2 batches × (20 isolated × 1.5 inflation) = 60 ms.
        let (d, fb) = cfg.decide_predictive(8, 95.0, 20.0, 70.0, 1.5, 1.0);
        assert!(d.is_ok() && !fb, "feasible headroom admitted, no fallback");
        // Note the snapshot path would have shed this (2 × 95 = 190 > 70):
        // the predictor sees through a stale rolling mean.
        assert!(cfg.decide(8, 95.0, 20.0, 70.0).is_err());
        let (d, fb) = cfg.decide_predictive(8, 10.0, 20.0, 50.0, 1.5, 1.0);
        assert!(d.is_err() && !fb, "60 ms predicted > 50 ms slack sheds");
    }

    #[test]
    fn predictive_p95_sheds_no_later_than_mean() {
        let p95 = AdmissionConfig {
            mode: AdmissionMode::Predictive,
            quantile: AdmissionQuantile::P95,
            ..Default::default()
        };
        let mean = AdmissionConfig {
            mode: AdmissionMode::Predictive,
            ..Default::default()
        };
        // 2 × 20 × 1.5 = 60 ms at mean; × 1.4 dispersion = 84 at p95.
        let (dm, _) = mean.decide_predictive(8, 10.0, 20.0, 70.0, 1.5, 1.4);
        let (dp, _) = p95.decide_predictive(8, 10.0, 20.0, 70.0, 1.5, 1.4);
        assert!(dm.is_ok() && dp.is_err(),
                "p95 pricing must be the stricter admit");
    }

    #[test]
    fn predictive_cold_falls_back_to_snapshot_bitwise() {
        let cfg = AdmissionConfig {
            mode: AdmissionMode::Predictive,
            ..Default::default()
        };
        for (q, mean, iso, slack) in [
            (0usize, f64::NAN, 20.0, 100.0),
            (40, 25.0, 20.0, 100.0),
            (8, f64::NAN, 60.0, 100.0),
            (8, 40.0, 40.0, 100.0),
        ] {
            let (d, fb) =
                cfg.decide_predictive(q, mean, iso, slack, f64::NAN, 1.0);
            assert!(fb, "cold predictor must report fallback");
            assert_eq!(d, cfg.decide(q, mean, iso, slack),
                       "fallback diverged from the snapshot oracle");
        }
        // Dead on arrival is decided before the predictor: no fallback.
        let (d, fb) = cfg.decide_predictive(0, 1.0, 1.0, 0.0, 1.5, 1.0);
        assert!(d.is_err() && !fb);
    }

    #[test]
    fn safety_factor_sheds_earlier() {
        let lax =
            AdmissionConfig { ref_batch: 8, safety: 1.0, ..Default::default() };
        let strict =
            AdmissionConfig { ref_batch: 8, safety: 2.0, ..Default::default() };
        assert!(lax.decide(8, 40.0, 40.0, 100.0).is_ok()); // 80 ≤ 100
        assert!(strict.decide(8, 40.0, 40.0, 100.0).is_err()); // 160 > 100
    }
}
