//! The concurrent serving runtime — BCEdge's missing online layer.
//!
//! The paper's premise is requests arriving *online* at a platform that
//! co-schedules batch size and concurrent instances; through PR #1 the
//! repo only simulated that inside a single-threaded virtual-clock loop.
//! This subsystem turns the engine into a real server:
//!
//! * [`ingress`] — per-model bounded MPSC channels with worker wakeups,
//!   lock-free per-(model, worker) serving gauges, and the epoch-stamped
//!   [`OwnershipTable`] mapping each model to the REPLICA SET of workers
//!   that currently drains it (one worker for a cold model, several for
//!   a hot one);
//! * [`admission`] — the SLO-aware admission controller: requests whose
//!   deadline is provably unmeetable (queue depth × profiled batch
//!   latency vs remaining slack, priced per replica) shed with typed
//!   reasons, at the ingress fast path and again exactly at the engine's
//!   ingest gate;
//! * [`worker`] — N OS threads, each owning an [`crate::coordinator::Engine`]
//!   + scheduler and draining the models the ownership table assigns it:
//!   the paper's concurrent instances as actual parallel execution.
//!   Replicas of one model pop bounded stripes of its shared channel and
//!   shed above-fair-share surplus through the handoff slot. The engine
//!   code is clock-generic: `VirtualClock` workers are deterministic
//!   discrete-event sims (bit-identical to the bare engine at
//!   `workers == 1`), wall-clock workers genuinely overlap;
//! * [`server`] — composition, the gauge-driven rebalance controller
//!   (hot-model replication: a model whose backlog outruns one worker's
//!   drain rate gains replicas on the least-loaded workers and collapses
//!   them when it subsides; dynamic resharding: backlogged models
//!   migrate off overloaded workers — both over the same lossless
//!   handoff protocol), and the drain/shutdown protocol (freeze shard
//!   map → stop intake → flush queues → join workers → merged
//!   [`crate::metrics::Metrics`]);
//! * [`loadgen`] — open- and closed-loop load generation over constant /
//!   MMPP-bursty / diurnal rate envelopes (`bcedge bench-serve`);
//! * `fabric` — the virtual arm of [`server::run_trace`] on the
//!   discrete-event fabric ([`crate::sim`]): workers, arrivals, and
//!   rebalance epochs as logical processes on one event heap, running
//!   the SAME dynamic control plane as live serving (resharding,
//!   replication, urgency-aware replica routing on live gauges)
//!   bit-reproducibly from a seed.
//!
//! Observability rides along the same seams ([`crate::telemetry`]):
//! each worker's engine optionally carries an
//! [`crate::telemetry::EngineTracer`] (deterministic id-keyed span
//! sampling, inert when `--trace-sample` is 0), workers fold their
//! completion/shed deltas into a shared [`crate::telemetry::TelemetryHub`]
//! when `--metrics-out` is set, and a publisher thread snapshots the hub
//! every `--metrics-interval-ms`.
//!
//! The module ↔ paper-section map, the request lifecycle, the pinned
//! invariants, and the consolidated CLI flags table live in
//! `rust/ARCHITECTURE.md`.

pub mod admission;
pub(crate) mod fabric;
pub mod ingress;
pub mod loadgen;
pub mod server;
pub mod worker;

pub use admission::{AdmissionConfig, AdmissionGate};
pub use ingress::{GaugeSnapshot, Ingress, ModelIntake, OwnershipTable,
                  SharedGauges};
pub use loadgen::{LoadGenConfig, LoadGenConfigBuilder, LoadMode};
pub use server::{ClockKind, RebalanceConfig, SchedulerSpec, ServeConfig,
                 ServeConfigBuilder, ServeReport, Server, run_trace,
                 INCARNATION_ID_STRIDE, NODE_ID_STRIDE};
pub use worker::{CompletionEvent, ServeEvent};
