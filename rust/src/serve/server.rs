//! The concurrent serving runtime: ingress + admission + worker pool +
//! dynamic resharding + drain protocol, composed behind two entry points:
//!
//! * [`run_trace`] — serve a pre-generated arrival trace across the
//!   worker pool. The virtual arm runs on the discrete-event fabric
//!   ([`super::fabric`]) with the SAME dynamic control plane as live
//!   serving — resharding, replication, urgency-aware replica routing
//!   on live gauges — deterministically. With `workers == 1`, a virtual
//!   clock, and no admission, it reproduces the single-threaded
//!   [`Engine`] run bit-for-bit (enforced by the seed-equivalence test
//!   below) — the serving layer adds concurrency without forking the
//!   engine's semantics.
//! * [`Server::start`] / [`Server::shutdown`] — a live wall-clock server:
//!   submit requests from any thread through the bounded ingress, workers
//!   drain their shards in parallel, shutdown stops intake, flushes every
//!   queue, joins the workers, and emits the final merged [`Metrics`].
//!
//! Shards are DYNAMIC (live and virtual-trace alike): a rebalance
//! controller reads the
//! per-(model, worker) [`SharedGauges`] each epoch (queue depth ×
//! rolling batch latency = estimated backlog-ms) and rewrites the
//! [`OwnershipTable`] along both of the paper's control axes:
//!
//! * **hot-model replication** — a model whose pool-wide backlog
//!   exceeds one worker's drain rate gains a REPLICA on the
//!   least-loaded worker, so several engines drain its intake
//!   concurrently (the m_c dimension crossing the worker boundary);
//!   replica sets collapse once the backlog subsides.
//! * **whole-model migration** — when no replica set is widened, model
//!   ownership migrates from overloaded to underloaded workers, so a
//!   hot model no longer drags its shard-siblings' round spans with it.
//!
//! Both actions reuse the same lossless [`ModelIntake`] handoff, so the
//! request-conservation invariant (outcomes + sheds + leftover ==
//! attempts) holds through every map rewrite.

use super::admission::AdmissionConfig;
use super::ingress::{Ingress, MAX_POOL, ModelIntake, OwnershipTable,
                     SharedGauges, WakeEvent};
use super::worker::{LiveWorker, ServeEvent, WorkerResult, run_trace_worker};
use crate::coordinator::baselines::{DeepRtScheduler, FixedScheduler};
use crate::coordinator::sac_sched;
use crate::coordinator::{Engine, EngineConfig, Scheduler};
use crate::metrics::{Metrics, ShedReason};
use crate::platform::{PlatformSim, PlatformSpec};
use crate::runtime::executor::SimDispatcher;
use crate::telemetry::{self, EngineTracer, TelemetryConfig, TelemetryHub,
                       TraceReport};
use crate::util::rng::Pcg32;
use crate::util::time::{Clock, ClockSource, WallClock};
use crate::workload::models::{ModelId, N_MODELS};
use crate::workload::request::Request;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Which time source the workers' engines run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClockKind {
    /// Discrete-event time per worker: deterministic, thousands× real
    /// time. Trace mode only.
    Virtual,
    /// One shared wall clock: dispatch spans actually elapse, workers
    /// genuinely overlap.
    Wall,
}

/// How each worker builds its scheduler (copyable so the spec crosses
/// into worker threads; construction happens on the worker's thread).
#[derive(Clone, Copy, Debug)]
pub enum SchedulerSpec {
    Fixed { batch: usize, m_c: usize },
    DeepRt,
    /// Learning SAC scheduler, trained online. Worker `i` derives its
    /// stream from `seed` (worker 0 uses `seed` itself, so single-worker
    /// runs match a standalone `sac_sched::sac(space, seeded(seed))`).
    Sac { seed: u64 },
}

impl SchedulerSpec {
    pub fn build(&self, cfg: &EngineConfig, worker: usize)
                 -> Box<dyn Scheduler> {
        match *self {
            SchedulerSpec::Fixed { batch, m_c } => {
                Box::new(FixedScheduler { batch, m_c })
            }
            SchedulerSpec::DeepRt => Box::new(DeepRtScheduler::default()),
            SchedulerSpec::Sac { seed } => {
                let mut rng = Pcg32::seeded(
                    seed.wrapping_add(worker as u64 * 0x9E37_79B9_97F4_A7C5),
                );
                Box::new(sac_sched::sac(cfg.action_space.clone(), &mut rng))
            }
        }
    }
}

/// Rebalance-controller tunables (live serving only).
#[derive(Clone, Copy, Debug)]
pub struct RebalanceConfig {
    /// How often the controller reads the gauges and considers one
    /// action (replica scaling or migration), ms.
    pub epoch_ms: u64,
    /// Migration trigger: the most-backlogged worker must exceed
    /// `ratio` × the least-backlogged one...
    pub ratio: f64,
    /// ...by at least this absolute gap, ms (hysteresis — tiny
    /// imbalances are noise, migrating on them would thrash).
    pub min_gap_ms: f64,
    /// Hot-model replication ceiling: the widest replica set any one
    /// model may reach (clamped to the pool size at decision time).
    /// `1` disables replication entirely (`--no-replication`), restoring
    /// the PR 3 one-owner-per-model behaviour.
    pub max_replicas: usize,
    /// Scale-up trigger: one model's pool-wide priced backlog must
    /// exceed this, ms — the point where a single worker's drain rate
    /// is provably behind and only another concurrent drainer helps.
    pub scale_up_backlog_ms: f64,
    /// Scale-down trigger: a replicated model whose pool-wide backlog
    /// falls below this collapses one replica. Keep well under the
    /// scale-up trigger (the band between them is the hysteresis that
    /// prevents replica flapping).
    pub scale_down_backlog_ms: f64,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig {
            epoch_ms: 200,
            ratio: 1.5,
            min_gap_ms: 25.0,
            max_replicas: MAX_POOL,
            scale_up_backlog_ms: 250.0,
            scale_down_backlog_ms: 30.0,
        }
    }
}

/// Width of each node's request-id window: ids `(n+1) * NODE_ID_STRIDE ..`
/// belong to cluster node `n`. Bits 40.. encode the node, bits 32..40 the
/// incarnation, leaving [`INCARNATION_ID_STRIDE`] ids per serving segment.
/// Single-node serving keeps base `0` (below every node window).
pub const NODE_ID_STRIDE: u64 = 1 << 40;

/// Width of each (node, incarnation) request-id window: every serving
/// segment stamps at most `2^32` ids, so a custom
/// [`ServeConfig::request_id_base`] must sit on a multiple of this stride
/// to stay disjoint from the cluster tier's windows (checked by
/// [`ServeConfigBuilder::build`]).
pub const INCARNATION_ID_STRIDE: u64 = 1 << 32;

/// Serving-runtime configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads (clamped to [1, N_MODELS]; model `m` STARTS on
    /// worker `m % workers` — live serving may reshard from there).
    pub workers: usize,
    pub clock: ClockKind,
    pub platform: PlatformSpec,
    /// Per-worker engine configuration (worker `i` perturbs the seed by
    /// `i`; worker 0 keeps it verbatim for seed equivalence).
    pub engine: EngineConfig,
    pub scheduler: SchedulerSpec,
    /// `None` disables admission control (every request is queued).
    pub admission: Option<AdmissionConfig>,
    /// Per-model ingress channel bound (live mode backpressure).
    pub queue_capacity: usize,
    /// Dynamic resharding + hot-model replication (live, multi-worker
    /// only). `None` pins the static modulo shard map — one fixed owner
    /// per model — for the whole run.
    pub rebalance: Option<RebalanceConfig>,
    /// Feed cross-worker gauge summaries into [`crate::coordinator::SchedCtx`]
    /// (live, multi-worker only — single-worker pools stay bit-identical
    /// to the bare engine regardless).
    pub cluster_hints: bool,
    /// First request id the live ingress assigns. Single-node serving
    /// keeps the default `0`; the cluster tier gives every node (and
    /// every drain/rejoin incarnation) a disjoint id window so outcome
    /// ids stay unique cluster-wide without coordination.
    pub request_id_base: u64,
    /// Request-lifecycle tracing + streaming telemetry knobs. Default is
    /// fully off, which keeps every path bit-identical to a build
    /// without the telemetry layer (pinned by the seed-equivalence
    /// test).
    pub telemetry: TelemetryConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 4,
            clock: ClockKind::Virtual,
            platform: PlatformSpec::xavier_nx(),
            engine: EngineConfig::default(),
            scheduler: SchedulerSpec::Sac { seed: 0x5AC },
            admission: Some(AdmissionConfig::default()),
            queue_capacity: 256,
            rebalance: Some(RebalanceConfig::default()),
            cluster_hints: true,
            request_id_base: 0,
            telemetry: TelemetryConfig::default(),
        }
    }
}

impl ServeConfig {
    /// Start a validated-construction builder seeded with the defaults.
    /// Prefer this over struct-literal construction at API boundaries:
    /// [`ServeConfigBuilder::build`] rejects configurations the runtime
    /// would silently misbehave under (zero workers/capacity, id bases
    /// off the cluster window grid, sampling rates that skew per-window
    /// trace density, inverted replication hysteresis).
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: ServeConfig::default() }
    }

    pub(crate) fn worker_count(&self) -> usize {
        self.workers.clamp(1, N_MODELS)
    }

    /// Worker index owning `model`.
    pub(crate) fn owner(&self, model: ModelId) -> usize {
        model as usize % self.worker_count()
    }

    pub(crate) fn build_engine(&self, worker: usize, clock: ClockSource)
                    -> Engine<SimDispatcher> {
        let mut cfg = self.engine.clone();
        cfg.seed ^= worker as u64; // worker 0: unchanged (seed equivalence)
        cfg.max_total_instances = self.platform.max_instances;
        let sim = PlatformSim::new(self.platform.clone());
        let mut engine = Engine::new(SimDispatcher::with_clock(sim, clock), cfg);
        if self.telemetry.tracing_on() {
            engine.set_tracer(Some(EngineTracer::new(&self.telemetry,
                                                     worker as u32)));
        }
        engine
    }

    /// Reference batch pricing backlog estimates (shared with admission).
    pub(crate) fn ref_batch(&self) -> usize {
        self.admission.map(|a| a.ref_batch).unwrap_or(8).max(1)
    }

    pub(crate) fn isolated_ref_table(&self) -> [f64; N_MODELS] {
        let ref_batch = self.ref_batch();
        let sim = PlatformSim::new(self.platform.clone());
        std::array::from_fn(|i| {
            sim.latency.isolated_ms(ModelId::from_index(i), ref_batch)
        })
    }
}

/// Validated constructor for [`ServeConfig`]: chain setters, then
/// [`build`](Self::build). Every CLI entry point goes through this, so a
/// bad flag combination fails with a message at startup instead of
/// producing a quietly wrong run.
#[derive(Clone, Debug)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    /// Worker threads in the pool.
    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    /// Clock arm (virtual = deterministic trace mode, wall = live).
    pub fn clock(mut self, clock: ClockKind) -> Self {
        self.cfg.clock = clock;
        self
    }

    /// Table-V platform preset the workers simulate.
    pub fn platform(mut self, platform: PlatformSpec) -> Self {
        self.cfg.platform = platform;
        self
    }

    /// Per-worker scheduler (SAC / DeepRT / fixed).
    pub fn scheduler(mut self, scheduler: SchedulerSpec) -> Self {
        self.cfg.scheduler = scheduler;
        self
    }

    /// SLO-aware admission control; `None` queues every request.
    pub fn admission(mut self, admission: Option<AdmissionConfig>) -> Self {
        self.cfg.admission = admission;
        self
    }

    /// Per-model ingress channel bound.
    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        self.cfg.queue_capacity = capacity;
        self
    }

    /// Dynamic resharding + replication controller; `None` pins the
    /// static modulo shard map.
    pub fn rebalance(mut self, rebalance: Option<RebalanceConfig>) -> Self {
        self.cfg.rebalance = rebalance;
        self
    }

    /// Feed cross-worker gauge summaries into the schedulers.
    pub fn cluster_hints(mut self, on: bool) -> Self {
        self.cfg.cluster_hints = on;
        self
    }

    /// First request id the ingress assigns. Must sit on a multiple of
    /// [`INCARNATION_ID_STRIDE`] (the cluster id-window grid).
    pub fn request_id_base(mut self, base: u64) -> Self {
        self.cfg.request_id_base = base;
        self
    }

    /// Tracing + streaming-telemetry knobs.
    pub fn telemetry(mut self, telemetry: TelemetryConfig) -> Self {
        self.cfg.telemetry = telemetry;
        self
    }

    /// Validate and return the configuration.
    pub fn build(self) -> Result<ServeConfig, String> {
        let cfg = self.cfg;
        if cfg.workers == 0 {
            return Err("--workers must be >= 1".into());
        }
        if cfg.queue_capacity == 0 {
            return Err("--queue-cap must be >= 1".into());
        }
        if cfg.request_id_base % INCARNATION_ID_STRIDE != 0 {
            return Err(format!(
                "request_id_base {} is not a multiple of the id-window \
                 stride 2^32 — it would overlap a cluster node's \
                 (node, incarnation) window",
                cfg.request_id_base
            ));
        }
        // Id-keyed 1/N sampling is uniform across id windows only when N
        // divides the window stride; otherwise each node/incarnation
        // window starts at a different phase of `id % N` and trace
        // density skews per node.
        if cfg.request_id_base != 0
            && cfg.telemetry.trace_sample > 0
            && INCARNATION_ID_STRIDE % cfg.telemetry.trace_sample != 0
        {
            return Err(format!(
                "--trace-sample {} does not divide the id-window stride \
                 2^32 (use a power of two) — windowed ids would be \
                 sampled at uneven per-node density",
                cfg.telemetry.trace_sample
            ));
        }
        if let Some(r) = &cfg.rebalance {
            if r.epoch_ms == 0 {
                return Err("--rebalance-epoch-ms must be >= 1".into());
            }
            if r.max_replicas == 0 {
                return Err("--max-replicas must be >= 1".into());
            }
            if !r.ratio.is_finite() || r.ratio < 1.0 {
                return Err("rebalance ratio must be finite and >= 1".into());
            }
            if !r.min_gap_ms.is_finite() || r.min_gap_ms < 0.0 {
                return Err("rebalance min_gap_ms must be finite and >= 0"
                    .into());
            }
            if !r.scale_up_backlog_ms.is_finite()
                || !r.scale_down_backlog_ms.is_finite()
                || r.scale_down_backlog_ms < 0.0
                || r.scale_up_backlog_ms <= r.scale_down_backlog_ms
            {
                return Err(
                    "replication thresholds need 0 <= scale_down < scale_up \
                     (the band between them is the hysteresis)"
                        .into(),
                );
            }
        }
        Ok(cfg)
    }
}

// ---------------------------------------------------------------------
// Dynamic resharding + hot-model replication
// ---------------------------------------------------------------------

/// One replica-scaling decision (worker indices into the live pool).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ScaleAction {
    /// Add `worker` to `model`'s replica set.
    Up { model: usize, worker: usize },
    /// Remove `worker` from `model`'s replica set.
    Down { model: usize, worker: usize },
}

/// Decide at most one replica-scaling action from the per-(model,
/// worker) backlog estimates. Pure so the policy is unit-testable
/// without threads. `model_total` and `worker_total[..workers]` are the
/// row/column sums of `backlog` — the caller (the controller's tick)
/// already aggregates them for imbalance stats and migration planning,
/// so the policy consumes the same numbers instead of re-deriving its
/// own.
///
/// * **scale-up** — the model with the LARGEST pool-wide backlog above
///   `up_ms` that still has replica headroom gains a replica on the
///   least-loaded worker outside its set. Backlog above the trigger
///   means one worker's drain rate is provably behind; only another
///   concurrent drainer (the paper's m_c crossing the worker boundary)
///   closes that gap — migration would merely relocate it.
/// * **scale-down** — a replicated model whose pool-wide backlog fell
///   below `down_ms` sheds the replica holding the LEAST of it (the
///   cheapest handoff). The `[down_ms, up_ms]` band is the hysteresis
///   that keeps sets from flapping.
///
/// Scale-ups outrank scale-downs (relieve pressure first); one action
/// per epoch bounds churn the same way migration planning does.
fn plan_scaling(backlog: &[[f64; MAX_POOL]; N_MODELS],
                model_total: &[f64; N_MODELS], worker_total: &[f64],
                replica_mask: &[u64; N_MODELS], workers: usize,
                max_replicas: usize, up_ms: f64, down_ms: f64)
                -> Option<ScaleAction> {
    let workers = workers.min(MAX_POOL).min(worker_total.len());
    let cap = max_replicas.min(workers);
    if workers < 2 || cap < 2 {
        return None;
    }
    // Scale-up arm: hottest eligible model.
    let mut hottest: Option<(usize, f64)> = None;
    for (m, &total) in model_total.iter().enumerate() {
        let count = replica_mask[m].count_ones() as usize;
        if total > up_ms
            && count < cap
            && hottest.map(|(_, t)| total > t).unwrap_or(true)
        {
            hottest = Some((m, total));
        }
    }
    if let Some((m, _)) = hottest {
        let target = (0..workers)
            .filter(|&w| replica_mask[m] & (1u64 << w) == 0)
            .min_by(|&a, &b| {
                worker_total[a]
                    .partial_cmp(&worker_total[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
        if let Some(w) = target {
            return Some(ScaleAction::Up { model: m, worker: w });
        }
    }
    // Scale-down arm: first subsided replicated model, cheapest member.
    for (m, &total) in model_total.iter().enumerate() {
        if replica_mask[m].count_ones() < 2 || total >= down_ms {
            continue;
        }
        let victim = (0..workers)
            .filter(|&w| replica_mask[m] & (1u64 << w) != 0)
            .min_by(|&a, &b| {
                backlog[m][a]
                    .partial_cmp(&backlog[m][b])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
        if let Some(w) = victim {
            return Some(ScaleAction::Down { model: m, worker: w });
        }
    }
    None
}

/// Decide at most one ownership migration from per-model backlog
/// estimates and the per-worker totals (`totals[w]` = worker `w`'s
/// lane-accurate backlog; the controller passes the SAME sums its
/// imbalance stat reads, so with replicas in play a worker busy
/// draining replica lanes is never mistaken for idle). Pure so the
/// policy is unit-testable without threads. `workers` is
/// `totals.len()`.
///
/// Trigger: the most-backlogged worker exceeds `ratio` × the least plus
/// `min_gap_ms`. Then:
///
/// * **hot-model isolation** — if one model carries ≥ half the hot
///   worker's backlog, peel the SMALLEST active sibling off to the cold
///   worker. Moving the dominant model only relocates the hotspot; what
///   actually helps is decoupling its siblings' round spans from it
///   (every co-resident model dispatches in the same concurrent group,
///   so the hot model's span and interference tax them all).
/// * **spread reduction** — otherwise move whichever active model most
///   reduces the max−min backlog spread, requiring strict improvement
///   (which is also what prevents ping-pong: a move that merely mirrors
///   the imbalance is rejected).
///
/// Returns `(model index, destination worker)`.
fn plan_migration(backlog_ms: &[f64; N_MODELS], active: &[bool; N_MODELS],
                  owner: &[usize; N_MODELS], totals: &[f64], ratio: f64,
                  min_gap_ms: f64) -> Option<(usize, usize)> {
    let workers = totals.len();
    if workers < 2 {
        return None;
    }
    let (w_max, _) = totals.iter().enumerate().fold(
        (0, f64::MIN),
        |acc, (i, &t)| if t > acc.1 { (i, t) } else { acc },
    );
    let (w_min, _) = totals.iter().enumerate().fold(
        (0, f64::MAX),
        |acc, (i, &t)| if t < acc.1 { (i, t) } else { acc },
    );
    if totals[w_max] <= ratio * totals[w_min] + min_gap_ms {
        return None;
    }
    let owned_active: Vec<usize> = (0..N_MODELS)
        .filter(|&m| owner[m] == w_max && active[m])
        .collect();
    if owned_active.len() < 2 {
        // Nothing to decouple: zero or one active model on the hot
        // worker (a lone hot model is already isolated).
        return None;
    }
    let min_backlog = |candidates: &[usize]| -> Option<usize> {
        candidates
            .iter()
            .min_by(|&&a, &&b| {
                backlog_ms[a]
                    .partial_cmp(&backlog_ms[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .copied()
    };
    let top = *owned_active
        .iter()
        .max_by(|&&a, &&b| {
            backlog_ms[a]
                .partial_cmp(&backlog_ms[b])
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .unwrap();
    if backlog_ms[top] >= 0.5 * totals[w_max] {
        // Prefer siblings that hold backlog RIGHT NOW (moving one
        // relieves the hot worker immediately AND decouples it);
        // idle-but-active siblings are the fallback, still worth moving
        // for the span decoupling alone.
        let siblings: Vec<usize> = owned_active
            .iter()
            .copied()
            .filter(|&m| m != top)
            .collect();
        let queued: Vec<usize> = siblings
            .iter()
            .copied()
            .filter(|&m| backlog_ms[m] > 0.0)
            .collect();
        let pool = if queued.is_empty() { &siblings } else { &queued };
        return min_backlog(pool).map(|m| (m, w_min));
    }
    // Spread-reduction arm: strict improvement required.
    let before = backlog_spread_ms(totals);
    let mut best: Option<(usize, f64)> = None;
    for &m in &owned_active {
        let mut after = totals.to_vec();
        after[w_max] -= backlog_ms[m];
        after[w_min] += backlog_ms[m];
        let s = backlog_spread_ms(&after);
        if s + 1e-9 < before && best.map(|(_, bs)| s < bs).unwrap_or(true) {
            best = Some((m, s));
        }
    }
    best.map(|(m, _)| (m, w_min))
}

/// Max−min backlog spread across workers, ms.
fn backlog_spread_ms(totals: &[f64]) -> f64 {
    let max = totals.iter().cloned().fold(f64::MIN, f64::max);
    let min = totals.iter().cloned().fold(f64::MAX, f64::min);
    max - min
}

/// Controller-side counters surfaced in the final report's metrics.
#[derive(Default)]
pub(crate) struct RebalanceStats {
    epochs: AtomicU64,
    /// Worst max−min backlog spread seen, as f64 bits (monotone max).
    peak_imbalance_bits: AtomicU64,
}

impl RebalanceStats {
    pub(crate) fn epochs(&self) -> u64 {
        self.epochs.load(Ordering::Relaxed)
    }

    fn observe_imbalance(&self, spread_ms: f64) {
        if !spread_ms.is_finite() {
            return;
        }
        let mut cur = self.peak_imbalance_bits.load(Ordering::Relaxed);
        while spread_ms > f64::from_bits(cur) {
            match self.peak_imbalance_bits.compare_exchange_weak(
                cur,
                spread_ms.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    pub(crate) fn peak_imbalance_ms(&self) -> f64 {
        f64::from_bits(self.peak_imbalance_bits.load(Ordering::Relaxed))
    }
}

/// The rebalance controller: reads gauges each epoch and rewrites the
/// ownership table (the only writer it has) — replica scaling first,
/// whole-model migration when no set is widened. The live pool runs it
/// on its own thread ([`Rebalancer::run`]); the virtual fabric holds one
/// and calls [`Rebalancer::tick`] at epoch events — same policy state,
/// no thread.
pub(crate) struct Rebalancer {
    cfg: RebalanceConfig,
    gauges: Arc<SharedGauges>,
    ownership: Arc<OwnershipTable>,
    worker_events: Vec<Arc<WakeEvent>>,
    isolated_ref_ms: [f64; N_MODELS],
    ref_batch: usize,
    stop: Arc<AtomicBool>,
    wake: Arc<WakeEvent>,
    stats: Arc<RebalanceStats>,
    /// Post-scale-down migration cooldown, epochs remaining per model. A
    /// model whose replica set just collapsed to one owner reads, for the
    /// 1–2 rounds until the ex-replica's flush lands, as if its POOL-WIDE
    /// backlog sat entirely on that owner — a transient that could bait
    /// migration planning into moving it (or a sibling) for load that is
    /// about to redistribute anyway. Sitting the model out of migration
    /// for one epoch after its scale-down removes that window.
    migration_cooldown: [u8; N_MODELS],
}

impl Rebalancer {
    /// Controller for the fabric's virtual arm: identical policy state,
    /// driven by fabric epoch events instead of a thread. The wake
    /// events and stop flag exist only to satisfy the struct (ticks
    /// notify them; nobody waits) — `worker_events.len()` doubles as
    /// the pool size `tick` reads, exactly as in the live pool.
    pub(crate) fn fabric_controller(
        cfg: RebalanceConfig,
        workers: usize,
        gauges: Arc<SharedGauges>,
        ownership: Arc<OwnershipTable>,
        isolated_ref_ms: [f64; N_MODELS],
        ref_batch: usize,
        stats: Arc<RebalanceStats>,
    ) -> Self {
        Rebalancer {
            cfg,
            gauges,
            ownership,
            worker_events: (0..workers)
                .map(|_| Arc::new(WakeEvent::new()))
                .collect(),
            isolated_ref_ms,
            ref_batch,
            stop: Arc::new(AtomicBool::new(false)),
            wake: Arc::new(WakeEvent::new()),
            stats,
            migration_cooldown: [0; N_MODELS],
        }
    }

    fn run(mut self) {
        loop {
            self.wake
                .wait_timeout(Duration::from_millis(self.cfg.epoch_ms.max(1)));
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            self.tick();
        }
    }

    pub(crate) fn tick(&mut self) {
        let workers = self.worker_events.len().min(MAX_POOL);
        let mut backlog = [[0.0f64; MAX_POOL]; N_MODELS];
        let mut model_total = [0.0f64; N_MODELS];
        let mut active = [false; N_MODELS];
        let mut owner = [0usize; N_MODELS];
        let mut replica_mask = [0u64; N_MODELS];
        for m in ModelId::all() {
            let i = m as usize;
            for (w, b) in backlog[i][..workers].iter_mut().enumerate() {
                *b = self.gauges.backlog_ms_for(
                    m, w, self.isolated_ref_ms[i], self.ref_batch);
                model_total[i] += *b;
            }
            owner[i] = self.ownership.owner(m);
            replica_mask[i] = self.ownership.replica_mask(m);
            // Replicated models are PINNED for migration — their queue
            // is spread across the set, so "moving the model" is
            // meaningless mid-replication — but their load still counts:
            // each replica's share lands in its own lane of the
            // worker totals below. Pinning per model keeps migration
            // alive for the rest of the zoo even while one model stays
            // replicated for a long stretch. A just-collapsed set stays
            // pinned one epoch longer (`migration_cooldown`): until the
            // ex-replica's flush lands, the model's backlog transiently
            // reads as all-on-owner.
            active[i] = self.gauges.is_active(m)
                && replica_mask[i].count_ones() <= 1
                && self.migration_cooldown[i] == 0;
        }
        for c in self.migration_cooldown.iter_mut() {
            *c = c.saturating_sub(1);
        }
        let mut worker_total = [0.0f64; MAX_POOL];
        for per_worker in backlog.iter() {
            for (w, b) in per_worker[..workers].iter().enumerate() {
                worker_total[w] += b;
            }
        }
        self.stats
            .observe_imbalance(backlog_spread_ms(&worker_total[..workers]));
        self.stats.epochs.fetch_add(1, Ordering::Relaxed);
        // Replica scaling is the first-class control: a hot model whose
        // backlog no single worker can drain gets another drainer.
        if self.cfg.max_replicas > 1 {
            if let Some(action) = plan_scaling(
                &backlog,
                &model_total,
                &worker_total[..workers],
                &replica_mask,
                workers,
                self.cfg.max_replicas,
                self.cfg.scale_up_backlog_ms,
                self.cfg.scale_down_backlog_ms,
            ) {
                self.apply_scaling(action);
                return;
            }
        }
        // Whole-model migration over the un-replicated models (the
        // replicated ones are pinned via `active` above — scaling is
        // their control axis), against the SAME lane-accurate worker
        // totals the imbalance stat reads: a worker busy draining
        // replica lanes is never mistaken for an idle destination.
        if let Some((m, to)) = plan_migration(&model_total, &active, &owner,
                                              &worker_total[..workers],
                                              self.cfg.ratio,
                                              self.cfg.min_gap_ms) {
            let from = owner[m];
            self.ownership.migrate(ModelId::from_index(m), to);
            // Wake both sides so the handoff starts now: the old owner
            // flushes the backlog, the new owner picks it up.
            self.worker_events[from].notify();
            self.worker_events[to].notify();
        }
    }

    /// Commit one scaling decision to the table and wake every affected
    /// worker so handoffs start immediately.
    fn apply_scaling(&mut self, action: ScaleAction) {
        match action {
            ScaleAction::Up { model, worker } => {
                let m = ModelId::from_index(model);
                if self.ownership.add_replica(m, worker).is_some() {
                    // The loaded replicas shed above-fair-share surplus
                    // into the handoff slot; the new one picks it up.
                    self.notify_replicas(m);
                }
            }
            ScaleAction::Down { model, worker } => {
                let m = ModelId::from_index(model);
                if self.ownership.remove_replica(m, worker).is_some() {
                    // Sit the model out of the NEXT epoch's migration
                    // planning: its pool-wide backlog reads as all-on-
                    // owner until this flush lands.
                    self.migration_cooldown[model] = 1;
                    // The removed worker flushes its share out...
                    self.worker_events[worker].notify();
                    // ...and the survivors pick it up.
                    self.notify_replicas(m);
                }
            }
        }
    }

    fn notify_replicas(&self, model: ModelId) {
        for (w, e) in self.worker_events.iter().enumerate() {
            if self.ownership.is_replica(model, w) {
                e.notify();
            }
        }
    }
}

/// Final report of a serving run: merged worker metrics + pool counters.
pub struct ServeReport {
    pub metrics: Metrics,
    /// Serving horizon (virtual or wall, matching the run's clock), ms.
    pub horizon_ms: f64,
    pub workers: usize,
    /// Total per-model scheduling slots across the pool.
    pub slots: u64,
    /// Requests still queued when the horizon expired (trace mode; the
    /// live drain protocol flushes to zero).
    pub leftover: usize,
    /// Sampled span records + action histograms folded across the pool
    /// (empty when tracing is off).
    pub telemetry: TraceReport,
}

impl ServeReport {
    pub fn achieved_rps(&self) -> f64 {
        self.metrics.completed() as f64 / (self.horizon_ms / 1e3).max(1e-9)
    }

    /// Human-readable summary (the `bcedge bench-serve` output).
    pub fn print(&self) {
        let m = &self.metrics;
        println!(
            "workers {} | {} slots | horizon {:.1}s",
            self.workers,
            self.slots,
            self.horizon_ms / 1e3
        );
        println!(
            "achieved {:.1} rps | e2e p50 {:.2} ms p99 {:.2} ms | \
             SLO violations {:.2}% | shed {:.2}%",
            self.achieved_rps(),
            m.latency_percentile_streaming(0.5),
            m.latency_percentile_streaming(0.99),
            100.0 * m.violation_rate(),
            100.0 * m.shed_rate(),
        );
        if m.shed_total() > 0 {
            let by: Vec<String> = ShedReason::all()
                .into_iter()
                .filter(|r| m.shed_by_reason(*r) > 0)
                .map(|r| format!("{}={}", r, m.shed_by_reason(r)))
                .collect();
            println!("sheds: {} ({})", m.shed_total(), by.join(", "));
        }
        if m.rebalance_epochs() > 0 {
            println!(
                "rebalance: {} migrations over {} epochs | peak worker \
                 imbalance {:.1} ms",
                m.migrations(),
                m.rebalance_epochs(),
                m.peak_imbalance_ms(),
            );
        }
        if m.scale_ups() > 0 || m.scale_downs() > 0 {
            println!(
                "replication: {} scale-ups, {} scale-downs | peak \
                 replicas {}",
                m.scale_ups(),
                m.scale_downs(),
                m.peak_replicas(),
            );
        }
        if self.leftover > 0 {
            println!("leftover in queue at horizon: {}", self.leftover);
        }
    }
}

pub(crate) fn merge_results(results: Vec<WorkerResult>, horizon_ms: f64,
                            workers: usize) -> ServeReport {
    let mut metrics = Metrics::new();
    let mut telemetry = TraceReport::default();
    let mut slots = 0;
    let mut leftover = 0;
    for r in results {
        // Worker results are owned: fold by move, no outcome clones.
        metrics.absorb(r.metrics);
        telemetry.merge(r.telemetry);
        slots += r.slots;
        leftover += r.leftover;
    }
    ServeReport { metrics, horizon_ms, workers, slots, leftover, telemetry }
}

/// Serve a pre-generated trace across the worker pool and report.
/// Requests must be sorted by arrival time (generator order).
///
/// The virtual arm runs on the discrete-event fabric
/// ([`super::fabric`]): workers, arrivals, and rebalance epochs are
/// logical processes on one event heap, so the FULL dynamic stack —
/// migration, replication, urgency-aware replica routing on live gauges
/// — runs in trace mode and replays bit-identically from a seed. The
/// wall arm keeps real threads on static modulo shards (wall trace runs
/// exist to pace real execution, not to exercise the control plane).
pub fn run_trace(cfg: &ServeConfig, requests: Vec<Request>,
                 horizon_ms: f64) -> ServeReport {
    if cfg.clock == ClockKind::Virtual {
        return super::fabric::run_trace_fabric(cfg, requests, horizon_ms);
    }
    let workers = cfg.worker_count();
    let mut shards: Vec<Vec<Request>> = (0..workers).map(|_| Vec::new()).collect();
    for r in requests {
        shards[cfg.owner(r.model)].push(r);
    }
    let wall = WallClock::new(); // shared origin across the pool
    let results: Vec<WorkerResult> = std::thread::scope(|s| {
        let handles: Vec<_> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                let wall = wall.clone();
                s.spawn(move || {
                    let clock = ClockSource::Wall(wall);
                    let mut engine = cfg.build_engine(i, clock);
                    if let Some(adm) = cfg.admission {
                        engine.set_ingress_gate(Some(Box::new(
                            super::admission::AdmissionGate::new(adm),
                        )));
                    }
                    let mut sched = cfg.scheduler.build(&cfg.engine, i);
                    run_trace_worker(engine, sched.as_mut(), shard, horizon_ms)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("serve worker panicked"))
            .collect()
    });
    merge_results(results, horizon_ms, workers)
}

/// A running live server (wall clock).
pub struct Server {
    ingress: Ingress,
    handles: Vec<std::thread::JoinHandle<WorkerResult>>,
    clock: WallClock,
    workers: usize,
    /// Shared intake slots, kept for the post-join conservation sweep.
    intake: Arc<Vec<Mutex<ModelIntake>>>,
    ownership: Arc<OwnershipTable>,
    /// Drain flag the workers watch (stop migrating backlog, serve what
    /// you hold).
    closed: Arc<AtomicBool>,
    rebalance_stop: Arc<AtomicBool>,
    rebalance_wake: Arc<WakeEvent>,
    rebalance_handle: Option<std::thread::JoinHandle<()>>,
    rebalance_stats: Arc<RebalanceStats>,
    telemetry_stop: Arc<AtomicBool>,
    telemetry_wake: Arc<WakeEvent>,
    /// Publisher thread appending live counter snapshots to
    /// `--metrics-out` every `--metrics-interval-ms` (spawned only when
    /// the flag is set — otherwise the pool carries no telemetry hub at
    /// all).
    telemetry_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Spawn the worker pool, the rebalance controller (when configured
    /// and `workers > 1`), and open the ingress. Live serving is
    /// wall-clock by definition (arrivals are stamped with real time), so
    /// `cfg.clock` is ignored here. `events`, when given, receives every
    /// request-terminal event — completion or engine-gate shed — for
    /// closed-loop load generation.
    pub fn start(cfg: &ServeConfig,
                 events_tx: Option<std::sync::mpsc::Sender<ServeEvent>>)
                 -> Server {
        let workers = cfg.worker_count();
        let clock = WallClock::new();
        let gauges = Arc::new(SharedGauges::new());
        let ownership = Arc::new(OwnershipTable::new_static(workers));
        let closed = Arc::new(AtomicBool::new(false));
        let worker_events: Vec<Arc<WakeEvent>> =
            (0..workers).map(|_| Arc::new(WakeEvent::new())).collect();
        let isolated_ref_ms = cfg.isolated_ref_table();
        let ref_batch = cfg.ref_batch();
        // Per-model bounded channels behind shared intake slots: the
        // ownership table (not channel plumbing) decides who drains what,
        // so a migration is a table write and the channels never move.
        let mut senders = Vec::with_capacity(N_MODELS);
        let mut slots = Vec::with_capacity(N_MODELS);
        for _ in ModelId::all() {
            let (tx, rx) = sync_channel::<Request>(cfg.queue_capacity.max(1));
            senders.push(tx);
            slots.push(Mutex::new(ModelIntake {
                rx,
                handoff: Vec::new(),
                closed: false,
            }));
        }
        let intake: Arc<Vec<Mutex<ModelIntake>>> = Arc::new(slots);
        let cluster_hints = cfg.cluster_hints && workers > 1;
        // Live telemetry hub: only materialized when a publisher will
        // read it, so the default pool carries no extra atomics.
        let telemetry_hub = if cfg.telemetry.metrics_out.is_some() {
            Some(Arc::new(TelemetryHub::new(cfg.telemetry.node_label)))
        } else {
            None
        };
        let handles = (0..workers)
            .map(|i| {
                let engine = cfg.build_engine(
                    i,
                    ClockSource::Wall(clock.clone()),
                );
                let worker = LiveWorker {
                    id: i,
                    engine,
                    intake: intake.clone(),
                    ownership: ownership.clone(),
                    worker_events: worker_events.clone(),
                    gauges: gauges.clone(),
                    admission: cfg.admission,
                    isolated_ref_ms,
                    ref_batch,
                    cluster_hints,
                    closed: closed.clone(),
                    events_tx: events_tx.clone(),
                    hub: telemetry_hub.clone(),
                };
                let spec = cfg.scheduler;
                let engine_cfg = cfg.engine.clone();
                std::thread::Builder::new()
                    .name(format!("bcedge-serve-{i}"))
                    .spawn(move || {
                        let mut sched = spec.build(&engine_cfg, i);
                        worker.run(sched.as_mut())
                    })
                    .expect("spawn serve worker")
            })
            .collect();
        let rebalance_stop = Arc::new(AtomicBool::new(false));
        let rebalance_wake = Arc::new(WakeEvent::new());
        let rebalance_stats = Arc::new(RebalanceStats::default());
        let rebalance_handle = match cfg.rebalance {
            Some(rcfg) if workers > 1 => {
                let controller = Rebalancer {
                    cfg: rcfg,
                    gauges: gauges.clone(),
                    ownership: ownership.clone(),
                    worker_events: worker_events.clone(),
                    isolated_ref_ms,
                    ref_batch,
                    stop: rebalance_stop.clone(),
                    wake: rebalance_wake.clone(),
                    stats: rebalance_stats.clone(),
                    migration_cooldown: [0; N_MODELS],
                };
                Some(
                    std::thread::Builder::new()
                        .name("bcedge-rebalance".into())
                        .spawn(move || controller.run())
                        .expect("spawn rebalance controller"),
                )
            }
            _ => None,
        };
        let telemetry_stop = Arc::new(AtomicBool::new(false));
        let telemetry_wake = Arc::new(WakeEvent::new());
        let telemetry_handle = match (&telemetry_hub, &cfg.telemetry.metrics_out)
        {
            (Some(hub), Some(path)) => {
                let hub = hub.clone();
                let path = path.clone();
                let stop = telemetry_stop.clone();
                let wake = telemetry_wake.clone();
                let pub_clock = clock.clone();
                let interval = std::time::Duration::from_secs_f64(
                    cfg.telemetry.metrics_interval_ms.max(10.0) / 1e3,
                );
                Some(
                    std::thread::Builder::new()
                        .name("bcedge-telemetry".into())
                        .spawn(move || loop {
                            wake.wait_timeout(interval);
                            if stop.load(Ordering::Acquire) {
                                break;
                            }
                            let now = pub_clock.now_ms();
                            let snap = hub.snapshot_json(now);
                            let _ = telemetry::append_jsonl(&path, &snap);
                            eprintln!("{}", hub.status_line(now));
                        })
                        .expect("spawn telemetry publisher"),
                )
            }
            _ => None,
        };
        let ingress = Ingress::new(senders, worker_events, ownership.clone(),
                                   gauges, cfg.admission, isolated_ref_ms,
                                   cfg.request_id_base);
        Server {
            ingress,
            handles,
            clock,
            workers,
            intake,
            ownership,
            closed,
            rebalance_stop,
            rebalance_wake,
            rebalance_handle,
            rebalance_stats,
            telemetry_stop,
            telemetry_wake,
            telemetry_handle,
        }
    }

    /// Milliseconds since the server started (the arrival timebase).
    pub fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }

    /// Submit a request arriving now. Typed rejection when admission
    /// control or backpressure refuses it.
    pub fn submit(&self, model: ModelId, slo_ms: f64, transmission_ms: f64)
                  -> Result<u64, ShedReason> {
        self.ingress
            .submit(model, slo_ms, transmission_ms, self.clock.now_ms())
    }

    /// Export the pool-wide gauge state the workers publish each round
    /// (queues priced per replica, profiled-or-isolated batch estimates,
    /// backlog totals). The cluster router reads this per node to price
    /// routing candidates — the same numbers the node's own admission
    /// fast path uses.
    pub fn gauge_snapshot(&self) -> super::ingress::GaugeSnapshot {
        self.ingress.gauge_snapshot()
    }

    /// Shard migrations performed so far (live observability).
    pub fn migrations(&self) -> u64 {
        self.ownership.migrations()
    }

    /// Hot-model replica scale-ups performed so far (live observability).
    pub fn scale_ups(&self) -> u64 {
        self.ownership.scale_ups()
    }

    /// Replica scale-downs performed so far (live observability).
    pub fn scale_downs(&self) -> u64 {
        self.ownership.scale_downs()
    }

    /// Drain and stop: freeze the shard map (join the rebalance
    /// controller), raise the drain flag, close intake, flush every
    /// queue, join the workers, and merge their metrics (ingress-side
    /// sheds and rebalance counters included).
    pub fn shutdown(self) -> ServeReport {
        let Server {
            mut ingress,
            handles,
            clock,
            workers,
            intake,
            ownership,
            closed,
            rebalance_stop,
            rebalance_wake,
            rebalance_handle,
            rebalance_stats,
            telemetry_stop,
            telemetry_wake,
            telemetry_handle,
        } = self;
        // 0. Stop the telemetry publisher first: the final snapshot is
        //    written by the caller from merged metrics, not this thread.
        telemetry_stop.store(true, Ordering::Release);
        telemetry_wake.notify();
        if let Some(h) = telemetry_handle {
            h.join().expect("telemetry publisher panicked");
        }
        // 1. Freeze the ownership table: no migrations during the drain.
        rebalance_stop.store(true, Ordering::Release);
        rebalance_wake.notify();
        if let Some(h) = rebalance_handle {
            h.join().expect("rebalance controller panicked");
        }
        // 2. Drain flag up: workers keep (and serve) any backlog they
        //    still hold for disowned models instead of bouncing it
        //    between exiting threads.
        closed.store(true, Ordering::Release);
        let horizon_ms = clock.now_ms();
        // 3. Stop intake, disconnect the channels (the workers' exit
        //    signal), and wake anyone parked so the drain starts now.
        ingress.close();
        ingress.drop_senders();
        ingress.wake_all();
        let results: Vec<WorkerResult> = handles
            .into_iter()
            .map(|h| h.join().expect("serve worker panicked"))
            .collect();
        let mut report = merge_results(results, horizon_ms, workers);
        ingress.fold_sheds_into(&mut report.metrics);
        // 4. Conservation sweep: anything a racing handoff left in a
        //    slot after its owner exited is accounted as leftover, never
        //    silently dropped.
        for slot in intake.iter() {
            let mut slot = slot.lock().unwrap();
            report.leftover += slot.handoff.len();
            slot.handoff.clear();
            while slot.rx.try_recv().is_ok() {
                report.leftover += 1;
            }
        }
        report.metrics.record_rebalance(
            rebalance_stats.epochs.load(Ordering::Relaxed),
            ownership.migrations(),
            rebalance_stats.peak_imbalance_ms(),
        );
        report.metrics.record_replication(
            ownership.scale_ups(),
            ownership.scale_downs(),
            ownership.peak_replicas() as u64,
        );
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::PoissonGenerator;

    fn fixed_cfg(workers: usize, admission: Option<AdmissionConfig>)
                 -> ServeConfig {
        ServeConfig {
            workers,
            clock: ClockKind::Virtual,
            scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 2 },
            admission,
            ..Default::default()
        }
    }

    /// The builder accepts the defaults and rejects configurations off
    /// the request-id window grid, sampling rates that skew per-window
    /// trace density, and degenerate pool/controller knobs.
    #[test]
    fn serve_builder_validates() {
        assert!(ServeConfig::builder().build().is_ok());
        assert!(ServeConfig::builder().workers(0).build().is_err());
        assert!(ServeConfig::builder().queue_capacity(0).build().is_err());

        // Id base must sit on a multiple of the incarnation stride.
        assert!(ServeConfig::builder().request_id_base(123).build().is_err());
        assert!(ServeConfig::builder()
            .request_id_base(3 * NODE_ID_STRIDE + 2 * INCARNATION_ID_STRIDE)
            .build()
            .is_ok());

        // With windowed ids, 1/N sampling must divide the window stride.
        let sampled = |n: u64| TelemetryConfig {
            trace_sample: n,
            ..Default::default()
        };
        assert!(ServeConfig::builder()
            .request_id_base(NODE_ID_STRIDE)
            .telemetry(sampled(100))
            .build()
            .is_err());
        assert!(ServeConfig::builder()
            .request_id_base(NODE_ID_STRIDE)
            .telemetry(sampled(64))
            .build()
            .is_ok());
        // Base 0 (single-node): any rate is fine, ids are contiguous.
        assert!(ServeConfig::builder().telemetry(sampled(100)).build().is_ok());

        // Replication hysteresis must not be inverted.
        let bad = RebalanceConfig {
            scale_up_backlog_ms: 10.0,
            scale_down_backlog_ms: 50.0,
            ..Default::default()
        };
        assert!(ServeConfig::builder().rebalance(Some(bad)).build().is_err());
        let zero_epoch = RebalanceConfig { epoch_ms: 0, ..Default::default() };
        assert!(ServeConfig::builder()
            .rebalance(Some(zero_epoch))
            .build()
            .is_err());
        assert!(ServeConfig::builder().rebalance(None).build().is_ok());
    }

    /// Acceptance criterion: with one worker, a virtual clock, and no
    /// admission gate, the serving runtime reproduces the single-threaded
    /// engine BIT-FOR-BIT on the same trace seed — for a deterministic
    /// scheduler and for the learning SAC scheduler (which exercises the
    /// engine RNG, the predictor, and online training through the worker
    /// path).
    #[test]
    fn single_worker_virtual_matches_bare_engine_bit_for_bit() {
        for spec in [SchedulerSpec::Fixed { batch: 4, m_c: 2 },
                     SchedulerSpec::Sac { seed: 0x5AC }] {
            let mut gen = PoissonGenerator::new(120.0, 1234);
            let trace = gen.generate_horizon(20_000.0);
            let horizon = 20_000.0;

            // Bare single-threaded engine, driven directly.
            let mut engine = Engine::new(
                SimDispatcher::new(PlatformSim::xavier_nx(),
                                   crate::util::time::VirtualClock::new()),
                EngineConfig::default(),
            );
            engine.submit(trace.clone());
            let mut sched = spec.build(&EngineConfig::default(), 0);
            let slots = engine.run(sched.as_mut(), horizon);

            // The same trace through the serving runtime.
            let cfg = fixed_cfg(1, None);
            let cfg = ServeConfig { scheduler: spec, ..cfg };
            let report = run_trace(&cfg, trace, horizon);

            assert_eq!(report.workers, 1);
            assert_eq!(report.slots, slots, "slot counts diverged ({spec:?})");
            assert_eq!(report.metrics.outcomes(), engine.metrics.outcomes(),
                       "outcome streams diverged ({spec:?})");
            assert_eq!(report.leftover, engine.total_queued());
            assert_eq!(report.metrics.shed_total(), 0);
        }
    }

    /// Tentpole acceptance: deterministic id-keyed trace sampling.
    /// Tracing on must not perturb the virtual run (outcome stream, slot
    /// count, and shed totals stay identical to the untraced run), the
    /// sampled completed-id set is exactly `id % N == 0` over the
    /// outcomes, per-stage spans sum to end-to-end, and two traced runs
    /// agree trace-for-trace.
    #[test]
    fn tracing_samples_deterministically_and_leaves_outcomes_untouched() {
        use crate::telemetry::TraceVerdict;
        use std::collections::BTreeSet;
        let mut gen = PoissonGenerator::new(150.0, 99);
        let trace = gen.generate_horizon(15_000.0);
        let horizon = 40_000.0;
        let base_cfg = fixed_cfg(2, Some(AdmissionConfig::default()));
        let plain = run_trace(&base_cfg, trace.clone(), horizon);
        assert!(plain.telemetry.traces.is_empty(), "tracing on by default");

        let traced_cfg = ServeConfig {
            telemetry: TelemetryConfig {
                trace_sample: 4,
                ..Default::default()
            },
            ..base_cfg.clone()
        };
        let a = run_trace(&traced_cfg, trace.clone(), horizon);
        assert_eq!(a.metrics.outcomes(), plain.metrics.outcomes(),
                   "tracing perturbed the outcome stream");
        assert_eq!(a.slots, plain.slots);
        assert_eq!(a.metrics.shed_total(), plain.metrics.shed_total());
        let b = run_trace(&traced_cfg, trace, horizon);
        assert_eq!(a.telemetry.traces, b.telemetry.traces,
                   "traced runs diverged on the same seed");

        let completed: BTreeSet<u64> = a.telemetry.traces.iter()
            .filter(|t| t.verdict == TraceVerdict::Completed)
            .map(|t| t.id)
            .collect();
        let expected: BTreeSet<u64> = a.metrics.outcomes().iter()
            .filter(|o| o.id % 4 == 0)
            .map(|o| o.id)
            .collect();
        assert_eq!(completed, expected,
                   "sampled id set is not exactly id % 4 == 0");
        assert!(!completed.is_empty(), "sampled set empty — vacuous test");
        for t in &a.telemetry.traces {
            if t.verdict == TraceVerdict::Completed {
                assert!((t.span_sum_ms() - t.e2e_ms).abs() < 1e-6,
                        "spans don't sum to e2e for id {}", t.id);
                assert!(t.batch >= 1);
            }
        }
        assert!(!a.telemetry.actions.is_empty(), "no decisions recorded");
    }

    #[test]
    fn multi_worker_conserves_requests_and_is_deterministic() {
        let mut gen = PoissonGenerator::new(180.0, 7);
        let trace = gen.generate_horizon(20_000.0);
        let n = trace.len();
        let cfg = fixed_cfg(3, None);
        let a = run_trace(&cfg, trace.clone(), 60_000.0);
        assert_eq!(a.workers, 3);
        assert_eq!(a.metrics.outcomes().len() + a.leftover, n,
                   "requests lost or duplicated across the pool");
        assert!(a.metrics.completed() > n * 8 / 10,
                "pool kept up with only {}/{n}", a.metrics.completed());
        // Every model still gets served after sharding.
        for model in ModelId::all() {
            let offered = trace.iter().filter(|r| r.model == model).count();
            let served = a
                .metrics
                .outcomes()
                .iter()
                .filter(|o| o.model == model)
                .count();
            assert!(offered == 0 || served > 0, "{model:?} starved");
        }
        // Same seed ⇒ identical merged report (workers are deterministic
        // discrete-event sims; merge order is worker order).
        let b = run_trace(&cfg, trace, 60_000.0);
        assert_eq!(a.metrics.outcomes(), b.metrics.outcomes());
        assert_eq!(a.slots, b.slots);
    }

    /// Worker-count sweep: more workers must not break conservation, and
    /// the clamp keeps `workers > N_MODELS` meaningful.
    #[test]
    fn worker_count_clamps_and_conserves() {
        let mut gen = PoissonGenerator::new(90.0, 21);
        let trace = gen.generate_horizon(10_000.0);
        let n = trace.len();
        for workers in [2, 4, 16] {
            let cfg = fixed_cfg(workers, None);
            let report = run_trace(&cfg, trace.clone(), 40_000.0);
            assert_eq!(report.workers, workers.clamp(1, N_MODELS));
            assert_eq!(report.metrics.outcomes().len() + report.leftover, n);
        }
    }

    /// Acceptance criterion: admission control is load-bearing. At ≥5×
    /// the sustainable rate, the admission-controlled server keeps the
    /// accepted-request SLO violation rate strictly below the
    /// no-admission baseline while shedding the overload — and sheds are
    /// accounted separately, never silently folded into violations.
    #[test]
    fn admission_beats_no_admission_at_5x_overload() {
        // Sustainable bound for a yolo-only load on the fixed (8, 2)
        // config: one batch of 8 per isolated span, two instances —
        // ignore interference, so this over-estimates sustainability and
        // the 5× multiplier is conservative.
        let sim = PlatformSim::xavier_nx();
        let batch_ms = sim.latency.isolated_ms(ModelId::Yolo, 8);
        let sustainable_rps = 2.0 * 8.0 / (batch_ms / 1e3);
        let rps = 5.0 * sustainable_rps;
        let horizon = 20_000.0;
        let mk_trace = || {
            PoissonGenerator::new(rps, 99)
                .with_models(&[ModelId::Yolo])
                .generate_horizon(horizon)
        };
        let n = mk_trace().len();
        let sched = SchedulerSpec::Fixed { batch: 8, m_c: 2 };

        let base_cfg = ServeConfig { scheduler: sched, ..fixed_cfg(1, None) };
        let base = run_trace(&base_cfg, mk_trace(), horizon);

        let adm_cfg = ServeConfig {
            scheduler: sched,
            ..fixed_cfg(1, Some(AdmissionConfig::default()))
        };
        let adm = run_trace(&adm_cfg, mk_trace(), horizon);

        // The overload is real: the baseline drowns.
        assert!(base.metrics.violation_rate() > 0.5,
                "baseline not overloaded: viol {:.3} at {rps:.0} rps",
                base.metrics.violation_rate());
        assert_eq!(base.metrics.shed_total(), 0);

        // Admission sheds the overload...
        assert!(adm.metrics.shed_total() > 0, "nothing shed at 5× overload");
        // ...keeps accepted-request violations strictly below baseline...
        assert!(adm.metrics.violation_rate() < base.metrics.violation_rate(),
                "admission did not help: {:.3} vs baseline {:.3}",
                adm.metrics.violation_rate(),
                base.metrics.violation_rate());
        // ...and accounts sheds separately (conservation incl. sheds).
        assert_eq!(adm.metrics.outcomes().len()
                       + adm.metrics.shed_total() as usize
                       + adm.leftover,
                   n);
        assert_eq!(adm.metrics.shed_by_reason(ShedReason::DeadlineUnmeetable),
                   adm.metrics.shed_total(),
                   "trace-mode sheds must all be deadline-based");
    }

    /// The migration policy, exercised without threads: triggers,
    /// hot-model isolation, spread reduction, hysteresis, thrash
    /// rejection.
    #[test]
    fn plan_migration_isolates_hot_models_and_balances_spread() {
        let owner = [0, 1, 0, 1, 0, 1];
        let all_active = [true; N_MODELS];
        // Hot model 0 dominates worker 0; siblings 2 and 4 ride along.
        let backlog = [400.0, 0.0, 12.0, 0.0, 30.0, 5.0];
        // Smallest QUEUED sibling (model 2) peels off to the cold worker.
        assert_eq!(
            migrate_plan(&backlog, &all_active, &owner, 2, 1.5, 25.0),
            Some((2, 1))
        );
        // A sibling holding backlog outranks an idle-but-profiled one:
        // moving the idle sibling would relieve nothing this epoch.
        let idle_first = [400.0, 0.0, 0.0, 0.0, 30.0, 0.0];
        assert_eq!(
            migrate_plan(&idle_first, &all_active, &owner, 2, 1.5, 25.0),
            Some((4, 1))
        );
        // A lone hot model is already isolated: nothing to move.
        let lone = [400.0, 3.0, 0.0, 1.0, 0.0, 2.0];
        let active = [true, true, false, true, false, true];
        assert_eq!(migrate_plan(&lone, &active, &owner, 2, 1.5, 25.0),
                   None);
        // Balanced-ish backlogs below the trigger: no churn.
        let calm = [30.0, 25.0, 20.0, 28.0, 22.0, 26.0];
        assert_eq!(migrate_plan(&calm, &all_active, &owner, 2, 1.5, 25.0),
                   None);
        // No dominant model: the spread-reducing move wins (moving one
        // 100 ms model from the 300 ms worker to the empty one).
        let owner3 = [0, 0, 0, 1, 1, 1];
        let flat = [100.0, 100.0, 100.0, 0.0, 0.0, 0.0];
        let got = migrate_plan(&flat, &all_active, &owner3, 2, 1.5, 25.0);
        let (m, to) = got.expect("spread reduction should fire");
        assert!(m < 3, "must move one of worker 0's models, got {m}");
        assert_eq!(to, 1);
        // Dominance with only two live models: the non-dominant one is
        // peeled off (inactive zero-traffic siblings are never moved —
        // relocating them changes nothing).
        let mirror = [0.0, 0.0, 90.0, 0.0, 40.0, 0.0];
        let two_live = [false, false, true, false, true, false];
        assert_eq!(
            migrate_plan(&mirror, &two_live, &owner, 2, 1.5, 25.0),
            Some((4, 1))
        );
        // Single worker: never migrates.
        assert_eq!(migrate_plan(&backlog, &all_active, &[0; 6], 1, 1.5,
                                  25.0),
                   None);
    }

    /// Test shim for the migration policy: owner-attributed worker
    /// totals, which are exactly the lane sums whenever every model has
    /// a single owner (true for all these cases).
    fn migrate_plan(backlog: &[f64; N_MODELS], active: &[bool; N_MODELS],
                    owner: &[usize; N_MODELS], workers: usize, ratio: f64,
                    min_gap_ms: f64) -> Option<(usize, usize)> {
        let mut totals = vec![0.0f64; workers.max(1)];
        for m in 0..N_MODELS {
            totals[owner[m].min(workers.max(1) - 1)] += backlog[m];
        }
        plan_migration(backlog, active, owner, &totals, ratio, min_gap_ms)
    }

    /// Test shim: aggregate the row/column totals exactly the way the
    /// controller's tick does before calling the policy.
    fn scaling(backlog: &[[f64; MAX_POOL]; N_MODELS],
               mask: &[u64; N_MODELS], workers: usize, cap: usize,
               up_ms: f64, down_ms: f64) -> Option<ScaleAction> {
        let w_n = workers.min(MAX_POOL);
        let mut model_total = [0.0f64; N_MODELS];
        let mut worker_total = [0.0f64; MAX_POOL];
        for (m, per_worker) in backlog.iter().enumerate() {
            for (w, b) in per_worker[..w_n].iter().enumerate() {
                model_total[m] += b;
                worker_total[w] += b;
            }
        }
        plan_scaling(backlog, &model_total, &worker_total[..w_n], mask,
                     workers, cap, up_ms, down_ms)
    }

    /// The scaling policy, exercised without threads: scale-up triggers,
    /// replica-headroom and pool caps, least-loaded targeting, scale-down
    /// hysteresis, last-drainer protection (by construction: only
    /// replicated models scale down).
    #[test]
    fn plan_scaling_grows_hot_models_and_collapses_idle_sets() {
        let one = |w: usize| 1u64 << w;
        let mut backlog = [[0.0f64; MAX_POOL]; N_MODELS];
        let mut mask = [0u64; N_MODELS];
        for (m, msk) in mask.iter_mut().enumerate() {
            *msk = one(m % 3);
        }
        // Model 0's backlog (all on worker 0) blows past the trigger;
        // worker 2 is the least-loaded non-replica.
        backlog[0][0] = 400.0;
        backlog[1][1] = 80.0;
        backlog[2][2] = 20.0;
        assert_eq!(
            scaling(&backlog, &mask, 3, MAX_POOL, 250.0, 30.0),
            Some(ScaleAction::Up { model: 0, worker: 2 })
        );
        // Two hot models: the hotter one wins the epoch's action.
        backlog[1][1] = 500.0;
        assert_eq!(
            scaling(&backlog, &mask, 3, MAX_POOL, 250.0, 30.0),
            Some(ScaleAction::Up { model: 1, worker: 2 })
        );
        backlog[1][1] = 80.0;
        // A model already at the replica cap cannot widen further.
        mask[0] = one(0) | one(1);
        assert_eq!(scaling(&backlog, &mask, 3, 2, 250.0, 30.0), None);
        // With headroom it still grows, onto the remaining worker.
        assert_eq!(
            scaling(&backlog, &mask, 3, 3, 250.0, 30.0),
            Some(ScaleAction::Up { model: 0, worker: 2 })
        );
        // In the hysteresis band (below up, above down): no action.
        backlog[0][0] = 100.0;
        backlog[0][1] = 60.0;
        assert_eq!(scaling(&backlog, &mask, 3, 3, 250.0, 30.0), None);
        // Subsided: the replica holding the least of the model goes.
        backlog[0][0] = 12.0;
        backlog[0][1] = 2.0;
        assert_eq!(
            scaling(&backlog, &mask, 3, 3, 250.0, 30.0),
            Some(ScaleAction::Down { model: 0, worker: 1 })
        );
        // Single-worker pools and max_replicas == 1 never scale.
        assert_eq!(scaling(&backlog, &mask, 1, 3, 250.0, 30.0), None);
        backlog[0][0] = 400.0;
        backlog[0][1] = 0.0;
        mask[0] = one(0);
        assert_eq!(scaling(&backlog, &mask, 3, 1, 250.0, 30.0), None);
    }

    /// Migration-policy edge cases the original unit test skipped:
    /// single-worker pools, an empty-gauge epoch (all backlog zero), and
    /// ALL backlog concentrated in one model.
    #[test]
    fn plan_migration_edge_cases() {
        let owner = [0, 1, 0, 1, 0, 1];
        let all_active = [true; N_MODELS];
        let hot = [500.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        // Single-worker pool: a no-op regardless of pressure.
        assert_eq!(migrate_plan(&hot, &all_active, &[0; 6], 1, 1.5, 25.0),
                   None);
        // Empty-gauge epoch (startup, or fully drained): zero totals
        // never clear the ratio+gap trigger, so the controller idles
        // instead of shuffling idle models.
        let empty = [0.0; N_MODELS];
        assert_eq!(migrate_plan(&empty, &all_active, &owner, 2, 1.5, 25.0),
                   None);
        assert_eq!(migrate_plan(&empty, &[false; N_MODELS], &owner, 2,
                                  1.5, 25.0),
                   None);
        // All backlog on ONE model whose siblings never saw traffic:
        // nothing to peel (moving inactive models changes nothing), and
        // moving the hot model itself would only relocate the hotspot.
        let one_live = [false, true, false, false, false, false];
        let solo = [0.0, 700.0, 0.0, 0.0, 0.0, 0.0];
        assert_eq!(migrate_plan(&solo, &one_live, &owner, 2, 1.5, 25.0),
                   None);
        // Same concentration but with an idle-yet-active sibling riding
        // the hot worker: the sibling is peeled off to decouple its
        // round spans (hot-model isolation, not hot-model motion).
        let with_sibling = [0.0, 700.0, 0.0, 1.0, 0.0, 0.0];
        assert_eq!(migrate_plan(&with_sibling, &all_active, &owner, 2,
                                  1.5, 25.0),
                   Some((3, 0)));
    }

    /// Post-scale-down migration cooldown (ROADMAP PR 4 follow-up): the
    /// epoch right after a model's replica set collapses, its pool-wide
    /// backlog transiently reads as all-on-owner — the controller must
    /// not let migration planning act on that model until the flush
    /// lands. Drives the Rebalancer's tick directly (no threads).
    #[test]
    fn scale_down_cooldown_pins_migration_for_one_epoch() {
        let gauges = Arc::new(SharedGauges::new());
        let ownership = Arc::new(OwnershipTable::new_static(2));
        let mut reb = Rebalancer {
            cfg: RebalanceConfig {
                epoch_ms: 1_000,
                ratio: 1.2,
                min_gap_ms: 10.0,
                max_replicas: 2,
                // Keep the scale-UP arm out of the way: this test is
                // about what happens after a scale-DOWN.
                scale_up_backlog_ms: 1e9,
                scale_down_backlog_ms: 30.0,
            },
            gauges: gauges.clone(),
            ownership: ownership.clone(),
            worker_events: vec![Arc::new(WakeEvent::new()),
                                Arc::new(WakeEvent::new())],
            isolated_ref_ms: [10.0; N_MODELS],
            ref_batch: 8,
            stop: Arc::new(AtomicBool::new(false)),
            wake: Arc::new(WakeEvent::new()),
            stats: Arc::new(RebalanceStats::default()),
            migration_cooldown: [0; N_MODELS],
        };
        // Yolo replicated on both workers with a subsided backlog
        // (10 + 5 = 15 ms < the 30 ms scale-down trigger).
        assert!(ownership.add_replica(ModelId::Yolo, 1).is_some());
        gauges.publish(ModelId::Yolo, 0, 8, f64::NAN);
        gauges.publish(ModelId::Yolo, 1, 4, f64::NAN);
        reb.tick();
        assert_eq!(ownership.replica_count(ModelId::Yolo), 1,
                   "subsided set should have collapsed");
        assert_eq!(ownership.scale_downs(), 1);
        assert_eq!(ownership.owner(ModelId::Yolo), 0);

        // The very next epoch, yolo's whole backlog (the ex-replica's
        // share included) reads as on worker 0, alongside sibling res —
        // a spread the planner would normally fix by moving yolo. The
        // cooldown pins yolo, and with only one other active model on
        // the hot worker there is nothing to decouple: no migration.
        gauges.publish(ModelId::Yolo, 0, 80, 10.0); // 100 ms backlog
        gauges.publish(ModelId::Yolo, 1, 0, f64::NAN);
        gauges.publish(ModelId::Res, 0, 80, 10.0); // 100 ms backlog
        reb.tick();
        assert_eq!(ownership.migrations(), 0,
                   "migrated during the post-scale-down cooldown");
        assert_eq!(ownership.owner(ModelId::Yolo), 0);

        // One epoch later the cooldown has expired; the same gauges now
        // trigger hot-model isolation (res dominates half the worker's
        // backlog) and yolo is migratable again.
        reb.tick();
        assert_eq!(ownership.migrations(), 1,
                   "cooldown must expire after one epoch");
        assert_eq!(ownership.owner(ModelId::Yolo), 1);
    }

    /// Tentpole conservation pin: under aggressive rebalancing epochs and
    /// a hot-model skew, ownership handoffs happen mid-stream and every
    /// submitted request is still accounted exactly once — completed,
    /// shed, or leftover; never lost, never double-served.
    #[test]
    fn migration_conserves_requests_under_skew() {
        let cfg = ServeConfig {
            workers: 2,
            clock: ClockKind::Wall,
            scheduler: SchedulerSpec::Fixed { batch: 2, m_c: 2 },
            admission: None,
            queue_capacity: 1024,
            rebalance: Some(RebalanceConfig {
                epoch_ms: 15,
                ratio: 1.1,
                min_gap_ms: 5.0,
                // This test pins the MIGRATION mechanism; replication is
                // covered by its own conservation/stress tests.
                max_replicas: 1,
                ..Default::default()
            }),
            ..Default::default()
        };
        let server = Server::start(&cfg, None);
        // ~70 % yolo (the hot model, statically on worker 0), the rest on
        // its shard-siblings res/inc so their backlog rides the same
        // worker until the controller peels them off.
        let mut attempts = 0u64;
        let mut accepted = std::collections::HashSet::new();
        for i in 0..60u64 {
            let model = match i % 10 {
                0..=6 => ModelId::Yolo,
                7 | 8 => ModelId::Res,
                _ => ModelId::Inc,
            };
            let slo = crate::workload::models::ModelSpec::get(model).slo_ms;
            attempts += 1;
            if let Ok(id) = server.submit(model, slo, 0.5) {
                assert!(accepted.insert(id), "ingress reused a request id");
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let report = server.shutdown();
        // Every attempt is accounted exactly once.
        assert_eq!(report.metrics.outcomes().len() as u64
                       + report.metrics.shed_total()
                       + report.leftover as u64,
                   attempts);
        // No double service: outcome ids are unique and were accepted.
        let mut seen = std::collections::HashSet::new();
        for o in report.metrics.outcomes() {
            assert!(seen.insert(o.id), "request {} served twice", o.id);
            assert!(accepted.contains(&o.id));
        }
        // The skew actually forced ownership handoffs.
        assert!(report.metrics.migrations() > 0,
                "rebalance controller never migrated under hot-model skew");
        assert!(report.metrics.rebalance_epochs() > 0);
        assert!(report.metrics.peak_imbalance_ms() > 0.0);
    }

    /// Live wall-clock server: parallel workers, bounded ingress, drain
    /// protocol, completion streaming. Short horizon to stay CI-friendly.
    #[test]
    fn live_server_serves_drains_and_streams_completions() {
        let cfg = ServeConfig {
            workers: 2,
            scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 1 },
            admission: None,
            queue_capacity: 64,
            ..Default::default()
        };
        let (tx, rx) = std::sync::mpsc::channel();
        let server = Server::start(&cfg, Some(tx));
        let attempts = 48u64;
        for i in 0..attempts {
            let model = if i % 2 == 0 { ModelId::Mob } else { ModelId::Bert };
            let slo = crate::workload::models::ModelSpec::get(model).slo_ms;
            // Ok ⇒ will surface as an outcome; Err ⇒ counted as a shed.
            let _ = server.submit(model, slo, 0.5);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let report = server.shutdown();
        // Drain protocol flushed everything that was accepted, and every
        // attempt is accounted exactly once (outcome XOR shed).
        assert_eq!(report.leftover, 0, "drain left requests queued");
        assert_eq!(report.metrics.outcomes().len() as u64
                       + report.metrics.shed_total(),
                   attempts);
        assert!(report.metrics.completed() > 0);
        assert!(report.slots > 0);
        assert!(report.horizon_ms > 0.0);
        // Every request-terminal event was streamed: one Completed per
        // outcome (admission is off, so no Shed events).
        let events: Vec<_> = rx.try_iter().collect();
        assert_eq!(events.len(), report.metrics.outcomes().len());
        assert!(events.iter().all(|e| matches!(e, ServeEvent::Completed(_))));
        // A shut-down server sheds at the door with a typed reason.
        // (submit would need the server; it is consumed — covered by the
        // ingress unit tests instead.)
    }
}
