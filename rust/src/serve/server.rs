//! The concurrent serving runtime: ingress + admission + worker pool +
//! drain protocol, composed behind two entry points:
//!
//! * [`run_trace`] — serve a pre-generated arrival trace across the
//!   worker pool (virtual or wall clock). With `workers == 1`, a virtual
//!   clock, and no admission, this reproduces the single-threaded
//!   [`Engine`] run bit-for-bit (enforced by the seed-equivalence test
//!   below) — the serving layer adds concurrency without forking the
//!   engine's semantics.
//! * [`Server::start`] / [`Server::shutdown`] — a live wall-clock server:
//!   submit requests from any thread through the bounded ingress, workers
//!   drain their shards in parallel, shutdown stops intake, flushes every
//!   queue, joins the workers, and emits the final merged [`Metrics`].

use super::admission::AdmissionConfig;
use super::ingress::{Ingress, SharedGauges, WakeEvent};
use super::worker::{LiveWorker, ServeEvent, WorkerResult, run_trace_worker};
use crate::coordinator::baselines::{DeepRtScheduler, FixedScheduler};
use crate::coordinator::sac_sched;
use crate::coordinator::{Engine, EngineConfig, Scheduler};
use crate::metrics::{Metrics, ShedReason};
use crate::platform::{PlatformSim, PlatformSpec};
use crate::runtime::executor::SimDispatcher;
use crate::util::rng::Pcg32;
use crate::util::time::{Clock, ClockSource, VirtualClock, WallClock};
use crate::workload::models::{ModelId, N_MODELS};
use crate::workload::request::Request;
use std::sync::mpsc::sync_channel;
use std::sync::Arc;

/// Which time source the workers' engines run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClockKind {
    /// Discrete-event time per worker: deterministic, thousands× real
    /// time. Trace mode only.
    Virtual,
    /// One shared wall clock: dispatch spans actually elapse, workers
    /// genuinely overlap.
    Wall,
}

/// How each worker builds its scheduler (copyable so the spec crosses
/// into worker threads; construction happens on the worker's thread).
#[derive(Clone, Copy, Debug)]
pub enum SchedulerSpec {
    Fixed { batch: usize, m_c: usize },
    DeepRt,
    /// Learning SAC scheduler, trained online. Worker `i` derives its
    /// stream from `seed` (worker 0 uses `seed` itself, so single-worker
    /// runs match a standalone `sac_sched::sac(space, seeded(seed))`).
    Sac { seed: u64 },
}

impl SchedulerSpec {
    pub fn build(&self, cfg: &EngineConfig, worker: usize)
                 -> Box<dyn Scheduler> {
        match *self {
            SchedulerSpec::Fixed { batch, m_c } => {
                Box::new(FixedScheduler { batch, m_c })
            }
            SchedulerSpec::DeepRt => Box::new(DeepRtScheduler::default()),
            SchedulerSpec::Sac { seed } => {
                let mut rng = Pcg32::seeded(
                    seed.wrapping_add(worker as u64 * 0x9E37_79B9_97F4_A7C5),
                );
                Box::new(sac_sched::sac(cfg.action_space.clone(), &mut rng))
            }
        }
    }
}

/// Serving-runtime configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads (clamped to [1, N_MODELS]; each worker owns the
    /// models `m` with `m % workers == i`).
    pub workers: usize,
    pub clock: ClockKind,
    pub platform: PlatformSpec,
    /// Per-worker engine configuration (worker `i` perturbs the seed by
    /// `i`; worker 0 keeps it verbatim for seed equivalence).
    pub engine: EngineConfig,
    pub scheduler: SchedulerSpec,
    /// `None` disables admission control (every request is queued).
    pub admission: Option<AdmissionConfig>,
    /// Per-model ingress channel bound (live mode backpressure).
    pub queue_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 4,
            clock: ClockKind::Virtual,
            platform: PlatformSpec::xavier_nx(),
            engine: EngineConfig::default(),
            scheduler: SchedulerSpec::Sac { seed: 0x5AC },
            admission: Some(AdmissionConfig::default()),
            queue_capacity: 256,
        }
    }
}

impl ServeConfig {
    fn worker_count(&self) -> usize {
        self.workers.clamp(1, N_MODELS)
    }

    /// Worker index owning `model`.
    fn owner(&self, model: ModelId) -> usize {
        model as usize % self.worker_count()
    }

    fn build_engine(&self, worker: usize, clock: ClockSource)
                    -> Engine<SimDispatcher> {
        let mut cfg = self.engine.clone();
        cfg.seed ^= worker as u64; // worker 0: unchanged (seed equivalence)
        cfg.max_total_instances = self.platform.max_instances;
        let sim = PlatformSim::new(self.platform.clone());
        Engine::new(SimDispatcher::with_clock(sim, clock), cfg)
    }

    fn isolated_ref_table(&self) -> [f64; N_MODELS] {
        let ref_batch =
            self.admission.map(|a| a.ref_batch).unwrap_or(8).max(1);
        let sim = PlatformSim::new(self.platform.clone());
        std::array::from_fn(|i| {
            sim.latency.isolated_ms(ModelId::from_index(i), ref_batch)
        })
    }
}

/// Final report of a serving run: merged worker metrics + pool counters.
pub struct ServeReport {
    pub metrics: Metrics,
    /// Serving horizon (virtual or wall, matching the run's clock), ms.
    pub horizon_ms: f64,
    pub workers: usize,
    /// Total per-model scheduling slots across the pool.
    pub slots: u64,
    /// Requests still queued when the horizon expired (trace mode; the
    /// live drain protocol flushes to zero).
    pub leftover: usize,
}

impl ServeReport {
    pub fn achieved_rps(&self) -> f64 {
        self.metrics.completed() as f64 / (self.horizon_ms / 1e3).max(1e-9)
    }

    /// Human-readable summary (the `bcedge bench-serve` output).
    pub fn print(&self) {
        let m = &self.metrics;
        println!(
            "workers {} | {} slots | horizon {:.1}s",
            self.workers,
            self.slots,
            self.horizon_ms / 1e3
        );
        println!(
            "achieved {:.1} rps | e2e p50 {:.2} ms p99 {:.2} ms | \
             SLO violations {:.2}% | shed {:.2}%",
            self.achieved_rps(),
            m.latency_percentile(0.5),
            m.latency_percentile(0.99),
            100.0 * m.violation_rate(),
            100.0 * m.shed_rate(),
        );
        if m.shed_total() > 0 {
            let by: Vec<String> = ShedReason::all()
                .into_iter()
                .filter(|r| m.shed_by_reason(*r) > 0)
                .map(|r| format!("{}={}", r, m.shed_by_reason(r)))
                .collect();
            println!("sheds: {} ({})", m.shed_total(), by.join(", "));
        }
        if self.leftover > 0 {
            println!("leftover in queue at horizon: {}", self.leftover);
        }
    }
}

fn merge_results(results: Vec<WorkerResult>, horizon_ms: f64,
                 workers: usize) -> ServeReport {
    let mut metrics = Metrics::new();
    let mut slots = 0;
    let mut leftover = 0;
    for r in results {
        metrics.merge(&r.metrics);
        slots += r.slots;
        leftover += r.leftover;
    }
    ServeReport { metrics, horizon_ms, workers, slots, leftover }
}

/// Serve a pre-generated trace across the worker pool and report.
/// Requests must be sorted by arrival time (generator order).
pub fn run_trace(cfg: &ServeConfig, requests: Vec<Request>,
                 horizon_ms: f64) -> ServeReport {
    let workers = cfg.worker_count();
    let mut shards: Vec<Vec<Request>> = (0..workers).map(|_| Vec::new()).collect();
    for r in requests {
        shards[cfg.owner(r.model)].push(r);
    }
    let wall = WallClock::new(); // shared origin if the run is wall-clocked
    let results: Vec<WorkerResult> = std::thread::scope(|s| {
        let handles: Vec<_> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                let wall = wall.clone();
                s.spawn(move || {
                    let clock = match cfg.clock {
                        ClockKind::Virtual => {
                            ClockSource::Virtual(VirtualClock::new())
                        }
                        ClockKind::Wall => ClockSource::Wall(wall),
                    };
                    let mut engine = cfg.build_engine(i, clock);
                    if let Some(adm) = cfg.admission {
                        engine.set_ingress_gate(Some(Box::new(
                            super::admission::AdmissionGate::new(adm),
                        )));
                    }
                    let mut sched = cfg.scheduler.build(&cfg.engine, i);
                    run_trace_worker(engine, sched.as_mut(), shard, horizon_ms)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("serve worker panicked"))
            .collect()
    });
    merge_results(results, horizon_ms, workers)
}

/// A running live server (wall clock).
pub struct Server {
    ingress: Ingress,
    handles: Vec<std::thread::JoinHandle<WorkerResult>>,
    clock: WallClock,
    workers: usize,
}

impl Server {
    /// Spawn the worker pool and open the ingress. Live serving is
    /// wall-clock by definition (arrivals are stamped with real time), so
    /// `cfg.clock` is ignored here. `events`, when given, receives every
    /// request-terminal event — completion or engine-gate shed — for
    /// closed-loop load generation.
    pub fn start(cfg: &ServeConfig,
                 events_tx: Option<std::sync::mpsc::Sender<ServeEvent>>)
                 -> Server {
        let workers = cfg.worker_count();
        let clock = WallClock::new();
        let gauges = Arc::new(SharedGauges::new());
        let events: Vec<Arc<WakeEvent>> =
            (0..workers).map(|_| Arc::new(WakeEvent::new())).collect();
        // Per-model bounded channels; receivers grouped by owning worker.
        let mut senders = Vec::with_capacity(N_MODELS);
        let mut per_worker: Vec<(Vec<ModelId>, Vec<_>)> =
            (0..workers).map(|_| (Vec::new(), Vec::new())).collect();
        for model in ModelId::all() {
            let (tx, rx) = sync_channel::<Request>(cfg.queue_capacity.max(1));
            senders.push(tx);
            let owner = cfg.owner(model);
            per_worker[owner].0.push(model);
            per_worker[owner].1.push(rx);
        }
        let model_events: Vec<Arc<WakeEvent>> = ModelId::all()
            .into_iter()
            .map(|m| events[cfg.owner(m)].clone())
            .collect();
        let handles = per_worker
            .into_iter()
            .enumerate()
            .map(|(i, (models, receivers))| {
                let engine = cfg.build_engine(
                    i,
                    ClockSource::Wall(clock.clone()),
                );
                let worker = LiveWorker {
                    engine,
                    models,
                    receivers,
                    event: events[i].clone(),
                    gauges: gauges.clone(),
                    admission: cfg.admission,
                    events_tx: events_tx.clone(),
                };
                let spec = cfg.scheduler;
                let engine_cfg = cfg.engine.clone();
                std::thread::Builder::new()
                    .name(format!("bcedge-serve-{i}"))
                    .spawn(move || {
                        let mut sched = spec.build(&engine_cfg, i);
                        worker.run(sched.as_mut())
                    })
                    .expect("spawn serve worker")
            })
            .collect();
        let ingress = Ingress::new(senders, model_events, gauges,
                                   cfg.admission, cfg.isolated_ref_table());
        Server { ingress, handles, clock, workers }
    }

    /// Milliseconds since the server started (the arrival timebase).
    pub fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }

    /// Submit a request arriving now. Typed rejection when admission
    /// control or backpressure refuses it.
    pub fn submit(&self, model: ModelId, slo_ms: f64, transmission_ms: f64)
                  -> Result<u64, ShedReason> {
        self.ingress
            .submit(model, slo_ms, transmission_ms, self.clock.now_ms())
    }

    /// Drain and stop: close intake, flush every queue, join the
    /// workers, and merge their metrics (ingress-side sheds included).
    pub fn shutdown(self) -> ServeReport {
        let Server { mut ingress, handles, clock, workers } = self;
        let horizon_ms = clock.now_ms();
        // Stop intake, disconnect the channels (the workers' exit
        // signal), and wake anyone parked so the drain starts now.
        ingress.close();
        ingress.drop_senders();
        ingress.wake_all();
        let results: Vec<WorkerResult> = handles
            .into_iter()
            .map(|h| h.join().expect("serve worker panicked"))
            .collect();
        let mut report = merge_results(results, horizon_ms, workers);
        ingress.fold_sheds_into(&mut report.metrics);
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::PoissonGenerator;

    fn fixed_cfg(workers: usize, admission: Option<AdmissionConfig>)
                 -> ServeConfig {
        ServeConfig {
            workers,
            clock: ClockKind::Virtual,
            scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 2 },
            admission,
            ..Default::default()
        }
    }

    /// Acceptance criterion: with one worker, a virtual clock, and no
    /// admission gate, the serving runtime reproduces the single-threaded
    /// engine BIT-FOR-BIT on the same trace seed — for a deterministic
    /// scheduler and for the learning SAC scheduler (which exercises the
    /// engine RNG, the predictor, and online training through the worker
    /// path).
    #[test]
    fn single_worker_virtual_matches_bare_engine_bit_for_bit() {
        for spec in [SchedulerSpec::Fixed { batch: 4, m_c: 2 },
                     SchedulerSpec::Sac { seed: 0x5AC }] {
            let mut gen = PoissonGenerator::new(120.0, 1234);
            let trace = gen.generate_horizon(20_000.0);
            let horizon = 20_000.0;

            // Bare single-threaded engine, driven directly.
            let mut engine = Engine::new(
                SimDispatcher::new(PlatformSim::xavier_nx(),
                                   crate::util::time::VirtualClock::new()),
                EngineConfig::default(),
            );
            engine.submit(trace.clone());
            let mut sched = spec.build(&EngineConfig::default(), 0);
            let slots = engine.run(sched.as_mut(), horizon);

            // The same trace through the serving runtime.
            let cfg = fixed_cfg(1, None);
            let cfg = ServeConfig { scheduler: spec, ..cfg };
            let report = run_trace(&cfg, trace, horizon);

            assert_eq!(report.workers, 1);
            assert_eq!(report.slots, slots, "slot counts diverged ({spec:?})");
            assert_eq!(report.metrics.outcomes(), engine.metrics.outcomes(),
                       "outcome streams diverged ({spec:?})");
            assert_eq!(report.leftover, engine.total_queued());
            assert_eq!(report.metrics.shed_total(), 0);
        }
    }

    #[test]
    fn multi_worker_conserves_requests_and_is_deterministic() {
        let mut gen = PoissonGenerator::new(180.0, 7);
        let trace = gen.generate_horizon(20_000.0);
        let n = trace.len();
        let cfg = fixed_cfg(3, None);
        let a = run_trace(&cfg, trace.clone(), 60_000.0);
        assert_eq!(a.workers, 3);
        assert_eq!(a.metrics.outcomes().len() + a.leftover, n,
                   "requests lost or duplicated across the pool");
        assert!(a.metrics.completed() > n * 8 / 10,
                "pool kept up with only {}/{n}", a.metrics.completed());
        // Every model still gets served after sharding.
        for model in ModelId::all() {
            let offered = trace.iter().filter(|r| r.model == model).count();
            let served = a
                .metrics
                .outcomes()
                .iter()
                .filter(|o| o.model == model)
                .count();
            assert!(offered == 0 || served > 0, "{model:?} starved");
        }
        // Same seed ⇒ identical merged report (workers are deterministic
        // discrete-event sims; merge order is worker order).
        let b = run_trace(&cfg, trace, 60_000.0);
        assert_eq!(a.metrics.outcomes(), b.metrics.outcomes());
        assert_eq!(a.slots, b.slots);
    }

    /// Worker-count sweep: more workers must not break conservation, and
    /// the clamp keeps `workers > N_MODELS` meaningful.
    #[test]
    fn worker_count_clamps_and_conserves() {
        let mut gen = PoissonGenerator::new(90.0, 21);
        let trace = gen.generate_horizon(10_000.0);
        let n = trace.len();
        for workers in [2, 4, 16] {
            let cfg = fixed_cfg(workers, None);
            let report = run_trace(&cfg, trace.clone(), 40_000.0);
            assert_eq!(report.workers, workers.clamp(1, N_MODELS));
            assert_eq!(report.metrics.outcomes().len() + report.leftover, n);
        }
    }

    /// Acceptance criterion: admission control is load-bearing. At ≥5×
    /// the sustainable rate, the admission-controlled server keeps the
    /// accepted-request SLO violation rate strictly below the
    /// no-admission baseline while shedding the overload — and sheds are
    /// accounted separately, never silently folded into violations.
    #[test]
    fn admission_beats_no_admission_at_5x_overload() {
        // Sustainable bound for a yolo-only load on the fixed (8, 2)
        // config: one batch of 8 per isolated span, two instances —
        // ignore interference, so this over-estimates sustainability and
        // the 5× multiplier is conservative.
        let sim = PlatformSim::xavier_nx();
        let batch_ms = sim.latency.isolated_ms(ModelId::Yolo, 8);
        let sustainable_rps = 2.0 * 8.0 / (batch_ms / 1e3);
        let rps = 5.0 * sustainable_rps;
        let horizon = 20_000.0;
        let mk_trace = || {
            PoissonGenerator::new(rps, 99)
                .with_models(&[ModelId::Yolo])
                .generate_horizon(horizon)
        };
        let n = mk_trace().len();
        let sched = SchedulerSpec::Fixed { batch: 8, m_c: 2 };

        let base_cfg = ServeConfig { scheduler: sched, ..fixed_cfg(1, None) };
        let base = run_trace(&base_cfg, mk_trace(), horizon);

        let adm_cfg = ServeConfig {
            scheduler: sched,
            ..fixed_cfg(1, Some(AdmissionConfig::default()))
        };
        let adm = run_trace(&adm_cfg, mk_trace(), horizon);

        // The overload is real: the baseline drowns.
        assert!(base.metrics.violation_rate() > 0.5,
                "baseline not overloaded: viol {:.3} at {rps:.0} rps",
                base.metrics.violation_rate());
        assert_eq!(base.metrics.shed_total(), 0);

        // Admission sheds the overload...
        assert!(adm.metrics.shed_total() > 0, "nothing shed at 5× overload");
        // ...keeps accepted-request violations strictly below baseline...
        assert!(adm.metrics.violation_rate() < base.metrics.violation_rate(),
                "admission did not help: {:.3} vs baseline {:.3}",
                adm.metrics.violation_rate(),
                base.metrics.violation_rate());
        // ...and accounts sheds separately (conservation incl. sheds).
        assert_eq!(adm.metrics.outcomes().len()
                       + adm.metrics.shed_total() as usize
                       + adm.leftover,
                   n);
        assert_eq!(adm.metrics.shed_by_reason(ShedReason::DeadlineUnmeetable),
                   adm.metrics.shed_total(),
                   "trace-mode sheds must all be deadline-based");
    }

    /// Live wall-clock server: parallel workers, bounded ingress, drain
    /// protocol, completion streaming. Short horizon to stay CI-friendly.
    #[test]
    fn live_server_serves_drains_and_streams_completions() {
        let cfg = ServeConfig {
            workers: 2,
            scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 1 },
            admission: None,
            queue_capacity: 64,
            ..Default::default()
        };
        let (tx, rx) = std::sync::mpsc::channel();
        let server = Server::start(&cfg, Some(tx));
        let attempts = 48u64;
        for i in 0..attempts {
            let model = if i % 2 == 0 { ModelId::Mob } else { ModelId::Bert };
            let slo = crate::workload::models::ModelSpec::get(model).slo_ms;
            // Ok ⇒ will surface as an outcome; Err ⇒ counted as a shed.
            let _ = server.submit(model, slo, 0.5);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let report = server.shutdown();
        // Drain protocol flushed everything that was accepted, and every
        // attempt is accounted exactly once (outcome XOR shed).
        assert_eq!(report.leftover, 0, "drain left requests queued");
        assert_eq!(report.metrics.outcomes().len() as u64
                       + report.metrics.shed_total(),
                   attempts);
        assert!(report.metrics.completed() > 0);
        assert!(report.slots > 0);
        assert!(report.horizon_ms > 0.0);
        // Every request-terminal event was streamed: one Completed per
        // outcome (admission is off, so no Shed events).
        let events: Vec<_> = rx.try_iter().collect();
        assert_eq!(events.len(), report.metrics.outcomes().len());
        assert!(events.iter().all(|e| matches!(e, ServeEvent::Completed(_))));
        // A shut-down server sheds at the door with a typed reason.
        // (submit would need the server; it is consumed — covered by the
        // ingress unit tests instead.)
    }
}
