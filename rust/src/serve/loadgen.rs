//! Built-in load generator: drive the serving runtime in-process at a
//! target rate and report what the paper's serving experiments report —
//! achieved rps, p50/p99 end-to-end latency, SLO violation rate, and
//! admission shed rate.
//!
//! Two client models:
//!
//! * **open loop** — arrivals follow a rate envelope (constant Poisson,
//!   MMPP bursts, or a diurnal swing) independent of server progress: the
//!   honest way to measure an overloaded server. On a virtual clock the
//!   trace is served through [`run_trace`] (deterministic, CI-fast); on
//!   the wall clock arrivals are paced in real time through the live
//!   ingress.
//! * **closed loop** — `concurrency` clients each keep one request in
//!   flight, submitting the next on completion (wall clock only: the
//!   feedback loop needs real completions).

use super::server::{ClockKind, ServeConfig, ServeReport, Server, run_trace};
use crate::metrics::{Metrics, ShedReason};
use crate::util::rng::Pcg32;
use crate::workload::envelope::{RateEnvelope, ShapedGenerator};
use crate::workload::models::{ModelId, ModelSpec, N_MODELS};
use crate::workload::request::Request;
use crate::workload::session::SessionSpec;
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Duration;

/// Client model for the load generator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoadMode {
    Open,
    Closed { concurrency: usize },
}

/// Load-generation knobs.
#[derive(Clone, Copy, Debug)]
pub struct LoadGenConfig {
    /// Base offered rate, requests/second (aggregate over the zoo).
    pub rps: f64,
    /// Serving horizon, seconds.
    pub seconds: f64,
    pub seed: u64,
    pub envelope: RateEnvelope,
    pub mode: LoadMode,
    /// Multiplier on every request's Table-IV SLO (1.0 = the paper's
    /// deadlines; see [`ShapedGenerator::with_slo_scale`]).
    pub slo_scale: f64,
    /// Fraction of requests drawing their input from a small popular
    /// pool (the rest are unique), for exercising the cluster tier's
    /// result cache. 0.0 = every input unique (cache can never hit).
    /// Digests are deterministic in `(seed, trace index)` — see
    /// [`crate::cluster::digest_for`].
    pub repeat_fraction: f64,
    /// `Some(spec)` turns every generated request into an autoregressive
    /// session head (`--workload llm`): the head carries a TTFT
    /// deadline, and each completed round re-enters the queue as the
    /// next decode step under the TPOT budget. `None` (the default) is
    /// the one-shot workload, untouched bit-for-bit.
    pub session: Option<SessionSpec>,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            rps: 60.0,
            seconds: 10.0,
            seed: 7,
            envelope: RateEnvelope::Constant,
            mode: LoadMode::Open,
            slo_scale: 1.0,
            repeat_fraction: 0.0,
            session: None,
        }
    }
}

impl LoadGenConfig {
    /// Start a validated-construction builder seeded with the defaults
    /// ([`LoadGenConfigBuilder::build`] rejects non-positive rates and
    /// horizons, out-of-range repeat fractions, and zero-concurrency
    /// closed loops).
    pub fn builder() -> LoadGenConfigBuilder {
        LoadGenConfigBuilder { cfg: LoadGenConfig::default() }
    }

    /// Build the config's arrival generator (shared by single-node and
    /// cluster drivers so the offered load cannot drift between them).
    pub fn generator(&self) -> ShapedGenerator {
        ShapedGenerator::new(self.rps, self.envelope, self.seed)
            .with_slo_scale(self.slo_scale)
    }

    /// Generate the arrival trace, re-stamped as session heads when the
    /// workload is LLM-style. The TTFT scale is applied AFTER generation
    /// (pure arithmetic, no RNG), so the underlying arrival stream is
    /// bit-identical to the one-shot workload's for the same seed.
    pub fn head_trace(&self, horizon_ms: f64) -> Vec<Request> {
        let mut trace = self.generator().generate_horizon(horizon_ms);
        if let Some(spec) = self.session {
            for r in &mut trace {
                spec.stamp_head(r);
            }
        }
        trace
    }
}

/// Validated constructor for [`LoadGenConfig`]: chain setters, then
/// [`build`](Self::build).
#[derive(Clone, Copy, Debug)]
pub struct LoadGenConfigBuilder {
    cfg: LoadGenConfig,
}

impl LoadGenConfigBuilder {
    /// Base offered rate, requests/second.
    pub fn rps(mut self, rps: f64) -> Self {
        self.cfg.rps = rps;
        self
    }

    /// Serving horizon, seconds.
    pub fn seconds(mut self, seconds: f64) -> Self {
        self.cfg.seconds = seconds;
        self
    }

    /// One seed pins the arrival trace, digests, schedulers, and router
    /// streams.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Arrival-rate envelope (constant / bursty / diurnal).
    pub fn envelope(mut self, envelope: RateEnvelope) -> Self {
        self.cfg.envelope = envelope;
        self
    }

    /// Client model (open or closed loop).
    pub fn mode(mut self, mode: LoadMode) -> Self {
        self.cfg.mode = mode;
        self
    }

    /// Multiplier on every request's Table-IV SLO.
    pub fn slo_scale(mut self, slo_scale: f64) -> Self {
        self.cfg.slo_scale = slo_scale;
        self
    }

    /// Fraction of requests drawing inputs from the popular pool.
    pub fn repeat_fraction(mut self, fraction: f64) -> Self {
        self.cfg.repeat_fraction = fraction;
        self
    }

    /// LLM-style session workload: every request becomes a session head
    /// with [`SessionSpec`]'s decode steps and dual TTFT/TPOT SLOs.
    pub fn session(mut self, session: Option<SessionSpec>) -> Self {
        self.cfg.session = session;
        self
    }

    /// Validate and return the configuration.
    pub fn build(self) -> Result<LoadGenConfig, String> {
        let cfg = self.cfg;
        if !cfg.rps.is_finite() || cfg.rps <= 0.0 {
            return Err("--rps must be a positive finite number".into());
        }
        if !cfg.seconds.is_finite() || cfg.seconds <= 0.0 {
            return Err("--seconds must be a positive finite number".into());
        }
        if !cfg.slo_scale.is_finite() || cfg.slo_scale <= 0.0 {
            return Err("--slo-scale must be a positive finite number".into());
        }
        if !cfg.repeat_fraction.is_finite()
            || !(0.0..=1.0).contains(&cfg.repeat_fraction)
        {
            return Err("--repeat-fraction must be in [0, 1]".into());
        }
        if let LoadMode::Closed { concurrency } = cfg.mode {
            if concurrency == 0 {
                return Err("--concurrency must be >= 1".into());
            }
            if cfg.session.is_some() {
                return Err(
                    "--workload llm needs the open loop — a session is \
                     itself a feedback loop (each step launches the next), \
                     so closed-loop concurrency slots have no meaning"
                        .into(),
                );
            }
        }
        if let Some(s) = cfg.session {
            if !s.tpot_ms.is_finite() || s.tpot_ms <= 0.0 {
                return Err("--tpot-ms must be a positive finite number"
                    .into());
            }
            if !s.ttft_slo_scale.is_finite() || s.ttft_slo_scale <= 0.0 {
                return Err(
                    "--ttft-slo-scale must be a positive finite number"
                        .into(),
                );
            }
            if s.decode_steps == 0
                || s.decode_steps
                    > crate::workload::session::MAX_DECODE_STEPS
            {
                return Err(format!(
                    "--decode-steps must be in 1..={} (the step index \
                     lives in the id's top byte)",
                    crate::workload::session::MAX_DECODE_STEPS
                ));
            }
        }
        Ok(cfg)
    }
}

/// One closed-loop launch attempt: round-robin over the zoo, submitting
/// through `submit` until some model is accepted (`true`) or every model
/// was refused (`false`). THE closed-loop client model — shared by the
/// single-node and cluster drivers so the workload (model rotation,
/// transmission stamp, SLO scaling) cannot drift between them.
pub(crate) fn launch_round_robin(
    rng: &mut Pcg32, rr: &mut usize, slo_scale: f64,
    mut submit: impl FnMut(ModelId, f64, f64) -> Result<u64, ShedReason>,
) -> bool {
    for _ in 0..N_MODELS {
        let model = ModelId::from_index(*rr % N_MODELS);
        *rr += 1;
        let spec = ModelSpec::get(model);
        let tx_ms = 0.5 + 2.5 * rng.f64();
        if submit(model, spec.slo_ms * slo_scale, tx_ms).is_ok() {
            return true;
        }
    }
    false
}

/// Run the load generator against a serving configuration.
pub fn run(serve: &ServeConfig, load: &LoadGenConfig)
           -> Result<ServeReport, String> {
    let horizon_ms = load.seconds * 1e3;
    match (load.mode, serve.clock) {
        (LoadMode::Open, ClockKind::Virtual) => {
            let trace = load.head_trace(horizon_ms);
            match load.session {
                Some(spec) => Ok(super::fabric::run_trace_sessions(
                    serve, trace, horizon_ms, spec,
                )),
                None => Ok(run_trace(serve, trace, horizon_ms)),
            }
        }
        (LoadMode::Open, ClockKind::Wall) => match load.session {
            Some(spec) => {
                Ok(open_loop_wall_llm(serve, load, horizon_ms, spec))
            }
            None => Ok(open_loop_wall(serve, load, horizon_ms)),
        },
        (LoadMode::Closed { .. }, _) if load.session.is_some() => Err(
            "--workload llm needs the open loop (sessions are their own \
             feedback loop)"
                .into(),
        ),
        (LoadMode::Closed { concurrency }, ClockKind::Wall) => {
            Ok(closed_loop_wall(serve, load, horizon_ms, concurrency.max(1)))
        }
        (LoadMode::Closed { .. }, ClockKind::Virtual) => Err(
            "closed-loop load generation needs --clock wall (the feedback \
             loop runs on real completions)"
                .into(),
        ),
    }
}

/// Open loop on the wall clock: pre-draw the arrival process, then pace
/// submissions against the server's clock. Late submission (the generator
/// thread fell behind) degrades to submit-immediately, which only makes
/// the offered load burstier — never lighter.
fn open_loop_wall(serve: &ServeConfig, load: &LoadGenConfig,
                  horizon_ms: f64) -> ServeReport {
    let trace = load.generator().generate_horizon(horizon_ms);
    let server = Server::start(serve, None);
    for r in trace {
        let wait_ms = r.arrival_ms - server.now_ms();
        if wait_ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(wait_ms / 1e3));
        }
        // Rejections are accounted by the ingress; nothing to do here.
        let _ = server.submit(r.model, r.slo_ms, r.transmission_ms);
    }
    server.shutdown()
}

/// Open loop, LLM-style sessions on the wall clock: heads are paced
/// like [`open_loop_wall`], and the completion stream drives the decode
/// loop — each completed round immediately re-submits the next step
/// through the SAME ingress path every other request takes (so steps
/// contend with heads for admission and batching, and a tighter-slack
/// request can jump ahead between a session's steps).
///
/// The live ingress assigns its own request ids, so the driver keeps an
/// id → step-index map instead of encoding the step in the id (the
/// virtual arms do the latter; the map is the wall arm's equivalent).
/// A step the ingress refuses is accounted by the ingress like any
/// other shed — the session simply ends there. Completions that arrive
/// after the horizon no longer spawn (the run is over), so every spawn
/// recorded in `session_steps_spawned` was genuinely offered:
/// `outcomes + sheds + leftover == heads + steps_spawned`.
fn open_loop_wall_llm(serve: &ServeConfig, load: &LoadGenConfig,
                      horizon_ms: f64, spec: SessionSpec) -> ServeReport {
    let trace = load.head_trace(horizon_ms);
    let (tx, rx) = mpsc::channel();
    let server = Server::start(serve, Some(tx));
    let mut driver = Metrics::new();
    // Ingress id of every in-flight round → its step index.
    let mut steps: HashMap<u64, u64> = HashMap::new();
    let on_event = |ev: super::worker::ServeEvent,
                    steps: &mut HashMap<u64, u64>,
                    driver: &mut Metrics| {
        let super::worker::ServeEvent::Completed(c) = ev else { return };
        let Some(k) = steps.remove(&c.id) else { return };
        driver.record_dual_slo(k, c.violated);
        if k < spec.decode_steps as u64 {
            // Spawn the next step: flat TPOT budget, no network charge
            // (decode output stays on-node in the single-node tier).
            driver.record_session_step();
            if let Ok(id) = server.submit(c.model, spec.tpot_ms, 0.0) {
                steps.insert(id, k + 1);
            }
        }
    };
    for r in trace {
        loop {
            let wait_ms = r.arrival_ms - server.now_ms();
            if wait_ms <= 0.0 {
                break;
            }
            match rx.recv_timeout(Duration::from_secs_f64(
                (wait_ms / 1e3).min(0.005),
            )) {
                Ok(ev) => on_event(ev, &mut steps, &mut driver),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        if let Ok(id) = server.submit(r.model, r.slo_ms, r.transmission_ms) {
            driver.record_session_start();
            steps.insert(id, 0);
        }
    }
    // Past the last head: keep the decode loops running to the horizon.
    while server.now_ms() < horizon_ms {
        match rx.recv_timeout(Duration::from_millis(5)) {
            Ok(ev) => on_event(ev, &mut steps, &mut driver),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    let mut report = server.shutdown();
    report.metrics.absorb(driver);
    report
}

/// Closed loop: keep `concurrency` requests in flight, launching the
/// next the moment one terminates — completion OR engine-gate shed (a
/// shed request never completes; not freeing its slot would starve the
/// loop under exactly the overload it measures).
fn closed_loop_wall(serve: &ServeConfig, load: &LoadGenConfig,
                    horizon_ms: f64, concurrency: usize) -> ServeReport {
    let (tx, rx) = mpsc::channel();
    let server = Server::start(serve, Some(tx));
    let mut rng = Pcg32::seeded(load.seed);
    let mut rr = 0usize;
    let slo_scale = load.slo_scale;
    // Round-robin over the zoo; skip models the ingress refuses.
    let launch = |rng: &mut Pcg32, rr: &mut usize| {
        launch_round_robin(rng, rr, slo_scale,
                           |m, slo, tx_ms| server.submit(m, slo, tx_ms))
    };
    let mut in_flight = 0usize;
    for _ in 0..concurrency {
        if launch(&mut rng, &mut rr) {
            in_flight += 1;
        }
    }
    while server.now_ms() < horizon_ms {
        match rx.recv_timeout(Duration::from_millis(20)) {
            // Completed and Shed both free an in-flight slot.
            Ok(_terminal_event) => {
                in_flight = in_flight.saturating_sub(1);
                if launch(&mut rng, &mut rr) {
                    in_flight += 1;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // Top back up (e.g. every model was refusing earlier).
                while in_flight < concurrency && launch(&mut rng, &mut rr) {
                    in_flight += 1;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    server.shutdown()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::admission::AdmissionConfig;
    use crate::serve::server::SchedulerSpec;

    fn quick_serve(workers: usize) -> ServeConfig {
        ServeConfig {
            workers,
            clock: ClockKind::Virtual,
            scheduler: SchedulerSpec::Fixed { batch: 4, m_c: 2 },
            admission: Some(AdmissionConfig::default()),
            ..Default::default()
        }
    }

    #[test]
    fn loadgen_builder_validates() {
        assert!(LoadGenConfig::builder().build().is_ok());
        assert!(LoadGenConfig::builder().rps(0.0).build().is_err());
        assert!(LoadGenConfig::builder().seconds(-1.0).build().is_err());
        assert!(LoadGenConfig::builder().slo_scale(0.0).build().is_err());
        assert!(LoadGenConfig::builder()
            .repeat_fraction(1.5)
            .build()
            .is_err());
        assert!(LoadGenConfig::builder()
            .mode(LoadMode::Closed { concurrency: 0 })
            .build()
            .is_err());
        let cfg = LoadGenConfig::builder()
            .rps(90.0)
            .seconds(2.0)
            .seed(11)
            .repeat_fraction(0.5)
            .build()
            .unwrap();
        assert_eq!(cfg.seed, 11);
        assert_eq!(cfg.rps, 90.0);
    }

    #[test]
    fn open_loop_virtual_reports_end_to_end() {
        let load = LoadGenConfig {
            rps: 120.0,
            seconds: 10.0,
            ..Default::default()
        };
        let report = run(&quick_serve(4), &load).unwrap();
        assert!(report.metrics.completed() > 0);
        assert!(report.achieved_rps() > 0.0);
        assert!(report.metrics.latency_percentile(0.99)
                    >= report.metrics.latency_percentile(0.5));
        assert!(report.metrics.violation_rate() <= 1.0);
    }

    #[test]
    fn bursty_envelope_flows_through() {
        let load = LoadGenConfig {
            rps: 90.0,
            seconds: 12.0,
            envelope: RateEnvelope::bursty(),
            ..Default::default()
        };
        let report = run(&quick_serve(2), &load).unwrap();
        assert!(report.metrics.completed() > 0);
    }

    #[test]
    fn closed_loop_on_virtual_clock_is_rejected() {
        let load = LoadGenConfig {
            mode: LoadMode::Closed { concurrency: 4 },
            ..Default::default()
        };
        assert!(run(&quick_serve(2), &load).is_err());
    }

    #[test]
    fn closed_loop_wall_keeps_requests_in_flight() {
        let serve = ServeConfig {
            clock: ClockKind::Wall,
            ..quick_serve(2)
        };
        let load = LoadGenConfig {
            seconds: 0.25,
            mode: LoadMode::Closed { concurrency: 4 },
            ..Default::default()
        };
        let report = run(&serve, &load).unwrap();
        assert!(report.metrics.completed() > 0, "closed loop served nothing");
        assert_eq!(report.leftover, 0);
    }
}
