//! Request ingress: per-model bounded MPSC channels in front of the
//! worker pool, with the admission controller's fast path at the door.
//!
//! Live traffic enters here. Each model has a bounded
//! [`std::sync::mpsc::sync_channel`]; the worker that owns the model's
//! shard drains it. Submission is non-blocking: a full channel is
//! backpressure and rejects with [`ShedReason::QueueFull`] rather than
//! stalling the caller — an edge box that cannot keep up must say so
//! immediately, not buffer unboundedly (SLICE-style ingress control).
//!
//! Workers publish per-model gauges (queue depth, rolling batch latency)
//! after every scheduling round; [`Ingress::submit`] reads them lock-free
//! to refuse provably-late requests before they ever cross a channel.
//! Requests that pass the fast path are re-checked exactly at the
//! engine's ingest gate, where queue depths are authoritative.

use super::admission::AdmissionConfig;
use crate::metrics::{Metrics, ShedReason, N_SHED_REASONS};
use crate::workload::models::{ModelId, N_MODELS};
use crate::workload::request::Request;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Lock-free per-model serving gauges, published by workers each round
/// and read by the ingress fast path. Latencies travel as f64 bit
/// patterns in an `AtomicU64`.
pub struct SharedGauges {
    queue_len: [AtomicUsize; N_MODELS],
    batch_ms_bits: [AtomicU64; N_MODELS],
}

impl Default for SharedGauges {
    fn default() -> Self {
        SharedGauges {
            queue_len: std::array::from_fn(|_| AtomicUsize::new(0)),
            batch_ms_bits: std::array::from_fn(|_| {
                AtomicU64::new(f64::NAN.to_bits())
            }),
        }
    }
}

impl SharedGauges {
    pub fn new() -> Self {
        SharedGauges::default()
    }

    pub fn publish(&self, model: ModelId, queue_len: usize, batch_ms: f64) {
        self.queue_len[model as usize].store(queue_len, Ordering::Relaxed);
        self.batch_ms_bits[model as usize]
            .store(batch_ms.to_bits(), Ordering::Relaxed);
    }

    pub fn queue_len(&self, model: ModelId) -> usize {
        self.queue_len[model as usize].load(Ordering::Relaxed)
    }

    /// Rolling batch latency estimate, ms (NaN before any publish).
    pub fn batch_ms(&self, model: ModelId) -> f64 {
        f64::from_bits(self.batch_ms_bits[model as usize].load(Ordering::Relaxed))
    }

    /// Estimated backlog for one model, ms: queue depth × the rolling
    /// per-request service estimate (profiled batch latency over the
    /// reference batch; `isolated_ref_ms` is the cold-start fallback).
    /// The rebalance controller sums this per worker to find overload,
    /// and the workers sum it pool-wide for the scheduler's gauge hints.
    pub fn backlog_ms(&self, model: ModelId, isolated_ref_ms: f64,
                      ref_batch: usize) -> f64 {
        let q = self.queue_len(model);
        if q == 0 {
            return 0.0;
        }
        let batch = self.batch_ms(model);
        let batch = if batch.is_finite() && batch > 0.0 {
            batch
        } else {
            isolated_ref_ms
        };
        q as f64 * batch / ref_batch.max(1) as f64
    }

    /// Has the model seen traffic — currently queued, or ever profiled
    /// (the latency gauge leaves NaN on the first served batch)?
    pub fn is_active(&self, model: ModelId) -> bool {
        self.queue_len(model) > 0 || self.batch_ms(model).is_finite()
    }
}

/// Which worker owns each model's intake — the shard map, made dynamic.
/// Reads are lock-free on the serve fast path (ingress wakeups, worker
/// intake scans); the rebalance controller is the only writer. Each
/// migration stamps a new epoch, so workers can cheaply notice that the
/// map changed and flush a disowned model's backlog to its new owner —
/// in-flight channel sends simply drain to whichever worker owns the
/// slot next, so the handoff loses nothing.
pub struct OwnershipTable {
    owner: [AtomicUsize; N_MODELS],
    epoch: AtomicU64,
    migrations: AtomicU64,
}

impl OwnershipTable {
    /// The static modulo shard map PR 2 hard-wired: model `m` starts on
    /// worker `m % workers`.
    pub fn new_static(workers: usize) -> Self {
        let workers = workers.max(1);
        OwnershipTable {
            owner: std::array::from_fn(|m| AtomicUsize::new(m % workers)),
            epoch: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
        }
    }

    /// Worker currently owning `model`'s intake.
    pub fn owner(&self, model: ModelId) -> usize {
        self.owner[model as usize].load(Ordering::Acquire)
    }

    /// Monotone stamp bumped by every migration.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Total migrations performed.
    pub fn migrations(&self) -> u64 {
        self.migrations.load(Ordering::Relaxed)
    }

    /// Reassign `model` to worker `to`, stamping a new epoch. Returns
    /// the new epoch. The old owner flushes the model's queued backlog
    /// into the shared [`ModelIntake`] slot on its next round; the new
    /// owner picks it up from there — no request is lost or served twice.
    pub fn migrate(&self, model: ModelId, to: usize) -> u64 {
        self.owner[model as usize].store(to, Ordering::Release);
        self.migrations.fetch_add(1, Ordering::Relaxed);
        self.epoch.fetch_add(1, Ordering::AcqRel) + 1
    }
}

/// One model's shared intake slot: the ingress channel's receive side
/// plus the migration handoff buffer. The slots live behind per-model
/// mutexes shared by the whole worker pool; the [`OwnershipTable`]
/// decides who drains each one, so a migration is just a table write —
/// the channel itself never moves.
pub struct ModelIntake {
    pub rx: Receiver<Request>,
    /// Backlog flushed out of the previous owner's engine mid-migration,
    /// waiting for the new owner's next intake pass.
    pub handoff: Vec<Request>,
    /// Channel disconnected AND fully drained (shutdown bookkeeping).
    pub closed: bool,
}

/// One worker's parking spot: the ingress rings it after delivering a
/// request so an idle worker wakes immediately instead of on its poll
/// timeout. A missed wake is harmless (workers park with a timeout).
pub struct WakeEvent {
    signaled: Mutex<bool>,
    cv: Condvar,
}

impl Default for WakeEvent {
    fn default() -> Self {
        WakeEvent { signaled: Mutex::new(false), cv: Condvar::new() }
    }
}

impl WakeEvent {
    pub fn new() -> Self {
        WakeEvent::default()
    }

    pub fn notify(&self) {
        *self.signaled.lock().unwrap() = true;
        self.cv.notify_all();
    }

    /// Park until notified or `timeout`, consuming the signal.
    pub fn wait_timeout(&self, timeout: Duration) {
        let mut signaled = self.signaled.lock().unwrap();
        if !*signaled {
            let (guard, _) = self.cv.wait_timeout(signaled, timeout).unwrap();
            signaled = guard;
        }
        *signaled = false;
    }
}

/// The ingress: admission fast path + per-model channel senders.
pub struct Ingress {
    senders: Vec<SyncSender<Request>>,
    /// One wake event per WORKER; the ownership table resolves which one
    /// a delivery should ring.
    worker_events: Vec<Arc<WakeEvent>>,
    ownership: Arc<OwnershipTable>,
    gauges: Arc<SharedGauges>,
    admission: Option<AdmissionConfig>,
    /// Isolated latency estimate at the admission reference batch, per
    /// model (cold-start pricing before workers publish profiles).
    isolated_ref_ms: [f64; N_MODELS],
    accepting: AtomicBool,
    next_id: AtomicU64,
    /// Requests refused at the ingress itself (the engine gate accounts
    /// its own sheds); folded into the final report's [`Metrics`].
    sheds: [[AtomicU64; N_SHED_REASONS]; N_MODELS],
}

impl Ingress {
    pub(crate) fn new(senders: Vec<SyncSender<Request>>,
                      worker_events: Vec<Arc<WakeEvent>>,
                      ownership: Arc<OwnershipTable>,
                      gauges: Arc<SharedGauges>,
                      admission: Option<AdmissionConfig>,
                      isolated_ref_ms: [f64; N_MODELS]) -> Self {
        assert_eq!(senders.len(), N_MODELS);
        assert!(!worker_events.is_empty());
        Ingress {
            senders,
            worker_events,
            ownership,
            gauges,
            admission,
            isolated_ref_ms,
            accepting: AtomicBool::new(true),
            next_id: AtomicU64::new(0),
            sheds: std::array::from_fn(|_| {
                std::array::from_fn(|_| AtomicU64::new(0))
            }),
        }
    }

    /// Submit a live request arriving NOW (`now_ms` from the server's
    /// wall clock). Assigns the request id, stamps the arrival, runs the
    /// admission fast path, and delivers into the model's channel.
    pub fn submit(&self, model: ModelId, slo_ms: f64, transmission_ms: f64,
                  now_ms: f64) -> Result<u64, ShedReason> {
        if !self.accepting.load(Ordering::Acquire) {
            self.count_shed(model, ShedReason::Shutdown);
            return Err(ShedReason::Shutdown);
        }
        if let Some(cfg) = &self.admission {
            // Fast path against published gauges: approximate (a round
            // stale), so it only front-runs the authoritative engine-gate
            // check — both use the same decision function.
            let slack = slo_ms - transmission_ms;
            if let Err(reason) = cfg.decide(
                self.gauges.queue_len(model),
                self.gauges.batch_ms(model),
                self.isolated_ref_ms[model as usize],
                slack,
            ) {
                self.count_shed(model, reason);
                return Err(reason);
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut r = Request::new(id, model, now_ms);
        r.slo_ms = slo_ms;
        r.transmission_ms = transmission_ms;
        match self.senders[model as usize].try_send(r) {
            Ok(()) => {
                // Ring the CURRENT owner (the table may have migrated the
                // model since the channel was created). A stale read just
                // wakes a worker that finds nothing — harmless.
                let owner =
                    self.ownership.owner(model).min(self.worker_events.len() - 1);
                self.worker_events[owner].notify();
                Ok(id)
            }
            Err(TrySendError::Full(_)) => {
                self.count_shed(model, ShedReason::QueueFull);
                Err(ShedReason::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.count_shed(model, ShedReason::Shutdown);
                Err(ShedReason::Shutdown)
            }
        }
    }

    pub fn is_accepting(&self) -> bool {
        self.accepting.load(Ordering::Acquire)
    }

    /// Stop intake (drain phase 1): subsequent submits shed with
    /// [`ShedReason::Shutdown`]. Dropping the ingress afterwards
    /// disconnects the channels, which is the workers' exit signal.
    pub fn close(&self) {
        self.accepting.store(false, Ordering::Release);
    }

    /// Wake every worker (used at shutdown so parked workers notice the
    /// disconnect immediately).
    pub fn wake_all(&self) {
        for e in &self.worker_events {
            e.notify();
        }
    }

    /// Disconnect every channel (drain phase 2): receivers see
    /// `Disconnected` once drained, which is the workers' exit signal.
    /// Call [`Ingress::close`] first — submits after this would panic.
    pub fn drop_senders(&mut self) {
        self.senders.clear();
    }

    /// Fold the ingress-side shed counters into a report's metrics.
    pub fn fold_sheds_into(&self, m: &mut Metrics) {
        for model in ModelId::all() {
            for reason in ShedReason::all() {
                let n = self.sheds[model as usize][reason as usize]
                    .load(Ordering::Relaxed);
                if n > 0 {
                    m.record_shed_n(model, reason, n);
                }
            }
        }
    }

    fn count_shed(&self, model: ModelId, reason: ShedReason) {
        self.sheds[model as usize][reason as usize]
            .fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    fn test_ingress(cap: usize, admission: Option<AdmissionConfig>)
                    -> (Ingress, Vec<std::sync::mpsc::Receiver<Request>>) {
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in 0..N_MODELS {
            let (tx, rx) = sync_channel(cap);
            senders.push(tx);
            receivers.push(rx);
        }
        let worker_events = vec![Arc::new(WakeEvent::new())];
        let ownership = Arc::new(OwnershipTable::new_static(1));
        let gauges = Arc::new(SharedGauges::new());
        let ing = Ingress::new(senders, worker_events, ownership, gauges,
                               admission, [10.0; N_MODELS]);
        (ing, receivers)
    }

    #[test]
    fn submit_assigns_ids_and_delivers() {
        let (ing, rx) = test_ingress(4, None);
        let a = ing.submit(ModelId::Res, 58.0, 1.0, 100.0).unwrap();
        let b = ing.submit(ModelId::Res, 58.0, 1.0, 101.0).unwrap();
        assert_ne!(a, b);
        let got = rx[ModelId::Res as usize].try_recv().unwrap();
        assert_eq!(got.id, a);
        assert_eq!(got.arrival_ms, 100.0);
        assert_eq!(got.slo_ms, 58.0);
    }

    #[test]
    fn full_channel_sheds_queue_full() {
        let (ing, _rx) = test_ingress(2, None);
        assert!(ing.submit(ModelId::Mob, 86.0, 0.0, 0.0).is_ok());
        assert!(ing.submit(ModelId::Mob, 86.0, 0.0, 0.0).is_ok());
        assert_eq!(ing.submit(ModelId::Mob, 86.0, 0.0, 0.0),
                   Err(ShedReason::QueueFull));
        let mut m = Metrics::new();
        ing.fold_sheds_into(&mut m);
        assert_eq!(m.shed_by_reason(ShedReason::QueueFull), 1);
        assert_eq!(m.shed_for(ModelId::Mob), 1);
    }

    #[test]
    fn closed_ingress_sheds_shutdown() {
        let (ing, _rx) = test_ingress(4, None);
        ing.close();
        assert!(!ing.is_accepting());
        assert_eq!(ing.submit(ModelId::Res, 58.0, 0.0, 0.0),
                   Err(ShedReason::Shutdown));
        let mut m = Metrics::new();
        ing.fold_sheds_into(&mut m);
        assert_eq!(m.shed_by_reason(ShedReason::Shutdown), 1);
    }

    #[test]
    fn fast_path_sheds_on_published_backlog() {
        let (ing, _rx) = test_ingress(64, Some(AdmissionConfig::default()));
        // Workers report 80 queued at 30 ms/batch → 11 batches ≈ 330 ms,
        // far beyond res's 58 ms SLO.
        ing.gauges.publish(ModelId::Res, 80, 30.0);
        assert_eq!(ing.submit(ModelId::Res, 58.0, 0.0, 0.0),
                   Err(ShedReason::DeadlineUnmeetable));
        // An idle model still admits.
        assert!(ing.submit(ModelId::Bert, 114.0, 0.0, 0.0).is_ok());
    }

    #[test]
    fn ownership_table_static_map_and_migration() {
        let t = OwnershipTable::new_static(2);
        for m in ModelId::all() {
            assert_eq!(t.owner(m), m as usize % 2, "static shard map");
        }
        assert_eq!(t.epoch(), 0);
        assert_eq!(t.migrations(), 0);
        let e1 = t.migrate(ModelId::Yolo, 1);
        assert_eq!(e1, 1);
        assert_eq!(t.owner(ModelId::Yolo), 1);
        assert_eq!(t.migrations(), 1);
        let e2 = t.migrate(ModelId::Res, 1);
        assert_eq!(e2, 2);
        assert_eq!(t.epoch(), 2);
        // Workers clamp to [1, ..]; a degenerate pool is all-on-worker-0.
        let solo = OwnershipTable::new_static(0);
        for m in ModelId::all() {
            assert_eq!(solo.owner(m), 0);
        }
    }

    #[test]
    fn gauge_backlog_estimate_and_activity() {
        let g = SharedGauges::new();
        // Unobserved and empty: no backlog, inactive.
        assert_eq!(g.backlog_ms(ModelId::Res, 40.0, 8), 0.0);
        assert!(!g.is_active(ModelId::Res));
        // Queued but unprofiled: priced by the isolated fallback.
        g.publish(ModelId::Res, 16, f64::NAN);
        assert!(g.is_active(ModelId::Res));
        assert!((g.backlog_ms(ModelId::Res, 40.0, 8) - 16.0 * 5.0).abs()
                    < 1e-9);
        // Profiled: priced by the rolling batch latency.
        g.publish(ModelId::Res, 16, 24.0);
        assert!((g.backlog_ms(ModelId::Res, 40.0, 8) - 16.0 * 3.0).abs()
                    < 1e-9);
        // Drained but profiled: active (it has traffic history), zero
        // backlog.
        g.publish(ModelId::Res, 0, 24.0);
        assert_eq!(g.backlog_ms(ModelId::Res, 40.0, 8), 0.0);
        assert!(g.is_active(ModelId::Res));
    }

    #[test]
    fn wake_event_roundtrip() {
        let e = Arc::new(WakeEvent::new());
        let e2 = e.clone();
        let t = std::thread::spawn(move || {
            e2.wait_timeout(Duration::from_secs(5));
        });
        std::thread::sleep(Duration::from_millis(10));
        e.notify();
        t.join().unwrap(); // returns promptly — would time out otherwise
        // Pre-signaled waits return immediately.
        e.notify();
        let t0 = std::time::Instant::now();
        e.wait_timeout(Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_secs(1));
    }
}
