//! Request ingress: per-model bounded MPSC channels in front of the
//! worker pool, with the admission controller's fast path at the door.
//!
//! Live traffic enters here. Each model has a bounded
//! [`std::sync::mpsc::sync_channel`]; the worker that owns the model's
//! shard drains it. Submission is non-blocking: a full channel is
//! backpressure and rejects with [`ShedReason::QueueFull`] rather than
//! stalling the caller — an edge box that cannot keep up must say so
//! immediately, not buffer unboundedly (SLICE-style ingress control).
//!
//! Workers publish per-(model, worker) gauges (queue depth, rolling
//! batch latency) after every scheduling round; [`Ingress::submit`] sums
//! them lock-free to refuse provably-late requests before they ever
//! cross a channel — divided by the model's replica count, since a
//! replicated model's summed backlog drains `R`× as fast. Requests that
//! pass the fast path are re-checked exactly at the engine's ingest
//! gate, where the local queue depth is authoritative.

use super::admission::AdmissionConfig;
use crate::predictor::AdmissionMode;
use crate::metrics::{Metrics, ShedReason, N_SHED_REASONS};
use crate::workload::models::{ModelId, N_MODELS};
use crate::workload::request::Request;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Upper bound on the worker-pool size ([`crate::serve::ServeConfig`]
/// clamps `workers` to `[1, N_MODELS]`). Sizes the per-worker gauge lanes
/// and the replica bitmasks' meaningful width.
pub const MAX_POOL: usize = N_MODELS;

/// A request counts as *urgent* for wake-target choice when its remaining
/// slack is under this many estimated batch spans — roughly the point
/// where one wrong queue position costs the deadline (see
/// [`pick_replica`]).
pub const URGENT_SLACK_BATCHES: f64 = 4.0;

/// Lock-free per-(model, worker) serving gauges, published by workers
/// each round and read by the ingress fast path and the rebalance
/// controller. Latencies travel as f64 bit patterns in an `AtomicU64`.
///
/// Each worker owns one LANE per model and republishes every model every
/// round (an uninvolved worker writes a zero queue), so a lane can never
/// go stale after a migration or a replica scale-down. The model-wide
/// view is the sum (queues, backlog) or the finite-mean (batch latency)
/// over lanes — with hot-model replication, one model's queue is split
/// across several workers, and only the summed view prices it honestly.
pub struct SharedGauges {
    queue_len: [[AtomicUsize; MAX_POOL]; N_MODELS],
    batch_ms_bits: [[AtomicU64; MAX_POOL]; N_MODELS],
    /// Per-(model, worker) predicted-inflation lanes: each involved
    /// worker's engine publishes its interference predictor's inflation
    /// estimate for one more reference batch (NaN = uninvolved lane,
    /// cold predictor, or snapshot-mode run). Predictive admission and
    /// slo-aware routing price headroom from the finite-lane mean; an
    /// all-NaN model (e.g. every replica an ex-drainer) aggregates to
    /// NaN, which is exactly the fallback trigger.
    pred_inflation_bits: [[AtomicU64; MAX_POOL]; N_MODELS],
    /// Per-worker predictor dispersion p95 (NaN = unknown); the
    /// aggregate takes the max over finite lanes — the conservative
    /// pool-wide tail factor.
    p95_factor_bits: [AtomicU64; MAX_POOL],
}

impl Default for SharedGauges {
    fn default() -> Self {
        SharedGauges {
            queue_len: std::array::from_fn(|_| {
                std::array::from_fn(|_| AtomicUsize::new(0))
            }),
            batch_ms_bits: std::array::from_fn(|_| {
                std::array::from_fn(|_| AtomicU64::new(f64::NAN.to_bits()))
            }),
            pred_inflation_bits: std::array::from_fn(|_| {
                std::array::from_fn(|_| AtomicU64::new(f64::NAN.to_bits()))
            }),
            p95_factor_bits: std::array::from_fn(|_| {
                AtomicU64::new(f64::NAN.to_bits())
            }),
        }
    }
}

impl SharedGauges {
    pub fn new() -> Self {
        SharedGauges::default()
    }

    /// Publish one worker's lane for `model`: its local queue depth and
    /// its engine's rolling batch-latency estimate (NaN if this worker
    /// never served the model).
    pub fn publish(&self, model: ModelId, worker: usize, queue_len: usize,
                   batch_ms: f64) {
        let w = worker.min(MAX_POOL - 1);
        self.queue_len[model as usize][w].store(queue_len, Ordering::Relaxed);
        self.batch_ms_bits[model as usize][w]
            .store(batch_ms.to_bits(), Ordering::Relaxed);
    }

    /// Pool-wide queue depth for `model` (sum over worker lanes).
    pub fn queue_len(&self, model: ModelId) -> usize {
        self.queue_len[model as usize]
            .iter()
            .map(|q| q.load(Ordering::Relaxed))
            .sum()
    }

    /// One worker's published queue depth for `model`.
    pub fn queue_len_for(&self, model: ModelId, worker: usize) -> usize {
        self.queue_len[model as usize][worker.min(MAX_POOL - 1)]
            .load(Ordering::Relaxed)
    }

    /// One worker's rolling batch latency estimate, ms (NaN before it
    /// ever served the model).
    pub fn batch_ms_for(&self, model: ModelId, worker: usize) -> f64 {
        f64::from_bits(
            self.batch_ms_bits[model as usize][worker.min(MAX_POOL - 1)]
                .load(Ordering::Relaxed),
        )
    }

    /// Rolling batch latency estimate for `model`, ms: the mean over
    /// workers that have served it (NaN before any publish anywhere).
    pub fn batch_ms(&self, model: ModelId) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for bits in &self.batch_ms_bits[model as usize] {
            let ms = f64::from_bits(bits.load(Ordering::Relaxed));
            if ms.is_finite() && ms > 0.0 {
                sum += ms;
                n += 1;
            }
        }
        if n == 0 { f64::NAN } else { sum / n as f64 }
    }

    /// Estimated backlog parked on ONE worker for `model`, ms: its lane's
    /// queue depth × its per-request service estimate (profiled batch
    /// latency over the reference batch; `isolated_ref_ms` is the
    /// cold-start fallback). The rebalance controller reads this per
    /// (model, worker) to find overload and replica imbalance.
    pub fn backlog_ms_for(&self, model: ModelId, worker: usize,
                          isolated_ref_ms: f64, ref_batch: usize) -> f64 {
        let q = self.queue_len_for(model, worker);
        if q == 0 {
            return 0.0;
        }
        let batch = self.batch_ms_for(model, worker);
        let batch = if batch.is_finite() && batch > 0.0 {
            batch
        } else {
            isolated_ref_ms
        };
        q as f64 * batch / ref_batch.max(1) as f64
    }

    /// Pool-wide estimated backlog for one model, ms (sum over worker
    /// lanes). The workers sum this over models for the scheduler's
    /// cross-worker gauge hints.
    pub fn backlog_ms(&self, model: ModelId, isolated_ref_ms: f64,
                      ref_batch: usize) -> f64 {
        (0..MAX_POOL)
            .map(|w| self.backlog_ms_for(model, w, isolated_ref_ms, ref_batch))
            .sum()
    }

    /// Has the model seen traffic — currently queued anywhere, or ever
    /// profiled by any worker (a lane's latency leaves NaN on that
    /// worker's first served batch)?
    pub fn is_active(&self, model: ModelId) -> bool {
        self.queue_len(model) > 0 || self.batch_ms(model).is_finite()
    }

    /// Publish one worker's predicted-inflation lane for `model` and its
    /// predictor's dispersion p95 (NaN = no prediction / unknown).
    pub fn publish_prediction(&self, model: ModelId, worker: usize,
                              inflation: f64, p95_factor: f64) {
        let w = worker.min(MAX_POOL - 1);
        self.pred_inflation_bits[model as usize][w]
            .store(inflation.to_bits(), Ordering::Relaxed);
        self.p95_factor_bits[w].store(p95_factor.to_bits(),
                                      Ordering::Relaxed);
    }

    /// Pool-wide predicted inflation for `model`: the mean over workers
    /// with a live (finite, positive) prediction lane; NaN when none —
    /// the predictive decision paths' fallback trigger.
    pub fn predicted_inflation(&self, model: ModelId) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for bits in &self.pred_inflation_bits[model as usize] {
            let v = f64::from_bits(bits.load(Ordering::Relaxed));
            if v.is_finite() && v > 0.0 {
                sum += v;
                n += 1;
            }
        }
        if n == 0 { f64::NAN } else { sum / n as f64 }
    }

    /// Pool-wide dispersion p95: the max over workers with a live lane
    /// (the conservative tail estimate); NaN when none.
    pub fn p95_factor(&self) -> f64 {
        let mut best = f64::NAN;
        for bits in &self.p95_factor_bits {
            let v = f64::from_bits(bits.load(Ordering::Relaxed));
            if v.is_finite() && (best.is_nan() || v > best) {
                best = v;
            }
        }
        best
    }
}

/// One coherent export of a server's pool-wide serving state, read
/// lock-free from the [`SharedGauges`] the workers publish each round.
/// The cluster router prices candidate nodes from this — the same
/// numbers the node's own admission fast path reads, so edge-of-cluster
/// routing and node-local admission can never disagree about what a
/// queue costs.
#[derive(Clone, Copy, Debug)]
pub struct GaugeSnapshot {
    /// Pool-wide queue depth per model, divided by the model's replica
    /// count (a replicated queue drains `R`× as fast — the same pricing
    /// [`Ingress::submit`] applies).
    pub queue_per_replica: [usize; N_MODELS],
    /// Estimated per-batch service latency per model at the reference
    /// batch, ms: the profiled finite-lane mean when any worker has
    /// served the model, the platform's isolated estimate otherwise —
    /// so a heterogeneous node's drain rate shows before its first batch.
    pub est_batch_ms: [f64; N_MODELS],
    /// Pool-wide estimated backlog per model, ms.
    pub backlog_ms: [f64; N_MODELS],
    /// Total estimated backlog across the zoo, ms (join-shortest-backlog
    /// routing reads this).
    pub total_backlog_ms: f64,
    /// Reference batch the estimates are priced at.
    pub ref_batch: usize,
    /// Pool-wide predicted inflation per model (finite-lane mean of the
    /// workers' interference-predictor lanes; NaN = every lane cold or
    /// the run is snapshot-mode). Rides the gossip stream so cluster
    /// routing prices the same headroom node-local admission does.
    pub predicted_inflation: [f64; N_MODELS],
    /// This node's isolated latency table at the reference batch, ms —
    /// the per-(model, platform) base the predicted inflation scales.
    pub isolated_ms: [f64; N_MODELS],
    /// Pool-wide predictor dispersion p95 (max over worker lanes; NaN =
    /// unknown), the p95-quantile widening factor.
    pub p95_factor: f64,
}

impl Default for GaugeSnapshot {
    fn default() -> Self {
        GaugeSnapshot {
            queue_per_replica: [0; N_MODELS],
            est_batch_ms: [f64::NAN; N_MODELS],
            backlog_ms: [0.0; N_MODELS],
            total_backlog_ms: 0.0,
            ref_batch: 1,
            predicted_inflation: [f64::NAN; N_MODELS],
            isolated_ms: [f64::NAN; N_MODELS],
            p95_factor: f64::NAN,
        }
    }
}

impl GaugeSnapshot {
    /// Optimistic completion estimate for one new request of `model`
    /// queued behind the snapshot's backlog, ms (excluding network):
    /// `⌈(q_per_replica + 1) / ref_batch⌉ × batch latency` — the
    /// admission decision's bound, computed from the exported state.
    pub fn service_est_ms(&self, model: ModelId) -> f64 {
        let i = model as usize;
        let batches_ahead =
            self.queue_per_replica[i] / self.ref_batch.max(1) + 1;
        batches_ahead as f64 * self.est_batch_ms[i]
    }

    /// Predictive completion estimate for one new request of `model`, ms
    /// (excluding network): the same batches-ahead bound priced at
    /// `isolated × predicted inflation` (× the dispersion p95 at the
    /// `p95` quantile) instead of the rolling snapshot. `None` when this
    /// node's predictor lanes are cold/NaN — the caller falls back to
    /// [`GaugeSnapshot::service_est_ms`], the snapshot oracle.
    pub fn predicted_service_ms(&self, model: ModelId,
                                quantile: crate::predictor::AdmissionQuantile)
                                -> Option<f64> {
        let i = model as usize;
        let cost = crate::predictor::predicted_batch_cost_ms(
            self.isolated_ms[i],
            self.predicted_inflation[i],
            self.p95_factor,
            quantile,
        )?;
        let batches_ahead =
            self.queue_per_replica[i] / self.ref_batch.max(1) + 1;
        Some(batches_ahead as f64 * cost)
    }
}

/// Which workers drain each model's intake — the shard map, made dynamic
/// (PR 3) and replicated (PR 4). Each model maps to a non-empty REPLICA
/// SET, stored as a bitmask of worker indices: several workers can
/// concurrently drain one hot model's intake, which is how a single
/// model's load gets past one worker's capacity (the paper's m_c
/// dimension crossing the worker boundary).
///
/// Reads are lock-free on the serve fast path (ingress wakeups, worker
/// intake scans); the rebalance controller is the only writer. Every
/// mutation — whole-model migration, replica scale-up, replica
/// scale-down — stamps a new epoch, so workers can cheaply notice the
/// map changed and flush a disowned model's backlog into the shared
/// [`ModelIntake`] slot for its current drainers; in-flight channel
/// sends simply drain to whichever replicas hold the slot next, so no
/// handoff loses anything.
pub struct OwnershipTable {
    /// Bitmask of workers currently draining each model (bit `w` set ⇒
    /// worker `w` is a replica). Invariant: never empty.
    replicas: [AtomicU64; N_MODELS],
    epoch: AtomicU64,
    migrations: AtomicU64,
    scale_ups: AtomicU64,
    scale_downs: AtomicU64,
    /// Widest replica set any model ever reached (monotone max; 1 when
    /// replication never triggered).
    peak_replicas: AtomicUsize,
}

impl OwnershipTable {
    /// The static modulo shard map PR 2 hard-wired: model `m` starts on
    /// worker `m % workers`, one replica each.
    pub fn new_static(workers: usize) -> Self {
        let workers = workers.max(1);
        OwnershipTable {
            replicas: std::array::from_fn(|m| {
                AtomicU64::new(1u64 << (m % workers))
            }),
            epoch: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            scale_ups: AtomicU64::new(0),
            scale_downs: AtomicU64::new(0),
            peak_replicas: AtomicUsize::new(1),
        }
    }

    /// The model's PRIMARY drainer (lowest worker index in the replica
    /// set). For an unreplicated model this is simply its owner; with
    /// replicas it is the worker accounting shared handoff backlog in
    /// its gauge lane.
    pub fn owner(&self, model: ModelId) -> usize {
        let mask = self.replica_mask(model);
        if mask == 0 {
            return 0; // unreachable by invariant; stay in bounds anyway
        }
        mask.trailing_zeros() as usize
    }

    /// Bitmask of workers currently draining `model`.
    pub fn replica_mask(&self, model: ModelId) -> u64 {
        self.replicas[model as usize].load(Ordering::Acquire)
    }

    /// Number of workers currently draining `model` (≥ 1).
    pub fn replica_count(&self, model: ModelId) -> usize {
        self.replica_mask(model).count_ones().max(1) as usize
    }

    /// Is `worker` currently one of `model`'s drainers?
    pub fn is_replica(&self, model: ModelId, worker: usize) -> bool {
        worker < 64 && self.replica_mask(model) & (1u64 << worker) != 0
    }

    /// The `n % replica_count`-th replica of `model`, ascending worker
    /// index. The ingress stripes delivery wakeups across the replica
    /// set with this.
    pub fn nth_replica(&self, model: ModelId, n: u64) -> usize {
        nth_of_mask(self.replica_mask(model), n)
    }

    /// Monotone stamp bumped by every map mutation (migration or replica
    /// scaling).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Total whole-model migrations performed.
    pub fn migrations(&self) -> u64 {
        self.migrations.load(Ordering::Relaxed)
    }

    /// Total replicas added by hot-model scale-ups.
    pub fn scale_ups(&self) -> u64 {
        self.scale_ups.load(Ordering::Relaxed)
    }

    /// Total replicas collapsed by scale-downs.
    pub fn scale_downs(&self) -> u64 {
        self.scale_downs.load(Ordering::Relaxed)
    }

    /// Widest replica set any model reached so far.
    pub fn peak_replicas(&self) -> usize {
        self.peak_replicas.load(Ordering::Relaxed)
    }

    /// Reassign `model` to worker `to` alone (collapsing any replica
    /// set), stamping a new epoch. Returns the new epoch. Former
    /// drainers flush the model's queued backlog into the shared
    /// [`ModelIntake`] slot on their next round; the new owner picks it
    /// up from there — no request is lost or served twice.
    pub fn migrate(&self, model: ModelId, to: usize) -> u64 {
        self.replicas[model as usize].store(1u64 << to, Ordering::Release);
        self.migrations.fetch_add(1, Ordering::Relaxed);
        self.epoch.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Add `worker` to `model`'s replica set (hot-model scale-up),
    /// stamping a new epoch. Returns `None` — and stamps nothing — when
    /// the worker already drains the model.
    pub fn add_replica(&self, model: ModelId, worker: usize) -> Option<u64> {
        let bit = 1u64 << worker;
        let prev = self.replicas[model as usize].fetch_or(bit, Ordering::AcqRel);
        if prev & bit != 0 {
            return None;
        }
        self.scale_ups.fetch_add(1, Ordering::Relaxed);
        let count = (prev | bit).count_ones() as usize;
        self.peak_replicas.fetch_max(count, Ordering::Relaxed);
        Some(self.epoch.fetch_add(1, Ordering::AcqRel) + 1)
    }

    /// Remove `worker` from `model`'s replica set (scale-down), stamping
    /// a new epoch. Refuses — returning `None` — when the worker is not
    /// a replica or is the LAST one: a model always keeps a drainer. The
    /// removed worker flushes its share of the model's backlog into the
    /// handoff slot for the surviving replicas.
    pub fn remove_replica(&self, model: ModelId, worker: usize)
                          -> Option<u64> {
        let bit = 1u64 << worker;
        let res = self.replicas[model as usize].fetch_update(
            Ordering::AcqRel,
            Ordering::Acquire,
            |mask| {
                if mask & bit == 0 || mask == bit {
                    None
                } else {
                    Some(mask & !bit)
                }
            },
        );
        if res.is_err() {
            return None;
        }
        self.scale_downs.fetch_add(1, Ordering::Relaxed);
        Some(self.epoch.fetch_add(1, Ordering::AcqRel) + 1)
    }
}

/// The `n % popcount`-th set bit of `mask`, ascending (worker 0 for an
/// empty mask). The striping primitive behind [`OwnershipTable::
/// nth_replica`] and the non-urgent arm of [`pick_replica`].
pub fn nth_of_mask(mask: u64, n: u64) -> usize {
    if mask == 0 {
        return 0;
    }
    let mut k = n % u64::from(mask.count_ones());
    let mut rest = mask;
    while k > 0 && rest.count_ones() > 1 {
        rest &= rest - 1; // clear the lowest set bit
        k -= 1;
    }
    rest.trailing_zeros() as usize
}

/// Deadline-aware wake-target choice for a replicated model: which
/// member of `mask` should be rung for this delivery?
///
/// * Not urgent (plenty of slack): stripe by request id — `nth_of_mask`
///   spreads deliveries evenly and keeps the choice O(popcount) with no
///   gauge reads at all.
/// * Urgent (slack within a few batch spans): ring the replica with the
///   EMPTIEST per-worker lane (`lane_queues`, indexed by worker id; ties
///   break to the lowest index). An urgent request parked behind the
///   fullest lane would burn its remaining slack waiting for a stripe
///   that a sibling replica could start immediately.
///
/// Pure — the submit path feeds it the live gauge lanes, tests feed it
/// literals.
pub fn pick_replica(mask: u64, lane_queues: &[usize], id: u64,
                    urgent: bool) -> usize {
    if mask == 0 {
        return 0;
    }
    if urgent && mask.count_ones() > 1 {
        let mut best = usize::MAX;
        let mut best_q = usize::MAX;
        let mut rest = mask;
        while rest != 0 {
            let w = rest.trailing_zeros() as usize;
            rest &= rest - 1;
            let q = lane_queues.get(w).copied().unwrap_or(0);
            if q < best_q {
                best_q = q;
                best = w;
            }
        }
        return best;
    }
    nth_of_mask(mask, id)
}

/// One model's shared intake slot: the ingress channel's receive side
/// plus the handoff buffer. The slots live behind per-model mutexes
/// shared by the whole worker pool; the [`OwnershipTable`] decides who
/// drains each one, so a migration or replica-scaling action is just a
/// table write — the channel itself never moves. With a replica set
/// wider than one, every replica pops the same channel under the slot's
/// mutex (a sharded MPSC pop: each takes a bounded stripe per pass, so
/// arrivals spread across the set).
pub struct ModelIntake {
    pub rx: Receiver<Request>,
    /// Backlog in flight between workers: flushed out of a drainer's
    /// engine mid-migration or mid-scale-down (or shed as above-fair-
    /// share surplus by an overloaded replica), waiting for a current
    /// replica's next intake pass.
    pub handoff: Vec<Request>,
    /// Channel disconnected AND fully drained (shutdown bookkeeping).
    pub closed: bool,
}

/// One worker's parking spot: the ingress rings it after delivering a
/// request so an idle worker wakes immediately instead of on its poll
/// timeout. A missed wake is harmless (workers park with a timeout).
pub struct WakeEvent {
    signaled: Mutex<bool>,
    cv: Condvar,
}

impl Default for WakeEvent {
    fn default() -> Self {
        WakeEvent { signaled: Mutex::new(false), cv: Condvar::new() }
    }
}

impl WakeEvent {
    pub fn new() -> Self {
        WakeEvent::default()
    }

    pub fn notify(&self) {
        *self.signaled.lock().unwrap() = true;
        self.cv.notify_all();
    }

    /// Park until notified or `timeout`, consuming the signal.
    pub fn wait_timeout(&self, timeout: Duration) {
        let mut signaled = self.signaled.lock().unwrap();
        if !*signaled {
            let (guard, _) = self.cv.wait_timeout(signaled, timeout).unwrap();
            signaled = guard;
        }
        *signaled = false;
    }
}

/// The ingress: admission fast path + per-model channel senders.
pub struct Ingress {
    senders: Vec<SyncSender<Request>>,
    /// One wake event per WORKER; the ownership table resolves which one
    /// a delivery should ring.
    worker_events: Vec<Arc<WakeEvent>>,
    ownership: Arc<OwnershipTable>,
    gauges: Arc<SharedGauges>,
    admission: Option<AdmissionConfig>,
    /// Isolated latency estimate at the admission reference batch, per
    /// model (cold-start pricing before workers publish profiles).
    isolated_ref_ms: [f64; N_MODELS],
    accepting: AtomicBool,
    next_id: AtomicU64,
    /// Requests refused at the ingress itself (the engine gate accounts
    /// its own sheds); folded into the final report's [`Metrics`].
    sheds: [[AtomicU64; N_SHED_REASONS]; N_MODELS],
    /// Fast-path decisions priced under the predictive headroom mode,
    /// and the cold/NaN snapshot fallbacks among them.
    headroom_decisions: AtomicU64,
    headroom_fallbacks: AtomicU64,
}

impl Ingress {
    pub(crate) fn new(senders: Vec<SyncSender<Request>>,
                      worker_events: Vec<Arc<WakeEvent>>,
                      ownership: Arc<OwnershipTable>,
                      gauges: Arc<SharedGauges>,
                      admission: Option<AdmissionConfig>,
                      isolated_ref_ms: [f64; N_MODELS],
                      first_request_id: u64) -> Self {
        assert_eq!(senders.len(), N_MODELS);
        assert!(!worker_events.is_empty());
        Ingress {
            senders,
            worker_events,
            ownership,
            gauges,
            admission,
            isolated_ref_ms,
            accepting: AtomicBool::new(true),
            next_id: AtomicU64::new(first_request_id),
            sheds: std::array::from_fn(|_| {
                std::array::from_fn(|_| AtomicU64::new(0))
            }),
            headroom_decisions: AtomicU64::new(0),
            headroom_fallbacks: AtomicU64::new(0),
        }
    }

    /// Export the current pool-wide gauge state (see [`GaugeSnapshot`]).
    /// Lock-free and approximate — gauges lag the engines by at most one
    /// scheduling round, exactly like the admission fast path's view.
    pub fn gauge_snapshot(&self) -> GaugeSnapshot {
        let ref_batch = self
            .admission
            .map(|a| a.ref_batch)
            .unwrap_or(8)
            .max(1);
        let mut snap = GaugeSnapshot { ref_batch, ..Default::default() };
        for m in ModelId::all() {
            let i = m as usize;
            let replicas = self.ownership.replica_count(m);
            snap.queue_per_replica[i] = self.gauges.queue_len(m) / replicas;
            let batch = self.gauges.batch_ms(m);
            snap.est_batch_ms[i] = if batch.is_finite() && batch > 0.0 {
                batch
            } else {
                self.isolated_ref_ms[i]
            };
            snap.backlog_ms[i] = self.gauges.backlog_ms(
                m, self.isolated_ref_ms[i], ref_batch);
            snap.total_backlog_ms += snap.backlog_ms[i];
            snap.predicted_inflation[i] = self.gauges.predicted_inflation(m);
            snap.isolated_ms[i] = self.isolated_ref_ms[i];
        }
        snap.p95_factor = self.gauges.p95_factor();
        snap
    }

    /// Submit a live request arriving NOW (`now_ms` from the server's
    /// wall clock). Assigns the request id, stamps the arrival, runs the
    /// admission fast path, and delivers into the model's channel.
    pub fn submit(&self, model: ModelId, slo_ms: f64, transmission_ms: f64,
                  now_ms: f64) -> Result<u64, ShedReason> {
        if !self.accepting.load(Ordering::Acquire) {
            self.count_shed(model, ShedReason::Shutdown);
            return Err(ShedReason::Shutdown);
        }
        if let Some(cfg) = &self.admission {
            // Fast path against published gauges: approximate (a round
            // stale), so it only front-runs the authoritative engine-gate
            // check — both use the same decision function. The pool-wide
            // queue is priced per replica: with R workers draining the
            // model, a new request waits behind roughly 1/R of the summed
            // backlog, so a scale-up immediately widens what admission
            // accepts.
            let slack = slo_ms - transmission_ms;
            let replicas = self.ownership.replica_count(model);
            let queue = self.gauges.queue_len(model) / replicas;
            let mean = self.gauges.batch_ms(model);
            let isolated = self.isolated_ref_ms[model as usize];
            let decision = match cfg.mode {
                AdmissionMode::Snapshot => {
                    cfg.decide(queue, mean, isolated, slack)
                }
                AdmissionMode::Predictive => {
                    // The prediction lanes are NaN unless a warm
                    // predictive-mode worker published them, so a cold
                    // pool falls back to the snapshot formula verbatim.
                    let (d, fell_back) = cfg.decide_predictive(
                        queue,
                        mean,
                        isolated,
                        slack,
                        self.gauges.predicted_inflation(model),
                        self.gauges.p95_factor(),
                    );
                    self.headroom_decisions.fetch_add(1, Ordering::Relaxed);
                    if fell_back {
                        self.headroom_fallbacks
                            .fetch_add(1, Ordering::Relaxed);
                    }
                    d
                }
            };
            if let Err(reason) = decision {
                self.count_shed(model, reason);
                return Err(reason);
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut r = Request::new(id, model, now_ms);
        r.slo_ms = slo_ms;
        r.transmission_ms = transmission_ms;
        match self.senders[model as usize].try_send(r) {
            Ok(()) => {
                // Ring one CURRENT replica (the table may have changed
                // since the channel was created — a stale read just wakes
                // a worker that finds nothing, harmless). Deliveries
                // stripe across the set by request id; a request whose
                // remaining slack is within a few batch spans instead
                // rings the replica with the emptiest lane, so urgent
                // work never parks behind the fullest queue.
                let mask = self.ownership.replica_mask(model);
                let slack = slo_ms - transmission_ms;
                let batch = self.gauges.batch_ms(model);
                let est = if batch.is_finite() && batch > 0.0 {
                    batch
                } else {
                    self.isolated_ref_ms[model as usize]
                };
                let urgent = est > 0.0
                    && slack < URGENT_SLACK_BATCHES * est;
                let workers = self.worker_events.len();
                let target = if urgent && mask.count_ones() > 1 {
                    let mut lanes = vec![0usize; workers];
                    for (w, lane) in lanes.iter_mut().enumerate() {
                        if mask & (1u64 << w) != 0 {
                            *lane = self.gauges.queue_len_for(model, w);
                        }
                    }
                    pick_replica(mask, &lanes, id, true)
                } else {
                    pick_replica(mask, &[], id, false)
                }
                .min(workers - 1);
                self.worker_events[target].notify();
                Ok(id)
            }
            Err(TrySendError::Full(_)) => {
                self.count_shed(model, ShedReason::QueueFull);
                Err(ShedReason::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.count_shed(model, ShedReason::Shutdown);
                Err(ShedReason::Shutdown)
            }
        }
    }

    pub fn is_accepting(&self) -> bool {
        self.accepting.load(Ordering::Acquire)
    }

    /// Stop intake (drain phase 1): subsequent submits shed with
    /// [`ShedReason::Shutdown`]. Dropping the ingress afterwards
    /// disconnects the channels, which is the workers' exit signal.
    pub fn close(&self) {
        self.accepting.store(false, Ordering::Release);
    }

    /// Wake every worker (used at shutdown so parked workers notice the
    /// disconnect immediately).
    pub fn wake_all(&self) {
        for e in &self.worker_events {
            e.notify();
        }
    }

    /// Disconnect every channel (drain phase 2): receivers see
    /// `Disconnected` once drained, which is the workers' exit signal.
    /// Call [`Ingress::close`] first — submits after this would panic.
    pub fn drop_senders(&mut self) {
        self.senders.clear();
    }

    /// Fold the ingress-side shed counters into a report's metrics.
    pub fn fold_sheds_into(&self, m: &mut Metrics) {
        for model in ModelId::all() {
            for reason in ShedReason::all() {
                let n = self.sheds[model as usize][reason as usize]
                    .load(Ordering::Relaxed);
                if n > 0 {
                    m.record_shed_n(model, reason, n);
                }
            }
        }
        m.record_headroom(
            self.headroom_decisions.load(Ordering::Relaxed),
            self.headroom_fallbacks.load(Ordering::Relaxed),
        );
    }

    fn count_shed(&self, model: ModelId, reason: ShedReason) {
        self.sheds[model as usize][reason as usize]
            .fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn nth_of_mask_stripes_over_set_bits() {
        // mask {1, 4, 6}: n cycles over the members in ascending order.
        let mask = (1 << 1) | (1 << 4) | (1 << 6);
        assert_eq!(nth_of_mask(mask, 0), 1);
        assert_eq!(nth_of_mask(mask, 1), 4);
        assert_eq!(nth_of_mask(mask, 2), 6);
        assert_eq!(nth_of_mask(mask, 3), 1);
        assert_eq!(nth_of_mask(0, 7), 0);
        assert_eq!(nth_of_mask(1 << 5, 1234), 5);
    }

    #[test]
    fn pick_replica_routes_urgent_requests_to_the_emptiest_lane() {
        let mask = (1 << 0) | (1 << 2) | (1 << 3);
        let lanes = [9, 0, 4, 2, 0, 0];
        // Urgent: the emptiest member lane wins (worker 3, queue 2 —
        // worker 1's empty lane is NOT a replica and never considered).
        assert_eq!(pick_replica(mask, &lanes, 0, true), 3);
        // Ties break to the lowest worker index.
        assert_eq!(pick_replica(mask, &[5, 0, 5, 5], 0, true), 0);
        // Not urgent: id-striping, gauges ignored.
        assert_eq!(pick_replica(mask, &lanes, 0, false), 0);
        assert_eq!(pick_replica(mask, &lanes, 1, false), 2);
        assert_eq!(pick_replica(mask, &lanes, 2, false), 3);
        // Single replica: urgency changes nothing.
        assert_eq!(pick_replica(1 << 2, &lanes, 9, true), 2);
        // Lanes shorter than the pool read as empty, never panic.
        assert_eq!(pick_replica((1 << 1) | (1 << 5), &[7, 3], 0, true), 5);
        assert_eq!(pick_replica(0, &[], 3, true), 0);
    }

    fn test_ingress(cap: usize, admission: Option<AdmissionConfig>)
                    -> (Ingress, Vec<std::sync::mpsc::Receiver<Request>>) {
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in 0..N_MODELS {
            let (tx, rx) = sync_channel(cap);
            senders.push(tx);
            receivers.push(rx);
        }
        let worker_events = vec![Arc::new(WakeEvent::new())];
        let ownership = Arc::new(OwnershipTable::new_static(1));
        let gauges = Arc::new(SharedGauges::new());
        let ing = Ingress::new(senders, worker_events, ownership, gauges,
                               admission, [10.0; N_MODELS], 0);
        (ing, receivers)
    }

    #[test]
    fn submit_assigns_ids_and_delivers() {
        let (ing, rx) = test_ingress(4, None);
        let a = ing.submit(ModelId::Res, 58.0, 1.0, 100.0).unwrap();
        let b = ing.submit(ModelId::Res, 58.0, 1.0, 101.0).unwrap();
        assert_ne!(a, b);
        let got = rx[ModelId::Res as usize].try_recv().unwrap();
        assert_eq!(got.id, a);
        assert_eq!(got.arrival_ms, 100.0);
        assert_eq!(got.slo_ms, 58.0);
    }

    #[test]
    fn full_channel_sheds_queue_full() {
        let (ing, _rx) = test_ingress(2, None);
        assert!(ing.submit(ModelId::Mob, 86.0, 0.0, 0.0).is_ok());
        assert!(ing.submit(ModelId::Mob, 86.0, 0.0, 0.0).is_ok());
        assert_eq!(ing.submit(ModelId::Mob, 86.0, 0.0, 0.0),
                   Err(ShedReason::QueueFull));
        let mut m = Metrics::new();
        ing.fold_sheds_into(&mut m);
        assert_eq!(m.shed_by_reason(ShedReason::QueueFull), 1);
        assert_eq!(m.shed_for(ModelId::Mob), 1);
    }

    #[test]
    fn closed_ingress_sheds_shutdown() {
        let (ing, _rx) = test_ingress(4, None);
        ing.close();
        assert!(!ing.is_accepting());
        assert_eq!(ing.submit(ModelId::Res, 58.0, 0.0, 0.0),
                   Err(ShedReason::Shutdown));
        let mut m = Metrics::new();
        ing.fold_sheds_into(&mut m);
        assert_eq!(m.shed_by_reason(ShedReason::Shutdown), 1);
    }

    #[test]
    fn fast_path_sheds_on_published_backlog() {
        let (ing, _rx) = test_ingress(64, Some(AdmissionConfig::default()));
        // Workers report 80 queued at 30 ms/batch → 11 batches ≈ 330 ms,
        // far beyond res's 58 ms SLO.
        ing.gauges.publish(ModelId::Res, 0, 80, 30.0);
        assert_eq!(ing.submit(ModelId::Res, 58.0, 0.0, 0.0),
                   Err(ShedReason::DeadlineUnmeetable));
        // An idle model still admits.
        assert!(ing.submit(ModelId::Bert, 114.0, 0.0, 0.0).is_ok());
    }

    /// With R replicas draining one model, the fast path prices the
    /// summed queue at 1/R — a scale-up immediately widens admission.
    #[test]
    fn fast_path_prices_replicated_queue_per_replica() {
        let mut senders = Vec::new();
        let mut _receivers = Vec::new();
        for _ in 0..N_MODELS {
            let (tx, rx) = sync_channel(64);
            senders.push(tx);
            _receivers.push(rx);
        }
        let worker_events =
            vec![Arc::new(WakeEvent::new()), Arc::new(WakeEvent::new())];
        let ownership = Arc::new(OwnershipTable::new_static(2));
        let gauges = Arc::new(SharedGauges::new());
        let ing = Ingress::new(senders, worker_events, ownership.clone(),
                               gauges, Some(AdmissionConfig::default()),
                               [10.0; N_MODELS], 0);
        // 80 queued at 30 ms/batch, 300 ms budget: 11 batches ≈ 330 ms —
        // a sole owner sheds.
        ing.gauges.publish(ModelId::Res, ownership.owner(ModelId::Res), 80,
                           30.0);
        assert_eq!(ing.submit(ModelId::Res, 300.0, 0.0, 0.0),
                   Err(ShedReason::DeadlineUnmeetable));
        // Two replicas: 40 effective → 6 batches ≈ 180 ms — admits.
        let other = 1 - ownership.owner(ModelId::Res);
        assert!(ownership.add_replica(ModelId::Res, other).is_some());
        assert!(ing.submit(ModelId::Res, 300.0, 0.0, 0.0).is_ok());
    }

    #[test]
    fn ownership_table_static_map_and_migration() {
        let t = OwnershipTable::new_static(2);
        for m in ModelId::all() {
            assert_eq!(t.owner(m), m as usize % 2, "static shard map");
            assert_eq!(t.replica_count(m), 1);
        }
        assert_eq!(t.epoch(), 0);
        assert_eq!(t.migrations(), 0);
        let e1 = t.migrate(ModelId::Yolo, 1);
        assert_eq!(e1, 1);
        assert_eq!(t.owner(ModelId::Yolo), 1);
        assert_eq!(t.migrations(), 1);
        let e2 = t.migrate(ModelId::Res, 1);
        assert_eq!(e2, 2);
        assert_eq!(t.epoch(), 2);
        // Workers clamp to [1, ..]; a degenerate pool is all-on-worker-0.
        let solo = OwnershipTable::new_static(0);
        for m in ModelId::all() {
            assert_eq!(solo.owner(m), 0);
        }
    }

    /// Replica-set lifecycle: scale-ups widen the mask (stamping epochs),
    /// scale-downs shrink it but never below one drainer, and a
    /// whole-model migration collapses the set to its destination.
    #[test]
    fn replica_set_scaling_guards_and_striping() {
        let t = OwnershipTable::new_static(3);
        let m = ModelId::Yolo;
        let home = t.owner(m);
        assert_eq!(t.replica_count(m), 1);
        assert!(t.is_replica(m, home));

        // Scale up onto two more workers.
        let others: Vec<usize> = (0..3).filter(|&w| w != home).collect();
        assert!(t.add_replica(m, others[0]).is_some());
        assert!(t.add_replica(m, others[1]).is_some());
        assert_eq!(t.replica_count(m), 3);
        assert_eq!(t.scale_ups(), 2);
        assert_eq!(t.peak_replicas(), 3);
        // Idempotent: re-adding an existing replica is a refused no-op.
        let epoch = t.epoch();
        assert!(t.add_replica(m, others[0]).is_none());
        assert_eq!(t.epoch(), epoch);
        // The primary is the lowest worker index in the set.
        assert_eq!(t.owner(m), 0);
        // nth_replica stripes over the set in ascending order, wrapping.
        assert_eq!(t.nth_replica(m, 0), 0);
        assert_eq!(t.nth_replica(m, 1), 1);
        assert_eq!(t.nth_replica(m, 2), 2);
        assert_eq!(t.nth_replica(m, 3), 0);

        // Scale down: removing a member works, removing a stranger or
        // the last member is refused.
        assert!(t.remove_replica(m, others[1]).is_some());
        assert_eq!(t.replica_count(m), 2);
        assert_eq!(t.scale_downs(), 1);
        assert!(t.remove_replica(m, others[1]).is_none(), "not a member");
        assert!(t.remove_replica(m, others[0]).is_some());
        assert!(t.remove_replica(m, home).is_none(),
                "must keep the last drainer");
        assert_eq!(t.replica_count(m), 1);

        // Migration collapses any set to exactly the destination.
        assert!(t.add_replica(m, others[0]).is_some());
        t.migrate(m, 2);
        assert_eq!(t.replica_count(m), 1);
        assert_eq!(t.owner(m), 2);
        // Peak survives the collapse (monotone high-water mark).
        assert_eq!(t.peak_replicas(), 3);
    }

    #[test]
    fn gauge_backlog_estimate_and_activity() {
        let g = SharedGauges::new();
        // Unobserved and empty: no backlog, inactive.
        assert_eq!(g.backlog_ms(ModelId::Res, 40.0, 8), 0.0);
        assert!(!g.is_active(ModelId::Res));
        // Queued but unprofiled: priced by the isolated fallback.
        g.publish(ModelId::Res, 0, 16, f64::NAN);
        assert!(g.is_active(ModelId::Res));
        assert!((g.backlog_ms(ModelId::Res, 40.0, 8) - 16.0 * 5.0).abs()
                    < 1e-9);
        // Profiled: priced by the rolling batch latency.
        g.publish(ModelId::Res, 0, 16, 24.0);
        assert!((g.backlog_ms(ModelId::Res, 40.0, 8) - 16.0 * 3.0).abs()
                    < 1e-9);
        // Drained but profiled: active (it has traffic history), zero
        // backlog.
        g.publish(ModelId::Res, 0, 0, 24.0);
        assert_eq!(g.backlog_ms(ModelId::Res, 40.0, 8), 0.0);
        assert!(g.is_active(ModelId::Res));
    }

    /// Per-worker gauge lanes: queues sum pool-wide, each lane prices its
    /// own backlog by its own latency profile, and the model-wide batch
    /// latency is the mean over lanes that have served it.
    #[test]
    fn gauge_lanes_sum_across_workers() {
        let g = SharedGauges::new();
        g.publish(ModelId::Yolo, 0, 24, 40.0);
        g.publish(ModelId::Yolo, 1, 8, f64::NAN);
        assert_eq!(g.queue_len(ModelId::Yolo), 32);
        assert_eq!(g.queue_len_for(ModelId::Yolo, 0), 24);
        assert_eq!(g.queue_len_for(ModelId::Yolo, 1), 8);
        // Lane 0 priced by its profile, lane 1 by the isolated fallback.
        assert!((g.backlog_ms_for(ModelId::Yolo, 0, 80.0, 8)
                     - 24.0 * 5.0).abs() < 1e-9);
        assert!((g.backlog_ms_for(ModelId::Yolo, 1, 80.0, 8)
                     - 8.0 * 10.0).abs() < 1e-9);
        assert!((g.backlog_ms(ModelId::Yolo, 80.0, 8) - 200.0).abs() < 1e-9);
        // Model-wide latency: mean over finite lanes only.
        assert!((g.batch_ms(ModelId::Yolo) - 40.0).abs() < 1e-9);
        g.publish(ModelId::Yolo, 1, 8, 20.0);
        assert!((g.batch_ms(ModelId::Yolo) - 30.0).abs() < 1e-9);
        // A worker emptying its lane keeps the others visible.
        g.publish(ModelId::Yolo, 0, 0, 40.0);
        assert_eq!(g.queue_len(ModelId::Yolo), 8);
        assert!(g.is_active(ModelId::Yolo));
    }

    /// The cluster-facing gauge export: queues priced per replica, batch
    /// estimates falling back to the isolated table before any profile,
    /// and totals summing over the zoo — the same numbers the admission
    /// fast path reads.
    #[test]
    fn gauge_snapshot_exports_pool_state() {
        let (ing, _rx) = test_ingress(8, Some(AdmissionConfig::default()));
        let cold = ing.gauge_snapshot();
        assert_eq!(cold.ref_batch, 8);
        assert_eq!(cold.queue_per_replica, [0; N_MODELS]);
        // Unprofiled models price at the isolated fallback (10 ms here).
        assert!((cold.est_batch_ms[ModelId::Res as usize] - 10.0).abs()
                    < 1e-9);
        assert_eq!(cold.total_backlog_ms, 0.0);
        // Empty queue: one batch ahead at the fallback latency.
        assert!((cold.service_est_ms(ModelId::Res) - 10.0).abs() < 1e-9);

        // 16 queued at 24 ms/batch: backlog 16 × 3 = 48 ms, service est
        // (16/8 + 1) × 24 = 72 ms.
        ing.gauges.publish(ModelId::Res, 0, 16, 24.0);
        let hot = ing.gauge_snapshot();
        assert_eq!(hot.queue_per_replica[ModelId::Res as usize], 16);
        assert!((hot.est_batch_ms[ModelId::Res as usize] - 24.0).abs()
                    < 1e-9);
        assert!((hot.backlog_ms[ModelId::Res as usize] - 48.0).abs() < 1e-9);
        assert!((hot.total_backlog_ms - 48.0).abs() < 1e-9);
        assert!((hot.service_est_ms(ModelId::Res) - 72.0).abs() < 1e-9);
    }

    /// Acceptance criterion (predictive tentpole): on a near-boundary
    /// overload where the rolling snapshot mean is stale-high (a burst
    /// just inflated it) but the predictor knows the true per-batch
    /// cost, predictive admission produces STRICTLY FEWER false sheds
    /// than snapshot at an equal-or-better accepted-violation rate.
    ///
    /// Every number is constructed, so ground truth is exact: isolated
    /// cost 10 ms/batch, true inflation 1.2 → a request behind 8 queued
    /// (2 batches at ref_batch 8) truly completes in 2 × 12 = 24 ms.
    /// The published rolling mean is 95 ms (stale), so the snapshot
    /// path prices the same request at 2 × 95 = 190 ms.
    #[test]
    fn predictive_admission_cuts_false_sheds_on_near_boundary_overload() {
        let true_e2e_ms = 24.0;
        let run = |admission: AdmissionConfig, warm: bool| -> (u64, u64, u64) {
            let (ing, _rx) = test_ingress(64, Some(admission));
            ing.gauges.publish(ModelId::Res, 0, 8, 95.0);
            if warm {
                ing.gauges.publish_prediction(ModelId::Res, 0, 1.2,
                                              f64::NAN);
            }
            let mut false_sheds = 0u64;
            let mut accepted_violations = 0u64;
            let mut accepted = 0u64;
            // 10 near-boundary (70 ms slack: truly feasible), 10 easy
            // (400 ms), 10 hopeless (20 ms: truly infeasible) arrivals.
            for slo in [70.0, 400.0, 20.0] {
                for _ in 0..10 {
                    let feasible = true_e2e_ms <= slo;
                    match ing.submit(ModelId::Res, slo, 0.0, 0.0) {
                        Ok(_) => {
                            accepted += 1;
                            if !feasible {
                                accepted_violations += 1;
                            }
                        }
                        Err(_) if feasible => false_sheds += 1,
                        Err(_) => {}
                    }
                }
            }
            (false_sheds, accepted_violations, accepted)
        };

        let snap = run(AdmissionConfig::default(), false);
        let pred = run(
            AdmissionConfig {
                mode: AdmissionMode::Predictive,
                ..Default::default()
            },
            true,
        );
        // Snapshot's stale mean sheds all 20 feasible requests (190 >
        // 70 and 190 > 400 is false — easy ones pass: 190 ≤ 400), so
        // only the 10 boundary requests are falsely shed.
        assert_eq!(snap, (10, 0, 10), "snapshot scenario drifted");
        // The predictor prices 24 ms: admits all 20 feasible, sheds the
        // 10 hopeless — zero false sheds, zero accepted violations.
        assert_eq!(pred, (0, 0, 20), "predictive scenario drifted");
        assert!(pred.0 < snap.0, "not strictly fewer false sheds");
        assert!(pred.1 <= snap.1, "accepted-violation rate regressed");

        // Fallback accounting: the warm run priced every decision from
        // the predictor; a cold pool (no published lanes) falls back on
        // every decision and reproduces snapshot behavior exactly.
        let cold_cfg = AdmissionConfig {
            mode: AdmissionMode::Predictive,
            ..Default::default()
        };
        let cold = run(cold_cfg, false);
        assert_eq!(cold, snap,
                   "cold predictive diverged from the snapshot oracle");
        let (ing, _rx) = test_ingress(64, Some(cold_cfg));
        ing.gauges.publish(ModelId::Res, 0, 8, 95.0);
        let _ = ing.submit(ModelId::Res, 70.0, 0.0, 0.0);
        let _ = ing.submit(ModelId::Res, 400.0, 0.0, 0.0);
        let mut m = Metrics::new();
        ing.fold_sheds_into(&mut m);
        assert_eq!((m.headroom_decisions(), m.headroom_fallbacks()), (2, 2),
                   "cold predictive decisions must all count as fallbacks");
    }

    /// Request-id namespacing: an ingress started at a non-zero id base
    /// stamps ids from there — how cluster nodes keep outcome ids unique
    /// pool-wide without coordination.
    #[test]
    fn first_request_id_offsets_the_id_space() {
        let mut senders = Vec::new();
        let mut _receivers = Vec::new();
        for _ in 0..N_MODELS {
            let (tx, rx) = sync_channel(4);
            senders.push(tx);
            _receivers.push(rx);
        }
        let ing = Ingress::new(senders, vec![Arc::new(WakeEvent::new())],
                               Arc::new(OwnershipTable::new_static(1)),
                               Arc::new(SharedGauges::new()), None,
                               [10.0; N_MODELS], 1u64 << 40);
        let a = ing.submit(ModelId::Res, 58.0, 1.0, 0.0).unwrap();
        let b = ing.submit(ModelId::Res, 58.0, 1.0, 1.0).unwrap();
        assert_eq!(a, 1u64 << 40);
        assert_eq!(b, (1u64 << 40) + 1);
    }

    #[test]
    fn wake_event_roundtrip() {
        let e = Arc::new(WakeEvent::new());
        let e2 = e.clone();
        let t = std::thread::spawn(move || {
            e2.wait_timeout(Duration::from_secs(5));
        });
        std::thread::sleep(Duration::from_millis(10));
        e.notify();
        t.join().unwrap(); // returns promptly — would time out otherwise
        // Pre-signaled waits return immediately.
        e.notify();
        let t0 = std::time::Instant::now();
        e.wait_timeout(Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_secs(1));
    }
}
