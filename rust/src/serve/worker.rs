//! Serving workers: N OS threads, each owning a full [`Engine`] (with its
//! own dispatcher, profiler, predictor, and scheduler) and draining a
//! shard of the model zoo. The paper's "concurrent model instances"
//! become actual parallel execution — worker threads overlap in wall
//! time — while the virtual-clock arm keeps every worker a deterministic
//! discrete-event simulation (bit-identical to the single-threaded
//! engine when `workers == 1`).
//!
//! Two intake modes share the engine code path:
//!
//! * **trace** — the worker's whole arrival shard is known up front
//!   (virtual-clock benches, seed-equivalence tests): submit + run.
//! * **live** — requests stream in over the per-model ingress channels
//!   (wall clock): drain channels, serve a round, publish gauges, park
//!   when idle, exit once the ingress disconnects and queues are flushed.

use super::admission::{AdmissionConfig, AdmissionGate};
use super::ingress::{SharedGauges, WakeEvent};
use crate::coordinator::{Engine, Scheduler};
use crate::metrics::Metrics;
use crate::runtime::executor::SimDispatcher;
use crate::workload::models::{ModelId, N_MODELS};
use crate::workload::request::Request;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

/// What one worker hands back at shutdown.
pub struct WorkerResult {
    pub metrics: Metrics,
    /// Per-model scheduling slots executed.
    pub slots: u64,
    /// Requests still queued when the worker stopped (horizon expired
    /// before the backlog drained).
    pub leftover: usize,
}

/// A request completion.
#[derive(Clone, Copy, Debug)]
pub struct CompletionEvent {
    pub id: u64,
    pub model: ModelId,
    pub e2e_ms: f64,
    pub violated: bool,
}

/// Request-terminal events streamed to load-generator clients. Closed-loop
/// clients must free an in-flight slot on EITHER variant — a request the
/// engine gate sheds will never produce a completion, and treating sheds
/// as still-in-flight would starve the client loop under exactly the
/// overload it exists to measure.
#[derive(Clone, Copy, Debug)]
pub enum ServeEvent {
    Completed(CompletionEvent),
    /// The engine-side admission gate shed a delivered request.
    Shed { model: ModelId },
}

/// Trace-mode worker: the shard's arrivals are fully known, so the run
/// IS the engine's serve loop — with one worker and no admission gate
/// this path is bit-identical to driving the engine directly.
pub fn run_trace_worker(mut engine: Engine<SimDispatcher>,
                        scheduler: &mut dyn Scheduler, shard: Vec<Request>,
                        horizon_ms: f64) -> WorkerResult {
    engine.submit(shard);
    let slots = engine.run(scheduler, horizon_ms);
    WorkerResult {
        slots,
        leftover: engine.total_queued(),
        metrics: std::mem::take(&mut engine.metrics),
    }
}

/// Everything a live worker owns.
pub struct LiveWorker {
    pub engine: Engine<SimDispatcher>,
    /// This worker's model shard, parallel to `receivers`.
    pub models: Vec<ModelId>,
    pub receivers: Vec<Receiver<Request>>,
    pub event: Arc<WakeEvent>,
    pub gauges: Arc<SharedGauges>,
    pub admission: Option<AdmissionConfig>,
    pub events_tx: Option<std::sync::mpsc::Sender<ServeEvent>>,
}

/// How long an idle live worker parks before re-polling its channels
/// (a missed wake costs at most this much added latency).
const IDLE_PARK: Duration = Duration::from_millis(1);

impl LiveWorker {
    /// The live serve loop. Returns after the ingress disconnects every
    /// channel AND the engine has flushed its queues (the drain
    /// protocol's "stop intake → flush → join" middle step).
    pub fn run(mut self, scheduler: &mut dyn Scheduler) -> WorkerResult {
        if let Some(cfg) = self.admission {
            self.engine.set_ingress_gate(Some(Box::new(AdmissionGate::new(cfg))));
        }
        let mut outcomes = Vec::new();
        let mut open = vec![true; self.receivers.len()];
        let mut slots = 0u64;
        let mut reported = 0usize;
        let mut sheds_seen = [0u64; N_MODELS];
        loop {
            // Intake: drain whatever the ingress has delivered.
            let mut intake_done = true;
            for (i, rx) in self.receivers.iter().enumerate() {
                if !open[i] {
                    continue;
                }
                loop {
                    match rx.try_recv() {
                        Ok(r) => self.engine.push_request(r),
                        Err(TryRecvError::Empty) => {
                            intake_done = false;
                            break;
                        }
                        Err(TryRecvError::Disconnected) => {
                            open[i] = false;
                            break;
                        }
                    }
                }
            }
            // Serve one scheduling round.
            let served = self.engine.step_into(scheduler, &mut outcomes);
            if let Some(n) = served {
                slots += n as u64;
            }
            self.publish_gauges();
            reported = self.notify_events(reported, &mut sheds_seen);
            match served {
                Some(_) => {}
                // Idle with intake closed and queues flushed: drained.
                None if intake_done => break,
                // Idle but the ingress is still open: park until work.
                None => self.event.wait_timeout(IDLE_PARK),
            }
        }
        WorkerResult {
            slots,
            leftover: self.engine.total_queued(),
            metrics: std::mem::take(&mut self.engine.metrics),
        }
    }

    /// Publish this shard's queue depths + rolling batch latencies for
    /// the ingress fast path. The latency gauge stays NaN until the
    /// profiler has observations — the admission decision function owns
    /// the isolated-estimate fallback, so the policy lives in one place.
    fn publish_gauges(&self) {
        for &m in &self.models {
            self.gauges.publish(m, self.engine.queue_len(m),
                                self.engine.profiler.mean_latency_ms(m));
        }
    }

    /// Stream request-terminal events recorded since the last round —
    /// completions AND engine-gate sheds — to the load-generator clients.
    /// Returns the new outcome high-water mark; `sheds_seen` tracks the
    /// per-model shed counts already reported.
    fn notify_events(&self, reported: usize,
                     sheds_seen: &mut [u64; N_MODELS]) -> usize {
        let outcomes = self.engine.metrics.outcomes();
        if let Some(tx) = &self.events_tx {
            for o in &outcomes[reported..] {
                // A dropped receiver just means nobody is listening.
                let _ = tx.send(ServeEvent::Completed(CompletionEvent {
                    id: o.id,
                    model: o.model,
                    e2e_ms: o.e2e_ms,
                    violated: o.violated,
                }));
            }
            for &m in &self.models {
                let seen = &mut sheds_seen[m as usize];
                let now = self.engine.metrics.shed_for(m);
                for _ in *seen..now {
                    let _ = tx.send(ServeEvent::Shed { model: m });
                }
                *seen = now;
            }
        }
        outcomes.len()
    }
}
