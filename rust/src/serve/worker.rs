//! Serving workers: N OS threads, each owning a full [`Engine`] (with its
//! own dispatcher, profiler, predictor, and scheduler) and draining the
//! shard of the model zoo the [`OwnershipTable`] currently assigns it.
//! The paper's "concurrent model instances" become actual parallel
//! execution — worker threads overlap in wall time — while the
//! virtual-clock arm keeps every worker a deterministic discrete-event
//! simulation (bit-identical to the single-threaded engine when
//! `workers == 1`).
//!
//! Two intake modes share the engine code path:
//!
//! * **trace** — the worker's whole arrival shard is known up front
//!   (virtual-clock benches, seed-equivalence tests): submit + run. The
//!   shard map is static here; resharding needs live gauges.
//! * **live** — requests stream in over the per-model ingress channels
//!   (wall clock): drain the channels of currently-owned models, serve a
//!   round, publish gauges, park when idle, exit once intake is closed
//!   and the queues are flushed. Ownership is DYNAMIC: when the
//!   rebalance controller migrates a model away, the worker flushes that
//!   model's queued backlog into the shared [`ModelIntake`] slot on its
//!   next round and the new owner picks it up — requests are handed
//!   over, never dropped or double-served.

use super::admission::{AdmissionConfig, AdmissionGate};
use super::ingress::{ModelIntake, OwnershipTable, SharedGauges, WakeEvent};
use crate::coordinator::{Engine, Scheduler};
use crate::metrics::Metrics;
use crate::runtime::executor::SimDispatcher;
use crate::workload::models::{ModelId, N_MODELS};
use crate::workload::request::Request;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::TryRecvError;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// What one worker hands back at shutdown.
pub struct WorkerResult {
    pub metrics: Metrics,
    /// Per-model scheduling slots executed.
    pub slots: u64,
    /// Requests still queued when the worker stopped (horizon expired
    /// before the backlog drained).
    pub leftover: usize,
}

/// A request completion.
#[derive(Clone, Copy, Debug)]
pub struct CompletionEvent {
    pub id: u64,
    pub model: ModelId,
    pub e2e_ms: f64,
    pub violated: bool,
}

/// Request-terminal events streamed to load-generator clients. Closed-loop
/// clients must free an in-flight slot on EITHER variant — a request the
/// engine gate sheds will never produce a completion, and treating sheds
/// as still-in-flight would starve the client loop under exactly the
/// overload it exists to measure.
#[derive(Clone, Copy, Debug)]
pub enum ServeEvent {
    Completed(CompletionEvent),
    /// The engine-side admission gate shed a delivered request.
    Shed { model: ModelId },
}

/// Trace-mode worker: the shard's arrivals are fully known, so the run
/// IS the engine's serve loop — with one worker and no admission gate
/// this path is bit-identical to driving the engine directly.
pub fn run_trace_worker(mut engine: Engine<SimDispatcher>,
                        scheduler: &mut dyn Scheduler, shard: Vec<Request>,
                        horizon_ms: f64) -> WorkerResult {
    engine.submit(shard);
    let slots = engine.run(scheduler, horizon_ms);
    WorkerResult {
        slots,
        leftover: engine.total_queued(),
        metrics: std::mem::take(&mut engine.metrics),
    }
}

/// Everything a live worker owns (or shares with the pool).
pub struct LiveWorker {
    /// This worker's index in the pool — matched against the ownership
    /// table every intake pass.
    pub id: usize,
    pub engine: Engine<SimDispatcher>,
    /// All N_MODELS intake slots, shared across the pool; the ownership
    /// table says which ones this worker drains right now.
    pub intake: Arc<Vec<Mutex<ModelIntake>>>,
    pub ownership: Arc<OwnershipTable>,
    /// Every worker's parking event — `worker_events[id]` is OURS (the
    /// ingress and the rebalance controller ring it); the rest are for
    /// waking a migration's new owner.
    pub worker_events: Vec<Arc<WakeEvent>>,
    pub gauges: Arc<SharedGauges>,
    pub admission: Option<AdmissionConfig>,
    /// Isolated latency at the reference batch, per model (prices the
    /// gauge-hint backlog before a model is profiled).
    pub isolated_ref_ms: [f64; N_MODELS],
    pub ref_batch: usize,
    /// Feed cross-worker backlog summaries into the scheduler context
    /// (off for single-worker pools so they stay bit-identical to the
    /// bare engine).
    pub cluster_hints: bool,
    /// Set by the server when a drain begins: stop handing backlog to
    /// other workers and serve whatever we hold.
    pub closed: Arc<AtomicBool>,
    pub events_tx: Option<std::sync::mpsc::Sender<ServeEvent>>,
}

/// How long an idle live worker parks before re-polling its channels
/// (a missed wake costs at most this much added latency).
const IDLE_PARK: Duration = Duration::from_millis(1);

impl LiveWorker {
    /// The live serve loop. Returns after the drain flag is up, every
    /// owned channel has disconnected, and the engine has flushed its
    /// queues (the drain protocol's "stop intake → flush → join" middle
    /// step).
    pub fn run(mut self, scheduler: &mut dyn Scheduler) -> WorkerResult {
        if let Some(cfg) = self.admission {
            self.engine.set_ingress_gate(Some(Box::new(AdmissionGate::new(cfg))));
        }
        let mut outcomes = Vec::new();
        let mut slots = 0u64;
        let mut reported = 0usize;
        let mut sheds_seen = [0u64; N_MODELS];
        // Ownership epoch seen at the last intake pass: the disowned-
        // backlog scan only needs to run when the table actually changed
        // (backlog for a model we don't own can only appear via a
        // migration). u64::MAX forces the first pass to scan.
        let mut seen_epoch = u64::MAX;
        loop {
            let closing = self.closed.load(Ordering::Acquire);
            let epoch = self.ownership.epoch();
            let intake_done = self.intake_pass(closing, epoch != seen_epoch);
            seen_epoch = epoch;
            // Serve one scheduling round.
            let served = self.engine.step_into(scheduler, &mut outcomes);
            if let Some(n) = served {
                slots += n as u64;
            }
            self.publish_gauges();
            if self.cluster_hints {
                self.update_cluster_hints();
            }
            reported = self.notify_events(reported, &mut sheds_seen);
            match served {
                Some(_) => {}
                // Idle with the drain flag up, every owned channel
                // disconnected, and no handoff pending: drained. The
                // final owned_intake_clear re-check closes the window
                // where a migration handoff lands between the intake
                // pass and this decision.
                None if closing && intake_done && self.owned_intake_clear() => {
                    break
                }
                // Idle but the ingress is still open: park until work.
                None => self.worker_events[self.id].wait_timeout(IDLE_PARK),
            }
        }
        WorkerResult {
            slots,
            leftover: self.engine.total_queued(),
            metrics: std::mem::take(&mut self.engine.metrics),
        }
    }

    /// One intake pass over every model slot. Owned models: pick up any
    /// migration handoff, then drain the ingress channel. When the
    /// ownership epoch moved (`scan_disowned`), also check for backlog
    /// we hold for models migrated away and flush it to the new owner
    /// (unless a drain has begun — then we keep and serve it ourselves,
    /// so shutdown never bounces requests between exiting workers).
    /// Returns true when every owned channel has disconnected.
    fn intake_pass(&mut self, closing: bool, scan_disowned: bool) -> bool {
        let mut done = true;
        for model in ModelId::all() {
            let idx = model as usize;
            if self.ownership.owner(model) == self.id {
                let mut slot = self.intake[idx].lock().unwrap();
                for r in slot.handoff.drain(..) {
                    self.engine.push_request(r);
                }
                if !slot.closed {
                    loop {
                        match slot.rx.try_recv() {
                            Ok(r) => self.engine.push_request(r),
                            Err(TryRecvError::Empty) => {
                                done = false;
                                break;
                            }
                            Err(TryRecvError::Disconnected) => {
                                slot.closed = true;
                                break;
                            }
                        }
                    }
                }
            } else if scan_disowned && !closing
                && self.engine.holds_model(model)
            {
                let new_owner = self.ownership.owner(model);
                let moved = {
                    let mut slot = self.intake[idx].lock().unwrap();
                    self.engine.drain_model_into(model, &mut slot.handoff)
                };
                if moved > 0 {
                    self.worker_events[new_owner].notify();
                }
            }
        }
        done
    }

    /// Exit gate: re-verify under the locks that every owned slot is
    /// disconnected with an empty handoff buffer, so a flush that landed
    /// after the intake pass is never stranded.
    fn owned_intake_clear(&self) -> bool {
        ModelId::all().into_iter().all(|m| {
            if self.ownership.owner(m) != self.id {
                return true;
            }
            let slot = self.intake[m as usize].lock().unwrap();
            slot.closed && slot.handoff.is_empty()
        })
    }

    /// Publish the owned shard's queue depths + rolling batch latencies
    /// for the ingress fast path and the rebalance controller. The
    /// latency gauge stays NaN until the profiler has observations — the
    /// admission decision function owns the isolated-estimate fallback,
    /// so the policy lives in one place.
    ///
    /// Mid-migration a model's backlog is split between the handoff slot
    /// (counted by the new owner below) and the OLD owner's engine
    /// (published by the still-holding branch), so a hot queue never
    /// reads 0 just because ownership moved — that blind spot would let
    /// the admission fast path under-price the model and feed the
    /// controller a falsely collapsed imbalance. The two sides may
    /// overwrite each other for the ≤1 round the flush takes; either
    /// value is honest about real queued work.
    fn publish_gauges(&self) {
        for m in ModelId::all() {
            let idx = m as usize;
            if self.ownership.owner(m) == self.id {
                let in_handoff = self.intake[idx].lock().unwrap().handoff.len();
                self.gauges.publish(m, self.engine.queue_len(m) + in_handoff,
                                    self.engine.profiler.mean_latency_ms(m));
            } else if self.engine.holds_model(m) {
                self.gauges.publish(m, self.engine.queue_len(m),
                                    self.engine.profiler.mean_latency_ms(m));
            }
        }
    }

    /// Fold the pool-wide gauges into the engine's decision context:
    /// total estimated backlog across every worker and this worker's
    /// share of it, so SAC/DeepRT see cluster pressure instead of just
    /// their own shard.
    fn update_cluster_hints(&mut self) {
        let mut total = 0.0;
        let mut local = 0.0;
        for m in ModelId::all() {
            let b = self.gauges.backlog_ms(
                m, self.isolated_ref_ms[m as usize], self.ref_batch);
            total += b;
            if self.ownership.owner(m) == self.id {
                local += b;
            }
        }
        let share = if total > 0.0 { local / total } else { 0.0 };
        self.engine.set_cluster_hints(total, share);
    }

    /// Stream request-terminal events recorded since the last round —
    /// completions AND engine-gate sheds — to the load-generator clients.
    /// Returns the new outcome high-water mark; `sheds_seen` tracks the
    /// per-model shed counts already reported.
    fn notify_events(&self, reported: usize,
                     sheds_seen: &mut [u64; N_MODELS]) -> usize {
        let outcomes = self.engine.metrics.outcomes();
        if let Some(tx) = &self.events_tx {
            for o in &outcomes[reported..] {
                // A dropped receiver just means nobody is listening.
                let _ = tx.send(ServeEvent::Completed(CompletionEvent {
                    id: o.id,
                    model: o.model,
                    e2e_ms: o.e2e_ms,
                    violated: o.violated,
                }));
            }
            for m in ModelId::all() {
                let seen = &mut sheds_seen[m as usize];
                let now = self.engine.metrics.shed_for(m);
                for _ in *seen..now {
                    let _ = tx.send(ServeEvent::Shed { model: m });
                }
                *seen = now;
            }
        }
        outcomes.len()
    }
}
