//! Serving workers: N OS threads, each owning a full [`Engine`] (with its
//! own dispatcher, profiler, predictor, and scheduler) and draining the
//! shard of the model zoo the [`OwnershipTable`] currently assigns it.
//! The paper's "concurrent model instances" become actual parallel
//! execution — worker threads overlap in wall time — while the
//! virtual-clock arm keeps every worker a deterministic discrete-event
//! simulation (bit-identical to the single-threaded engine when
//! `workers == 1`).
//!
//! Two intake modes share the engine code path:
//!
//! * **trace** — the worker's whole arrival shard is known up front:
//!   submit + run. Wall-clock trace runs use this per-thread path on
//!   static modulo shards; virtual trace runs instead go through the
//!   fabric (`super::fabric`), where deliveries arrive per-event and the
//!   same dynamic resharding/replication below applies.
//! * **live** — requests stream in over the per-model ingress channels
//!   (wall clock): drain the channels of currently-assigned models,
//!   serve a round, publish gauges, park when idle, exit once intake is
//!   closed and the queues are flushed. Ownership is DYNAMIC and may be
//!   REPLICATED: when the rebalance controller migrates a model away (or
//!   scales this worker out of its replica set), the worker flushes that
//!   model's queued backlog into the shared [`ModelIntake`] slot on its
//!   next round and the current drainers pick it up — requests are
//!   handed over, never dropped or double-served. When several workers
//!   replicate one hot model, each pops a bounded stripe of its channel
//!   per pass and sheds above-fair-share surplus back through the same
//!   handoff slot, so the model's queue stays spread across the set.

use super::admission::{AdmissionConfig, AdmissionGate};
use crate::predictor::AdmissionMode;
use super::ingress::{ModelIntake, OwnershipTable, SharedGauges, WakeEvent};
use crate::coordinator::{Engine, Scheduler};
use crate::metrics::Metrics;
use crate::runtime::executor::SimDispatcher;
use crate::telemetry::{TelemetryHub, TraceReport};
use crate::workload::models::{ModelId, N_MODELS};
use crate::workload::request::Request;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::TryRecvError;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// What one worker hands back at shutdown.
pub struct WorkerResult {
    pub metrics: Metrics,
    /// Per-model scheduling slots executed.
    pub slots: u64,
    /// Requests still queued when the worker stopped (horizon expired
    /// before the backlog drained).
    pub leftover: usize,
    /// Sampled span records + raw action histogram this worker's tracer
    /// collected (empty when tracing is off).
    pub telemetry: TraceReport,
}

/// A request completion.
#[derive(Clone, Copy, Debug)]
pub struct CompletionEvent {
    pub id: u64,
    pub model: ModelId,
    pub e2e_ms: f64,
    pub violated: bool,
}

/// Request-terminal events streamed to load-generator clients. Closed-loop
/// clients must free an in-flight slot on EITHER variant — a request the
/// engine gate sheds will never produce a completion, and treating sheds
/// as still-in-flight would starve the client loop under exactly the
/// overload it exists to measure.
#[derive(Clone, Copy, Debug)]
pub enum ServeEvent {
    Completed(CompletionEvent),
    /// The engine-side admission gate shed a delivered request.
    Shed { model: ModelId },
}

/// Trace-mode worker: the shard's arrivals are fully known, so the run
/// IS the engine's serve loop — with one worker and no admission gate
/// this path is bit-identical to driving the engine directly.
pub fn run_trace_worker(mut engine: Engine<SimDispatcher>,
                        scheduler: &mut dyn Scheduler, shard: Vec<Request>,
                        horizon_ms: f64) -> WorkerResult {
    engine.submit(shard);
    let slots = engine.run(scheduler, horizon_ms);
    let telemetry = engine.take_telemetry();
    WorkerResult {
        slots,
        leftover: engine.total_queued(),
        metrics: std::mem::take(&mut engine.metrics),
        telemetry,
    }
}

/// Everything a live worker owns (or shares with the pool).
pub struct LiveWorker {
    /// This worker's index in the pool — matched against the ownership
    /// table every intake pass.
    pub id: usize,
    pub engine: Engine<SimDispatcher>,
    /// All N_MODELS intake slots, shared across the pool; the ownership
    /// table says which ones this worker drains right now.
    pub intake: Arc<Vec<Mutex<ModelIntake>>>,
    pub ownership: Arc<OwnershipTable>,
    /// Every worker's parking event — `worker_events[id]` is OURS (the
    /// ingress and the rebalance controller ring it); the rest are for
    /// waking a migration's new owner.
    pub worker_events: Vec<Arc<WakeEvent>>,
    pub gauges: Arc<SharedGauges>,
    pub admission: Option<AdmissionConfig>,
    /// Isolated latency at the reference batch, per model (prices the
    /// gauge-hint backlog before a model is profiled).
    pub isolated_ref_ms: [f64; N_MODELS],
    pub ref_batch: usize,
    /// Feed cross-worker backlog summaries into the scheduler context
    /// (off for single-worker pools so they stay bit-identical to the
    /// bare engine).
    pub cluster_hints: bool,
    /// Set by the server when a drain begins: stop handing backlog to
    /// other workers and serve whatever we hold.
    pub closed: Arc<AtomicBool>,
    pub events_tx: Option<std::sync::mpsc::Sender<ServeEvent>>,
    /// Live telemetry counters shared with the server's publisher
    /// thread (`None` unless `--metrics-out` is set — the hot path then
    /// carries no atomics at all).
    pub hub: Option<Arc<TelemetryHub>>,
}

/// How long an idle live worker parks before re-polling its channels
/// (a missed wake costs at most this much added latency).
const IDLE_PARK: Duration = Duration::from_millis(1);

/// Channel pops per REPLICATED model per intake pass: when several
/// workers drain one model, each takes a bounded stripe so arrivals
/// spread across the replica set instead of all landing on whichever
/// replica polls first. Sole owners (and workers mid-drain) pop
/// unbounded — exactly the pre-replication behaviour. Doubling as the
/// fair-share hysteresis, it also bounds how lopsided a replica set can
/// get before the surplus flush kicks in.
const REPLICA_STRIPE: usize = 32;

/// Size a striped replica's per-pass channel budget by deadline
/// pressure. `min_slack_ms` is how much slack the replica's most urgent
/// QUEUED request has left (`None` = empty queue); `est_batch_ms` prices
/// one batch span. Plenty of slack (≥ 4 spans) keeps the base stripe —
/// the spreading behaviour replication was built on; under 2 spans the
/// stripe quadruples so channel arrivals reach the scheduler inside the
/// deadline instead of waiting out extra passes; between them it
/// doubles. Unpriceable batch estimates (NaN/zero — nothing profiled
/// yet) keep the base stripe: no evidence, no deviation. Pure, so the
/// policy is unit-testable without a pool.
fn stripe_budget(base: usize, min_slack_ms: Option<f64>,
                 est_batch_ms: f64) -> usize {
    if !est_batch_ms.is_finite() || est_batch_ms <= 0.0 {
        return base;
    }
    match min_slack_ms {
        None => base,
        Some(slack) => {
            let spans = slack / est_batch_ms;
            if spans >= 4.0 {
                base
            } else if spans >= 2.0 {
                base * 2
            } else {
                base * 4
            }
        }
    }
}

impl LiveWorker {
    /// The live serve loop. Returns after the drain flag is up, every
    /// owned channel has disconnected, and the engine has flushed its
    /// queues (the drain protocol's "stop intake → flush → join" middle
    /// step).
    pub fn run(mut self, scheduler: &mut dyn Scheduler) -> WorkerResult {
        if let Some(cfg) = self.admission {
            self.engine.set_ingress_gate(Some(Box::new(AdmissionGate::new(cfg))));
        }
        let mut outcomes = Vec::new();
        let mut slots = 0u64;
        let mut reported = 0usize;
        let mut sheds_seen = [0u64; N_MODELS];
        // Ownership epoch seen at the last intake pass: the disowned-
        // backlog scan only needs to run when the table actually changed
        // (backlog for a model we don't own can only appear via a
        // migration). u64::MAX forces the first pass to scan.
        let mut seen_epoch = u64::MAX;
        // Models whose surplus this worker flushed on the previous round:
        // it skips exactly one handoff-pickup pass for them, so another
        // replica gets first claim on the flush (see share_excess).
        let mut flushed = [false; N_MODELS];
        loop {
            let closing = self.closed.load(Ordering::Acquire);
            let epoch = self.ownership.epoch();
            let intake_done =
                self.intake_pass(closing, epoch != seen_epoch, &mut flushed);
            seen_epoch = epoch;
            if !closing {
                self.share_excess(&mut flushed);
            }
            if self.cluster_hints {
                // Pool-state scheduler features share one opt-out:
                // --no-gauge-hints keeps the decision context pool-blind
                // (cluster AND replica features stay 0, the bare-engine
                // encoding), even while replication keeps acting on the
                // queues themselves.
                self.update_replica_shares();
            }
            // Serve one scheduling round.
            let served = self.engine.step_into(scheduler, &mut outcomes);
            if let Some(n) = served {
                slots += n as u64;
            }
            self.publish_gauges();
            if self.cluster_hints {
                self.update_cluster_hints();
            }
            reported = self.notify_events(reported, &mut sheds_seen);
            match served {
                Some(_) => {}
                // Idle with the drain flag up, every owned channel
                // disconnected, and no handoff pending: drained. The
                // final owned_intake_clear re-check closes the window
                // where a migration handoff lands between the intake
                // pass and this decision.
                None if closing && intake_done && self.owned_intake_clear() => {
                    break
                }
                // Idle but the ingress is still open: park until work.
                None => self.worker_events[self.id].wait_timeout(IDLE_PARK),
            }
        }
        let telemetry = self.engine.take_telemetry();
        let (decisions, fallbacks) = self.engine.gate_headroom_stats();
        self.engine.metrics.record_headroom(decisions, fallbacks);
        WorkerResult {
            slots,
            leftover: self.engine.total_queued(),
            metrics: std::mem::take(&mut self.engine.metrics),
            telemetry,
        }
    }

    /// One intake pass over every model slot. Models this worker drains
    /// (sole owner or replica-set member): pick up any handoff backlog,
    /// then pop the ingress channel — unbounded as a sole owner, a
    /// bounded stripe per pass inside a replica set, so arrivals spread
    /// across the set. When the ownership epoch moved (`scan_disowned`),
    /// also check for backlog we hold for models we no longer drain —
    /// migrated away or scaled down — and flush it to the current
    /// drainers (unless a drain has begun — then we keep and serve it
    /// ourselves, so shutdown never bounces requests between exiting
    /// workers). Returns true when every drained channel has
    /// disconnected and no handoff is pending.
    fn intake_pass(&mut self, closing: bool, scan_disowned: bool,
                   flushed: &mut [bool; N_MODELS]) -> bool {
        let mut done = true;
        for model in ModelId::all() {
            let idx = model as usize;
            // One mask load, both facts derived from it: reading
            // membership and set width separately could straddle a
            // concurrent scale event and combine "I'm a replica" with
            // the post-removal count, turning this pass into an
            // unbounded pop on a model we no longer drain.
            let mask = self.ownership.replica_mask(model);
            if mask & (1u64 << self.id) != 0 {
                let replicas = mask.count_ones().max(1) as usize;
                let striped = replicas > 1 && !closing;
                // Handoff pickup: a striped replica only takes it while
                // at or below its fair share of the model's pool-wide
                // queue, and NEVER on the pass right after it shed
                // surplus itself — the gauges lag a round, so without
                // the `flushed` latch the flusher would still look
                // under-share and reclaim its own flush before the
                // notified replica reaches the slot lock.
                let was_flushed = std::mem::take(&mut flushed[idx]);
                let fair = if striped {
                    Some(self.fair_share(model, replicas))
                } else {
                    None
                };
                let take_handoff = !(striped && was_flushed)
                    && fair.map(|(mine, share)| mine <= share).unwrap_or(true);
                let mut slot = self.intake[idx].lock().unwrap();
                if take_handoff && !slot.handoff.is_empty() {
                    // Bounded pickup: only up to this replica's fair-
                    // share headroom (floored at one stripe so a small
                    // remainder is never stranded); the rest stays for
                    // the other replicas instead of bouncing through
                    // this one in a re-flush. Head-first, because the
                    // flusher sheds tightest deadlines first — the head
                    // is the most urgent work.
                    let take = match fair {
                        Some((mine, share)) => slot.handoff.len().min(
                            share.saturating_sub(mine).max(REPLICA_STRIPE),
                        ),
                        None => slot.handoff.len(),
                    };
                    for r in slot.handoff.drain(..take) {
                        self.engine.push_request(r);
                    }
                }
                if !slot.handoff.is_empty() {
                    done = false;
                }
                if !slot.closed {
                    // Deadline-aware stripe sizing: a striped replica
                    // whose queued work's tightest deadline is within a
                    // couple of batch spans pops a deeper stripe this
                    // pass — urgent arrivals must reach the scheduler
                    // before their slack is gone, and the fair-share
                    // flush rebalances any overshoot next round.
                    let mut budget = if striped {
                        let batch = self.gauges.batch_ms(model);
                        let est = if batch.is_finite() && batch > 0.0 {
                            batch
                        } else {
                            self.isolated_ref_ms[idx]
                        };
                        let min_slack = self
                            .engine
                            .min_deadline_ms(model)
                            .map(|d| d - self.engine.now_ms());
                        stripe_budget(REPLICA_STRIPE, min_slack, est)
                    } else {
                        usize::MAX
                    };
                    loop {
                        if budget == 0 {
                            done = false;
                            break;
                        }
                        match slot.rx.try_recv() {
                            Ok(r) => {
                                self.engine.push_request(r);
                                budget -= 1;
                            }
                            Err(TryRecvError::Empty) => {
                                done = false;
                                break;
                            }
                            Err(TryRecvError::Disconnected) => {
                                slot.closed = true;
                                break;
                            }
                        }
                    }
                }
            } else if scan_disowned && !closing
                && self.engine.holds_model(model)
            {
                let moved = {
                    let mut slot = self.intake[idx].lock().unwrap();
                    self.engine.drain_model_into(model, &mut slot.handoff)
                };
                if moved > 0 {
                    self.notify_replicas(model);
                }
            }
        }
        done
    }

    /// This worker's local queue for `model` and its fair share of the
    /// replica set's pool-wide queue, per the last-published gauges.
    /// (Gauges lag a round; the pool sum is floored by our own live
    /// count so a fresh replica never divides by a stale zero.) The ONE
    /// fair-share definition both the surplus shed and the handoff
    /// pickup use, so the hysteresis pair can never drift apart.
    fn fair_share(&self, model: ModelId, replicas: usize) -> (usize, usize) {
        let mine = self.engine.queue_len(model);
        let total = self.gauges.queue_len(model).max(mine);
        (mine, total / replicas.max(1))
    }

    /// Intra-set load balancing: when this worker holds clearly more
    /// than its fair share of a replicated model's pool-wide queue
    /// (fair share + one stripe of hysteresis), flush the surplus into
    /// the shared handoff slot for an under-loaded replica to pick up.
    /// The `flushed` latch makes this worker sit out the next pickup
    /// pass (so a notified replica gets first claim — if none takes it,
    /// the flusher may reclaim it a round later rather than strand it);
    /// the hysteresis keeps gauge staleness from ping-ponging requests
    /// between replicas.
    fn share_excess(&mut self, flushed: &mut [bool; N_MODELS]) {
        for model in ModelId::all() {
            // Single mask load (see intake_pass) for a consistent
            // membership + width view.
            let mask = self.ownership.replica_mask(model);
            if mask & (1u64 << self.id) == 0 {
                continue;
            }
            let replicas = mask.count_ones() as usize;
            if replicas < 2 {
                continue;
            }
            let (mine, share) = self.fair_share(model, replicas);
            if mine > share + REPLICA_STRIPE {
                let moved = {
                    let mut slot =
                        self.intake[model as usize].lock().unwrap();
                    self.engine.drain_model_excess_into(
                        model, share, &mut slot.handoff)
                };
                if moved > 0 {
                    flushed[model as usize] = true;
                    self.notify_replicas(model);
                }
            }
        }
    }

    /// Wake every other worker currently draining `model` (handoff
    /// backlog is waiting for one of them).
    fn notify_replicas(&self, model: ModelId) {
        for (w, e) in self.worker_events.iter().enumerate() {
            if w != self.id && self.ownership.is_replica(model, w) {
                e.notify();
            }
        }
    }

    /// Surface each model's replica-set width to the scheduler
    /// ([`crate::coordinator::SchedCtx::replica_share`]). Gated behind
    /// `cluster_hints` by the caller — `--no-gauge-hints` keeps every
    /// pool-state feature out of the decision context — and skipped for
    /// single-worker pools, where every share is structurally 0 anyway:
    /// both keep the bare-engine encoding bit-identical.
    fn update_replica_shares(&mut self) {
        let workers = self.worker_events.len();
        if workers < 2 {
            return;
        }
        for model in ModelId::all() {
            let count = self.ownership.replica_count(model);
            let share =
                count.saturating_sub(1) as f64 / (workers - 1) as f64;
            self.engine.set_replica_share(model, share);
        }
    }

    /// Exit gate: re-verify under the locks that every drained slot is
    /// disconnected with an empty handoff buffer, so a flush that landed
    /// after the intake pass is never stranded.
    fn owned_intake_clear(&self) -> bool {
        ModelId::all().into_iter().all(|m| {
            if !self.ownership.is_replica(m, self.id) {
                return true;
            }
            let slot = self.intake[m as usize].lock().unwrap();
            slot.closed && slot.handoff.is_empty()
        })
    }

    /// Publish this worker's gauge LANE for every model: its local queue
    /// depth plus its engine's rolling batch latency (NaN until this
    /// worker's profiler has observations — the admission decision
    /// function owns the isolated-estimate fallback, so the policy lives
    /// in one place). Uninvolved workers publish a zero queue AND a NaN
    /// latency, so a lane can never go stale after a migration or a
    /// replica scale-down.
    ///
    /// Mid-handoff a model's backlog is split between the handoff slot
    /// (counted in the PRIMARY drainer's lane) and the flushing worker's
    /// engine (its own lane), so a hot queue never reads 0 just because
    /// ownership moved — that blind spot would let the admission fast
    /// path under-price the model and feed the controller a falsely
    /// collapsed imbalance.
    fn publish_gauges(&self) {
        // Prediction lanes exist only under predictive admission: a
        // snapshot-mode pool never probes the predictor, so its hot
        // path (and the virtual arm's event stream) is unchanged.
        let warmup = self
            .admission
            .filter(|c| matches!(c.mode, AdmissionMode::Predictive))
            .map(|c| c.predictor_warmup);
        for m in ModelId::all() {
            let idx = m as usize;
            let mut queue = self.engine.queue_len(m);
            if self.ownership.owner(m) == self.id {
                queue += self.intake[idx].lock().unwrap().handoff.len();
            }
            // A real latency only while draining or holding the model:
            // an ex-replica's frozen profile must not keep skewing the
            // pool-wide finite-lane mean after it stops serving (its
            // lane goes NaN, exactly like the queue side going 0 —
            // pre-replication, the single last-writer slot self-
            // corrected the same way).
            let involved = self.ownership.is_replica(m, self.id)
                || self.engine.holds_model(m);
            let latency = if involved {
                self.engine.profiler.mean_latency_ms(m)
            } else {
                f64::NAN
            };
            self.gauges.publish(m, self.id, queue, latency);
            if let Some(warmup) = warmup {
                // Same involvement rule as the latency lane: an
                // ex-drainer's prediction must go NaN with it.
                let inflation = if involved {
                    self.engine
                        .predict_inflation(m, self.ref_batch, 1, warmup)
                } else {
                    f64::NAN
                };
                self.gauges.publish_prediction(
                    m,
                    self.id,
                    inflation,
                    self.engine.inflation_p95_factor(warmup),
                );
            }
        }
    }

    /// Fold the pool-wide gauges into the engine's decision context:
    /// total estimated backlog across every worker and this worker's
    /// share of it (the backlog parked in its own gauge lane), so
    /// SAC/DeepRT see cluster pressure instead of just their own shard.
    fn update_cluster_hints(&mut self) {
        let mut total = 0.0;
        let mut local = 0.0;
        for m in ModelId::all() {
            let iso = self.isolated_ref_ms[m as usize];
            total += self.gauges.backlog_ms(m, iso, self.ref_batch);
            local += self.gauges.backlog_ms_for(m, self.id, iso,
                                                self.ref_batch);
        }
        let share = if total > 0.0 { local / total } else { 0.0 };
        self.engine.set_cluster_hints(total, share);
    }

    /// Stream request-terminal events recorded since the last round —
    /// completions AND engine-gate sheds — to the load-generator clients,
    /// and bump the shared telemetry hub with the same deltas so the
    /// publisher thread's live snapshots track the pool without walking
    /// any metrics. Returns the new outcome high-water mark; `sheds_seen`
    /// tracks the per-model shed counts already reported.
    fn notify_events(&self, reported: usize,
                     sheds_seen: &mut [u64; N_MODELS]) -> usize {
        let outcomes = self.engine.metrics.outcomes();
        let fresh = &outcomes[reported..];
        if let Some(hub) = &self.hub {
            let violated =
                fresh.iter().filter(|o| o.violated).count() as u64;
            hub.add_completed(fresh.len() as u64, violated);
        }
        if let Some(tx) = &self.events_tx {
            for o in fresh {
                // A dropped receiver just means nobody is listening.
                let _ = tx.send(ServeEvent::Completed(CompletionEvent {
                    id: o.id,
                    model: o.model,
                    e2e_ms: o.e2e_ms,
                    violated: o.violated,
                }));
            }
        }
        if self.events_tx.is_some() || self.hub.is_some() {
            for m in ModelId::all() {
                let seen = &mut sheds_seen[m as usize];
                let now = self.engine.metrics.shed_for(m);
                if now > *seen {
                    if let Some(hub) = &self.hub {
                        hub.add_shed(now - *seen);
                    }
                    if let Some(tx) = &self.events_tx {
                        for _ in *seen..now {
                            let _ = tx.send(ServeEvent::Shed { model: m });
                        }
                    }
                    *seen = now;
                }
            }
        }
        outcomes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::stripe_budget;

    #[test]
    fn stripe_budget_scales_with_deadline_pressure() {
        // Empty queue or comfortable slack: the base stripe.
        assert_eq!(stripe_budget(32, None, 10.0), 32);
        assert_eq!(stripe_budget(32, Some(100.0), 10.0), 32);
        assert_eq!(stripe_budget(32, Some(40.0), 10.0), 32); // 4 spans
        // Squeezed (2–4 spans): doubled.
        assert_eq!(stripe_budget(32, Some(39.9), 10.0), 64);
        assert_eq!(stripe_budget(32, Some(20.0), 10.0), 64);
        // Critical (< 2 spans, including already-late): quadrupled.
        assert_eq!(stripe_budget(32, Some(19.9), 10.0), 128);
        assert_eq!(stripe_budget(32, Some(0.0), 10.0), 128);
        assert_eq!(stripe_budget(32, Some(-5.0), 10.0), 128);
        // Unpriceable batch estimate: no evidence, no deviation.
        assert_eq!(stripe_budget(32, Some(1.0), f64::NAN), 32);
        assert_eq!(stripe_budget(32, Some(1.0), 0.0), 32);
        assert_eq!(stripe_budget(32, Some(1.0), f64::INFINITY), 32);
    }
}
