//! Fabric-backed virtual arm of the serving runtime: the worker pool,
//! the rebalance controller's epochs, and the arrival stream as logical
//! processes on one [`EventHeap`] (see [`crate::sim`]).
//!
//! The wall arm keeps real threads; this arm replaces them with a
//! deterministic discrete-event loop, which is what lets trace mode run
//! the FULL dynamic stack — migration, hot-model replication,
//! urgency-aware replica routing on live [`SharedGauges`] — and still
//! replay bit-identically from a seed.
//!
//! Process-id map (ties at one timestamp fire in pid order):
//!
//! | pid     | process                                              |
//! |---------|------------------------------------------------------|
//! | `0`     | arrival delivery (the trace, one event at a time)    |
//! | `1`     | rebalance controller epoch tick                      |
//! | `2 + w` | worker `w` activation (one engine scheduling round)  |
//!
//! Delivery before worker activation at the same instant mirrors the
//! bare engine, whose per-round `ingest()` pulls every arrival at or
//! before "now" *before* scheduling the round.
//!
//! Three invariants carry the whole design:
//!
//! * **Engines self-advance; the fabric only picks activation order.**
//!   A worker's engine still drives its own [`VirtualClock`] through
//!   `wait_until`/dispatch exactly as the bare engine does — the fabric
//!   never writes a worker clock. With one worker this makes the arm
//!   literally the bare engine's step sequence (the seed-equivalence
//!   test in [`super::server`] pins it).
//! * **At most one scheduled activation per worker.** A worker is
//!   either `idle` (no activation in the heap; the next delivery or
//!   handoff to it schedules one) or has exactly one pending activation
//!   (scheduled at its previous round's local end time). `done` workers
//!   (local clock past the horizon — the same check `Engine::run` makes
//!   between rounds) are never activated again; late deliveries pile up
//!   as leftover, exactly like un-ingested pending in a bare run.
//! * **Handoffs are atomic at the epoch.** Where live workers flush
//!   into [`ModelIntake`](super::ingress::ModelIntake) slots and owners
//!   drain them over subsequent passes, the fabric resolves the same
//!   transfer eagerly inside the rebalance tick: ex-members flush
//!   everything, survivors of a widened set shed their above-fair-share
//!   surplus, and the flushed backlog lands on the least-loaded members
//!   (ties to the lowest worker index). Requests only ever move, so the
//!   conservation identity (outcomes + sheds + leftover == attempts)
//!   holds through every rewrite.

use super::admission::AdmissionGate;
use crate::predictor::AdmissionMode;
use super::ingress::{pick_replica, GaugeSnapshot, OwnershipTable,
                     SharedGauges, URGENT_SLACK_BATCHES};
use super::server::{merge_results, RebalanceStats, Rebalancer, ServeConfig,
                    ServeReport};
use super::worker::WorkerResult;
use crate::coordinator::{Engine, Scheduler, SlotOutcome};
use crate::metrics::{Metrics, RequestOutcome, ShedReason};
use crate::workload::session::{step_of, SessionSpec};
use crate::runtime::executor::SimDispatcher;
use crate::sim::EventHeap;
use crate::util::time::{ClockSource, VirtualClock};
use crate::workload::models::{ModelId, N_MODELS};
use crate::workload::request::Request;
use std::sync::Arc;

/// Arrival-delivery process id.
pub(crate) const PID_DELIVER: u32 = 0;
/// Rebalance-controller process id.
pub(crate) const PID_REBALANCE: u32 = 1;

/// Worker `w`'s process id.
pub(crate) fn pid_of_worker(w: usize) -> u32 {
    2 + w as u32
}

/// Event payloads of the serve tier's fabric.
enum Ev {
    /// Deliver the next trace request (the arrival stream keeps exactly
    /// one Deliver in the heap — its own timestamp order is the trace
    /// order, so one at a time is enough and keeps the heap tiny).
    Deliver(Request),
    /// Rebalance epoch `k` (ticks at `k × epoch_ms` for `k ≥ 1`).
    Rebalance { k: u64 },
    /// Run one scheduling round on worker `w`.
    Activate(usize),
}

/// One worker as a logical process: its engine (self-advancing its own
/// clock), its scheduler, and the two fabric flags.
struct WorkerProc {
    engine: Engine<SimDispatcher>,
    scheduler: Box<dyn Scheduler>,
    clock: VirtualClock,
    /// Reusable slot-outcome buffer for `step_into` (cleared per round).
    outcomes: Vec<SlotOutcome>,
    /// High-water mark into `engine.metrics.outcomes()` for
    /// [`ServeFabric::for_new_outcomes`] (the cluster tier's completion
    /// stream; unused cursors cost nothing).
    outcome_cursor: usize,
    slots: u64,
    /// No activation scheduled; the next delivery/handoff schedules one.
    idle: bool,
    /// Local clock reached the horizon; never activate again.
    done: bool,
}

/// The serve tier's virtual arm as a set of logical processes. Owns the
/// same control-plane pieces `Server::start` wires between threads —
/// [`SharedGauges`], [`OwnershipTable`], the [`Rebalancer`] — but drives
/// them from fabric events instead of a controller thread.
///
/// Also the per-node building block of the cluster fabric: the cluster
/// driver embeds one `ServeFabric` per node, delivers routed requests
/// into it, and reads its gauges for gossip snapshots.
pub(crate) struct ServeFabric {
    procs: Vec<WorkerProc>,
    gauges: Arc<SharedGauges>,
    ownership: Arc<OwnershipTable>,
    rebalancer: Option<Rebalancer>,
    stats: Arc<RebalanceStats>,
    /// Replica mask per model as of the last applied handoff — diffed
    /// against the table after each tick to detect migrations/scaling.
    prev_mask: [u64; N_MODELS],
    isolated_ref_ms: [f64; N_MODELS],
    ref_batch: usize,
    /// Cross-worker gauge hints into `SchedCtx` (multi-worker only, same
    /// gate as the live pool — single-worker runs stay bit-identical to
    /// the bare engine).
    hints: bool,
    horizon_ms: f64,
    workers: usize,
    /// Reusable handoff scratch (the fabric's stand-in for the live
    /// `ModelIntake` slots).
    handoff_buf: Vec<Request>,
    /// `Some(predictor_warmup)` iff predictive admission is on — gates
    /// the prediction-lane publishes exactly like the live worker.
    predictive_warmup: Option<usize>,
}

impl ServeFabric {
    pub(crate) fn new(cfg: &ServeConfig, horizon_ms: f64) -> Self {
        let workers = cfg.worker_count();
        let gauges = Arc::new(SharedGauges::new());
        let ownership = Arc::new(OwnershipTable::new_static(workers));
        let isolated_ref_ms = cfg.isolated_ref_table();
        let ref_batch = cfg.ref_batch();
        let stats = Arc::new(RebalanceStats::default());
        let rebalancer = match cfg.rebalance {
            Some(rcfg) if workers > 1 => Some(Rebalancer::fabric_controller(
                rcfg,
                workers,
                gauges.clone(),
                ownership.clone(),
                isolated_ref_ms,
                ref_batch,
                stats.clone(),
            )),
            _ => None,
        };
        let procs = (0..workers)
            .map(|i| {
                let clock = VirtualClock::new();
                let mut engine =
                    cfg.build_engine(i, ClockSource::Virtual(clock.clone()));
                if let Some(adm) = cfg.admission {
                    engine.set_ingress_gate(Some(Box::new(
                        AdmissionGate::new(adm),
                    )));
                }
                let scheduler = cfg.scheduler.build(&cfg.engine, i);
                WorkerProc {
                    engine,
                    scheduler,
                    clock,
                    outcomes: Vec::new(),
                    outcome_cursor: 0,
                    slots: 0,
                    idle: true,
                    done: false,
                }
            })
            .collect();
        let prev_mask =
            std::array::from_fn(|i| ownership.replica_mask(ModelId::from_index(i)));
        ServeFabric {
            procs,
            gauges,
            ownership,
            rebalancer,
            stats,
            prev_mask,
            isolated_ref_ms,
            ref_batch,
            hints: cfg.cluster_hints && workers > 1,
            horizon_ms,
            workers,
            handoff_buf: Vec::new(),
            predictive_warmup: cfg
                .admission
                .filter(|c| matches!(c.mode, AdmissionMode::Predictive))
                .map(|c| c.predictor_warmup),
        }
    }

    pub(crate) fn has_rebalancer(&self) -> bool {
        self.rebalancer.is_some()
    }

    pub(crate) fn worker_count(&self) -> usize {
        self.workers
    }

    /// Export the pool-wide gauge state for cluster gossip, priced
    /// exactly as the live `Ingress::gauge_snapshot` prices it — same
    /// replica division, same profiled-batch-else-isolated fallback —
    /// so edge-of-cluster routing reads the numbers a live node would
    /// publish, not a side-channel estimate.
    pub(crate) fn gauge_snapshot(&self) -> GaugeSnapshot {
        let ref_batch = self.ref_batch.max(1);
        let mut snap = GaugeSnapshot { ref_batch, ..Default::default() };
        for m in ModelId::all() {
            let i = m as usize;
            let replicas = self.ownership.replica_count(m);
            snap.queue_per_replica[i] = self.gauges.queue_len(m) / replicas;
            let batch = self.gauges.batch_ms(m);
            snap.est_batch_ms[i] = if batch.is_finite() && batch > 0.0 {
                batch
            } else {
                self.isolated_ref_ms[i]
            };
            snap.backlog_ms[i] = self.gauges.backlog_ms(
                m, self.isolated_ref_ms[i], ref_batch);
            snap.total_backlog_ms += snap.backlog_ms[i];
            snap.predicted_inflation[i] = self.gauges.predicted_inflation(m);
            snap.isolated_ms[i] = self.isolated_ref_ms[i];
        }
        snap.p95_factor = self.gauges.p95_factor();
        snap
    }

    /// Route one arrival to a worker, exactly as the live
    /// `Ingress::submit` picks its wake target: the id-affine member of
    /// the replica set, except urgent requests (slack below
    /// [`URGENT_SLACK_BATCHES`] estimated batch spans) which go to the
    /// emptiest replica lane. Workers that received work while idle are
    /// appended to `wake` for the driver to schedule.
    pub(crate) fn deliver(&mut self, r: Request, wake: &mut Vec<usize>) {
        let m = r.model;
        let mask = self.ownership.replica_mask(m);
        let batch = self.gauges.batch_ms(m);
        let est = if batch.is_finite() && batch > 0.0 {
            batch
        } else {
            self.isolated_ref_ms[m as usize]
        };
        let slack = r.slo_ms - r.transmission_ms;
        let urgent = est > 0.0 && slack < URGENT_SLACK_BATCHES * est;
        let target = if urgent && mask.count_ones() > 1 {
            let mut lanes = vec![0usize; self.workers];
            for (w, lane) in lanes.iter_mut().enumerate() {
                if mask & (1u64 << w) != 0 {
                    *lane = self.gauges.queue_len_for(m, w);
                }
            }
            pick_replica(mask, &lanes, r.id, true)
        } else {
            pick_replica(mask, &[], r.id, false)
        }
        .min(self.workers - 1);
        self.push_to(target, r, wake);
    }

    fn push_to(&mut self, w: usize, r: Request, wake: &mut Vec<usize>) {
        let proc = &mut self.procs[w];
        proc.engine.push_request(r);
        if proc.idle && !proc.done {
            proc.idle = false;
            wake.push(w);
        }
    }

    /// Run one scheduling round on worker `w`, mirroring one pass of
    /// `LiveWorker::run`: replica shares in, `step_into`, gauges out,
    /// cluster hints out. Returns the worker's local end-of-round time
    /// (µs) to schedule its next activation at, or `None` when it went
    /// idle or retired at the horizon.
    pub(crate) fn activate(&mut self, w: usize) -> Option<u64> {
        if self.procs[w].done {
            self.procs[w].idle = true;
            return None;
        }
        // Same between-rounds check as `Engine::run`, against the
        // worker's LOCAL clock: a round whose wait crosses the horizon
        // still runs (the bare engine serves it too); the worker retires
        // on the next activation.
        if self.procs[w].engine.now_ms() >= self.horizon_ms {
            let proc = &mut self.procs[w];
            proc.done = true;
            proc.idle = true;
            return None;
        }
        if self.hints {
            self.update_replica_shares(w);
        }
        let proc = &mut self.procs[w];
        let served = {
            let WorkerProc { engine, scheduler, outcomes, .. } = proc;
            engine.step_into(scheduler.as_mut(), outcomes)
        };
        let next = match served {
            Some(n) => {
                proc.slots += n as u64;
                Some(proc.clock.now_us())
            }
            None => {
                proc.idle = true;
                None
            }
        };
        self.publish_gauges(w);
        if self.hints {
            self.update_cluster_hints(w);
        }
        next
    }

    /// Publish worker `w`'s per-model gauges, exactly as
    /// `LiveWorker::publish_gauges` does — minus the intake-slot handoff
    /// term, which the fabric's eager handoffs make always-empty.
    fn publish_gauges(&self, w: usize) {
        let proc = &self.procs[w];
        for m in ModelId::all() {
            let queue = proc.engine.queue_len(m);
            let involved = self.ownership.is_replica(m, w)
                || proc.engine.holds_model(m);
            let latency = if involved {
                proc.engine.profiler.mean_latency_ms(m)
            } else {
                f64::NAN
            };
            self.gauges.publish(m, w, queue, latency);
            if let Some(warmup) = self.predictive_warmup {
                let inflation = if involved {
                    proc.engine
                        .predict_inflation(m, self.ref_batch, 1, warmup)
                } else {
                    f64::NAN
                };
                self.gauges.publish_prediction(
                    m,
                    w,
                    inflation,
                    proc.engine.inflation_p95_factor(warmup),
                );
            }
        }
    }

    fn update_replica_shares(&mut self, w: usize) {
        if self.workers < 2 {
            return;
        }
        for m in ModelId::all() {
            let count = self.ownership.replica_count(m);
            let share =
                count.saturating_sub(1) as f64 / (self.workers - 1) as f64;
            self.procs[w].engine.set_replica_share(m, share);
        }
    }

    fn update_cluster_hints(&mut self, w: usize) {
        let mut total = 0.0;
        let mut local = 0.0;
        for m in ModelId::all() {
            let i = m as usize;
            total += self.gauges.backlog_ms(m, self.isolated_ref_ms[i],
                                            self.ref_batch);
            local += self.gauges.backlog_ms_for(m, w, self.isolated_ref_ms[i],
                                                self.ref_batch);
        }
        let share = if total > 0.0 { local / total } else { 0.0 };
        self.procs[w].engine.set_cluster_hints(total, share);
    }

    /// One rebalance epoch: run the controller's tick against the live
    /// gauges, then resolve whatever ownership rewrites it made as
    /// atomic-at-the-epoch handoffs. No-op without a controller.
    pub(crate) fn rebalance_tick(&mut self, wake: &mut Vec<usize>) {
        let Some(rb) = self.rebalancer.as_mut() else { return };
        rb.tick();
        for m in ModelId::all() {
            self.apply_handoffs(m, wake);
        }
    }

    /// Diff `model`'s replica mask against the last applied one and move
    /// the backlog accordingly. Requests only ever move between engines —
    /// never dropped — so conservation holds through every rewrite.
    fn apply_handoffs(&mut self, m: ModelId, wake: &mut Vec<usize>) {
        let idx = m as usize;
        let new_mask = self.ownership.replica_mask(m);
        let old_mask = self.prev_mask[idx];
        if new_mask == old_mask {
            return;
        }
        self.prev_mask[idx] = new_mask;
        let mut buf = std::mem::take(&mut self.handoff_buf);
        // Ex-members (migration source, scale-down victim) flush
        // everything they hold, queued and pending alike.
        let mut removed = old_mask & !new_mask;
        while removed != 0 {
            let w = removed.trailing_zeros() as usize;
            removed &= removed - 1;
            if w < self.procs.len() {
                self.procs[w].engine.drain_model_into(m, &mut buf);
            }
        }
        let members: Vec<usize> = (0..self.procs.len())
            .filter(|&w| new_mask & (1u64 << w) != 0)
            .collect();
        if members.is_empty() {
            self.handoff_buf = buf;
            return;
        }
        // A widened set rebalances immediately: surviving members shed
        // their above-fair-share surplus for the new replica to pick up
        // (the live pool's share_excess flush, resolved eagerly).
        if (new_mask & !old_mask) != 0 && members.len() > 1 {
            let total: usize = members
                .iter()
                .map(|&w| self.procs[w].engine.queue_len(m))
                .sum::<usize>()
                + buf.len();
            let share = total / members.len();
            for &w in &members {
                if old_mask & (1u64 << w) != 0
                    && self.procs[w].engine.queue_len(m) > share
                {
                    self.procs[w]
                        .engine
                        .drain_model_excess_into(m, share, &mut buf);
                }
            }
        }
        // The flushed backlog lands on the least-loaded members, ties to
        // the lowest worker index (the fair-share pickup, eagerly).
        if !buf.is_empty() {
            let mut lanes: Vec<(usize, usize)> = members
                .iter()
                .map(|&w| (w, self.procs[w].engine.queue_len(m)))
                .collect();
            for r in buf.drain(..) {
                let mut k = 0;
                for j in 1..lanes.len() {
                    if lanes[j].1 < lanes[k].1 {
                        k = j;
                    }
                }
                lanes[k].1 += 1;
                let w = lanes[k].0;
                self.push_to(w, r, wake);
            }
        }
        self.handoff_buf = buf;
    }

    /// Stream every request outcome recorded since the last call (across
    /// all workers, in worker order) — the cluster tier's completion
    /// feed for its result cache.
    pub(crate) fn for_new_outcomes(&mut self,
                                   mut f: impl FnMut(&RequestOutcome)) {
        for proc in &mut self.procs {
            let outcomes = proc.engine.metrics.outcomes();
            for o in &outcomes[proc.outcome_cursor..] {
                f(o);
            }
            proc.outcome_cursor = outcomes.len();
        }
    }

    /// Fold the workers into the run report, mirroring `run_trace`'s
    /// merge plus (when a controller ran) the rebalance/replication
    /// counters `Server::shutdown` records.
    pub(crate) fn finish(self, horizon_ms: f64) -> ServeReport {
        let workers = self.workers;
        let had_rebalancer = self.rebalancer.is_some();
        let results: Vec<WorkerResult> = self
            .procs
            .into_iter()
            .map(|mut p| {
                let telemetry = p.engine.take_telemetry();
                let (decisions, fallbacks) = p.engine.gate_headroom_stats();
                p.engine.metrics.record_headroom(decisions, fallbacks);
                WorkerResult {
                    slots: p.slots,
                    leftover: p.engine.total_queued(),
                    metrics: std::mem::take(&mut p.engine.metrics),
                    telemetry,
                }
            })
            .collect();
        let mut report = merge_results(results, horizon_ms, workers);
        if had_rebalancer {
            report.metrics.record_rebalance(
                self.stats.epochs(),
                self.ownership.migrations(),
                self.stats.peak_imbalance_ms(),
            );
            report.metrics.record_replication(
                self.ownership.scale_ups(),
                self.ownership.scale_downs(),
                self.ownership.peak_replicas() as u64,
            );
        }
        report
    }
}

/// The virtual arm of [`super::server::run_trace`]: serve a sorted
/// arrival trace through the fabric. Deterministic — same config, trace,
/// and horizon produce a bit-identical report.
pub(crate) fn run_trace_fabric(cfg: &ServeConfig, requests: Vec<Request>,
                               horizon_ms: f64) -> ServeReport {
    let mut fabric = ServeFabric::new(cfg, horizon_ms);
    let mut heap: EventHeap<Ev> = EventHeap::new();
    let mut trace = requests.into_iter();
    if let Some(first) = trace.next() {
        heap.schedule_ms(first.arrival_ms, PID_DELIVER, Ev::Deliver(first));
    }
    let epoch_ms = cfg
        .rebalance
        .map(|r| r.epoch_ms.max(1))
        .unwrap_or(u64::MAX);
    if fabric.has_rebalancer() && (epoch_ms as f64) < horizon_ms {
        heap.schedule_ms(epoch_ms as f64, PID_REBALANCE, Ev::Rebalance { k: 1 });
    }
    let mut wake: Vec<usize> = Vec::new();
    while let Some(firing) = heap.pop() {
        match firing.event {
            Ev::Deliver(r) => {
                fabric.deliver(r, &mut wake);
                if let Some(next) = trace.next() {
                    heap.schedule_ms(next.arrival_ms, PID_DELIVER,
                                     Ev::Deliver(next));
                }
            }
            Ev::Rebalance { k } => {
                fabric.rebalance_tick(&mut wake);
                let next = (k + 1).saturating_mul(epoch_ms);
                if (next as f64) < horizon_ms {
                    heap.schedule_ms(next as f64, PID_REBALANCE,
                                     Ev::Rebalance { k: k + 1 });
                }
            }
            Ev::Activate(w) => {
                if let Some(at_us) = fabric.activate(w) {
                    heap.schedule_us(at_us, pid_of_worker(w), Ev::Activate(w));
                }
            }
        }
        // Workers that received work while idle activate at this event's
        // timestamp (delivery pid < worker pids, so a same-instant
        // activation still sees every same-instant arrival first).
        for w in wake.drain(..) {
            heap.schedule_us(firing.time_us, pid_of_worker(w), Ev::Activate(w));
        }
    }
    fabric.finish(horizon_ms)
}

/// The virtual session arm: serve a trace of session HEADS, spawning
/// each completed round's successor back into the fabric until every
/// session runs out of decode steps or the run drains. Deterministic
/// for the same reason [`run_trace_fabric`] is — spawns happen inside
/// the event loop at the completing activation's timestamp, in worker
/// order, consuming no RNG.
///
/// Accounting: each delivered head opens a session
/// (`sessions_started`); each spawn is counted (`session_steps_spawned`)
/// so the trace-side identity becomes
/// `outcomes + sheds + leftover == heads + steps_spawned`. Heads whose
/// per-round service estimate cannot hold TPOT cadence are shed at
/// admission as [`ShedReason::SessionAbort`] (no session opens — every
/// step would be born late). A dropped round ends its session silently:
/// the drop is already accounted as an outcome, and spawning from it
/// would chase a deadline the session has lost.
pub(crate) fn run_trace_sessions(cfg: &ServeConfig, heads: Vec<Request>,
                                 horizon_ms: f64, spec: SessionSpec)
                                 -> ServeReport {
    let mut fabric = ServeFabric::new(cfg, horizon_ms);
    let mut driver = Metrics::new();
    let mut heap: EventHeap<Ev> = EventHeap::new();
    let mut trace = heads.into_iter();
    if let Some(first) = trace.next() {
        heap.schedule_ms(first.arrival_ms, PID_DELIVER, Ev::Deliver(first));
    }
    let epoch_ms = cfg
        .rebalance
        .map(|r| r.epoch_ms.max(1))
        .unwrap_or(u64::MAX);
    if fabric.has_rebalancer() && (epoch_ms as f64) < horizon_ms {
        heap.schedule_ms(epoch_ms as f64, PID_REBALANCE, Ev::Rebalance { k: 1 });
    }
    let mut wake: Vec<usize> = Vec::new();
    let mut spawned: Vec<Request> = Vec::new();
    while let Some(firing) = heap.pop() {
        match firing.event {
            Ev::Deliver(r) => {
                let est = fabric.gauge_snapshot().service_est_ms(r.model);
                if spec.cadence_feasible(est) {
                    driver.record_session_start();
                    fabric.deliver(r, &mut wake);
                } else {
                    driver.record_shed(r.model, ShedReason::SessionAbort);
                }
                if let Some(next) = trace.next() {
                    heap.schedule_ms(next.arrival_ms, PID_DELIVER,
                                     Ev::Deliver(next));
                }
            }
            Ev::Rebalance { k } => {
                fabric.rebalance_tick(&mut wake);
                let next = (k + 1).saturating_mul(epoch_ms);
                if (next as f64) < horizon_ms {
                    heap.schedule_ms(next as f64, PID_REBALANCE,
                                     Ev::Rebalance { k: k + 1 });
                }
            }
            Ev::Activate(w) => {
                if let Some(at_us) = fabric.activate(w) {
                    heap.schedule_us(at_us, pid_of_worker(w), Ev::Activate(w));
                }
                // Completed rounds spawn their successors NOW, at this
                // activation's timestamp (the collect-then-deliver split
                // only satisfies the borrow checker).
                fabric.for_new_outcomes(|o| {
                    driver.record_dual_slo(step_of(o.id), o.violated);
                    if !o.dropped {
                        if let Some(next) =
                            spec.next_step(o.id, o.model, o.completed_ms, 0.0)
                        {
                            spawned.push(next);
                        }
                    }
                });
                for s in spawned.drain(..) {
                    driver.record_session_step();
                    fabric.deliver(s, &mut wake);
                }
            }
        }
        for w in wake.drain(..) {
            heap.schedule_us(firing.time_us, pid_of_worker(w), Ev::Activate(w));
        }
    }
    let mut report = fabric.finish(horizon_ms);
    report.metrics.absorb(driver);
    report
}
