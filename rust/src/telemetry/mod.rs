//! Request-lifecycle tracing + streaming telemetry (the observability
//! layer).
//!
//! Every claim the repro makes — utility, violation rate, shed
//! accounting, conservation — used to be computed *after* a run from the
//! unbounded outcome vec in [`crate::metrics::Metrics`]. This module adds
//! the during-the-run view, in three pieces:
//!
//! * **Span records** ([`RequestTrace`]): where a request spent its
//!   budget — ingress-queue wait (arrival → engine ingest), batch
//!   assembly wait (ingest → dispatch), inference span (dispatch →
//!   completion, serialization included), and the network RTT charged
//!   into Eq. 2 — plus the admission/cache verdict, the batch it joined,
//!   and worker/node/shard labels. By construction the four spans sum to
//!   the reported e2e latency exactly (see [`RequestTrace::span_sum_ms`]).
//!   Collection is **deterministic id-keyed sampling**: a request is
//!   sampled iff `id % N == 0` for `--trace-sample N`, so the virtual arm
//!   stays bit-reproducible and two runs of the same seed sample the
//!   same id set. Sampled traces land in bounded per-worker rings
//!   ([`TraceRing`]) and are flushed as JSON-lines to `--trace-out`.
//! * **Streaming aggregates**: fixed-size log-bucketed latency/slack
//!   histograms ([`LogHistogram`], mergeable across workers and nodes
//!   like `Metrics::merge`), per-model outcome/violation counters, and
//!   SAC action histograms — all snapshot-able without touching the
//!   outcome vec, which survives only as the exact-percentile test
//!   oracle. Live wall-clock runs publish [`TelemetryHub`] counters to a
//!   `--metrics-out` JSON-lines stream every `--metrics-interval-ms`;
//!   every run appends one `kind: "final"` line from which the
//!   conservation identity `completed + sheds + cache_served + leftover
//!   == attempts` is recomputable from counters alone.
//! * **A zero-cost off switch** ([`TelemetryConfig`], default fully
//!   off): the engine's tracer seam is an `Option` exactly like its
//!   ingress gate, so disabled telemetry keeps the bare engine and the
//!   `--workers 1` virtual arm bit-identical (pinned by the
//!   seed-equivalence test) and the `telemetry_overhead` bench section
//!   measures the off / sampled / full cost directly.

use crate::metrics::{Metrics, ShedReason};
use crate::util::json::{arr, num, obj, s, Json};
use crate::workload::models::{ModelId, N_MODELS};
use crate::workload::request::Request;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};

/// Telemetry knobs, threaded through [`crate::serve::ServeConfig`] into
/// every worker engine and cluster node. Default is fully off — the
/// engine takes no tracer, workers take no hub, and the hot path is
/// bit-identical to a build without this module.
#[derive(Clone, Debug)]
pub struct TelemetryConfig {
    /// JSON-lines destination for sampled [`RequestTrace`] records
    /// (`--trace-out`). `None` keeps traces in memory only (they still
    /// ride the reports when sampling is on).
    pub trace_out: Option<String>,
    /// Deterministic sampling rate: a request is traced iff
    /// `id % trace_sample == 0`. `0` disables tracing entirely; `1`
    /// traces every request.
    pub trace_sample: u64,
    /// JSON-lines destination for metrics snapshots (`--metrics-out`).
    pub metrics_out: Option<String>,
    /// Live snapshot cadence for the wall-clock publisher thread, ms
    /// (`--metrics-interval-ms`). Virtual runs emit only the final line.
    pub metrics_interval_ms: f64,
    /// Cluster node index stamped into traces and snapshot lines
    /// (set by the cluster tier; `0` for single-node serving).
    pub node_label: u32,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            trace_out: None,
            trace_sample: 0,
            metrics_out: None,
            metrics_interval_ms: 500.0,
            node_label: 0,
        }
    }
}

impl TelemetryConfig {
    /// Is span tracing on at all?
    pub fn tracing_on(&self) -> bool {
        self.trace_sample > 0
    }

    /// Deterministic id-keyed sampling decision. Stable across runs,
    /// workers, and node id-window striding (ids are offset by multiples
    /// of `2^32`, so `id % N` stays well-defined per id, and the same id
    /// always gets the same verdict).
    pub fn sampled(&self, id: u64) -> bool {
        self.trace_sample > 0 && id % self.trace_sample == 0
    }
}

/// Terminal disposition of a traced request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceVerdict {
    /// Dispatched, inferred, completed (violated or not — see the flag).
    Completed,
    /// Refused with a typed reason: at the cluster edge
    /// ([`ShedReason::NoFeasibleNode`]), by a node's admission gate, or
    /// by the engine-side ingress gate.
    Shed(ShedReason),
    /// Terminated at the front-end result cache: a fresh hit.
    CacheHit,
    /// Terminated at the cache: coalesced onto an in-flight leader.
    CacheCoalesced,
}

impl TraceVerdict {
    /// Stable string label (the `verdict` field of the JSON line).
    pub fn label(&self) -> &'static str {
        match self {
            TraceVerdict::Completed => "completed",
            TraceVerdict::Shed(r) => r.label(),
            TraceVerdict::CacheHit => "cache-hit",
            TraceVerdict::CacheCoalesced => "cache-coalesced",
        }
    }
}

/// One sampled request's lifecycle, spans in milliseconds.
///
/// For `verdict == Completed` the identity
/// `ingress_wait_ms + batch_wait_ms + infer_ms + net_ms == e2e_ms`
/// holds by construction (the spans are differences of the same three
/// timestamps the engine's accounting uses), up to floating-point
/// re-association — the validator allows 1e-6 ms. Shed and cache records
/// carry only the spans that happened (the rest are zero).
#[derive(Clone, Debug, PartialEq)]
pub struct RequestTrace {
    /// Request id (cluster-unique). Front-end cache/edge records, which
    /// terminate before a node assigns an id, use the trace index.
    pub id: u64,
    /// The model requested.
    pub model: ModelId,
    /// Terminal disposition.
    pub verdict: TraceVerdict,
    /// Cluster node index (0 for single-node serving).
    pub node: u32,
    /// Worker index inside the node's pool.
    pub worker: u32,
    /// Front-end router shard (meaningful for cache/edge records).
    pub shard: u32,
    /// Arrival timestamp on the serving clock, ms.
    pub arrival_ms: f64,
    /// Network RTT charged into the e2e budget (Eq. 2 transmission).
    pub net_ms: f64,
    /// Arrival → engine ingest (time spent in the ingress queue).
    pub ingress_wait_ms: f64,
    /// Ingest → dispatch (time waiting for a batch to assemble).
    pub batch_wait_ms: f64,
    /// Dispatch → completion (inference + serialization span).
    pub infer_ms: f64,
    /// End-to-end latency as accounted against the SLO.
    pub e2e_ms: f64,
    /// The request's SLO budget, ms.
    pub slo_ms: f64,
    /// Real requests in the batch this request joined.
    pub batch: usize,
    /// Batch size after artifact padding (0 when not dispatched).
    pub padded: usize,
    /// Did the request miss its SLO?
    pub violated: bool,
}

impl RequestTrace {
    /// A record that never reached dispatch (shed / cache-served):
    /// everything zero except what the caller fills in.
    pub fn stub(id: u64, model: ModelId, verdict: TraceVerdict) -> Self {
        RequestTrace {
            id,
            model,
            verdict,
            node: 0,
            worker: 0,
            shard: 0,
            arrival_ms: 0.0,
            net_ms: 0.0,
            ingress_wait_ms: 0.0,
            batch_wait_ms: 0.0,
            infer_ms: 0.0,
            e2e_ms: 0.0,
            slo_ms: 0.0,
            batch: 0,
            padded: 0,
            violated: false,
        }
    }

    /// Sum of the four per-stage spans — equals `e2e_ms` (within clock
    /// resolution) for completed requests.
    pub fn span_sum_ms(&self) -> f64 {
        self.ingress_wait_ms + self.batch_wait_ms + self.infer_ms
            + self.net_ms
    }

    /// One JSON-lines record (deterministic key order).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("id", num(self.id as f64)),
            ("model", s(self.model.name())),
            ("verdict", s(self.verdict.label())),
            ("node", num(self.node as f64)),
            ("worker", num(self.worker as f64)),
            ("shard", num(self.shard as f64)),
            ("arrival_ms", num(self.arrival_ms)),
            ("net_ms", num(self.net_ms)),
            ("ingress_wait_ms", num(self.ingress_wait_ms)),
            ("batch_wait_ms", num(self.batch_wait_ms)),
            ("infer_ms", num(self.infer_ms)),
            ("e2e_ms", num(self.e2e_ms)),
            ("slo_ms", num(self.slo_ms)),
            ("batch", num(self.batch as f64)),
            ("padded", num(self.padded as f64)),
            ("violated", Json::Bool(self.violated)),
        ])
    }
}

/// Default per-worker trace ring capacity: at 1/64 sampling this holds
/// the last ~4M requests' worth of samples — overflow evicts oldest and
/// counts, never blocks the hot path.
pub const TRACE_RING_CAP: usize = 65_536;

/// Cap on in-flight sampled-request bookkeeping per worker. Overflow
/// stops *tracking* new samples (counted), never touches the request.
const PENDING_CAP: usize = 8_192;

/// Bounded ring of sampled traces: push is O(1), overflow evicts the
/// oldest record and bumps a drop counter.
#[derive(Clone, Debug)]
pub struct TraceRing {
    buf: VecDeque<RequestTrace>,
    cap: usize,
    dropped: u64,
}

impl TraceRing {
    /// A ring holding at most `cap` records (min 1).
    pub fn new(cap: usize) -> Self {
        TraceRing { buf: VecDeque::new(), cap: cap.max(1), dropped: 0 }
    }

    /// Append, evicting the oldest record when full.
    pub fn push(&mut self, t: RequestTrace) {
        if self.buf.len() >= self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(t);
    }

    /// Records currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Is the ring empty?
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Records evicted by overflow so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Take every held record (oldest first), leaving the ring empty.
    pub fn drain(&mut self) -> Vec<RequestTrace> {
        self.buf.drain(..).collect()
    }
}

/// Everything one engine's tracer collected, folded worker → node →
/// cluster alongside `Metrics`.
#[derive(Clone, Debug, Default)]
pub struct TraceReport {
    /// Sampled span records, in completion order per worker.
    pub traces: Vec<RequestTrace>,
    /// Raw SAC/scheduler action histogram: `(batch, m_c) → decisions`
    /// (pre-veto, so it shows what the policy asked for).
    pub actions: BTreeMap<(usize, usize), u64>,
    /// Trace records lost to ring overflow or pending-map caps.
    pub dropped: u64,
}

impl TraceReport {
    /// Fold another report in (by value — no clones).
    pub fn merge(&mut self, mut other: TraceReport) {
        self.traces.append(&mut other.traces);
        for (k, v) in other.actions {
            *self.actions.entry(k).or_insert(0) += v;
        }
        self.dropped += other.dropped;
    }

    /// The action histogram as a JSON array of `{batch, m_c, count}`.
    pub fn actions_json(&self) -> Json {
        arr(self.actions.iter().map(|(&(b, m_c), &count)| {
            obj(vec![
                ("batch", num(b as f64)),
                ("m_c", num(m_c as f64)),
                ("count", num(count as f64)),
            ])
        }))
    }
}

/// Per-engine tracer: the engine holds `Option<EngineTracer>` (default
/// `None`, mirroring its ingress-gate seam) so disabled tracing costs
/// one untaken branch per request and keeps the seed-equivalence
/// invariant bit-for-bit. All state is worker-local — no locks, no
/// atomics on the hot path.
#[derive(Clone, Debug)]
pub struct EngineTracer {
    sample: u64,
    worker: u32,
    node: u32,
    /// `(id, t_ingest)` for sampled requests awaiting completion. Linear
    /// scan on completion — at 1/64 sampling this holds a handful of
    /// entries; entries survive OOM requeues (removed only on
    /// completion).
    pending: Vec<(u64, f64)>,
    ring: TraceRing,
    actions: BTreeMap<(usize, usize), u64>,
    pending_overflow: u64,
}

impl EngineTracer {
    /// Tracer for one worker; `cfg.trace_sample == 0` is treated as 1
    /// (callers only install a tracer when tracing is on).
    pub fn new(cfg: &TelemetryConfig, worker: u32) -> Self {
        EngineTracer {
            sample: cfg.trace_sample.max(1),
            worker,
            node: cfg.node_label,
            pending: Vec::new(),
            ring: TraceRing::new(TRACE_RING_CAP),
            actions: BTreeMap::new(),
            pending_overflow: 0,
        }
    }

    /// Deterministic sampling verdict for `id`.
    pub fn sampled(&self, id: u64) -> bool {
        id % self.sample == 0
    }

    /// A request left the ingress queue and entered the engine's router
    /// at `now_ms` — the ingress-wait / batch-wait boundary.
    pub fn on_ingest(&mut self, id: u64, now_ms: f64) {
        if !self.sampled(id) {
            return;
        }
        if self.pending.len() >= PENDING_CAP {
            self.pending_overflow += 1;
            return;
        }
        self.pending.push((id, now_ms));
    }

    /// The engine-side ingress gate refused a request at ingest time.
    pub fn on_shed(&mut self, r: &Request, now_ms: f64, reason: ShedReason) {
        if !self.sampled(r.id) {
            return;
        }
        let mut t = RequestTrace::stub(r.id, r.model,
                                       TraceVerdict::Shed(reason));
        t.node = self.node;
        t.worker = self.worker;
        t.arrival_ms = r.arrival_ms;
        t.net_ms = r.transmission_ms;
        t.ingress_wait_ms = now_ms - r.arrival_ms;
        t.slo_ms = r.slo_ms;
        self.ring.push(t);
    }

    /// A request completed: dispatched at `t_dispatch`, inference (plus
    /// serialization) took `infer_ms`, in a batch of `batch` real
    /// requests padded to `padded`. Computes the same e2e the metrics
    /// path records, split into spans.
    pub fn on_complete(&mut self, r: &Request, t_dispatch: f64,
                       infer_ms: f64, batch: usize, padded: usize,
                       violated: bool) {
        if !self.sampled(r.id) {
            return;
        }
        let t_ingest = match self.pending.iter().position(|&(id, _)| id == r.id)
        {
            Some(i) => self.pending.swap_remove(i).1,
            // Pending cap overflowed when this id ingested: charge the
            // whole wait to batch assembly rather than lose the record.
            None => r.arrival_ms,
        };
        let completion = t_dispatch + infer_ms;
        self.ring.push(RequestTrace {
            id: r.id,
            model: r.model,
            verdict: TraceVerdict::Completed,
            node: self.node,
            worker: self.worker,
            shard: 0,
            arrival_ms: r.arrival_ms,
            net_ms: r.transmission_ms,
            ingress_wait_ms: t_ingest - r.arrival_ms,
            batch_wait_ms: t_dispatch - t_ingest,
            infer_ms,
            e2e_ms: completion - r.arrival_ms + r.transmission_ms,
            slo_ms: r.slo_ms,
            batch,
            padded,
            violated,
        });
    }

    /// Record one raw scheduler decision (pre-veto `(batch, m_c)`).
    pub fn record_action(&mut self, batch: usize, m_c: usize) {
        *self.actions.entry((batch, m_c)).or_insert(0) += 1;
    }

    /// Drain everything collected so far into a report (the tracer
    /// stays installed and keeps collecting).
    pub fn take_report(&mut self) -> TraceReport {
        TraceReport {
            dropped: self.ring.dropped() + self.pending_overflow,
            traces: self.ring.drain(),
            actions: std::mem::take(&mut self.actions),
        }
    }
}

// ---------------------------------------------------------------------
// Streaming histograms
// ---------------------------------------------------------------------

/// Log-bucket count for [`LogHistogram`].
pub const HIST_BUCKETS: usize = 64;
/// Lowest bucket edge, ms: everything at or below lands in bucket 0.
pub const HIST_LO_MS: f64 = 0.05;
/// Highest bucket edge, ms: everything above lands in the top bucket.
pub const HIST_HI_MS: f64 = 1e5;

fn ln_growth() -> f64 {
    (HIST_HI_MS / HIST_LO_MS).ln() / (HIST_BUCKETS - 1) as f64
}

/// Fixed-size log-bucketed histogram of non-negative millisecond values.
///
/// 64 buckets span 0.05 ms … 100 s with geometric growth `g =
/// (HI/LO)^(1/63) ≈ 1.26`, so any quantile read is within one bucket
/// width — a ≈26 % relative band — of the exact value (see
/// [`LogHistogram::growth`]). Mergeable by element-wise addition, like
/// `Metrics::merge`; constant memory regardless of run length. Negative
/// or sub-`LO` values clamp into bucket 0 (slack histograms put every
/// violated request there).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: [u64; HIST_BUCKETS],
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: [0; HIST_BUCKETS],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl LogHistogram {
    /// The geometric bucket growth factor (the relative error bound of
    /// any quantile read is one factor of this either side).
    pub fn growth() -> f64 {
        ln_growth().exp()
    }

    /// Upper edge of bucket `i` (`HIST_LO_MS` for bucket 0).
    fn edge(i: usize) -> f64 {
        HIST_LO_MS * (ln_growth() * i as f64).exp()
    }

    fn bucket_of(v: f64) -> usize {
        if !(v > HIST_LO_MS) {
            return 0; // covers v <= LO, zero, negatives, and NaN
        }
        let i = ((v / HIST_LO_MS).ln() / ln_growth()).ceil() as usize;
        i.min(HIST_BUCKETS - 1)
    }

    /// Add one observation.
    pub fn add(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.counts[Self::bucket_of(v)] += 1;
        self.total += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean of the recorded observations (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.sum / self.total as f64 }
    }

    /// Largest recorded observation (0 when empty).
    pub fn max_ms(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.max }
    }

    /// Element-wise merge (same bucket layout by construction).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// The bucket index holding the `q`-quantile observation
    /// (nearest-rank), or `None` when empty.
    fn quantile_bucket(&self, q: f64) -> Option<usize> {
        if self.total == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64)
            .max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Some(i);
            }
        }
        Some(HIST_BUCKETS - 1)
    }

    /// Streaming `q`-quantile estimate: the upper edge of the bucket
    /// holding the nearest-rank observation, clamped to the observed
    /// max. Exact value is within one bucket width (see
    /// [`LogHistogram::quantile_bounds`]); 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        match self.quantile_bucket(q) {
            Some(i) => Self::edge(i).min(self.max),
            None => 0.0,
        }
    }

    /// `(lower, upper)` edges of the bucket the `q`-quantile fell in —
    /// the error bound the tests assert the exact oracle against.
    pub fn quantile_bounds(&self, q: f64) -> (f64, f64) {
        match self.quantile_bucket(q) {
            Some(0) => (0.0, HIST_LO_MS),
            Some(i) => (Self::edge(i - 1), Self::edge(i)),
            None => (0.0, 0.0),
        }
    }

    /// Bucket counts + moments as JSON (the snapshot wire format).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("count", num(self.total as f64)),
            ("sum_ms", num(self.sum)),
            ("min_ms", num(if self.total == 0 { 0.0 } else { self.min })),
            ("max_ms", num(self.max_ms())),
            ("p50_ms", num(self.quantile(0.5))),
            ("p99_ms", num(self.quantile(0.99))),
            ("buckets",
             arr(self.counts.iter().map(|&c| num(c as f64)))),
        ])
    }
}

// ---------------------------------------------------------------------
// Live counters + snapshot lines
// ---------------------------------------------------------------------

/// Shared live counters for the wall-clock publisher thread: workers
/// bump them as outcomes land (relaxed atomics, off the lock-free hot
/// path), the publisher snapshots them every `--metrics-interval-ms`.
/// Engine-side counters only — ingress fast-path sheds (refused before
/// an id exists) fold in at shutdown via the final snapshot.
#[derive(Debug)]
pub struct TelemetryHub {
    node: u32,
    completed: AtomicU64,
    violated: AtomicU64,
    shed: AtomicU64,
}

impl TelemetryHub {
    /// A hub stamped with the cluster node index (0 single-node).
    pub fn new(node: u32) -> Self {
        TelemetryHub {
            node,
            completed: AtomicU64::new(0),
            violated: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    /// Fold a batch of freshly recorded outcomes in.
    pub fn add_completed(&self, n: u64, violated: u64) {
        if n > 0 {
            self.completed.fetch_add(n, Ordering::Relaxed);
        }
        if violated > 0 {
            self.violated.fetch_add(violated, Ordering::Relaxed);
        }
    }

    /// Fold freshly observed engine-side sheds in.
    pub fn add_shed(&self, n: u64) {
        if n > 0 {
            self.shed.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// One `kind: "snapshot"` JSON line at `t_ms` on the serving clock.
    pub fn snapshot_json(&self, t_ms: f64) -> Json {
        let completed = self.completed.load(Ordering::Relaxed);
        let violated = self.violated.load(Ordering::Relaxed);
        let shed = self.shed.load(Ordering::Relaxed);
        obj(vec![
            ("kind", s("snapshot")),
            ("node", num(self.node as f64)),
            ("t_ms", num(t_ms)),
            ("completed", num(completed as f64)),
            ("violated", num(violated as f64)),
            ("sheds", num(shed as f64)),
        ])
    }

    /// Compact human-readable status (the live one-liner).
    pub fn status_line(&self, t_ms: f64) -> String {
        let completed = self.completed.load(Ordering::Relaxed);
        let violated = self.violated.load(Ordering::Relaxed);
        let shed = self.shed.load(Ordering::Relaxed);
        let viol_pct = if completed == 0 {
            0.0
        } else {
            100.0 * violated as f64 / completed as f64
        };
        format!(
            "[telemetry] node {} t={:.1}s completed={} viol={:.2}% shed={}",
            self.node,
            t_ms / 1e3,
            completed,
            viol_pct,
            shed,
        )
    }
}

/// The end-of-run `kind: "final"` snapshot: every term of the
/// conservation identity as a counter (`completed + sheds + cache_served
/// + leftover == attempts` — recomputable with no outcome vec), the
/// streaming latency/slack histograms, per-model and per-reason
/// breakdowns, and the SAC action histogram.
pub fn final_snapshot(horizon_ms: f64, attempts: u64, cache_served: u64,
                      leftover: u64, metrics: &Metrics,
                      telemetry: &TraceReport) -> Json {
    let per_model = arr(ModelId::all().into_iter().map(|m| {
        obj(vec![
            ("model", s(m.name())),
            ("completed", num(metrics.outcomes_for(m) as f64)),
            ("violated", num(metrics.violations_for(m) as f64)),
            ("shed", num(metrics.shed_for(m) as f64)),
        ])
    }));
    let sheds_by_reason = Json::Obj(
        ShedReason::all()
            .into_iter()
            .map(|r| {
                (r.label().to_string(),
                 num(metrics.shed_by_reason(r) as f64))
            })
            .collect(),
    );
    obj(vec![
        ("kind", s("final")),
        ("horizon_ms", num(horizon_ms)),
        ("attempts", num(attempts as f64)),
        ("completed", num(metrics.recorded_outcomes() as f64)),
        ("violated", num(metrics.violations_total() as f64)),
        ("violation_rate", num(metrics.violation_rate())),
        ("sheds", num(metrics.shed_total() as f64)),
        ("sheds_by_reason", sheds_by_reason),
        ("cache_served", num(cache_served as f64)),
        ("leftover", num(leftover as f64)),
        ("shed_rate", num(metrics.shed_rate())),
        ("headroom_decisions", num(metrics.headroom_decisions() as f64)),
        ("headroom_fallbacks", num(metrics.headroom_fallbacks() as f64)),
        ("sessions_started", num(metrics.sessions_started() as f64)),
        ("session_steps", num(metrics.session_steps_spawned() as f64)),
        ("ttft_misses", num(metrics.ttft_misses() as f64)),
        ("tpot_misses", num(metrics.tpot_misses() as f64)),
        ("latency", metrics.latency_hist().to_json()),
        ("slack", metrics.slack_hist().to_json()),
        ("per_model", per_model),
        ("actions", telemetry.actions_json()),
        ("traces_dropped", num(telemetry.dropped as f64)),
    ])
}

// ---------------------------------------------------------------------
// JSON-lines file plumbing
// ---------------------------------------------------------------------

/// Truncate (or create) a JSON-lines file at run start.
pub fn init_jsonl(path: &str) -> std::io::Result<()> {
    std::fs::write(path, "")
}

/// Append one JSON line (single `write_all` on an append-mode fd, so
/// concurrent per-node publishers interleave whole lines).
pub fn append_jsonl(path: &str, line: &Json) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    let mut text = line.to_string();
    text.push('\n');
    f.write_all(text.as_bytes())
}

/// Write every sampled trace as JSON-lines (truncating).
pub fn write_trace_file(path: &str, traces: &[RequestTrace])
                        -> std::io::Result<()> {
    let mut out = String::new();
    for t in traces {
        out.push_str(&t.to_json().to_string());
        out.push('\n');
    }
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::stats::percentile;

    #[test]
    fn sampling_is_deterministic_and_id_keyed() {
        let cfg = TelemetryConfig { trace_sample: 64, ..Default::default() };
        let a: Vec<u64> = (0..10_000).filter(|&id| cfg.sampled(id)).collect();
        let b: Vec<u64> = (0..10_000).filter(|&id| cfg.sampled(id)).collect();
        assert_eq!(a, b, "same rate must sample the same id set");
        assert_eq!(a.len(), 10_000 / 64 + 1);
        assert!(a.iter().all(|id| id % 64 == 0));
        // Node id-window striding (multiples of 2^32) keeps per-id
        // verdicts stable: the verdict depends only on the id.
        let strided = (1u64 << 40) + 128;
        assert_eq!(cfg.sampled(strided), strided % 64 == 0);
        // Off and full-rate extremes.
        let off = TelemetryConfig::default();
        assert!(!off.tracing_on());
        assert!(!off.sampled(0));
        let full = TelemetryConfig { trace_sample: 1, ..Default::default() };
        assert!((0..100).all(|id| full.sampled(id)));
    }

    #[test]
    fn trace_ring_is_bounded_and_counts_drops() {
        let mut ring = TraceRing::new(4);
        for id in 0..10u64 {
            ring.push(RequestTrace::stub(id, ModelId::Yolo,
                                         TraceVerdict::Completed));
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.dropped(), 6);
        let drained = ring.drain();
        assert!(ring.is_empty());
        // Oldest evicted first: the survivors are the newest four.
        let ids: Vec<u64> = drained.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
    }

    #[test]
    fn histogram_quantiles_match_exact_oracle_within_one_bucket() {
        // Log-uniform data spanning the histogram's whole range.
        let mut rng = Pcg32::seeded(0x7E1E);
        let lo_ln = 0.1f64.ln();
        let hi_ln = 5_000.0f64.ln();
        let xs: Vec<f64> = (0..10_000)
            .map(|_| (lo_ln + (hi_ln - lo_ln) * rng.next_f64()).exp())
            .collect();
        let mut hist = LogHistogram::default();
        for &x in &xs {
            hist.add(x);
        }
        assert_eq!(hist.count(), xs.len() as u64);
        let g = LogHistogram::growth();
        for q in [0.1, 0.5, 0.9, 0.99] {
            let exact = percentile(&xs, q);
            let est = hist.quantile(q);
            let (lo, hi) = hist.quantile_bounds(q);
            assert!(lo <= est + 1e-12 && est <= hi * (1.0 + 1e-12),
                    "estimate {est} outside its own bucket [{lo}, {hi}]");
            // Within one bucket width of the oracle, either side.
            assert!(exact >= lo / g - 1e-9 && exact <= hi * g + 1e-9,
                    "q={q}: exact {exact} vs bucket [{lo}, {hi}] (g={g})");
        }
        // Sub-LO and negative values clamp into bucket 0.
        let mut h0 = LogHistogram::default();
        h0.add(-5.0);
        h0.add(0.0);
        h0.add(0.01);
        assert_eq!(h0.count(), 3);
        assert!(h0.quantile(0.99) <= HIST_LO_MS);
        // Empty histogram answers zeros.
        let empty = LogHistogram::default();
        assert_eq!(empty.quantile(0.5), 0.0);
        assert_eq!(empty.mean(), 0.0);
    }

    #[test]
    fn histogram_merge_is_associative_and_order_free() {
        let mk = |seed: u64, n: usize| -> LogHistogram {
            let mut rng = Pcg32::seeded(seed);
            let mut h = LogHistogram::default();
            for _ in 0..n {
                h.add(rng.next_f64() * 400.0);
            }
            h
        };
        let (a, b, c) = (mk(1, 500), mk(2, 800), mk(3, 300));
        // (a + b) + c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a + (b + c)
        let mut right_inner = b.clone();
        right_inner.merge(&c);
        let mut right = a.clone();
        right.merge(&right_inner);
        assert_eq!(left.counts, right.counts);
        assert_eq!(left.count(), right.count());
        assert_eq!(left.quantile(0.9), right.quantile(0.9));
        assert!((left.mean() - right.mean()).abs() < 1e-9);
    }

    #[test]
    fn tracer_span_sum_equals_e2e_and_survives_pending_reuse() {
        let cfg = TelemetryConfig { trace_sample: 2, ..Default::default() };
        let mut tracer = EngineTracer::new(&cfg, 3);
        let mut r = Request::new(4, ModelId::Res, 100.0);
        r.slo_ms = 80.0;
        r.transmission_ms = 2.5;
        tracer.on_ingest(r.id, 101.0);
        tracer.record_action(8, 2);
        tracer.record_action(8, 2);
        tracer.on_complete(&r, 110.0, 30.0, 5, 8, false);
        // Unsampled ids (odd) leave no record at all.
        let mut r_odd = Request::new(5, ModelId::Res, 100.0);
        r_odd.slo_ms = 80.0;
        tracer.on_ingest(r_odd.id, 101.0);
        tracer.on_complete(&r_odd, 110.0, 30.0, 5, 8, false);
        let report = tracer.take_report();
        assert_eq!(report.traces.len(), 1);
        let t = &report.traces[0];
        assert_eq!(t.id, 4);
        assert_eq!(t.worker, 3);
        assert_eq!(t.verdict, TraceVerdict::Completed);
        assert!((t.ingress_wait_ms - 1.0).abs() < 1e-9);
        assert!((t.batch_wait_ms - 9.0).abs() < 1e-9);
        assert!((t.infer_ms - 30.0).abs() < 1e-9);
        // The span identity: ingress + batch + infer + net == e2e.
        assert!((t.span_sum_ms() - t.e2e_ms).abs() < 1e-9,
                "spans {} != e2e {}", t.span_sum_ms(), t.e2e_ms);
        assert_eq!(report.actions.get(&(8, 2)), Some(&2));
        // The report drained: a second take is empty.
        assert!(tracer.take_report().traces.is_empty());
    }

    #[test]
    fn trace_json_round_trips_through_the_parser() {
        let mut t = RequestTrace::stub(128, ModelId::Bert,
                                       TraceVerdict::Shed(
                                           ShedReason::DeadlineUnmeetable));
        t.ingress_wait_ms = 4.25;
        t.slo_ms = 60.0;
        let line = t.to_json().to_string();
        let parsed = crate::util::json::parse(&line).expect("line parses");
        assert_eq!(parsed.get("id").and_then(Json::as_f64), Some(128.0));
        assert_eq!(parsed.get("model").and_then(Json::as_str), Some("bert"));
        assert_eq!(parsed.get("verdict").and_then(Json::as_str),
                   Some("deadline-unmeetable"));
        assert_eq!(parsed.get("ingress_wait_ms").and_then(Json::as_f64),
                   Some(4.25));
    }

    #[test]
    fn hub_snapshot_counts_and_formats() {
        let hub = TelemetryHub::new(2);
        hub.add_completed(10, 3);
        hub.add_shed(4);
        hub.add_completed(0, 0); // no-op
        let snap = hub.snapshot_json(1_500.0);
        assert_eq!(snap.get("kind").and_then(Json::as_str), Some("snapshot"));
        assert_eq!(snap.get("node").and_then(Json::as_f64), Some(2.0));
        assert_eq!(snap.get("completed").and_then(Json::as_f64), Some(10.0));
        assert_eq!(snap.get("violated").and_then(Json::as_f64), Some(3.0));
        assert_eq!(snap.get("sheds").and_then(Json::as_f64), Some(4.0));
        let line = hub.status_line(1_500.0);
        assert!(line.contains("completed=10"), "{line}");
        assert!(line.contains("30.00%"), "{line}");
    }
}
