//! `bcedge` — launcher CLI for the BCEdge serving framework.
//!
//! Subcommands:
//!   serve         — serve Poisson traffic (sim or real PJRT backend,
//!                   single-threaded engine loop)
//!   bench-serve   — drive the CONCURRENT serving runtime with the
//!                   built-in load generator: multi-worker engine pool
//!                   behind a bounded ingress with SLO-aware admission
//!                   control, gauge-driven dynamic resharding, and
//!                   hot-model replication
//!   bench-cluster — drive the HETEROGENEOUS EDGE-CLUSTER tier: several
//!                   nodes (each a full serving runtime on its own
//!                   Table-V platform behind its own network link)
//!                   behind a SHARDED front-end — K router shards
//!                   working from gossiped gauge snapshots, with an
//!                   optional deduplicating result cache in front of
//!                   routing and an optional mid-run node drain/rejoin
//!   train         — offline SAC training on the platform simulator
//!   sweep         — Fig. 1 style (batch × concurrency) sweep on the
//!                   simulator
//!   info          — print zoo / artifact / platform information
//!
//! Every subcommand's full flag set lives in ONE place: the consolidated
//! flags table in `rust/ARCHITECTURE.md` (§ "CLI flags"), next to the
//! module map and the serving-stack invariants. This header deliberately
//! does not duplicate it.
//!
//! Reported by bench-serve: achieved rps, p50/p99 end-to-end latency, SLO
//! violation rate over accepted requests, the admission shed rate with
//! typed reasons, and (live multi-worker) migrations + peak worker
//! imbalance + replica scale-ups/scale-downs. bench-cluster adds the
//! per-node breakdown (dispatched / completed / violations / sheds) and
//! the router's edge-shed count.
//!
//! Examples:
//!   bcedge serve --backend sim --rps 30 --seconds 300 --scheduler sac
//!   bcedge bench-serve --workers 4 --rps 200 --seconds 10
//!   bcedge bench-serve --clock wall --mode closed --concurrency 32
//!   bcedge bench-serve --platform tx2 --rps 60 --seconds 10
//!   bcedge bench-cluster --nodes xavier-nx:2:2,tx2:2:6,nano:1:12 \
//!          --policy slo-aware --rps 250 --seconds 5 --slo-scale 3
//!   bcedge bench-cluster --policy round-robin --drain-node 1
//!   bcedge bench-cluster --router-shards 4 --gossip-ms 5 \
//!          --cache-ttl-ms 500 --cache-capacity 4096 --repeat-fraction 0.5
//!   bcedge bench-cluster --clock virtual --workload llm --decode-steps 8 \
//!          --tpot-ms 40 --link-bw-mbps 2 --net-pricing contention
//!   bcedge train --episodes 100 --out results/sac_policy.json
//!   bcedge info

use bcedge::coordinator::baselines::{self, DeepRtScheduler, FixedScheduler};
use bcedge::coordinator::sac_sched::{self, SchedEnv};
use bcedge::coordinator::{Engine, EngineConfig, Scheduler, STATE_DIM};
use bcedge::platform::{PlatformSim, PlatformSpec};
use bcedge::rl::env::{train_episodes, Env};
use bcedge::rl::sac::{DiscreteSac, SacConfig};
use bcedge::rl::ActionSpace;
use bcedge::runtime::{PjrtRuntime, RealDispatcher, SimDispatcher};
use bcedge::util::cli::Args;
use bcedge::util::rng::Pcg32;
use bcedge::util::time::VirtualClock;
use bcedge::workload::models::{ModelId, ModelSpec};
use bcedge::workload::PoissonGenerator;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["no-predictor", "greedy", "no-admission",
                                "no-rebalance", "no-gauge-hints",
                                "no-replication"])
        .map_err(anyhow::Error::msg)?;
    match args.positional().first().map(String::as_str) {
        Some("serve") => serve(&args),
        Some("bench-serve") => bench_serve(&args),
        Some("bench-cluster") => bench_cluster(&args),
        Some("train") => train(&args),
        Some("sweep") => sweep(&args),
        Some("info") => info(&args),
        Some("validate-telemetry") => validate_telemetry(&args),
        _ => {
            eprintln!("usage: bcedge <serve|bench-serve|bench-cluster|train|sweep|info|validate-telemetry> [options]");
            eprintln!("  serve --backend sim|real --rps N --seconds N \\");
            eprintln!("        --scheduler sac|tac|deeprt|fixed [--policy F] [--no-predictor]");
            eprintln!("  bench-serve --workers N --rps N --seconds N [--clock virtual|wall] \\");
            eprintln!("        [--platform xavier-nx|tx2|nano|host] \\");
            eprintln!("        --mode open|closed [--concurrency K] --envelope constant|bursty|diurnal \\");
            eprintln!("        --scheduler sac|deeprt|fixed [--no-admission] [--queue-cap N] [--seed S] \\");
            eprintln!("        [--rebalance-epoch-ms N] [--no-rebalance] [--no-gauge-hints] \\");
            eprintln!("        [--max-replicas N] [--no-replication] [--slo-scale X] \\");
            eprintln!("        [--admission snapshot|predictive] [--admission-quantile mean|p95] \\");
            eprintln!("        [--predictor-warmup N] \\");
            eprintln!("        [--workload oneshot|llm] [--decode-steps N] [--ttft-slo-scale X] \\");
            eprintln!("        [--tpot-ms T]");
            eprintln!("  bench-cluster --nodes PLAT[:WORKERS[:RTT_MS]],... --policy round-robin|\\");
            eprintln!("        join-shortest-backlog|power-of-two|slo-aware --rps N --seconds N \\");
            eprintln!("        [--clock wall|virtual] [--mode open|closed] [--slo-scale X] \\");
            eprintln!("        [--router-shards K] [--gossip-ms T] [--cache-ttl-ms T] \\");
            eprintln!("        [--cache-capacity N] [--repeat-fraction F] \\");
            eprintln!("        [--drain-node I] [--drain-at-s T] [--rejoin-at-s T] \\");
            eprintln!("        [--link-bw-mbps B] [--net-pricing contention|static-rtt] + bench-serve knobs");
            eprintln!("  (bench-serve/bench-cluster observability) [--trace-out F] [--trace-sample N] \\");
            eprintln!("        [--metrics-out F] [--metrics-interval-ms T]");
            eprintln!("  train --episodes N --rps N --platform xavier-nx|tx2|nano --out F");
            eprintln!("  sweep --model yolo");
            eprintln!("  info  [--artifacts DIR]");
            eprintln!("  validate-telemetry [--metrics F] [--trace F]");
            eprintln!("full flags table: rust/ARCHITECTURE.md");
            std::process::exit(2);
        }
    }
}

fn make_scheduler(name: &str, space: &ActionSpace, rng: &mut Pcg32,
                  policy: Option<&str>, greedy: bool)
                  -> anyhow::Result<Box<dyn Scheduler>> {
    Ok(match name {
        "sac" => {
            let mut s = sac_sched::sac(space.clone(), rng);
            if let Some(path) = policy {
                let text = std::fs::read_to_string(path)?;
                let v = bcedge::util::json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                s.agent.load_policy(&v).map_err(anyhow::Error::msg)?;
            }
            s.set_greedy(greedy);
            Box::new(s)
        }
        "tac" => Box::new(baselines::tac(space.clone(), rng)),
        "ddqn" => Box::new(baselines::ddqn(space.clone(), rng)),
        "ppo" => Box::new(baselines::ppo(space.clone(), rng)),
        "deeprt" => Box::new(DeepRtScheduler::default()),
        "fixed" => Box::new(FixedScheduler { batch: 4, m_c: 2 }),
        other => anyhow::bail!("unknown scheduler {other}"),
    })
}

/// Parse one platform name (Table V presets + the calibrated host).
fn parse_platform(name: &str) -> anyhow::Result<PlatformSpec> {
    Ok(match name {
        "nx" | "xavier-nx" => PlatformSpec::xavier_nx(),
        "tx2" => PlatformSpec::jetson_tx2(),
        "nano" => PlatformSpec::jetson_nano(),
        "host" => PlatformSpec::host_cpu(),
        other => anyhow::bail!(
            "unknown platform {other} (xavier-nx|nx|tx2|nano|host)"
        ),
    })
}

fn platform_of(args: &Args) -> anyhow::Result<PlatformSpec> {
    parse_platform(args.get_or("platform", "nx"))
}

fn report(m: &bcedge::metrics::Metrics, horizon_ms: f64) {
    println!("{:<6} {:>10} {:>12} {:>12} {:>10}",
             "model", "completed", "mean(ms)", "SLO(ms)", "viol%");
    for model in ModelId::all() {
        let spec = ModelSpec::get(model);
        let n = m.outcomes().iter().filter(|o| o.model == model).count();
        if n == 0 {
            continue;
        }
        println!("{:<6} {:>10} {:>12.2} {:>12.0} {:>9.1}%",
                 spec.name, n, m.mean_latency_ms(Some(model)), spec.slo_ms,
                 100.0 * m.violation_rate_for(model));
    }
    println!("aggregate: {:.1} rps | mean {:.2} ms | p99 {:.2} ms | viol {:.2}% | utility {:.3}",
             m.throughput_rps(horizon_ms), m.mean_latency_ms(None),
             m.latency_percentile(0.99), 100.0 * m.violation_rate(),
             m.mean_utility(None));
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let rps: f64 = args.get_parse("rps", 30.0).map_err(anyhow::Error::msg)?;
    let seconds: f64 =
        args.get_parse("seconds", 60.0).map_err(anyhow::Error::msg)?;
    let backend = args.get_or("backend", "sim");
    let sched = args.get_or("scheduler", "sac").to_string();
    let platform = platform_of(args)?;
    let horizon_ms = seconds * 1e3;
    let space = ActionSpace::standard();
    let mut rng = Pcg32::seeded(
        args.get_parse("seed", 42u64).map_err(anyhow::Error::msg)?,
    );
    let mut scheduler = make_scheduler(&sched, &space, &mut rng,
                                       args.get("policy"), args.flag("greedy"))?;
    let cfg = EngineConfig {
        action_space: space,
        use_predictor: !args.flag("no-predictor"),
        pad_to_artifacts: backend == "real",
        max_total_instances: platform.max_instances,
        learn: true,
        ..Default::default()
    };
    println!("bcedge serve — backend {backend}, scheduler {}, {rps} rps, {seconds}s",
             scheduler.name());
    let mut gen = PoissonGenerator::new(rps, 7);
    let reqs = gen.generate_horizon(horizon_ms);
    match backend {
        "sim" => {
            let clock = VirtualClock::new();
            let sim = PlatformSim::new(platform);
            let mut engine =
                Engine::new(SimDispatcher::new(sim, clock), cfg);
            engine.submit(reqs);
            let slots = engine.run(scheduler.as_mut(), horizon_ms);
            println!("{slots} scheduling slots (virtual time)");
            report(&engine.metrics, horizon_ms);
        }
        "real" => {
            let dir = args.get_or("artifacts", "artifacts");
            let runtime = Arc::new(PjrtRuntime::load(dir)?);
            let threads: usize =
                args.get_parse("threads", 4).map_err(anyhow::Error::msg)?;
            let mut dispatcher = RealDispatcher::new(runtime.clone(), threads);
            dispatcher.warm_all(&runtime.index().batch_sizes.clone())?;
            dispatcher.reset_origin();
            let mut engine = Engine::new(dispatcher, cfg);
            engine.submit(reqs);
            let slots = engine.run(scheduler.as_mut(), horizon_ms);
            println!("{slots} scheduling slots (wall time)");
            report(&engine.metrics, horizon_ms);
        }
        other => anyhow::bail!("unknown backend {other}"),
    }
    Ok(())
}

/// Observability knobs shared by bench-serve and bench-cluster:
/// `--trace-out F` (sampled span records, JSON-lines), `--trace-sample N`
/// (deterministic 1/N id-keyed sampling; defaults to 64 when a trace
/// file is requested, 0 = off otherwise), `--metrics-out F` (streaming
/// counter snapshots + the final conservation snapshot), and
/// `--metrics-interval-ms T` (publisher cadence). Truncates the metrics
/// stream so each run starts a fresh file.
fn telemetry_of(args: &Args)
                -> anyhow::Result<bcedge::telemetry::TelemetryConfig> {
    let trace_out = args.get("trace-out").map(str::to_string);
    let default_sample: u64 = if trace_out.is_some() { 64 } else { 0 };
    let cfg = bcedge::telemetry::TelemetryConfig {
        trace_out,
        trace_sample: args
            .get_parse("trace-sample", default_sample)
            .map_err(anyhow::Error::msg)?,
        metrics_out: args.get("metrics-out").map(str::to_string),
        metrics_interval_ms: args
            .get_parse("metrics-interval-ms", 500.0)
            .map_err(anyhow::Error::msg)?,
        node_label: 0,
    };
    if let Some(path) = &cfg.metrics_out {
        bcedge::telemetry::init_jsonl(path)?;
    }
    Ok(cfg)
}

/// Flush a run's sampled traces and final counter snapshot to the
/// `--trace-out` / `--metrics-out` streams.
fn flush_telemetry(tcfg: &bcedge::telemetry::TelemetryConfig,
                   horizon_ms: f64, attempts: u64, cache_served: u64,
                   leftover: u64, metrics: &bcedge::metrics::Metrics,
                   telemetry: &bcedge::telemetry::TraceReport)
                   -> anyhow::Result<()> {
    if let Some(path) = &tcfg.trace_out {
        bcedge::telemetry::write_trace_file(path, &telemetry.traces)?;
        println!("traces: {} sampled spans (1/{}) -> {path}{}",
                 telemetry.traces.len(),
                 tcfg.trace_sample.max(1),
                 if telemetry.dropped > 0 {
                     format!(" ({} dropped)", telemetry.dropped)
                 } else {
                     String::new()
                 });
    }
    if let Some(path) = &tcfg.metrics_out {
        let line = bcedge::telemetry::final_snapshot(
            horizon_ms, attempts, cache_served, leftover, metrics,
            telemetry);
        bcedge::telemetry::append_jsonl(path, &line)?;
        println!("metrics stream -> {path}");
    }
    Ok(())
}

/// Shared serving-runtime knobs for bench-serve and bench-cluster:
/// scheduler, admission, queue capacity, rebalance/replication, gauge
/// hints. Clock defaults differ per subcommand, so it is a parameter.
fn serve_config_of(args: &Args, clock: bcedge::serve::ClockKind,
                   seed: u64) -> anyhow::Result<bcedge::serve::ServeConfig> {
    use bcedge::serve::{RebalanceConfig, SchedulerSpec, ServeConfig};
    let scheduler = match args.get_or("scheduler", "sac") {
        "sac" => SchedulerSpec::Sac { seed: seed ^ 0x5AC },
        "deeprt" => SchedulerSpec::DeepRt,
        "fixed" => SchedulerSpec::Fixed { batch: 4, m_c: 2 },
        other => anyhow::bail!("unknown scheduler {other}"),
    };
    let rebalance = if args.flag("no-rebalance") {
        None
    } else {
        let defaults = RebalanceConfig::default();
        let max_replicas = if args.flag("no-replication") {
            1 // one owner per model: the PR 3 resharding-only behaviour
        } else {
            args.get_parse("max-replicas", defaults.max_replicas)
                .map_err(anyhow::Error::msg)?
        };
        Some(RebalanceConfig {
            epoch_ms: args
                .get_parse("rebalance-epoch-ms", defaults.epoch_ms)
                .map_err(anyhow::Error::msg)?,
            max_replicas,
            ..Default::default()
        })
    };
    ServeConfig::builder()
        .workers(args.get_parse("workers", 4).map_err(anyhow::Error::msg)?)
        .clock(clock)
        .platform(platform_of(args)?)
        .scheduler(scheduler)
        .admission(if args.flag("no-admission") {
            None
        } else {
            Some(admission_of(args)?)
        })
        .queue_capacity(
            args.get_parse("queue-cap", 256).map_err(anyhow::Error::msg)?,
        )
        .rebalance(rebalance)
        .cluster_hints(!args.flag("no-gauge-hints"))
        .telemetry(telemetry_of(args)?)
        .build()
        .map_err(anyhow::Error::msg)
}

/// Admission knobs: `--admission snapshot|predictive` picks the pricing
/// source, `--admission-quantile mean|p95` the predictive risk posture,
/// `--predictor-warmup N` the observation count before the predictor is
/// trusted (cold decisions fall back to the snapshot formula).
fn admission_of(args: &Args)
                -> anyhow::Result<bcedge::serve::AdmissionConfig> {
    use bcedge::predictor::{AdmissionMode, AdmissionQuantile};
    let mut cfg = bcedge::serve::AdmissionConfig::default();
    let mode = args.get_or("admission", AdmissionMode::Snapshot.name());
    cfg.mode = AdmissionMode::from_name(mode)
        .ok_or_else(|| anyhow::anyhow!("unknown --admission {mode}"))?;
    let quantile =
        args.get_or("admission-quantile", AdmissionQuantile::Mean.name());
    cfg.quantile = AdmissionQuantile::from_name(quantile).ok_or_else(|| {
        anyhow::anyhow!("unknown --admission-quantile {quantile}")
    })?;
    cfg.predictor_warmup = args
        .get_parse("predictor-warmup", cfg.predictor_warmup)
        .map_err(anyhow::Error::msg)?;
    Ok(cfg)
}

/// Shared load-generation knobs (rate, horizon, envelope, client model,
/// SLO scale). `--workload llm` turns every admitted request into an
/// autoregressive SESSION: the head carries a TTFT deadline
/// (`slo_ms × --ttft-slo-scale`) and each completion spawns the next of
/// `--decode-steps` decode rounds under a flat `--tpot-ms` cadence
/// budget.
fn loadgen_of(args: &Args, rps_default: f64, seconds_default: f64)
              -> anyhow::Result<bcedge::serve::LoadGenConfig> {
    use bcedge::serve::{LoadGenConfig, LoadMode};
    use bcedge::workload::{RateEnvelope, SessionSpec};
    let mode = match args.get_or("mode", "open") {
        "open" => LoadMode::Open,
        "closed" => LoadMode::Closed {
            concurrency: args
                .get_parse("concurrency", 16)
                .map_err(anyhow::Error::msg)?,
        },
        other => anyhow::bail!("unknown mode {other}"),
    };
    let envelope = match args.get_or("envelope", "constant") {
        "constant" => RateEnvelope::Constant,
        "bursty" => RateEnvelope::bursty(),
        "diurnal" => RateEnvelope::diurnal(),
        other => anyhow::bail!("unknown envelope {other}"),
    };
    // Struct literal, not SessionSpec::new: the builder reports bad
    // knob values as Err instead of a panic.
    let session = match args.get_or("workload", "oneshot") {
        "oneshot" => None,
        "llm" => Some(SessionSpec {
            decode_steps: args
                .get_parse("decode-steps", 4u32)
                .map_err(anyhow::Error::msg)?,
            ttft_slo_scale: args
                .get_parse("ttft-slo-scale", 1.0)
                .map_err(anyhow::Error::msg)?,
            tpot_ms: args
                .get_parse("tpot-ms", 40.0)
                .map_err(anyhow::Error::msg)?,
        }),
        other => anyhow::bail!("unknown workload {other} (oneshot|llm)"),
    };
    LoadGenConfig::builder()
        .rps(args
            .get_parse("rps", rps_default)
            .map_err(anyhow::Error::msg)?)
        .seconds(
            args.get_parse("seconds", seconds_default)
                .map_err(anyhow::Error::msg)?,
        )
        .seed(args.get_parse("seed", 7u64).map_err(anyhow::Error::msg)?)
        .envelope(envelope)
        .mode(mode)
        .slo_scale(
            args.get_parse("slo-scale", 1.0).map_err(anyhow::Error::msg)?,
        )
        .repeat_fraction(
            args.get_parse("repeat-fraction", 0.0)
                .map_err(anyhow::Error::msg)?,
        )
        .session(session)
        .build()
        .map_err(anyhow::Error::msg)
}

/// Drive the concurrent serving runtime with the built-in load generator.
fn bench_serve(args: &Args) -> anyhow::Result<()> {
    use bcedge::serve::{self, LoadMode};

    let load = loadgen_of(args, 200.0, 10.0)?;
    let seed = load.seed; // one --seed pins trace AND schedulers
    let clock = match (args.get("clock"), load.mode) {
        // Closed loop runs on real completions: wall unless overridden.
        (None, LoadMode::Closed { .. }) => serve::ClockKind::Wall,
        (None, LoadMode::Open) | (Some("virtual"), _) => {
            serve::ClockKind::Virtual
        }
        (Some("wall"), _) => serve::ClockKind::Wall,
        (Some(other), _) => anyhow::bail!("unknown clock {other}"),
    };
    let serve_cfg = serve_config_of(args, clock, seed)?;
    println!(
        "bcedge bench-serve — {} workers on {}, {:?} clock, {:?} mode, \
         {} rps × {}s, admission {}",
        serve_cfg.workers,
        serve_cfg.platform.name,
        clock,
        load.mode,
        load.rps,
        load.seconds,
        if serve_cfg.admission.is_some() { "on" } else { "off" },
    );
    let report = serve::loadgen::run(&serve_cfg, &load)
        .map_err(anyhow::Error::msg)?;
    report.print();
    // Single-node conservation from counters alone (no cache tier):
    // attempts = recorded outcomes + sheds + leftover.
    let attempts = report.metrics.recorded_outcomes()
        + report.metrics.shed_total()
        + report.leftover as u64;
    flush_telemetry(&serve_cfg.telemetry, report.horizon_ms, attempts, 0,
                    report.leftover as u64, &report.metrics,
                    &report.telemetry)?;
    Ok(())
}

/// Drive the heterogeneous edge-cluster tier: parse the node specs,
/// stand up one serving runtime per node, route the load-generator
/// stream through the chosen policy, optionally drain/rejoin a node
/// mid-run, and print the cluster report.
fn bench_cluster(args: &Args) -> anyhow::Result<()> {
    use bcedge::cluster::{self, CacheConfig, ClusterConfig, DrainScenario,
                          FrontEndConfig, NodeSpec, RoutePolicy};
    use bcedge::serve::ClockKind;

    let load = loadgen_of(args, 200.0, 5.0)?;
    let seed = load.seed; // one --seed pins trace, schedulers, and router
    // The cluster tier is live by default (routing reads live gauge
    // snapshots); the virtual arm is the deterministic trace mode.
    let clock = match args.get_or("clock", "wall") {
        "wall" => ClockKind::Wall,
        "virtual" => ClockKind::Virtual,
        other => anyhow::bail!("unknown clock {other}"),
    };
    let policy = RoutePolicy::from_name(args.get_or("policy", "slo-aware"))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown policy (round-robin|join-shortest-backlog|\
                 power-of-two|slo-aware)"
            )
        })?;
    // Node spec grammar: PLATFORM[:WORKERS[:RTT_MS]], comma-separated.
    let mut nodes: Vec<NodeSpec> = args
        .get_or("nodes", "xavier-nx:2:2,tx2:2:6,nano:1:12")
        .split(',')
        .map(|spec| -> anyhow::Result<NodeSpec> {
            let mut parts = spec.split(':');
            let platform = parse_platform(
                parts.next().filter(|p| !p.is_empty()).ok_or_else(|| {
                    anyhow::anyhow!("empty node spec in --nodes")
                })?,
            )?;
            let workers: usize = match parts.next() {
                None => 2,
                Some(w) => w.parse().map_err(|_| {
                    anyhow::anyhow!("bad worker count in node spec {spec:?}")
                })?,
            };
            let rtt_ms: f64 = match parts.next() {
                None => 5.0,
                Some(r) => r.parse().map_err(|_| {
                    anyhow::anyhow!("bad RTT in node spec {spec:?}")
                })?,
            };
            if workers == 0 {
                anyhow::bail!("node spec {spec:?} needs >= 1 worker");
            }
            if !rtt_ms.is_finite() || rtt_ms < 0.0 {
                anyhow::bail!(
                    "node spec {spec:?} needs a non-negative finite RTT"
                );
            }
            if parts.next().is_some() {
                anyhow::bail!(
                    "node spec {spec:?} has too many fields \
                     (PLATFORM[:WORKERS[:RTT_MS]])"
                );
            }
            Ok(NodeSpec::new(platform, workers, rtt_ms))
        })
        .collect::<anyhow::Result<_>>()?;
    // Shared uplinks: `--link-bw-mbps B` puts every node behind a
    // B-Mbps fair-share link so payload transmission (and queueing
    // behind in-flight transfers) shows up in end-to-end latency.
    // 0 (default) keeps the seed-era infinite-bandwidth wire.
    let link_bw_mbps: f64 = args
        .get_parse("link-bw-mbps", 0.0)
        .map_err(anyhow::Error::msg)?;
    if link_bw_mbps < 0.0 || !link_bw_mbps.is_finite() {
        anyhow::bail!("--link-bw-mbps needs a non-negative finite value");
    }
    if link_bw_mbps > 0.0 {
        for n in &mut nodes {
            n.net = n.net.with_bandwidth(link_bw_mbps);
        }
    }
    // `--net-pricing static-rtt` blinds ROUTING to link contention
    // (the wire is still charged physically) — the ablation baseline.
    let contention_pricing = match args.get_or("net-pricing", "contention")
    {
        "contention" => true,
        "static-rtt" => false,
        other => anyhow::bail!(
            "unknown --net-pricing {other} (contention|static-rtt)"
        ),
    };
    let drain = match args.get("drain-node") {
        None => None,
        Some(n) => {
            let node: usize = n.parse().map_err(|_| {
                anyhow::anyhow!("--drain-node: cannot parse {n:?}")
            })?;
            let at_s: f64 = args
                .get_parse("drain-at-s", 0.4 * load.seconds)
                .map_err(anyhow::Error::msg)?;
            let rejoin_s: f64 = args
                .get_parse("rejoin-at-s", 0.7 * load.seconds)
                .map_err(anyhow::Error::msg)?;
            Some(DrainScenario {
                node,
                at_ms: at_s * 1e3,
                rejoin_at_ms: rejoin_s * 1e3,
            })
        }
    };
    // Front-end tier: router shards, gossip cadence, result cache
    // (--cache-ttl-ms 0 = cache off, the default).
    let cache_ttl_ms: f64 = args
        .get_parse("cache-ttl-ms", 0.0)
        .map_err(anyhow::Error::msg)?;
    let frontend = FrontEndConfig {
        router_shards: args
            .get_parse("router-shards", 1usize)
            .map_err(anyhow::Error::msg)?,
        gossip_ms: args
            .get_parse("gossip-ms", 5.0)
            .map_err(anyhow::Error::msg)?,
        cache: if cache_ttl_ms > 0.0 {
            Some(CacheConfig {
                ttl_ms: cache_ttl_ms,
                capacity: args
                    .get_parse("cache-capacity", 65_536usize)
                    .map_err(anyhow::Error::msg)?,
            })
        } else {
            None
        },
        contention_pricing,
    };
    // Per-node template: the node specs override platform/workers, so
    // --workers and --platform are ignored here in favour of --nodes.
    let serve_cfg = serve_config_of(args, clock, seed)?;
    let cfg = ClusterConfig::builder()
        .nodes(nodes)
        .policy(policy)
        .serve(serve_cfg)
        .drain(drain)
        .frontend(frontend)
        .build()
        .map_err(anyhow::Error::msg)?;
    println!(
        "bcedge bench-cluster — {} nodes, {} routing, {:?} clock, \
         {:?} mode, {} rps × {}s, slo×{}, {} router shard(s), \
         gossip {} ms, cache {}",
        cfg.nodes.len(),
        policy.name(),
        clock,
        load.mode,
        load.rps,
        load.seconds,
        load.slo_scale,
        frontend.router_shards,
        frontend.gossip_ms,
        match frontend.cache {
            Some(c) => format!("ttl {} ms / cap {}", c.ttl_ms, c.capacity),
            None => "off".to_string(),
        },
    );
    for (i, n) in cfg.nodes.iter().enumerate() {
        println!("  node {i}: {} ×{} workers, rtt {} ms", n.platform.name,
                 n.workers, n.net.rtt_ms);
    }
    let report = cluster::run_cluster(&cfg, &load)
        .map_err(anyhow::Error::msg)?;
    report.print();
    flush_telemetry(&cfg.serve.telemetry, report.horizon_ms,
                    report.attempts, report.cache_served(),
                    report.leftover as u64, &report.metrics,
                    &report.telemetry)?;
    Ok(())
}

/// Validate JSON-lines telemetry streams (the CI smoke gate):
/// `--metrics F` — every line parses, and the final snapshot satisfies
/// the conservation identity recomputed from counters alone
/// (`completed + sheds + cache_served + leftover == attempts`);
/// `--trace F` — every line parses, and completed spans sum to their
/// end-to-end latency within clock resolution.
fn validate_telemetry(args: &Args) -> anyhow::Result<()> {
    use bcedge::util::json::{parse, Json};
    if args.get("metrics").is_none() && args.get("trace").is_none() {
        anyhow::bail!(
            "validate-telemetry needs --metrics F and/or --trace F");
    }
    if let Some(path) = args.get("metrics") {
        let text = std::fs::read_to_string(path)?;
        let mut snapshots = 0usize;
        let mut fin: Option<Json> = None;
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = parse(line).map_err(|e| {
                anyhow::anyhow!("{path}:{}: bad JSON: {e}", i + 1)
            })?;
            match v.get("kind").and_then(|k| k.as_str()) {
                Some("snapshot") => snapshots += 1,
                Some("final") => fin = Some(v),
                other => {
                    anyhow::bail!("{path}:{}: unknown kind {other:?}", i + 1)
                }
            }
        }
        let fin = fin
            .ok_or_else(|| anyhow::anyhow!("{path}: no final snapshot"))?;
        let field = |k: &str| -> anyhow::Result<f64> {
            fin.get(k).and_then(|v| v.as_f64()).ok_or_else(|| {
                anyhow::anyhow!("{path}: final snapshot missing {k}")
            })
        };
        let attempts = field("attempts")?;
        let completed = field("completed")?;
        let sheds = field("sheds")?;
        let cache_served = field("cache_served")?;
        let leftover = field("leftover")?;
        // Counters are exact in f64 up to 2^53, so the sum is exact.
        if completed + sheds + cache_served + leftover != attempts {
            anyhow::bail!(
                "{path}: conservation broken: {completed} completed + \
                 {sheds} sheds + {cache_served} cache_served + {leftover} \
                 leftover != {attempts} attempts");
        }
        // Headroom counters are conservation-neutral but must be
        // internally sane: a fallback IS a decision.
        let headroom_decisions = field("headroom_decisions")?;
        let headroom_fallbacks = field("headroom_fallbacks")?;
        if headroom_fallbacks > headroom_decisions {
            anyhow::bail!(
                "{path}: headroom counters broken: {headroom_fallbacks} \
                 fallbacks > {headroom_decisions} decisions");
        }
        // Dual-SLO session counters: a session has exactly one head and
        // each spawned decode step completes at most once, so the miss
        // counters are bounded by the session counters.
        let sessions_started = field("sessions_started")?;
        let session_steps = field("session_steps")?;
        let ttft_misses = field("ttft_misses")?;
        let tpot_misses = field("tpot_misses")?;
        if ttft_misses > sessions_started {
            anyhow::bail!(
                "{path}: dual-SLO counters broken: {ttft_misses} TTFT \
                 misses > {sessions_started} sessions started");
        }
        if tpot_misses > session_steps {
            anyhow::bail!(
                "{path}: dual-SLO counters broken: {tpot_misses} TPOT \
                 misses > {session_steps} decode steps spawned");
        }
        println!(
            "{path}: OK — {snapshots} snapshot(s) + final; conservation \
             holds ({completed} + {sheds} + {cache_served} + {leftover} == \
             {attempts})");
    }
    if let Some(path) = args.get("trace") {
        let text = std::fs::read_to_string(path)?;
        let mut spans = 0usize;
        let mut completed = 0usize;
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = parse(line).map_err(|e| {
                anyhow::anyhow!("{path}:{}: bad JSON: {e}", i + 1)
            })?;
            spans += 1;
            if v.get("verdict").and_then(|k| k.as_str())
                != Some("completed")
            {
                continue;
            }
            let field = |k: &str| -> anyhow::Result<f64> {
                v.get(k).and_then(|x| x.as_f64()).ok_or_else(|| {
                    anyhow::anyhow!("{path}:{}: trace missing {k}", i + 1)
                })
            };
            let sum = field("ingress_wait_ms")? + field("batch_wait_ms")?
                + field("infer_ms")? + field("net_ms")?;
            let e2e = field("e2e_ms")?;
            if (sum - e2e).abs() > 1e-6 {
                anyhow::bail!(
                    "{path}:{}: spans sum to {sum} but e2e is {e2e}",
                    i + 1);
            }
            completed += 1;
        }
        println!(
            "{path}: OK — {spans} trace line(s), {completed} completed \
             span(s) sum to e2e");
    }
    Ok(())
}

fn train(args: &Args) -> anyhow::Result<()> {
    let episodes: usize =
        args.get_parse("episodes", 100).map_err(anyhow::Error::msg)?;
    let rps: f64 = args.get_parse("rps", 30.0).map_err(anyhow::Error::msg)?;
    let out = args.get_or("out", "results/sac_policy.json");
    let space = ActionSpace::standard();
    let mut env = SchedEnv::new(space.clone(), rps, platform_of(args)?);
    env.episode_len = 96;
    let mut rng = Pcg32::seeded(0x7EA1);
    let cfg = SacConfig { batch_size: 128, warmup: 256, ..Default::default() };
    let mut agent = DiscreteSac::new(STATE_DIM, env.n_actions(), cfg, &mut rng);
    let hist = train_episodes(&mut env, &mut agent, episodes, 96, &mut rng);
    for (i, (ret, loss)) in hist.iter().enumerate() {
        if i % 10 == 0 || i + 1 == hist.len() {
            println!("episode {i:>4}: return {ret:>9.2} loss {loss:>9.4}");
        }
    }
    if let Some(parent) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(out, agent.policy_json().to_string())?;
    println!("saved {out}");
    Ok(())
}

fn sweep(args: &Args) -> anyhow::Result<()> {
    use bcedge::runtime::executor::{BatchJob, Dispatcher};
    let model = ModelId::from_name(args.get_or("model", "yolo"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let platform = platform_of(args)?;
    println!("(batch × concurrency) sweep for {} on sim {}",
             model.name(), platform.name);
    println!("{:>5} {:>5} {:>12} {:>12}", "b", "m_c", "rps", "latency(ms)");
    for b in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        for c in [1usize, 2, 4, 8] {
            let clock = VirtualClock::new();
            let mut d = SimDispatcher::new(
                PlatformSim::new(platform.clone()), clock);
            let jobs: Vec<BatchJob> = (0..c)
                .map(|_| BatchJob { model, batch: b, n_real: b })
                .collect();
            let res = d.run_group(&jobs);
            if res.iter().any(|r| r.is_err()) {
                println!("{b:>5} {c:>5} {:>12} {:>12}", "OOM", "OOM");
                continue;
            }
            let span = res.iter().map(|r| *r.as_ref().unwrap())
                .fold(0.0f64, f64::max);
            println!("{b:>5} {c:>5} {:>12.1} {:>12.2}",
                     (b * c) as f64 / (span / 1e3), span);
        }
    }
    Ok(())
}

fn info(args: &Args) -> anyhow::Result<()> {
    println!("bcedge {} — SLO-aware DNN inference serving", bcedge::version());
    println!("\nmodel zoo (paper Table IV):");
    println!("{:<6} {:<16} {:>10} {:>12}", "name", "paper", "SLO(ms)",
             "weights(MB)");
    for spec in ModelSpec::all() {
        println!("{:<6} {:<16} {:>10.0} {:>12.0}", spec.name,
                 spec.paper_name, spec.slo_ms, spec.memory.weights_mb);
    }
    println!("\nplatforms (paper Table V):");
    for p in PlatformSpec::scalability_set() {
        println!("  {:<12} compute ×{:.3}, {} MB, {} cores, ≤{} instances",
                 p.name, p.compute_scale, p.memory_mb, p.cuda_cores,
                 p.max_instances);
    }
    let dir = args.get_or("artifacts", "artifacts");
    match bcedge::runtime::ArtifactIndex::load(dir) {
        Ok(idx) => println!("\nartifacts: {} entries in {dir}/ (batches {:?})",
                            idx.len(), idx.batch_sizes),
        Err(e) => println!("\nartifacts: not available ({e}) — run `make artifacts`"),
    }
    Ok(())
}
