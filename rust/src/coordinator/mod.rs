//! The BCEdge coordinator — the paper's system contribution (Fig. 2):
//! per-model SLO-priority request queues (①), the performance-profiler
//! feedback loop (②), the SLO-aware interference predictor hook (③), the
//! learning-based scheduler (④), and the batched/concurrent executor
//! drive (⑤), composed by [`engine::Engine`].

pub mod batcher;
pub mod baselines;
pub mod engine;
pub mod harness;
pub mod instances;
pub mod queue;
pub mod sac_sched;
pub mod scheduler;
pub mod slo;
pub mod utility;

pub use engine::{Engine, EngineConfig, IngressGate, IngressSnapshot,
                 SlotOutcome};
pub use queue::{ModelQueue, Router};
pub use sac_sched::{SacScheduler, SchedEnv};
pub use scheduler::{SchedCtx, Scheduler, STATE_DIM};
pub use utility::utility;
