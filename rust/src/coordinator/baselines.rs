//! Baseline schedulers of paper §V-B, behind the [`Scheduler`] trait:
//!
//! * [`FixedScheduler`] — plain Triton: manually configured static
//!   (batch, m_c);
//! * [`DeepRtScheduler`] — DeepRT [12]: EDF-ordered dynamic batching,
//!   NO concurrent instances (m_c ≡ 1), batch sized to fit the earliest
//!   deadline;
//! * [`TacScheduler`] — "Triton with Actor-Critic": learning-based 2-D
//!   scheduling like BCEdge but with an entropy-free actor-critic;
//! * [`DdqnScheduler`] / [`PpoScheduler`] — the Fig. 10 DRL alternatives
//!   ported into the BCEdge framework.

use super::scheduler::{SchedCtx, Scheduler};
use crate::rl::ac::{AcConfig, ActorCritic};
use crate::rl::ddqn::{Ddqn, DdqnConfig};
use crate::rl::env::{Agent, Transition};
use crate::rl::ppo::{Ppo, PpoConfig};
use crate::rl::spaces::ActionSpace;
use crate::util::rng::Pcg32;

/// Static (batch, m_c) — what stock Triton's config file expresses.
#[derive(Clone, Copy, Debug)]
pub struct FixedScheduler {
    pub batch: usize,
    pub m_c: usize,
}

impl Scheduler for FixedScheduler {
    fn decide(&mut self, _ctx: &SchedCtx, _rng: &mut Pcg32) -> (usize, usize) {
        (self.batch, self.m_c)
    }

    fn name(&self) -> &'static str {
        "Fixed (Triton static)"
    }
}

/// DeepRT-style soft real-time scheduler: earliest-deadline-first dynamic
/// batching (the queue already pops shortest-SLO first), concurrency
/// fixed at 1 (the paper: "the lower utility of DeepRT is caused by the
/// lack of concurrent inference"). Batch grows with backlog but is capped
/// so the estimated batch latency fits the tightest deadline's slack.
#[derive(Clone, Copy, Debug)]
pub struct DeepRtScheduler {
    pub max_batch: usize,
}

impl Default for DeepRtScheduler {
    fn default() -> Self {
        DeepRtScheduler { max_batch: 32 }
    }
}

impl Scheduler for DeepRtScheduler {
    fn decide(&mut self, ctx: &SchedCtx, _rng: &mut Pcg32) -> (usize, usize) {
        // Estimated per-batch latency from the profiler's rolling mean
        // (fall back to half the SLO when unobserved). EDF admission:
        // largest power-of-two batch whose estimate fits the minimum
        // slack, with at least batch 1.
        let est = if ctx.recent_latency_ms.is_finite() && ctx.recent_latency_ms > 0.0 {
            ctx.recent_latency_ms
        } else {
            ctx.slo_ms * 0.5
        };
        let slack = ctx.min_slack_ms.max(1.0);
        let mut b = 1usize;
        while b < self.max_batch
            && b * 2 <= ctx.queue_len.max(1)
            // crude scaling: latency grows sublinearly with batch; assume
            // doubling the batch costs 1.6×.
            && est * 1.6f64.powf(((b * 2) as f64).log2()) < slack
        {
            b *= 2;
        }
        // Cross-worker gauge hint: when this shard holds the bulk of the
        // pool's backlog, take one extra doubling beyond the queue-paced
        // growth (slack permitting) to drain the hot queue faster. Inert
        // at the hints' 0.0 default, so the bare engine's DeepRT is
        // unchanged.
        if ctx.cluster_share > 0.6 && b < self.max_batch {
            let next = b * 2;
            if est * 1.6f64.powf((next as f64).log2()) < slack {
                b = next;
            }
        }
        (b.min(self.max_batch), 1)
    }

    fn name(&self) -> &'static str {
        "DeepRT (EDF, no concurrency)"
    }
}

/// Shared plumbing for DRL agents behind the [`Scheduler`] trait.
pub struct AgentScheduler<A: Agent> {
    pub agent: A,
    pub space: ActionSpace,
    greedy: bool,
    static_name: &'static str,
}

impl<A: Agent> AgentScheduler<A> {
    pub fn new(agent: A, space: ActionSpace, name: &'static str) -> Self {
        AgentScheduler { agent, space, greedy: false, static_name: name }
    }
}

impl<A: Agent> Scheduler for AgentScheduler<A> {
    fn decide(&mut self, ctx: &SchedCtx, rng: &mut Pcg32) -> (usize, usize) {
        let state = ctx.encode();
        let a = self.agent.act(&state, rng, self.greedy);
        self.space.decode(a)
    }

    fn feedback(&mut self, prev: &SchedCtx, action: (usize, usize),
                reward: f64, next: &SchedCtx, done: bool, rng: &mut Pcg32)
                -> f32 {
        let Some(a) = self.space.encode(action.0, action.1) else {
            return 0.0;
        };
        self.agent.observe(Transition {
            state: prev.encode().to_vec(),
            action: a,
            reward: reward as f32,
            next_state: next.encode().to_vec(),
            done,
        });
        self.agent.update(rng)
    }

    fn set_greedy(&mut self, greedy: bool) {
        self.greedy = greedy;
    }

    fn name(&self) -> &'static str {
        self.static_name
    }
}

/// TAC: Triton + actor-critic without entropy (§V-B).
pub type TacScheduler = AgentScheduler<ActorCritic>;

/// DDQN ported into BCEdge (§V-B 2).
pub type DdqnScheduler = AgentScheduler<Ddqn>;

/// PPO ported into BCEdge (§V-B 2).
pub type PpoScheduler = AgentScheduler<Ppo>;

/// Construct the TAC baseline on a given action space.
pub fn tac(space: ActionSpace, rng: &mut Pcg32) -> TacScheduler {
    use super::scheduler::STATE_DIM;
    let agent = ActorCritic::new(STATE_DIM, space.len(), AcConfig::default(), rng);
    AgentScheduler::new(agent, space, "TAC (Triton + actor-critic)")
}

/// Construct the DDQN baseline.
pub fn ddqn(space: ActionSpace, rng: &mut Pcg32) -> DdqnScheduler {
    use super::scheduler::STATE_DIM;
    let agent = Ddqn::new(STATE_DIM, space.len(), DdqnConfig::default(), rng);
    AgentScheduler::new(agent, space, "DDQN")
}

/// Construct the PPO baseline.
pub fn ppo(space: ActionSpace, rng: &mut Pcg32) -> PpoScheduler {
    use super::scheduler::STATE_DIM;
    let agent = Ppo::new(STATE_DIM, space.len(), PpoConfig::default(), rng);
    AgentScheduler::new(agent, space, "PPO")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::ModelId;

    fn ctx(queue_len: usize, slack: f64, recent_latency: f64) -> SchedCtx {
        SchedCtx {
            model: ModelId::Res,
            queue_len,
            min_slack_ms: slack,
            slo_ms: 58.0,
            mem_free_frac: 0.8,
            compute_demand: 0.5,
            active_instances: 1,
            recent_latency_ms: recent_latency,
            recent_throughput_rps: 40.0,
            recent_inflation: 1.1,
            cluster_backlog_ms: 0.0,
            cluster_share: 0.0,
            replica_share: 0.0,
        }
    }

    #[test]
    fn fixed_always_fixed() {
        let mut s = FixedScheduler { batch: 8, m_c: 2 };
        let mut rng = Pcg32::seeded(1);
        assert_eq!(s.decide(&ctx(100, 50.0, 10.0), &mut rng), (8, 2));
        assert_eq!(s.decide(&ctx(0, -5.0, 90.0), &mut rng), (8, 2));
    }

    #[test]
    fn deeprt_never_concurrent() {
        let mut s = DeepRtScheduler::default();
        let mut rng = Pcg32::seeded(2);
        for q in [1, 8, 64] {
            let (_, m_c) = s.decide(&ctx(q, 40.0, 5.0), &mut rng);
            assert_eq!(m_c, 1);
        }
    }

    #[test]
    fn deeprt_batches_more_with_backlog_and_slack() {
        let mut s = DeepRtScheduler::default();
        let mut rng = Pcg32::seeded(3);
        let (b_small, _) = s.decide(&ctx(1, 50.0, 5.0), &mut rng);
        let (b_big, _) = s.decide(&ctx(64, 500.0, 5.0), &mut rng);
        assert!(b_big > b_small, "{b_small} !< {b_big}");
        // Tight slack forces batch 1 regardless of backlog.
        let (b_tight, _) = s.decide(&ctx(64, 3.0, 5.0), &mut rng);
        assert_eq!(b_tight, 1);
    }

    /// The gauge hint buys exactly one extra doubling when this shard
    /// dominates the pool's backlog — and stays inert at the default.
    #[test]
    fn deeprt_drains_harder_when_shard_dominates_cluster() {
        let mut s = DeepRtScheduler::default();
        let mut rng = Pcg32::seeded(5);
        let mut c = ctx(4, 500.0, 5.0);
        let (b_base, _) = s.decide(&c, &mut rng);
        c.cluster_share = 0.9;
        c.cluster_backlog_ms = 600.0;
        let (b_hot, m_c) = s.decide(&c, &mut rng);
        assert_eq!(m_c, 1);
        assert_eq!(b_hot, b_base * 2, "hint should buy one doubling");
        // Tight slack still wins over the hint.
        let mut tight = ctx(64, 3.0, 5.0);
        tight.cluster_share = 0.9;
        let (b_tight, _) = s.decide(&tight, &mut rng);
        assert_eq!(b_tight, 1);
    }

    #[test]
    fn agent_scheduler_decides_on_grid() {
        let mut rng = Pcg32::seeded(4);
        let mut s = tac(ActionSpace::standard(), &mut rng);
        let (b, m) = s.decide(&ctx(10, 40.0, 10.0), &mut rng);
        assert!(ActionSpace::standard().encode(b, m).is_some());
        // Feedback path must not panic and returns a finite loss.
        let c = ctx(10, 40.0, 10.0);
        let loss = s.feedback(&c, (b, m), 1.0, &c, false, &mut rng);
        assert!(loss.is_finite());
    }
}
