//! The serving engine: composes the router, dynamic batcher, instance
//! manager, profiler, SLO-aware interference predictor, metrics, and an
//! execution dispatcher into the scheduling loop of paper Fig. 2 /
//! Algorithm 1.
//!
//! One call to [`Engine::step`] is one scheduling slot: pick the next
//! model with pending work (round-robin fairness), encode the MDP state,
//! ask the scheduler for (b, m_c), optionally let the interference
//! predictor *veto-and-shrink* SLO-infeasible actions (§IV-F), assemble
//! and dispatch the instance-batches (Figs. 3/4), account completions,
//! compute the Eq. (3) utility and Eq. (6) reward, and feed it all back to
//! the learning scheduler. "BCEdge starts the next scheduling immediately
//! after finishing the current scheduling to reduce the GPU idle."
//!
//! Hot-path discipline (PR #1, finished in PR #2): the round loop is
//! allocation-free in steady state. All per-round buffers — the
//! busy-model walk, per-model plans, the flattened job list, dispatch
//! results, and the assembled batches with their request vectors — live
//! in the private `RoundScratch` and are recycled between rounds; the
//! outcome vector is caller-owned ([`Engine::step_into`]);
//! queue/profiler aggregate reads are O(1); and OOM'd requests are
//! requeued by move instead of clone. The `seed_equivalence` test module
//! proves the optimized loop emits a bit-identical [`SlotOutcome`]
//! stream to the seed implementation.
//!
//! Serving-runtime seams (PRs #2–#4), all inert on the bare engine: an
//! optional [`IngressGate`] is consulted as arrivals move into the
//! per-model queues, so the `serve` subsystem's SLO-aware admission
//! controller can shed provably-late requests at ingress; the
//! queue-surgery drains ([`Engine::drain_model_into`],
//! [`Engine::drain_model_excess_into`]) let the serving runtime hand
//! backlog between worker engines losslessly for shard migration and
//! multi-owner (replicated) draining; and the cross-worker hint setters
//! ([`Engine::set_cluster_hints`], [`Engine::set_replica_share`]) widen
//! the decision context with pool state. With no gate installed and no
//! hints injected the path is byte-identical to PR #1.

use super::batcher::{AssembledBatch, Batcher};
use super::instances::InstanceManager;
use super::queue::Router;
use super::scheduler::{SchedCtx, Scheduler};
use super::utility;
use crate::metrics::{Metrics, RequestOutcome, ShedReason};
use crate::predictor::{InterferencePredictor, PredictorSample};
use crate::profiler::{ProfileSample, Profiler};
use crate::rl::spaces::ActionSpace;
use crate::runtime::executor::{BatchJob, Dispatcher, ExecError};
use crate::telemetry::{EngineTracer, TraceReport};
use crate::util::rng::Pcg32;
use crate::workload::models::{ModelId, ModelSpec, N_MODELS};
use crate::workload::request::Request;
use std::collections::VecDeque;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub action_space: ActionSpace,
    /// Enable the §IV-F interference predictor in the decision path.
    pub use_predictor: bool,
    /// Pad batches to the compiled artifact grid (real backend) or run
    /// exact sizes (simulation).
    pub pad_to_artifacts: bool,
    /// Platform-wide concurrent-instance cap (spec.max_instances).
    pub max_total_instances: usize,
    /// Train the scheduler online (feedback + update every slot).
    pub learn: bool,
    /// Request serialization overhead (Eq. 2 tᵢ_s), ms per batch.
    pub serialization_ms: f64,
    /// Seed for the engine's decision RNG.
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            action_space: ActionSpace::standard(),
            use_predictor: true,
            pad_to_artifacts: false,
            max_total_instances: 8,
            learn: true,
            serialization_ms: 0.15,
            seed: 0xBCED6E,
        }
    }
}

/// O(1) view of the state an ingress-time admission decision needs, all
/// rolling aggregates the engine already maintains.
#[derive(Clone, Copy, Debug)]
pub struct IngressSnapshot {
    pub now_ms: f64,
    /// Depth of the request's model queue (requests already ahead of it).
    pub queue_len: usize,
    /// Rolling profiled mean batch latency for the model, ms (NaN before
    /// the first observation).
    pub mean_batch_ms: f64,
    /// Isolated latency estimate at the gate's reference batch size, ms —
    /// the optimistic cold-start fallback.
    pub isolated_ref_ms: f64,
    /// The engine predictor's inflation estimate for one more reference
    /// batch of this model under current utilization; NaN when the
    /// predictor is off, colder than the gate's warmup, or the gate never
    /// asked ([`IngressGate::predictor_warmup`] == `usize::MAX`).
    pub predicted_inflation: f64,
    /// The predictor's observed dispersion p95 (NaN under the same
    /// conditions); quantile-aware gates widen the prediction by it.
    pub p95_factor: f64,
}

/// Admission hook consulted as requests move from arrivals into the
/// per-model queues. `None` on the engine means every request is routed —
/// byte-for-byte the pre-gate behaviour. The serving runtime installs
/// [`crate::serve::AdmissionGate`] here; tests can install ad-hoc gates.
pub trait IngressGate: Send {
    /// Reference batch size for the snapshot's isolated-latency estimate.
    fn ref_batch(&self) -> usize;

    /// Minimum predictor samples before this gate wants predictions in
    /// its snapshots. The default `usize::MAX` means "never probe the
    /// predictor" — snapshot-only gates (and ad-hoc test gates) keep the
    /// pre-headroom ingest path untouched.
    fn predictor_warmup(&self) -> usize {
        usize::MAX
    }

    /// `Some(reason)` sheds the request at ingress (recorded in
    /// [`Metrics`] as a shed, not a violation); `None` admits it.
    fn decide(&mut self, r: &Request, snap: &IngressSnapshot)
              -> Option<ShedReason>;

    /// Per-decision headroom accounting: (decisions priced under the
    /// predictive mode, snapshot fallbacks among them). Zero for gates
    /// that never price headroom.
    fn headroom_stats(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// Result of one scheduling slot.
#[derive(Clone, Debug, PartialEq)]
pub struct SlotOutcome {
    pub model: ModelId,
    pub batch: usize,
    pub m_c: usize,
    /// Requests completed in this slot.
    pub completed: usize,
    /// SLO violations among them.
    pub violations: usize,
    pub oom: bool,
    pub utility: f64,
    pub reward: f64,
    /// Scheduler training loss (0 for heuristics / greedy mode).
    pub loss: f32,
    /// Wall/virtual span of the slot, ms.
    pub span_ms: f64,
}

/// One model's planned share of a scheduling round.
struct SlotPlan {
    model: ModelId,
    batch: usize,
    m_c: usize,
    assembled: Vec<AssembledBatch>,
}

/// One busy model's state through a round: decision context, the raw
/// scheduler action (pre-veto, what the learner must be credited for),
/// and the assembled plan.
struct RoundEntry {
    ctx: SchedCtx,
    action: (usize, usize),
    plan: SlotPlan,
}

/// Reusable per-round buffers (tentpole: the steady-state round loop
/// allocates nothing). `spare_plans` recycles assembled-batch vectors —
/// and the request vectors inside them — between rounds.
#[derive(Default)]
struct RoundScratch {
    busy: Vec<ModelId>,
    entries: Vec<RoundEntry>,
    jobs: Vec<BatchJob>,
    ranges: Vec<(usize, usize)>,
    results: Vec<Result<f64, ExecError>>,
    spare_plans: Vec<Vec<AssembledBatch>>,
}

/// The serving engine over any execution dispatcher.
pub struct Engine<D: Dispatcher> {
    pub cfg: EngineConfig,
    dispatcher: D,
    router: Router,
    batcher: Batcher,
    instances: InstanceManager,
    pub profiler: Profiler,
    pub metrics: Metrics,
    pub predictor: Option<InterferencePredictor>,
    pending: VecDeque<Request>,
    rng: Pcg32,
    last_model: usize,
    slots_run: u64,
    scratch: RoundScratch,
    gate: Option<Box<dyn IngressGate>>,
    /// Request-lifecycle tracer (PR #7), inert like the gate: `None` —
    /// the default — keeps ingest/account/decide byte-identical to the
    /// untraced engine; `Some` stamps ingest times and emits sampled
    /// span records + raw action histograms into worker-local buffers.
    tracer: Option<EngineTracer>,
    /// Cross-worker gauge hints (see [`SchedCtx::cluster_backlog_ms`]).
    /// Both stay 0.0 unless a serving-runtime worker injects them, so the
    /// bare engine's decision context is hint-free by construction.
    cluster_backlog_ms: f64,
    cluster_share: f64,
    /// Per-model replica-set width hints (see [`SchedCtx::replica_share`]).
    /// All 0.0 unless a serving-runtime worker injects them — the bare
    /// engine is a sole owner of everything by construction.
    replica_share: [f64; N_MODELS],
}

impl<D: Dispatcher> Engine<D> {
    pub fn new(dispatcher: D, cfg: EngineConfig) -> Self {
        let mut rng = Pcg32::seeded(cfg.seed);
        let predictor = if cfg.use_predictor {
            Some(InterferencePredictor::new(&mut rng))
        } else {
            None
        };
        Engine {
            batcher: if cfg.pad_to_artifacts {
                Batcher::for_artifacts()
            } else {
                Batcher::exact()
            },
            instances: InstanceManager::new(cfg.max_total_instances),
            profiler: Profiler::new(512),
            metrics: Metrics::new(),
            predictor,
            pending: VecDeque::new(),
            rng,
            last_model: 0,
            slots_run: 0,
            router: Router::new(),
            dispatcher,
            cfg,
            scratch: RoundScratch::default(),
            gate: None,
            tracer: None,
            cluster_backlog_ms: 0.0,
            cluster_share: 0.0,
            replica_share: [0.0; N_MODELS],
        }
    }

    /// Install (or clear) the ingress admission gate. With `None` —
    /// the default — every arrival is routed, exactly as before the
    /// serving runtime existed.
    pub fn set_ingress_gate(&mut self, gate: Option<Box<dyn IngressGate>>) {
        self.gate = gate;
    }

    /// Install (or clear) the request-lifecycle tracer. With `None` —
    /// the default — the hot path is exactly the untraced engine
    /// (one untaken branch per request / decision).
    pub fn set_tracer(&mut self, tracer: Option<EngineTracer>) {
        self.tracer = tracer;
    }

    /// Drain everything the tracer has collected so far (sampled span
    /// records, the raw action histogram, drop counters). Empty report
    /// when tracing is off; the tracer stays installed.
    pub fn take_telemetry(&mut self) -> TraceReport {
        self.tracer
            .as_mut()
            .map(EngineTracer::take_report)
            .unwrap_or_default()
    }

    /// Queue future arrivals (must be sorted by arrival time).
    pub fn submit(&mut self, requests: Vec<Request>) {
        debug_assert!(requests.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        self.pending.extend(requests);
    }

    /// Queue a single live arrival. Unlike [`Engine::submit`] this does
    /// not assert global arrival ordering: the serving runtime's workers
    /// interleave several per-model ingress channels whose wall-clock
    /// stamps may be microseconds out of order; every such request is
    /// already due, so ordering slack is harmless.
    pub fn push_request(&mut self, r: Request) {
        self.pending.push_back(r);
    }

    pub fn now_ms(&self) -> f64 {
        self.dispatcher.now_ms()
    }

    pub fn dispatcher(&self) -> &D {
        &self.dispatcher
    }

    pub fn total_queued(&self) -> usize {
        self.router.total_queued() + self.pending.len()
    }

    /// Depth of one model's routed queue (excludes not-yet-due arrivals).
    pub fn queue_len(&self, model: ModelId) -> usize {
        self.router.queue(model).len()
    }

    /// Tightest deadline among `model`'s routed requests, O(1) (`None`
    /// when the queue is empty). The serving runtime's intake pass sizes
    /// its per-wakeup stripe budget from this: a queue whose most urgent
    /// deadline is nearly due gets a deeper intake stripe so the request
    /// reaches the scheduler before the deadline passes.
    pub fn min_deadline_ms(&self, model: ModelId) -> Option<f64> {
        self.router.queue(model).min_deadline_ms()
    }

    /// Does the engine hold any request for `model` — routed or still in
    /// the not-yet-ingested pending deque? The serving runtime uses this
    /// to detect backlog left behind after a shard migration.
    pub fn holds_model(&self, model: ModelId) -> bool {
        !self.router.queue(model).is_empty()
            || self.pending.iter().any(|r| r.model == model)
    }

    /// Pop queued requests for `model` (priority order — tightest
    /// deadlines first) into `out` until only `keep` remain routed,
    /// returning the count moved. The serving runtime's multi-owner
    /// drain uses this: a replica holding more than its fair share of a
    /// replicated model's queue sheds the surplus for an under-loaded
    /// replica, handing the most urgent work to the engine that will
    /// reach it soonest. Unlike [`Engine::drain_model_into`] it leaves
    /// not-yet-ingested pending arrivals alone (those are already this
    /// engine's to route). Never called by the engine itself.
    pub fn drain_model_excess_into(&mut self, model: ModelId, keep: usize,
                                   out: &mut Vec<Request>) -> usize {
        let q = self.router.queue_mut(model);
        let mut moved = 0usize;
        while q.len() > keep {
            match q.pop() {
                Some(r) => {
                    out.push(r);
                    moved += 1;
                }
                None => break,
            }
        }
        moved
    }

    /// Remove every queued request for `model` — the routed queue (in
    /// priority order) and any not-yet-ingested pending arrivals (in
    /// arrival order, appended after) — into `out`. Returns the count.
    /// The serving runtime hands a migrated model's backlog to its new
    /// owner with this; the engine itself never calls it, so the bare
    /// scheduling loop is unaffected.
    pub fn drain_model_into(&mut self, model: ModelId,
                            out: &mut Vec<Request>) -> usize {
        let mut moved = 0usize;
        let q = self.router.queue_mut(model);
        while let Some(r) = q.pop() {
            out.push(r);
            moved += 1;
        }
        if self.pending.iter().any(|r| r.model == model) {
            let mut keep = VecDeque::with_capacity(self.pending.len());
            for r in self.pending.drain(..) {
                if r.model == model {
                    out.push(r);
                    moved += 1;
                } else {
                    keep.push_back(r);
                }
            }
            self.pending = keep;
        }
        moved
    }

    /// Inject the cross-worker gauge hints surfaced through
    /// [`SchedCtx`]: the pool-wide estimated backlog (ms) and this
    /// worker's share of it. Never called outside the serving runtime —
    /// both default to 0.0, keeping the bare engine's context
    /// bit-identical to the pre-hint encoding.
    pub fn set_cluster_hints(&mut self, cluster_backlog_ms: f64,
                             local_share: f64) {
        self.cluster_backlog_ms = cluster_backlog_ms;
        self.cluster_share = local_share;
    }

    /// Surface one model's replica-set width to the decision context
    /// ([`SchedCtx::replica_share`]; 0.0 = sole owner). Never called
    /// outside the serving runtime, so the bare engine's context stays
    /// bit-identical to the pre-replication encoding.
    pub fn set_replica_share(&mut self, model: ModelId, share: f64) {
        self.replica_share[model as usize] = share;
    }

    pub fn slots_run(&self) -> u64 {
        self.slots_run
    }

    fn ingest(&mut self) {
        let now = self.dispatcher.now_ms();
        while let Some(front) = self.pending.front() {
            if front.arrival_ms > now {
                break;
            }
            let r = self.pending.pop_front().unwrap();
            let Some(g) = &self.gate else {
                if let Some(tr) = &mut self.tracer {
                    tr.on_ingest(r.id, now);
                }
                self.router.route(r);
                continue;
            };
            let (warmup, ref_batch) = (g.predictor_warmup(), g.ref_batch());
            // Predictions are pure probes of gauge/utilization state —
            // no RNG — so a cold or snapshot-mode gate leaves the ingest
            // stream bit-identical to the pre-headroom path.
            let (predicted_inflation, p95_factor) = if warmup == usize::MAX {
                (f64::NAN, f64::NAN)
            } else {
                (self.predict_inflation(r.model, ref_batch, 1, warmup),
                 self.inflation_p95_factor(warmup))
            };
            let snap = IngressSnapshot {
                now_ms: now,
                queue_len: self.router.queue(r.model).len(),
                mean_batch_ms: self.profiler.mean_latency_ms(r.model),
                isolated_ref_ms: self
                    .dispatcher
                    .isolated_estimate_ms(r.model, ref_batch),
                predicted_inflation,
                p95_factor,
            };
            let gate = self.gate.as_mut().unwrap();
            match gate.decide(&r, &snap) {
                Some(reason) => {
                    if let Some(tr) = &mut self.tracer {
                        tr.on_shed(&r, now, reason);
                    }
                    self.metrics.record_shed(r.model, reason);
                }
                None => {
                    if let Some(tr) = &mut self.tracer {
                        tr.on_ingest(r.id, now);
                    }
                    self.router.route(r)
                }
            }
        }
    }

    /// Build the scheduler context for `model` at the current instant.
    /// O(1): every input is a rolling aggregate or a snapshot read.
    pub fn ctx_for(&self, model: ModelId) -> SchedCtx {
        let q = self.router.queue(model);
        let now = self.dispatcher.now_ms();
        let (compute_demand, mem_pressure, active) =
            self.dispatcher.utilization();
        SchedCtx {
            model,
            queue_len: q.len(),
            min_slack_ms: q
                .min_deadline_ms()
                .map(|d| d - now)
                .unwrap_or(ModelSpec::get(model).slo_ms),
            slo_ms: ModelSpec::get(model).slo_ms,
            mem_free_frac: 1.0 - mem_pressure,
            compute_demand,
            active_instances: active,
            recent_latency_ms: self.profiler.mean_latency_ms(model),
            recent_throughput_rps: self.profiler.throughput_rps(model),
            recent_inflation: self.profiler.mean_inflation(),
            cluster_backlog_ms: self.cluster_backlog_ms,
            cluster_share: self.cluster_share,
            replica_share: self.replica_share[model as usize],
        }
    }

    /// Find the next model with pending work, advancing time across idle
    /// gaps. Returns `None` when the workload is exhausted.
    pub fn next_model(&mut self) -> Option<ModelId> {
        loop {
            self.ingest();
            if let Some(m) = self.router.first_busy_after(self.last_model) {
                return Some(m);
            }
            let next_arrival = self.pending.front()?.arrival_ms;
            self.dispatcher.wait_until(next_arrival);
        }
    }

    /// §IV-F veto-and-shrink. The predictor guards against the three ways
    /// a configuration destroys SLOs on edge hardware, without throttling
    /// healthy batching (shrinking batch on mere deadline pressure starves
    /// throughput and melts the queue down — worse than serving):
    ///
    /// 1. OOM risk (Eq. 4 m ≤ M): demanded memory must fit free memory;
    /// 2. interference blow-up: predicted latency inflation from adding
    ///    m_c instances must stay under a threshold — drop concurrency
    ///    first, it is the superlinear dimension (Fig. 1);
    /// 3. hopeless spans: a batch whose *predicted* span alone exceeds the
    ///    model's SLO can never meet any fresh request's deadline.
    fn predictor_adjust(&self, model: ModelId, mut b: usize, mut m_c: usize,
                        ctx: &SchedCtx) -> (usize, usize) {
        const MAX_INFLATION: f64 = 1.6;
        let Some(p) = &self.predictor else { return (b, m_c) };
        if p.samples() < 128 {
            return (b, m_c); // cold start: no veto power yet
        }
        let (compute_demand, mem_pressure, active) =
            self.dispatcher.utilization();
        let spec = ModelSpec::get(model);
        // (1) memory guard
        let free_frac = ctx.mem_free_frac.clamp(0.0, 1.0);
        let free_mb = free_frac * crate::platform::PlatformSpec::xavier_nx().memory_mb;
        while m_c * b > 1 && spec.memory.total_mb(b, m_c) > free_mb {
            if m_c > 1 {
                m_c -= 1;
            } else {
                b = (b / 2).max(1);
            }
        }
        // (2) interference guard + (3) hopeless-span guard
        for _ in 0..8 {
            let sample = PredictorSample {
                memory_pressure: mem_pressure,
                compute_demand: compute_demand
                    + spec.compute_demand * m_c as f64,
                active_instances: active + m_c,
                concurrency: m_c,
                batch: b,
                inflation: 1.0,
            };
            let inflation = p.predict(&sample);
            let predicted_ms =
                self.dispatcher.isolated_estimate_ms(model, b) * inflation;
            let interference_bad = inflation > MAX_INFLATION && m_c > 1;
            let span_hopeless = predicted_ms > ctx.slo_ms && b > 1;
            if !interference_bad && !span_hopeless {
                break;
            }
            if interference_bad {
                m_c -= 1;
            } else {
                b = (b / 2).max(1);
            }
        }
        (b, m_c)
    }

    /// Predicted latency-inflation factor for `m_c` more instance-batches
    /// of `batch` × `model` under the CURRENT utilization — a pure probe
    /// of the online §IV-F predictor (no RNG, no state change), the price
    /// predictive admission and routing build headroom from. NaN when
    /// the predictor is disabled or holds fewer than `min_samples`
    /// ground-truth observations (the caller's fallback trigger).
    pub fn predict_inflation(&self, model: ModelId, batch: usize,
                             m_c: usize, min_samples: usize) -> f64 {
        let Some(p) = &self.predictor else { return f64::NAN };
        if p.samples() < min_samples {
            return f64::NAN;
        }
        let (compute_demand, mem_pressure, active) =
            self.dispatcher.utilization();
        let spec = ModelSpec::get(model);
        p.predict(&PredictorSample {
            memory_pressure: mem_pressure,
            compute_demand: compute_demand + spec.compute_demand * m_c as f64,
            active_instances: active + m_c,
            concurrency: m_c,
            batch,
            inflation: 1.0,
        })
    }

    /// The predictor's observed dispersion p95 — how far reality has
    /// recently strayed above its point estimates. NaN when the predictor
    /// is disabled, colder than `min_samples`, or before the first
    /// dispersion refresh; decision points clamp it to ≥ 1.
    pub fn inflation_p95_factor(&self, min_samples: usize) -> f64 {
        let Some(p) = &self.predictor else { return f64::NAN };
        if p.samples() < min_samples {
            return f64::NAN;
        }
        p.dispersion_p95()
    }

    /// Per-decision headroom accounting from the installed ingress gate:
    /// (decisions priced predictively, snapshot fallbacks among them).
    pub fn gate_headroom_stats(&self) -> (u64, u64) {
        self.gate.as_ref().map_or((0, 0), |g| g.headroom_stats())
    }

    /// Execute one scheduling slot for a single model with an explicit
    /// action. Public so the offline-training environment
    /// ([`super::sac_sched::SchedEnv`]) can drive the engine
    /// action-by-action; the serving path uses [`Engine::step`], which
    /// dispatches ALL busy models as one concurrent group (paper Fig. 4).
    pub fn execute_slot(&mut self, model: ModelId, batch: usize, m_c: usize)
                        -> SlotOutcome {
        let ctx = self.ctx_for(model);
        let buf = self.scratch.spare_plans.pop().unwrap_or_default();
        let mut plan = self.plan_slot(model, batch, m_c, &ctx, buf);
        let t_dispatch = self.dispatcher.now_ms();
        if plan.assembled.is_empty() {
            let out = self.empty_outcome(model, batch, plan.m_c);
            self.recycle_plan(plan);
            return out;
        }
        let mut jobs = std::mem::take(&mut self.scratch.jobs);
        let mut results = std::mem::take(&mut self.scratch.results);
        jobs.clear();
        push_jobs(&mut jobs, &plan);
        self.dispatcher.run_group_into(&jobs, &mut results);
        let outcome = self.account_slot(&mut plan, t_dispatch, &results);
        jobs.clear();
        results.clear();
        self.scratch.jobs = jobs;
        self.scratch.results = results;
        self.recycle_plan(plan);
        self.finish_round();
        outcome
    }

    fn empty_outcome(&self, model: ModelId, batch: usize, m_c: usize)
                     -> SlotOutcome {
        SlotOutcome {
            model,
            batch,
            m_c,
            completed: 0,
            violations: 0,
            oom: false,
            utility: 0.0,
            reward: 0.0,
            loss: 0.0,
            span_ms: 0.0,
        }
    }

    /// Apply the §IV-F veto, register instances, and drain the queue into
    /// instance-batches for one model (no execution yet). `ctx` is the
    /// decision context already computed for this model this round —
    /// nothing observable changes between the decision and the plan, so
    /// recomputing it (as the seed did) is pure waste. `assembled` is a
    /// recycled buffer; the plan takes ownership and returns it to the
    /// pool via [`Engine::recycle_plan`].
    fn plan_slot(&mut self, model: ModelId, batch: usize, m_c: usize,
                 ctx: &SchedCtx, mut assembled: Vec<AssembledBatch>)
                 -> SlotPlan {
        self.slots_run += 1;
        self.last_model = model as usize;
        let (batch, m_c) = self.predictor_adjust(model, batch, m_c, ctx);
        // Register the scheduler's configuration first, THEN clamp by what
        // the platform admits (global instance cap minus other models'
        // in-flight instances).
        self.instances.configure(model, m_c);
        let m_c = m_c.min(self.instances.admissible(model).max(1));
        self.batcher.assemble_into(
            self.router.queue_mut(model), batch, m_c, &mut assembled);
        let n_instances = assembled.len();
        if n_instances > 0 {
            self.instances
                .acquire(model, n_instances.min(self.instances.admissible(model)));
        }
        SlotPlan { model, batch, m_c, assembled }
    }

    /// Return a plan's assembled-batch buffer (and the request vectors
    /// inside it) to the scratch pool for the next round.
    fn recycle_plan(&mut self, mut plan: SlotPlan) {
        for a in plan.assembled.iter_mut() {
            a.requests.clear();
        }
        if self.scratch.spare_plans.len() < N_MODELS {
            self.scratch.spare_plans.push(std::mem::take(&mut plan.assembled));
        }
    }

    /// Account one model's share of a dispatched group: completions,
    /// violations, profiler/predictor samples, utility, reward. Failed
    /// instance-batches requeue their requests BY MOVE (the seed cloned
    /// every request back into the queue).
    fn account_slot(&mut self, plan: &mut SlotPlan, t_dispatch: f64,
                    results: &[Result<f64, ExecError>])
                    -> SlotOutcome {
        let model = plan.model;
        let n_instances = plan.assembled.len();
        let (compute_demand, mem_pressure, active) =
            self.dispatcher.utilization();
        let mut completed = 0usize;
        let mut violations = 0usize;
        let mut oom = false;
        let mut span_ms: f64 = 0.0;
        let mut latency_sum = 0.0;
        let mut slo_sum = 0.0;
        for (a, res) in plan.assembled.iter_mut().zip(results) {
            match res {
                Ok(lat_ms) => {
                    let lat_ms = lat_ms + self.cfg.serialization_ms;
                    span_ms = span_ms.max(lat_ms);
                    latency_sum += lat_ms;
                    let completion = t_dispatch + lat_ms;
                    for r in &a.requests {
                        let e2e = completion - r.arrival_ms + r.transmission_ms;
                        let v = e2e > r.slo_ms;
                        violations += v as usize;
                        completed += 1;
                        slo_sum += r.slo_ms;
                        self.metrics.record(RequestOutcome {
                            id: r.id,
                            model,
                            arrival_ms: r.arrival_ms,
                            completed_ms: completion,
                            e2e_ms: e2e,
                            slo_ms: r.slo_ms,
                            violated: v,
                            dropped: false,
                        });
                        if let Some(tr) = &mut self.tracer {
                            tr.on_complete(r, t_dispatch, lat_ms,
                                           a.requests.len(), a.padded, v);
                        }
                    }
                    // Profile + predictor ground truth.
                    let isolated =
                        self.dispatcher.isolated_estimate_ms(model, a.padded);
                    let inflation = (lat_ms / isolated).max(1.0);
                    self.profiler.record(ProfileSample {
                        t_ms: t_dispatch,
                        model,
                        batch: a.padded,
                        concurrency: n_instances,
                        latency_ms: lat_ms,
                        completed: a.n_real(),
                        compute_demand,
                        memory_pressure: mem_pressure,
                        active_instances: active,
                        inflation,
                    });
                    if let Some(p) = &mut self.predictor {
                        p.observe(PredictorSample {
                            memory_pressure: mem_pressure,
                            compute_demand: compute_demand
                                + ModelSpec::get(model).compute_demand
                                    * n_instances as f64,
                            active_instances: active + n_instances,
                            concurrency: n_instances,
                            batch: a.padded,
                            inflation,
                        });
                    }
                }
                Err(_) => {
                    // OOM / backend failure: requeue (by move) so requests
                    // are not lost; the reward penalty teaches the
                    // scheduler.
                    oom = true;
                    let q = self.router.queue_mut(model);
                    for r in a.requests.drain(..) {
                        q.push(r);
                    }
                }
            }
        }
        let (u, reward) = if completed > 0 {
            let n_ok = results.iter().filter(|r| r.is_ok()).count().max(1);
            let mean_latency = latency_sum / n_ok as f64;
            let throughput = completed as f64 / (span_ms.max(1e-3) / 1e3);
            let u = utility::utility(throughput, mean_latency, slo_sum,
                                     n_instances.max(1));
            let vf = violations as f64 / completed as f64;
            (u, utility::reward(u, vf, oom))
        } else {
            (0.0, utility::reward(0.0, 0.0, oom))
        };
        self.metrics.record_utility(t_dispatch, model, u);

        SlotOutcome {
            model,
            batch: plan.batch,
            m_c: n_instances,
            completed,
            violations,
            oom,
            utility: u,
            reward,
            loss: 0.0,
            span_ms,
        }
    }

    /// Post-round bookkeeping: release instances, amortized predictor
    /// training.
    fn finish_round(&mut self) {
        for model in ModelId::all() {
            let active = self.instances.active(model);
            if active > 0 {
                self.instances.release(model, active);
            }
        }
        if self.slots_run % 4 == 0 {
            if let Some(p) = &mut self.predictor {
                p.train_step(&mut self.rng);
            }
        }
    }

    /// One scheduling ROUND with a policy: every model with pending work
    /// gets a decision, and all chosen instance-batches dispatch as a
    /// single concurrent group — the paper Fig. 4 pipeline, where the
    /// accelerator's hardware scheduler runs different models' instances
    /// simultaneously. Writes one outcome per scheduled model into the
    /// caller-owned `outcomes` buffer (cleared first) and returns the
    /// count, or `None` when the workload is exhausted.
    ///
    /// Every buffer below is moved out of `self.scratch`, used, cleared,
    /// and moved back, and the outcome vector is the caller's to recycle —
    /// the round loop is now allocation-free end to end ([`Engine::step`]
    /// keeps the seed's allocating signature as a convenience wrapper).
    pub fn step_into<S: Scheduler + ?Sized>(
        &mut self, scheduler: &mut S, outcomes: &mut Vec<SlotOutcome>,
    ) -> Option<usize> {
        outcomes.clear();
        self.next_model()?; // advances time to work; round-robin anchor
        let mut busy = std::mem::take(&mut self.scratch.busy);
        self.router.busy_models_into(self.last_model, &mut busy);
        let mut rng = self.rng.split();

        // Phase 1: decide + assemble for every busy model.
        let mut entries = std::mem::take(&mut self.scratch.entries);
        let mut jobs = std::mem::take(&mut self.scratch.jobs);
        let mut ranges = std::mem::take(&mut self.scratch.ranges);
        debug_assert!(entries.is_empty() && jobs.is_empty() && ranges.is_empty());
        for &model in &busy {
            let ctx = self.ctx_for(model);
            let (b, m_c) = scheduler.decide(&ctx, &mut rng);
            if let Some(tr) = &mut self.tracer {
                // Raw pre-veto decision: what the policy asked for.
                tr.record_action(b, m_c);
            }
            let buf = self.scratch.spare_plans.pop().unwrap_or_default();
            let plan = self.plan_slot(model, b, m_c, &ctx, buf);
            let start = jobs.len();
            push_jobs(&mut jobs, &plan);
            ranges.push((start, jobs.len()));
            entries.push(RoundEntry { ctx, action: (b, m_c), plan });
        }
        busy.clear();
        self.scratch.busy = busy;

        if jobs.is_empty() {
            // Queues held only already-drained models; outcomes are empty.
            for e in entries.drain(..) {
                self.recycle_plan(e.plan);
            }
            ranges.clear();
            self.scratch.entries = entries;
            self.scratch.jobs = jobs;
            self.scratch.ranges = ranges;
            return Some(0);
        }

        // Phase 2: one concurrent dispatch for the whole round.
        let t_dispatch = self.dispatcher.now_ms();
        let mut results = std::mem::take(&mut self.scratch.results);
        self.dispatcher.run_group_into(&jobs, &mut results);

        // Phase 3: per-model accounting + learning feedback.
        for (mut e, (start, end)) in entries.drain(..).zip(ranges.iter().copied())
        {
            let mut outcome = if e.plan.assembled.is_empty() {
                self.empty_outcome(e.plan.model, e.plan.batch, e.plan.m_c)
            } else {
                self.account_slot(&mut e.plan, t_dispatch, &results[start..end])
            };
            if self.cfg.learn {
                let next_ctx = self.ctx_for(e.plan.model);
                outcome.loss = scheduler.feedback(
                    &e.ctx, e.action, outcome.reward, &next_ctx, false, &mut rng,
                );
            }
            outcomes.push(outcome);
            self.recycle_plan(e.plan);
        }
        jobs.clear();
        ranges.clear();
        results.clear();
        self.scratch.entries = entries;
        self.scratch.jobs = jobs;
        self.scratch.ranges = ranges;
        self.scratch.results = results;
        self.finish_round();
        Some(outcomes.len())
    }

    /// Allocating wrapper over [`Engine::step_into`] — the seed's
    /// signature, kept for callers that want an owned outcome vector
    /// (and as the bench's "before" path).
    pub fn step<S: Scheduler + ?Sized>(&mut self, scheduler: &mut S)
                                       -> Option<Vec<SlotOutcome>> {
        let mut outcomes = Vec::new();
        self.step_into(scheduler, &mut outcomes).map(|_| outcomes)
    }

    /// Serve until the virtual/real horizon passes or work runs out.
    /// Returns the number of per-model slots executed. One outcome buffer
    /// is recycled across every round.
    pub fn run<S: Scheduler + ?Sized>(&mut self, scheduler: &mut S,
                                      horizon_ms: f64) -> u64 {
        let mut outcomes = Vec::new();
        let mut slots = 0;
        while self.dispatcher.now_ms() < horizon_ms {
            match self.step_into(scheduler, &mut outcomes) {
                Some(n) => slots += n as u64,
                None => break,
            }
        }
        slots
    }
}

/// Flatten a plan's assembled batches into dispatcher jobs.
fn push_jobs(jobs: &mut Vec<BatchJob>, plan: &SlotPlan) {
    for a in &plan.assembled {
        jobs.push(BatchJob {
            model: plan.model,
            batch: a.padded,
            n_real: a.n_real(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::baselines::FixedScheduler;
    use crate::platform::PlatformSim;
    use crate::runtime::executor::SimDispatcher;
    use crate::util::time::VirtualClock;
    use crate::workload::generator::PoissonGenerator;

    fn sim_engine(cfg: EngineConfig) -> Engine<SimDispatcher> {
        let clock = VirtualClock::new();
        Engine::new(SimDispatcher::new(PlatformSim::xavier_nx(), clock), cfg)
    }

    #[test]
    fn serves_poisson_traffic_end_to_end() {
        let mut engine = sim_engine(EngineConfig::default());
        let mut gen = PoissonGenerator::new(30.0, 42);
        let reqs = gen.generate_horizon(10_000.0);
        let n = reqs.len();
        engine.submit(reqs);
        let mut sched = FixedScheduler { batch: 4, m_c: 2 };
        engine.run(&mut sched, 60_000.0);
        // Conservation: every request either completed or still queued.
        assert_eq!(engine.metrics.outcomes().len() + engine.total_queued(), n);
        // With a sane static config at 30 rps the engine must keep up.
        assert!(engine.metrics.completed() > n * 9 / 10,
                "completed {}/{n}", engine.metrics.completed());
        assert!(engine.metrics.mean_latency_ms(None) > 0.0);
    }

    #[test]
    fn idle_engine_advances_to_arrivals() {
        let mut engine = sim_engine(EngineConfig::default());
        let mut r = Request::new(0, ModelId::Res, 5_000.0);
        r.transmission_ms = 0.0;
        engine.submit(vec![r]);
        let model = engine.next_model().unwrap();
        assert_eq!(model, ModelId::Res);
        assert!(engine.now_ms() >= 5_000.0);
    }

    #[test]
    fn exhausted_workload_returns_none() {
        let mut engine = sim_engine(EngineConfig::default());
        let mut sched = FixedScheduler { batch: 1, m_c: 1 };
        assert!(engine.step(&mut sched).is_none());
    }

    #[test]
    fn oversized_actions_respect_instance_cap() {
        let mut engine = sim_engine(EngineConfig {
            max_total_instances: 2,
            use_predictor: false,
            ..Default::default()
        });
        let reqs: Vec<Request> =
            (0..64).map(|i| Request::new(i, ModelId::Mob, 0.0)).collect();
        engine.submit(reqs);
        engine.next_model().unwrap();
        let out = engine.execute_slot(ModelId::Mob, 8, 8);
        assert!(out.m_c <= 2, "m_c {} exceeded cap", out.m_c);
    }

    #[test]
    fn oom_requeues_requests_and_penalizes() {
        let mut engine = sim_engine(EngineConfig {
            use_predictor: false,
            action_space: ActionSpace::sim_wide(),
            ..Default::default()
        });
        let reqs: Vec<Request> = (0..1024)
            .map(|i| Request::new(i, ModelId::Yolo, 0.0))
            .collect();
        engine.submit(reqs);
        engine.next_model().unwrap();
        let out = engine.execute_slot(ModelId::Yolo, 128, 8);
        assert!(out.oom, "expected the Fig. 1 OOM corner");
        assert!(out.reward < 0.0, "OOM must be penalized: {}", out.reward);
        // Nothing lost.
        assert_eq!(
            engine.metrics.outcomes().len() + engine.total_queued(),
            1024
        );
    }

    #[test]
    fn utility_recorded_per_slot() {
        let mut engine = sim_engine(EngineConfig::default());
        let reqs: Vec<Request> =
            (0..16).map(|i| Request::new(i, ModelId::Res, 0.0)).collect();
        engine.submit(reqs);
        engine.next_model().unwrap();
        let out = engine.execute_slot(ModelId::Res, 8, 2);
        assert!(out.completed > 0);
        assert!(out.utility.is_finite());
        assert!(engine.metrics.mean_utility(Some(ModelId::Res)).is_finite());
    }

    #[test]
    fn step_into_reuses_buffer_and_matches_step() {
        let cfg = EngineConfig { learn: false, ..Default::default() };
        let mut a = sim_engine(cfg.clone());
        let mut b = sim_engine(cfg);
        for e in [&mut a, &mut b] {
            let mut gen = PoissonGenerator::new(60.0, 11);
            e.submit(gen.generate_horizon(10_000.0));
        }
        let mut sa = FixedScheduler { batch: 4, m_c: 2 };
        let mut sb = FixedScheduler { batch: 4, m_c: 2 };
        let mut buf = Vec::new();
        for _ in 0..30 {
            let n = a.step_into(&mut sa, &mut buf);
            let owned = b.step(&mut sb);
            match (n, owned) {
                (Some(n), Some(owned)) => {
                    assert_eq!(n, buf.len());
                    assert_eq!(buf, owned);
                }
                (None, None) => break,
                (x, y) => panic!("paths diverged: {x:?} vs {:?}", y.map(|v| v.len())),
            }
        }
    }

    /// An ingress gate that sheds every request for one model and admits
    /// the rest — pins the gate seam: sheds land in Metrics (not as
    /// violations), admitted traffic is unaffected, nothing is lost.
    struct BlockModel(ModelId);
    impl crate::coordinator::engine::IngressGate for BlockModel {
        fn ref_batch(&self) -> usize {
            8
        }
        fn decide(&mut self, r: &Request, snap: &IngressSnapshot)
                  -> Option<ShedReason> {
            assert!(snap.isolated_ref_ms > 0.0);
            assert!(snap.now_ms >= r.arrival_ms);
            (r.model == self.0).then_some(ShedReason::DeadlineUnmeetable)
        }
    }

    #[test]
    fn ingress_gate_sheds_into_metrics_not_violations() {
        let mut engine = sim_engine(EngineConfig {
            learn: false,
            ..Default::default()
        });
        engine.set_ingress_gate(Some(Box::new(BlockModel(ModelId::Yolo))));
        let mut gen = PoissonGenerator::new(60.0, 5);
        let reqs = gen.generate_horizon(10_000.0);
        let n = reqs.len();
        let n_yolo = reqs.iter().filter(|r| r.model == ModelId::Yolo).count();
        assert!(n_yolo > 0, "trace must offer yolo traffic");
        engine.submit(reqs);
        let mut sched = FixedScheduler { batch: 4, m_c: 2 };
        engine.run(&mut sched, 60_000.0);
        let m = &engine.metrics;
        assert_eq!(m.shed_total(), n_yolo as u64);
        assert_eq!(m.shed_for(ModelId::Yolo), n_yolo as u64);
        assert_eq!(m.shed_by_reason(ShedReason::DeadlineUnmeetable),
                   n_yolo as u64);
        // Shed requests never execute and never count as violations.
        assert!(m.outcomes().iter().all(|o| o.model != ModelId::Yolo));
        // Conservation: executed + still queued + shed == offered.
        assert_eq!(m.outcomes().len() + engine.total_queued()
                       + m.shed_total() as usize,
                   n);
    }

    /// Shard-migration support: draining one model's backlog removes it
    /// completely (routed queue AND pending arrivals), conserves every
    /// request, and the drained set serves correctly after re-submission
    /// to another engine — the handoff the serving runtime performs.
    #[test]
    fn drain_model_into_conserves_and_rehomes() {
        let mut src = sim_engine(EngineConfig {
            learn: false,
            ..Default::default()
        });
        let mut gen = PoissonGenerator::new(120.0, 31);
        let reqs = gen.generate_horizon(4_000.0);
        let n = reqs.len();
        let n_yolo = reqs.iter().filter(|r| r.model == ModelId::Yolo).count();
        assert!(n_yolo > 0);
        // One future arrival keeps the pending deque non-empty so the
        // drain must cover both stations.
        let mut future = Request::new(u64::MAX, ModelId::Yolo, 1e9);
        future.slo_ms = 138.0;
        src.submit(reqs);
        src.push_request(future);
        src.next_model().unwrap(); // ingest everything already due
        let mut handoff = Vec::new();
        let moved = src.drain_model_into(ModelId::Yolo, &mut handoff);
        assert_eq!(moved, handoff.len());
        assert_eq!(moved, n_yolo + 1);
        assert!(!src.holds_model(ModelId::Yolo));
        assert!(handoff.iter().any(|r| r.id == u64::MAX),
                "pending arrival missed by the drain");
        // Nothing else was touched, nothing lost.
        assert_eq!(src.total_queued() + moved, n + 1);
        // Re-homed backlog serves on a fresh engine.
        let mut dst = sim_engine(EngineConfig {
            learn: false,
            ..Default::default()
        });
        for r in handoff {
            if r.id != u64::MAX {
                dst.push_request(r);
            }
        }
        let mut sched = FixedScheduler { batch: 8, m_c: 2 };
        dst.run(&mut sched, 120_000.0);
        assert_eq!(dst.metrics.outcomes().len() + dst.total_queued(), n_yolo);
        assert!(dst.metrics.completed() > 0);
    }

    /// Gauge and replica hints flow into the decision context verbatim,
    /// per model where applicable, and default to the hint-free 0.0
    /// encoding.
    #[test]
    fn cluster_hints_flow_into_ctx() {
        let mut engine = sim_engine(EngineConfig::default());
        let ctx = engine.ctx_for(ModelId::Res);
        assert_eq!(ctx.cluster_backlog_ms, 0.0);
        assert_eq!(ctx.cluster_share, 0.0);
        assert_eq!(ctx.replica_share, 0.0);
        engine.set_cluster_hints(420.0, 0.75);
        engine.set_replica_share(ModelId::Res, 0.5);
        let ctx = engine.ctx_for(ModelId::Res);
        assert_eq!(ctx.cluster_backlog_ms, 420.0);
        assert_eq!(ctx.cluster_share, 0.75);
        assert_eq!(ctx.replica_share, 0.5);
        // Replica shares are per model: other models stay at 0.
        assert_eq!(engine.ctx_for(ModelId::Yolo).replica_share, 0.0);
    }

    /// Multi-owner drain support: shedding surplus down to `keep` moves
    /// exactly the excess (tightest deadlines first), conserves every
    /// request, and leaves pending arrivals and other models untouched.
    #[test]
    fn drain_model_excess_keeps_fair_share() {
        let mut engine = sim_engine(EngineConfig {
            learn: false,
            ..Default::default()
        });
        let reqs: Vec<Request> = (0..32)
            .map(|i| Request::new(i, ModelId::Yolo, 0.0))
            .collect();
        engine.submit(reqs);
        engine.push_request(Request::new(99, ModelId::Res, 0.0));
        // One far-future yolo arrival stays in the pending deque: the
        // excess drain must NOT touch it (unlike drain_model_into).
        engine.push_request(Request::new(u64::MAX, ModelId::Yolo, 1e9));
        engine.next_model().unwrap(); // ingest everything already due
        assert_eq!(engine.queue_len(ModelId::Yolo), 32);
        let mut out = Vec::new();
        let moved = engine.drain_model_excess_into(ModelId::Yolo, 20, &mut out);
        assert_eq!(moved, 12);
        assert_eq!(out.len(), 12);
        assert_eq!(engine.queue_len(ModelId::Yolo), 20);
        assert!(out.iter().all(|r| r.model == ModelId::Yolo));
        assert!(out.iter().all(|r| r.id != u64::MAX),
                "pending arrivals are not excess");
        assert!(engine.holds_model(ModelId::Yolo));
        assert_eq!(engine.queue_len(ModelId::Res), 1);
        // keep ≥ len is a no-op.
        assert_eq!(
            engine.drain_model_excess_into(ModelId::Yolo, 64, &mut out),
            0
        );
        // Conservation: routed + drained + pending covers every submit.
        assert_eq!(engine.total_queued() + out.len(), 32 + 1 + 1);
    }

    #[test]
    fn scratch_pool_stays_bounded() {
        let mut engine = sim_engine(EngineConfig::default());
        let mut gen = PoissonGenerator::new(120.0, 9);
        engine.submit(gen.generate_horizon(5_000.0));
        let mut sched = FixedScheduler { batch: 4, m_c: 2 };
        engine.run(&mut sched, 30_000.0);
        assert!(engine.scratch.spare_plans.len() <= N_MODELS);
        assert!(engine.scratch.entries.is_empty());
        assert!(engine.scratch.jobs.is_empty());
        for buf in &engine.scratch.spare_plans {
            assert!(buf.iter().all(|a| a.requests.is_empty()),
                    "recycled plans must not hold live requests");
        }
    }
}

/// Proof obligation for the hot-path refactor: the optimized round loop
/// must emit a BIT-IDENTICAL `SlotOutcome` stream to the seed
/// implementation. `seed_step` below is a faithful port of the seed's
/// `step`/`plan_slot`/`account_slot` — fresh `Vec`s everywhere, O(n)
/// naive queue/profiler scans, clone-based OOM requeue — driven against
/// the same engine state via private access. Runs are capped under the
/// profiler window (512 samples) so the naive inflation scan and the
/// rolling sum are the same left-to-right float sum; beyond the window
/// they agree only to rounding, which is covered by the profiler unit
/// tests instead.
#[cfg(test)]
mod seed_equivalence {
    use super::*;
    use crate::coordinator::baselines::{DeepRtScheduler, FixedScheduler};
    use crate::coordinator::sac_sched;
    use crate::platform::PlatformSim;
    use crate::runtime::executor::SimDispatcher;
    use crate::util::time::VirtualClock;
    use crate::workload::generator::PoissonGenerator;

    type SimEngine = Engine<SimDispatcher>;

    fn sim_engine(cfg: EngineConfig) -> SimEngine {
        let clock = VirtualClock::new();
        Engine::new(SimDispatcher::new(PlatformSim::xavier_nx(), clock), cfg)
    }

    /// Seed `ctx_for`: O(n) scans over the queue and the profiler window.
    fn seed_ctx_for(e: &SimEngine, model: ModelId) -> SchedCtx {
        let q = e.router.queue(model);
        let now = e.dispatcher.now_ms();
        let (compute_demand, mem_pressure, active) = e.dispatcher.utilization();
        SchedCtx {
            model,
            queue_len: q.len(),
            min_slack_ms: q
                .min_deadline_naive_ms()
                .map(|d| d - now)
                .unwrap_or(ModelSpec::get(model).slo_ms),
            slo_ms: ModelSpec::get(model).slo_ms,
            mem_free_frac: 1.0 - mem_pressure,
            compute_demand,
            active_instances: active,
            recent_latency_ms: e.profiler.mean_latency_ms(model),
            recent_throughput_rps: e.profiler.throughput_rps(model),
            recent_inflation: e.profiler.mean_inflation_naive(),
            cluster_backlog_ms: e.cluster_backlog_ms,
            cluster_share: e.cluster_share,
            replica_share: e.replica_share[model as usize],
        }
    }

    /// Seed `plan_slot`: recomputes the context, allocates the assembled
    /// batches fresh.
    fn seed_plan_slot(e: &mut SimEngine, model: ModelId, batch: usize,
                      m_c: usize) -> SlotPlan {
        e.slots_run += 1;
        e.last_model = model as usize;
        let ctx = seed_ctx_for(e, model);
        let (batch, m_c) = e.predictor_adjust(model, batch, m_c, &ctx);
        e.instances.configure(model, m_c);
        let m_c = m_c.min(e.instances.admissible(model).max(1));
        let assembled =
            e.batcher.assemble(e.router.queue_mut(model), batch, m_c);
        let n_instances = assembled.len();
        if n_instances > 0 {
            e.instances
                .acquire(model, n_instances.min(e.instances.admissible(model)));
        }
        SlotPlan { model, batch, m_c, assembled }
    }

    /// Seed `account_slot`: clone-based OOM requeue.
    fn seed_account_slot(e: &mut SimEngine, plan: &SlotPlan, t_dispatch: f64,
                         results: &[Result<f64, ExecError>]) -> SlotOutcome {
        let model = plan.model;
        let n_instances = plan.assembled.len();
        let (compute_demand, mem_pressure, active) = e.dispatcher.utilization();
        let mut completed = 0usize;
        let mut violations = 0usize;
        let mut oom = false;
        let mut span_ms: f64 = 0.0;
        let mut latency_sum = 0.0;
        let mut slo_sum = 0.0;
        for (a, res) in plan.assembled.iter().zip(results) {
            match res {
                Ok(lat_ms) => {
                    let lat_ms = lat_ms + e.cfg.serialization_ms;
                    span_ms = span_ms.max(lat_ms);
                    latency_sum += lat_ms;
                    let completion = t_dispatch + lat_ms;
                    for r in &a.requests {
                        let e2e = completion - r.arrival_ms + r.transmission_ms;
                        let v = e2e > r.slo_ms;
                        violations += v as usize;
                        completed += 1;
                        slo_sum += r.slo_ms;
                        e.metrics.record(RequestOutcome {
                            id: r.id,
                            model,
                            arrival_ms: r.arrival_ms,
                            completed_ms: completion,
                            e2e_ms: e2e,
                            slo_ms: r.slo_ms,
                            violated: v,
                            dropped: false,
                        });
                    }
                    let isolated =
                        e.dispatcher.isolated_estimate_ms(model, a.padded);
                    let inflation = (lat_ms / isolated).max(1.0);
                    e.profiler.record(ProfileSample {
                        t_ms: t_dispatch,
                        model,
                        batch: a.padded,
                        concurrency: n_instances,
                        latency_ms: lat_ms,
                        completed: a.n_real(),
                        compute_demand,
                        memory_pressure: mem_pressure,
                        active_instances: active,
                        inflation,
                    });
                    if let Some(p) = &mut e.predictor {
                        p.observe(PredictorSample {
                            memory_pressure: mem_pressure,
                            compute_demand: compute_demand
                                + ModelSpec::get(model).compute_demand
                                    * n_instances as f64,
                            active_instances: active + n_instances,
                            concurrency: n_instances,
                            batch: a.padded,
                            inflation,
                        });
                    }
                }
                Err(_) => {
                    oom = true;
                    for r in &a.requests {
                        e.router.queue_mut(model).push(r.clone());
                    }
                }
            }
        }
        let (u, reward) = if completed > 0 {
            let n_ok = results.iter().filter(|r| r.is_ok()).count().max(1);
            let mean_latency = latency_sum / n_ok as f64;
            let throughput = completed as f64 / (span_ms.max(1e-3) / 1e3);
            let u = utility::utility(throughput, mean_latency, slo_sum,
                                     n_instances.max(1));
            let vf = violations as f64 / completed as f64;
            (u, utility::reward(u, vf, oom))
        } else {
            (0.0, utility::reward(0.0, 0.0, oom))
        };
        e.metrics.record_utility(t_dispatch, model, u);
        SlotOutcome {
            model,
            batch: plan.batch,
            m_c: n_instances,
            completed,
            violations,
            oom,
            utility: u,
            reward,
            loss: 0.0,
            span_ms,
        }
    }

    /// Faithful port of the seed's `Engine::step`.
    fn seed_step<S: Scheduler + ?Sized>(e: &mut SimEngine, scheduler: &mut S)
                                        -> Option<Vec<SlotOutcome>> {
        e.next_model()?;
        let busy = e.router.busy_models_after(e.last_model);
        let mut rng = e.rng.split();

        let mut plans: Vec<(SchedCtx, (usize, usize), SlotPlan)> = Vec::new();
        let mut jobs: Vec<BatchJob> = Vec::new();
        let mut ranges: Vec<(usize, usize)> = Vec::new();
        for model in busy {
            let ctx = seed_ctx_for(e, model);
            let (b, m_c) = scheduler.decide(&ctx, &mut rng);
            let plan = seed_plan_slot(e, model, b, m_c);
            let start = jobs.len();
            push_jobs(&mut jobs, &plan);
            ranges.push((start, jobs.len()));
            plans.push((ctx, (b, m_c), plan));
        }
        if jobs.is_empty() {
            return Some(vec![]);
        }

        let t_dispatch = e.dispatcher.now_ms();
        let results = e.dispatcher.run_group(&jobs);

        let mut outcomes = Vec::with_capacity(plans.len());
        for ((ctx, action, plan), (start, end)) in
            plans.into_iter().zip(ranges)
        {
            let mut outcome = if plan.assembled.is_empty() {
                e.empty_outcome(plan.model, plan.batch, plan.m_c)
            } else {
                seed_account_slot(e, &plan, t_dispatch, &results[start..end])
            };
            if e.cfg.learn {
                let next_ctx = seed_ctx_for(e, plan.model);
                outcome.loss = scheduler.feedback(
                    &ctx, action, outcome.reward, &next_ctx, false, &mut rng,
                );
            }
            outcomes.push(outcome);
        }
        e.finish_round();
        Some(outcomes)
    }

    /// Drive both loops over identically-seeded engines + schedulers and
    /// require bit-identical outcome streams and end states.
    fn assert_equivalent<S: Scheduler + ?Sized>(
        mut opt_engine: SimEngine, mut seed_engine: SimEngine,
        opt_sched: &mut S, seed_sched: &mut S, rounds: usize,
    ) {
        for round in 0..rounds {
            let a = opt_engine.step(opt_sched);
            let b = seed_step(&mut seed_engine, seed_sched);
            assert_eq!(a, b, "SlotOutcome streams diverged at round {round}");
            if a.is_none() {
                break;
            }
        }
        // The premise of bit-equality: the profiler window never rolled.
        assert!(opt_engine.profiler.len() < 512,
                "test invalidated itself: profiler window rolled over");
        assert_eq!(opt_engine.metrics.outcomes().len(),
                   seed_engine.metrics.outcomes().len());
        assert_eq!(opt_engine.total_queued(), seed_engine.total_queued());
        assert!((opt_engine.now_ms() - seed_engine.now_ms()).abs() < 1e-12,
                "virtual clocks diverged");
    }

    /// Context-sensitive deterministic scheduler + active predictor veto:
    /// exercises the rolling queue/profiler aggregates through real
    /// decisions (DeepRT reads min_slack and recent latency every slot).
    #[test]
    fn matches_seed_with_deeprt_and_predictor() {
        let cfg = EngineConfig { learn: false, ..Default::default() };
        let mut opt_engine = sim_engine(cfg.clone());
        let mut seed_engine = sim_engine(cfg);
        for e in [&mut opt_engine, &mut seed_engine] {
            let mut gen = PoissonGenerator::new(120.0, 1234);
            e.submit(gen.generate_horizon(60_000.0));
        }
        let mut a = DeepRtScheduler::default();
        let mut b = DeepRtScheduler::default();
        assert_equivalent(opt_engine, seed_engine, &mut a, &mut b, 70);
    }

    /// Learning path: SAC decides stochastically from the encoded context
    /// and trains on the reward stream — any drift in ctx values, reward,
    /// or RNG call order diverges the streams immediately.
    #[test]
    fn matches_seed_with_learning_sac() {
        let cfg = EngineConfig::default(); // learn: true, predictor: on
        let mut opt_engine = sim_engine(cfg.clone());
        let mut seed_engine = sim_engine(cfg);
        for e in [&mut opt_engine, &mut seed_engine] {
            let mut gen = PoissonGenerator::new(90.0, 77);
            e.submit(gen.generate_horizon(60_000.0));
        }
        let space = ActionSpace::standard();
        let mut ra = Pcg32::seeded(0x5AC);
        let mut rb = Pcg32::seeded(0x5AC);
        let mut a = sac_sched::sac(space.clone(), &mut ra);
        let mut b = sac_sched::sac(space, &mut rb);
        assert_equivalent(opt_engine, seed_engine, &mut a, &mut b, 55);
    }

    /// Forced OOM/requeue churn: every round demands the Fig. 1 OOM
    /// corner, so the move-based requeue runs constantly; its queue
    /// re-insertion order must match the seed's clone-based one exactly.
    #[test]
    fn matches_seed_under_oom_requeue_churn() {
        let cfg = EngineConfig {
            use_predictor: false,
            learn: false,
            action_space: ActionSpace::sim_wide(),
            ..Default::default()
        };
        let mut opt_engine = sim_engine(cfg.clone());
        let mut seed_engine = sim_engine(cfg);
        for e in [&mut opt_engine, &mut seed_engine] {
            let reqs: Vec<Request> = (0..512)
                .map(|i| Request::new(i, ModelId::Yolo, (i / 8) as f64))
                .collect();
            e.submit(reqs);
        }
        let mut a = FixedScheduler { batch: 128, m_c: 8 };
        let mut b = FixedScheduler { batch: 128, m_c: 8 };
        assert_equivalent(opt_engine, seed_engine, &mut a, &mut b, 40);
    }
}
