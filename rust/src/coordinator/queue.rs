//! Per-model request queues with SLO priority (paper Fig. 3): "it sorts
//! the priority based on the SLO of inference requests in each queue, the
//! shorter the SLO, the higher the priority … batch requests are scheduled
//! in the order of arrival if have the same priority."

use crate::workload::models::{ModelId, N_MODELS};
use crate::workload::request::Request;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(Debug)]
struct QueueItem {
    request: Request,
    seq: u64,
}

impl PartialEq for QueueItem {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for QueueItem {}

impl PartialOrd for QueueItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueueItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so smaller SLO (then earlier
        // seq) pops first.
        other
            .request
            .slo_ms
            .partial_cmp(&self.request.slo_ms)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// One model's pending-request queue.
#[derive(Debug, Default)]
pub struct ModelQueue {
    heap: BinaryHeap<QueueItem>,
    seq: u64,
}

impl ModelQueue {
    pub fn new() -> Self {
        ModelQueue::default()
    }

    pub fn push(&mut self, request: Request) {
        self.heap.push(QueueItem { request, seq: self.seq });
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.heap.pop().map(|i| i.request)
    }

    pub fn peek(&self) -> Option<&Request> {
        self.heap.peek().map(|i| &i.request)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Earliest arrival among queued requests (for slack computation).
    pub fn oldest_arrival_ms(&self) -> Option<f64> {
        self.heap
            .iter()
            .map(|i| i.request.arrival_ms)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Tightest deadline among queued requests.
    pub fn min_deadline_ms(&self) -> Option<f64> {
        self.heap
            .iter()
            .map(|i| i.request.deadline_ms())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Drain up to `n` requests in priority order.
    pub fn drain(&mut self, n: usize) -> Vec<Request> {
        let mut out = Vec::with_capacity(n.min(self.len()));
        for _ in 0..n {
            match self.pop() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }
}

/// The router (paper Fig. 2 ①): maintains one queue per model and
/// dispatches incoming requests by model type.
#[derive(Debug, Default)]
pub struct Router {
    queues: [ModelQueue; N_MODELS],
    routed: u64,
}

impl Router {
    pub fn new() -> Self {
        Router::default()
    }

    pub fn route(&mut self, request: Request) {
        self.routed += 1;
        self.queues[request.model as usize].push(request);
    }

    pub fn queue(&self, model: ModelId) -> &ModelQueue {
        &self.queues[model as usize]
    }

    pub fn queue_mut(&mut self, model: ModelId) -> &mut ModelQueue {
        &mut self.queues[model as usize]
    }

    pub fn total_queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn total_routed(&self) -> u64 {
        self.routed
    }

    /// Models with pending work, in round-robin order starting after
    /// `after` (the engine's fairness walk).
    pub fn busy_models_after(&self, after: usize) -> Vec<ModelId> {
        (1..=N_MODELS)
            .map(|k| ModelId::from_index((after + k) % N_MODELS))
            .filter(|m| !self.queue(*m).is_empty())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: ModelId, slo: f64, arrival: f64) -> Request {
        let mut r = Request::new(id, model, arrival);
        r.slo_ms = slo;
        r
    }

    #[test]
    fn pops_shortest_slo_first() {
        let mut q = ModelQueue::new();
        q.push(req(1, ModelId::Res, 100.0, 0.0));
        q.push(req(2, ModelId::Res, 20.0, 1.0));
        q.push(req(3, ModelId::Res, 50.0, 2.0));
        assert_eq!(q.pop().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 3);
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn fifo_within_equal_slo() {
        let mut q = ModelQueue::new();
        for id in 0..5 {
            q.push(req(id, ModelId::Res, 58.0, id as f64));
        }
        let order: Vec<u64> = q.drain(5).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn oldest_and_deadline_track_heap_contents() {
        let mut q = ModelQueue::new();
        q.push(req(1, ModelId::Res, 100.0, 50.0));
        q.push(req(2, ModelId::Res, 10.0, 80.0));
        assert_eq!(q.oldest_arrival_ms(), Some(50.0));
        assert_eq!(q.min_deadline_ms(), Some(90.0)); // 80 + 10
    }

    #[test]
    fn router_routes_by_model() {
        let mut r = Router::new();
        r.route(req(1, ModelId::Yolo, 138.0, 0.0));
        r.route(req(2, ModelId::Bert, 114.0, 0.0));
        r.route(req(3, ModelId::Yolo, 138.0, 1.0));
        assert_eq!(r.queue(ModelId::Yolo).len(), 2);
        assert_eq!(r.queue(ModelId::Bert).len(), 1);
        assert_eq!(r.queue(ModelId::Res).len(), 0);
        assert_eq!(r.total_queued(), 3);
        assert_eq!(r.total_routed(), 3);
    }

    #[test]
    fn busy_walk_is_round_robin() {
        let mut r = Router::new();
        r.route(req(1, ModelId::Mob, 86.0, 0.0));
        r.route(req(2, ModelId::Bert, 114.0, 0.0));
        // Starting after Mob (index 1): Bert (5) comes before Mob again.
        let order = r.busy_models_after(ModelId::Mob as usize);
        assert_eq!(order, vec![ModelId::Bert, ModelId::Mob]);
    }
}
