//! Per-model request queues with SLO priority (paper Fig. 3): "it sorts
//! the priority based on the SLO of inference requests in each queue, the
//! shorter the SLO, the higher the priority … batch requests are scheduled
//! in the order of arrival if have the same priority."
//!
//! The scheduler's state encoder reads the tightest deadline and oldest
//! arrival on EVERY decision, so those aggregates are maintained as
//! lazy-deletion min-heaps alongside the priority heap: `push`/`pop` stay
//! O(log n) amortized and `min_deadline_ms`/`oldest_arrival_ms` are O(1)
//! peeks instead of the O(n) scans the seed implementation used — decision
//! cost no longer grows with queue depth (hot-path PR #1). The O(n) scans
//! survive as `*_naive_ms` oracles for the equivalence tests.

use crate::workload::models::{ModelId, N_MODELS};
use crate::workload::request::Request;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashSet};

#[derive(Debug)]
struct QueueItem {
    request: Request,
    seq: u64,
}

impl PartialEq for QueueItem {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for QueueItem {}

impl PartialOrd for QueueItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueueItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so smaller SLO (then earlier
        // seq) pops first.
        other
            .request
            .slo_ms
            .partial_cmp(&self.request.slo_ms)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Aggregate-heap entry: a (key, seq) pair ordered so the SMALLEST key is
/// on top of the max-heap.
#[derive(Debug)]
struct KeyedEntry {
    key: f64,
    seq: u64,
}

impl PartialEq for KeyedEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for KeyedEntry {}

impl PartialOrd for KeyedEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for KeyedEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .key
            .partial_cmp(&self.key)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// One model's pending-request queue.
///
/// Invariant: after every `push`/`pop`, the tops of `by_deadline` and
/// `by_arrival` refer to live requests, so the O(1) aggregate reads never
/// see a stale entry. Dead entries below the top are purged lazily as
/// they surface.
#[derive(Debug, Default)]
pub struct ModelQueue {
    heap: BinaryHeap<QueueItem>,
    seq: u64,
    by_deadline: BinaryHeap<KeyedEntry>,
    by_arrival: BinaryHeap<KeyedEntry>,
    dead_deadline: HashSet<u64>,
    dead_arrival: HashSet<u64>,
}

impl ModelQueue {
    pub fn new() -> Self {
        ModelQueue::default()
    }

    pub fn push(&mut self, request: Request) {
        let seq = self.seq;
        self.seq += 1;
        self.by_deadline.push(KeyedEntry { key: request.deadline_ms(), seq });
        self.by_arrival.push(KeyedEntry { key: request.arrival_ms, seq });
        self.heap.push(QueueItem { request, seq });
    }

    pub fn pop(&mut self) -> Option<Request> {
        let item = self.heap.pop()?;
        self.dead_deadline.insert(item.seq);
        self.dead_arrival.insert(item.seq);
        Self::purge(&mut self.by_deadline, &mut self.dead_deadline);
        Self::purge(&mut self.by_arrival, &mut self.dead_arrival);
        Some(item.request)
    }

    /// Drop dead entries from the top of an aggregate heap so its peek is
    /// always live.
    fn purge(heap: &mut BinaryHeap<KeyedEntry>, dead: &mut HashSet<u64>) {
        while let Some(top) = heap.peek() {
            if dead.remove(&top.seq) {
                heap.pop();
            } else {
                break;
            }
        }
    }

    pub fn peek(&self) -> Option<&Request> {
        self.heap.peek().map(|i| &i.request)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Iterate queued requests in arbitrary (heap) order.
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.heap.iter().map(|i| &i.request)
    }

    /// Earliest arrival among queued requests (for slack computation).
    /// O(1): peek of the arrival aggregate heap.
    pub fn oldest_arrival_ms(&self) -> Option<f64> {
        self.by_arrival.peek().map(|e| e.key)
    }

    /// Tightest deadline among queued requests. O(1).
    pub fn min_deadline_ms(&self) -> Option<f64> {
        self.by_deadline.peek().map(|e| e.key)
    }

    /// O(n) recomputation of [`ModelQueue::oldest_arrival_ms`] — the
    /// seed implementation, kept as a test oracle.
    pub fn oldest_arrival_naive_ms(&self) -> Option<f64> {
        self.heap
            .iter()
            .map(|i| i.request.arrival_ms)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// O(n) recomputation of [`ModelQueue::min_deadline_ms`] — the seed
    /// implementation, kept as a test oracle.
    pub fn min_deadline_naive_ms(&self) -> Option<f64> {
        self.heap
            .iter()
            .map(|i| i.request.deadline_ms())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Drain up to `n` requests in priority order.
    pub fn drain(&mut self, n: usize) -> Vec<Request> {
        let mut out = Vec::with_capacity(n.min(self.len()));
        for _ in 0..n {
            match self.pop() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }
}

/// The router (paper Fig. 2 ①): maintains one queue per model and
/// dispatches incoming requests by model type.
#[derive(Debug, Default)]
pub struct Router {
    queues: [ModelQueue; N_MODELS],
    routed: u64,
}

impl Router {
    pub fn new() -> Self {
        Router::default()
    }

    pub fn route(&mut self, request: Request) {
        self.routed += 1;
        self.queues[request.model as usize].push(request);
    }

    pub fn queue(&self, model: ModelId) -> &ModelQueue {
        &self.queues[model as usize]
    }

    pub fn queue_mut(&mut self, model: ModelId) -> &mut ModelQueue {
        &mut self.queues[model as usize]
    }

    pub fn total_queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn total_routed(&self) -> u64 {
        self.routed
    }

    /// First model with pending work after `after` in round-robin order —
    /// the engine's fairness anchor, allocation-free.
    pub fn first_busy_after(&self, after: usize) -> Option<ModelId> {
        (1..=N_MODELS)
            .map(|k| ModelId::from_index((after + k) % N_MODELS))
            .find(|m| !self.queue(*m).is_empty())
    }

    /// Models with pending work, in round-robin order starting after
    /// `after`, written into a caller-owned buffer (hot path: the engine
    /// reuses one buffer across rounds).
    pub fn busy_models_into(&self, after: usize, out: &mut Vec<ModelId>) {
        out.clear();
        out.extend(
            (1..=N_MODELS)
                .map(|k| ModelId::from_index((after + k) % N_MODELS))
                .filter(|m| !self.queue(*m).is_empty()),
        );
    }

    /// Allocating convenience wrapper over [`Router::busy_models_into`].
    pub fn busy_models_after(&self, after: usize) -> Vec<ModelId> {
        let mut out = Vec::new();
        self.busy_models_into(after, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: ModelId, slo: f64, arrival: f64) -> Request {
        let mut r = Request::new(id, model, arrival);
        r.slo_ms = slo;
        r
    }

    #[test]
    fn pops_shortest_slo_first() {
        let mut q = ModelQueue::new();
        q.push(req(1, ModelId::Res, 100.0, 0.0));
        q.push(req(2, ModelId::Res, 20.0, 1.0));
        q.push(req(3, ModelId::Res, 50.0, 2.0));
        assert_eq!(q.pop().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 3);
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn fifo_within_equal_slo() {
        let mut q = ModelQueue::new();
        for id in 0..5 {
            q.push(req(id, ModelId::Res, 58.0, id as f64));
        }
        let order: Vec<u64> = q.drain(5).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn oldest_and_deadline_track_heap_contents() {
        let mut q = ModelQueue::new();
        q.push(req(1, ModelId::Res, 100.0, 50.0));
        q.push(req(2, ModelId::Res, 10.0, 80.0));
        assert_eq!(q.oldest_arrival_ms(), Some(50.0));
        assert_eq!(q.min_deadline_ms(), Some(90.0)); // 80 + 10
    }

    #[test]
    fn rolling_aggregates_survive_pops() {
        let mut q = ModelQueue::new();
        // Pops come out in SLO order, which is neither deadline nor
        // arrival order — exactly the interleaving that stresses the
        // lazy-deletion heaps.
        q.push(req(1, ModelId::Res, 100.0, 0.0)); // deadline 100
        q.push(req(2, ModelId::Res, 20.0, 30.0)); // deadline 50 <- min
        q.push(req(3, ModelId::Res, 60.0, 10.0)); // deadline 70
        assert_eq!(q.min_deadline_ms(), Some(50.0));
        assert_eq!(q.oldest_arrival_ms(), Some(0.0));
        assert_eq!(q.pop().unwrap().id, 2); // removes the deadline min
        assert_eq!(q.min_deadline_ms(), Some(70.0));
        assert_eq!(q.oldest_arrival_ms(), Some(0.0));
        assert_eq!(q.pop().unwrap().id, 3);
        assert_eq!(q.min_deadline_ms(), Some(100.0));
        assert_eq!(q.oldest_arrival_ms(), Some(0.0));
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.min_deadline_ms(), None);
        assert_eq!(q.oldest_arrival_ms(), None);
    }

    #[test]
    fn rolling_aggregates_match_naive_oracles() {
        let mut rng = crate::util::rng::Pcg32::seeded(0xA66);
        let mut q = ModelQueue::new();
        for id in 0..400u64 {
            if rng.below(3) > 0 || q.is_empty() {
                q.push(req(id, ModelId::Res, 10.0 + rng.f64() * 150.0,
                           rng.f64() * 1000.0));
            } else {
                q.pop();
            }
            assert_eq!(q.min_deadline_ms(), q.min_deadline_naive_ms());
            assert_eq!(q.oldest_arrival_ms(), q.oldest_arrival_naive_ms());
        }
        while q.pop().is_some() {
            assert_eq!(q.min_deadline_ms(), q.min_deadline_naive_ms());
            assert_eq!(q.oldest_arrival_ms(), q.oldest_arrival_naive_ms());
        }
    }

    #[test]
    fn router_routes_by_model() {
        let mut r = Router::new();
        r.route(req(1, ModelId::Yolo, 138.0, 0.0));
        r.route(req(2, ModelId::Bert, 114.0, 0.0));
        r.route(req(3, ModelId::Yolo, 138.0, 1.0));
        assert_eq!(r.queue(ModelId::Yolo).len(), 2);
        assert_eq!(r.queue(ModelId::Bert).len(), 1);
        assert_eq!(r.queue(ModelId::Res).len(), 0);
        assert_eq!(r.total_queued(), 3);
        assert_eq!(r.total_routed(), 3);
    }

    #[test]
    fn busy_walk_is_round_robin() {
        let mut r = Router::new();
        r.route(req(1, ModelId::Mob, 86.0, 0.0));
        r.route(req(2, ModelId::Bert, 114.0, 0.0));
        // Starting after Mob (index 1): Bert (5) comes before Mob again.
        let order = r.busy_models_after(ModelId::Mob as usize);
        assert_eq!(order, vec![ModelId::Bert, ModelId::Mob]);
        assert_eq!(r.first_busy_after(ModelId::Mob as usize),
                   Some(ModelId::Bert));
        let empty = Router::new();
        assert_eq!(empty.first_busy_after(0), None);
    }
}
