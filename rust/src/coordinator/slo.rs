//! SLO bookkeeping: scheduling-slot budget of paper Eq. (1) and
//! per-request violation accounting used by the reward and Figs. 14/15.

use crate::workload::request::Request;

/// Eq. (1): the i-th scheduling slot tᵢ = Σⱼ SLOⱼ / m_c over the batch
/// requests. Returns ms.
pub fn slot_budget_ms(requests: &[Request], m_c: usize) -> f64 {
    assert!(m_c >= 1);
    let slo_sum: f64 = requests.iter().map(|r| r.slo_ms).sum();
    slo_sum / m_c as f64
}

/// Σⱼ SLOⱼ over a batch.
pub fn slo_sum_ms(requests: &[Request]) -> f64 {
    requests.iter().map(|r| r.slo_ms).sum()
}

/// Violation check for one completed request (Eq. 4: Lᵢ < SLOᵢ).
pub fn violated(request: &Request, completed_ms: f64) -> bool {
    completed_ms - request.arrival_ms > request.slo_ms
}

/// Fraction of a batch completing past its SLO at `completed_ms`.
pub fn violation_fraction(requests: &[Request], completed_ms: f64) -> f64 {
    if requests.is_empty() {
        return 0.0;
    }
    requests.iter().filter(|r| violated(r, completed_ms)).count() as f64
        / requests.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::ModelId;

    fn req(slo: f64, arrival: f64) -> Request {
        let mut r = Request::new(0, ModelId::Res, arrival);
        r.slo_ms = slo;
        r
    }

    #[test]
    fn eq1_slot_budget() {
        let batch = vec![req(60.0, 0.0), req(60.0, 0.0), req(120.0, 0.0)];
        assert_eq!(slot_budget_ms(&batch, 1), 240.0);
        assert_eq!(slot_budget_ms(&batch, 4), 60.0);
        assert_eq!(slo_sum_ms(&batch), 240.0);
    }

    #[test]
    fn violation_accounting() {
        let batch = vec![req(50.0, 100.0), req(200.0, 100.0)];
        assert!(!violated(&batch[0], 140.0));
        assert!(violated(&batch[0], 151.0));
        assert_eq!(violation_fraction(&batch, 160.0), 0.5);
        assert_eq!(violation_fraction(&batch, 120.0), 0.0);
        assert_eq!(violation_fraction(&[], 0.0), 0.0);
    }
}
