//! Dynamic batching module (paper Fig. 3): drains a model's priority
//! queue into up to m_c instance-batches of up to b requests each, and
//! pads each batch to the nearest compiled artifact size (the
//! TensorRT-engine-per-batch analogue — see DESIGN.md §2).

use super::queue::ModelQueue;
use crate::workload::request::Request;

/// One assembled instance-batch.
#[derive(Clone, Debug)]
pub struct AssembledBatch {
    pub requests: Vec<Request>,
    /// Execution batch size after padding (≥ requests.len()).
    pub padded: usize,
}

impl AssembledBatch {
    pub fn n_real(&self) -> usize {
        self.requests.len()
    }
}

/// Split policy + padding for one scheduling slot.
#[derive(Clone, Copy, Debug)]
pub struct Batcher {
    /// Compiled batch sizes, ascending (None entries pad to exact size —
    /// the simulator executes any batch size).
    pub compiled: Option<[usize; 6]>,
}

impl Batcher {
    /// Batcher padding to the standard AOT grid {1,2,4,8,16,32}.
    pub fn for_artifacts() -> Self {
        Batcher { compiled: Some([1, 2, 4, 8, 16, 32]) }
    }

    /// Simulator batcher: no padding constraint.
    pub fn exact() -> Self {
        Batcher { compiled: None }
    }

    /// Pad a real batch size up to the nearest compiled size (clamping to
    /// the largest compiled engine).
    pub fn pad(&self, n: usize) -> usize {
        assert!(n > 0);
        match &self.compiled {
            None => n,
            Some(sizes) => *sizes
                .iter()
                .find(|&&s| s >= n)
                .unwrap_or(sizes.last().unwrap()),
        }
    }

    /// Drain up to `b × m_c` requests from `queue` and split them into at
    /// most `m_c` batches of at most `b` (paper Fig. 3: the dynamically
    /// created batches are distributed to all configured instances).
    /// Requests keep priority order: batch 0 gets the most urgent block.
    pub fn assemble(&self, queue: &mut ModelQueue, b: usize, m_c: usize)
                    -> Vec<AssembledBatch> {
        assert!(b > 0 && m_c > 0);
        // A chunk can never exceed the largest compiled engine — a
        // scheduler asking for more gets the engine ceiling (TensorRT
        // behaviour), not an unservable batch.
        let b = match &self.compiled {
            None => b,
            Some(sizes) => b.min(*sizes.last().unwrap()),
        };
        let take = (b * m_c).min(queue.len());
        let drained = queue.drain(take);
        drained
            .chunks(b)
            .map(|chunk| AssembledBatch {
                requests: chunk.to_vec(),
                padded: self.pad(chunk.len()),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::ModelId;

    fn filled_queue(n: usize) -> ModelQueue {
        let mut q = ModelQueue::new();
        for id in 0..n as u64 {
            q.push(Request::new(id, ModelId::Res, id as f64));
        }
        q
    }

    #[test]
    fn splits_into_instance_batches() {
        let mut q = filled_queue(10);
        let batches = Batcher::exact().assemble(&mut q, 4, 2);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].n_real(), 4);
        assert_eq!(batches[1].n_real(), 4);
        assert_eq!(q.len(), 2); // leftovers stay queued
    }

    #[test]
    fn underfull_queue_yields_partial_batches() {
        let mut q = filled_queue(3);
        let batches = Batcher::exact().assemble(&mut q, 4, 2);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].n_real(), 3);
        assert!(q.is_empty());
    }

    #[test]
    fn empty_queue_yields_no_batches() {
        let mut q = ModelQueue::new();
        assert!(Batcher::exact().assemble(&mut q, 8, 4).is_empty());
    }

    #[test]
    fn padding_to_compiled_sizes() {
        let b = Batcher::for_artifacts();
        assert_eq!(b.pad(1), 1);
        assert_eq!(b.pad(3), 4);
        assert_eq!(b.pad(5), 8);
        assert_eq!(b.pad(32), 32);
        assert_eq!(b.pad(100), 32); // clamp to largest engine
        assert_eq!(Batcher::exact().pad(100), 100);
    }

    #[test]
    fn conservation_no_drop_no_dup() {
        let mut q = filled_queue(9);
        let batches = Batcher::exact().assemble(&mut q, 4, 3);
        let mut ids: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id))
            .collect();
        ids.extend(q.drain(q.len()).iter().map(|r| r.id));
        ids.sort_unstable();
        assert_eq!(ids, (0..9).collect::<Vec<u64>>());
    }

    #[test]
    fn priority_block_goes_to_first_instance() {
        let mut q = ModelQueue::new();
        let mut urgent = Request::new(99, ModelId::Res, 100.0);
        urgent.slo_ms = 5.0;
        q.push(Request::new(1, ModelId::Res, 0.0));
        q.push(urgent);
        let batches = Batcher::exact().assemble(&mut q, 1, 2);
        assert_eq!(batches[0].requests[0].id, 99);
    }
}
